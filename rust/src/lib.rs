//! # MegaScale-Infer
//!
//! A reproduction of *"MegaScale-Infer: Serving Mixture-of-Experts at Scale
//! with Disaggregated Expert Parallelism"* (ByteDance Seed / Peking
//! University, 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The library implements the paper's full system:
//!
//! * **Disaggregated expert parallelism** — attention nodes (data-parallel
//!   replicas, TP inside a node) and expert nodes (expert-parallel, one
//!   expert per node) as separate pools ([`coordinator`]).
//! * **Ping-pong pipeline parallelism** — `m` micro-batches shuttled between
//!   the pools so compute hides communication ([`coordinator::pingpong`]).
//! * **Deployment plan search** — Algorithm 1: enumerate `(tp_a, tp_e)`,
//!   balance `n_a`, sweep `m`, binary-search the max batch under the TPOT
//!   SLO, maximize throughput per dollar ([`plan`]).
//! * **M2N communication library** — an RDMA-style sender/receiver model and
//!   an NCCL baseline on a discrete-event network simulator ([`m2n`]).
//! * **Analytical performance model** — roofline GEMM timing (Table 2),
//!   `T_a`/`T_e`/`T_c` models and iteration-latency equations (Eq. 4–6)
//!   ([`perf_model`]).
//! * **Baselines + the Figure-8 comparison** — vLLM-like and
//!   TensorRT-LLM-like monolithic deployments, both as closed forms and as
//!   *simulated systems* running through the same cluster engine as the
//!   disaggregated path, so `msi compare` reproduces the paper's central
//!   per-GPU-throughput comparison on arbitrary traffic
//!   ([`baselines`], [`baselines::run_compare`]).
//! * **Disaggregated prefill** — an explicit request-lifecycle state
//!   machine (`Queued → Prefill → KvTransfer → Decode → Done`) with a
//!   packed chunked-prefill pool ahead of the decode pools, TTFT
//!   decomposed per request into queue/prefill/transfer/first-decode, and
//!   vLLM-style inline chunked prefill interfering with decode on the
//!   colocated baselines ([`sim::engine`], [`perf_model::PrefillModel`]).
//! * **Sim-validated plan choice** — `msi plan --validate-top K` re-scores
//!   the top analytic plans through short engine runs and picks by
//!   simulated goodput per dollar ([`plan::validate_top_k`]).
//! * **PJRT runtime** — loads JAX/Pallas-AOT-compiled HLO artifacts and runs
//!   the same coordinator logic against real compute (`runtime`, behind the
//!   `pjrt` cargo feature: it needs a locally-provided `xla` binding crate,
//!   see DESIGN.md).
//! * **Cluster engine** — a deterministic trace-driven end-to-end serving
//!   simulation as an event-driven engine: router, attention pool, M2N
//!   link and expert pool as pluggable components on one virtual clock,
//!   sharing a single ping-pong pipeline machine with every other
//!   simulation path ([`sim::engine`], [`sim::pipeline`], [`sim::cluster`]).
//!   Arrivals stream through a pull-based [`workload::ArrivalSource`]
//!   (trace- or generator-backed), so memory stays bounded by in-flight
//!   requests at million-request scale; [`sim::sweep`] fans scenario grids
//!   (rate × skew × micro-batches × tenant mix × serving system) across
//!   worker threads with deterministic per-cell seeds.
//!
//! See `README.md` for the quickstart, `DESIGN.md` for the experiment
//! index and substitution notes, and `EXPERIMENTS.md` for measured
//! results.

// Docs are a first-class deliverable: every public item is documented, and
// CI builds `cargo doc --no-deps` with `-D warnings` so coverage and
// intra-doc links stay green.
#![warn(missing_docs)]

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod m2n;
pub mod metrics;
pub mod perf_model;
pub mod plan;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

pub use config::{ClusterSpec, GpuSpec, ModelConfig};
pub use plan::{DeploymentPlan, PlanSearcher};
