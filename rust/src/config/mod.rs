//! Static configuration: model architectures (paper Table 4), GPU hardware
//! specifications (paper Table 3), and cluster descriptions.
//!
//! Configurations serialize via the in-tree JSON support
//! (`crate::util::json`) for the `msi` CLI.

mod cluster;
mod hardware;
mod model;

pub use cluster::{ClusterSpec, NodeSpec};
pub use hardware::{GpuSpec, GpuKind, gpu_catalog};
pub use model::{ModelConfig, DTYPE_BYTES};
