//! GPU hardware specifications and cost-effectiveness ratios (paper Table 3),
//! plus the network parameters of the paper's testbeds (§7.1).

/// The GPU types evaluated in the paper (Table 3) plus the Ampere testbed
/// part (80GB, A800-class NVLink box with 200 Gbps NICs, §7.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuKind {
    /// Table 3 row: L20 (PCIe, the price-normalization baseline).
    L20,
    /// Table 3 row: H800 (flagship compute + NVLink).
    H800,
    /// Table 3 row: A800 (Ampere-class NVLink part).
    A800,
    /// Table 3 row: H20 (huge HBM bandwidth per cost).
    H20,
    /// Table 3 row: L40S (best compute per cost, PCIe).
    L40S,
    /// "NVIDIA 80GB Ampere" of the homogeneous testbed; modeled with A100
    /// SXM numbers used in the paper's §2.3 roofline example
    /// (312 TFLOPS bf16, 2 TB/s HBM).
    Ampere80G,
}

/// Performance/price description of one GPU type.
///
/// `price` is normalized by L20 = 1.00, exactly as in paper Table 3.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Which catalog entry this is.
    pub kind: GpuKind,
    /// Human-readable part name.
    pub name: String,
    /// Normalized purchase price (L20 = 1.00).
    pub price: f64,
    /// Memory capacity in GB.
    pub mem_gb: f64,
    /// Memory bandwidth in GB/s.
    pub mem_bw_gbps: f64,
    /// Dense bf16 compute in TFLOPS.
    pub tflops: f64,
    /// Network bandwidth per GPU in Gbps (NIC).
    pub nic_gbps: f64,
    /// Intra-node interconnect bandwidth per GPU in GB/s (NVLink or PCIe).
    pub intra_node_gbps: f64,
    /// Maximum GPUs per node for this part.
    pub max_per_node: usize,
}

impl GpuSpec {
    /// Memory-capacity per unit cost (GB / price) — Table 3 column.
    pub fn gb_per_cost(&self) -> f64 {
        self.mem_gb / self.price
    }
    /// Memory-bandwidth per unit cost (GB/s / price) — Table 3 column.
    pub fn bw_per_cost(&self) -> f64 {
        self.mem_bw_gbps / self.price
    }
    /// Compute per unit cost (TFLOPS / price) — Table 3 column.
    pub fn tflops_per_cost(&self) -> f64 {
        self.tflops / self.price
    }
    /// Minimum batch size for a GEMM to become compute-bound on this GPU:
    /// `b >= F/B` from the roofline model (§2.3).
    pub fn roofline_batch(&self) -> f64 {
        self.tflops * 1e12 / (self.mem_bw_gbps * 1e9)
    }

    /// Memory capacity in bytes.
    pub fn mem_bytes(&self) -> f64 {
        self.mem_gb * 1e9
    }

    /// Look up a spec by kind from the catalog.
    pub fn of(kind: GpuKind) -> GpuSpec {
        gpu_catalog()
            .into_iter()
            .find(|g| g.kind == kind)
            .expect("all kinds present in catalog")
    }
}

/// The full Table 3 catalog (plus the Ampere 80GB testbed part).
pub fn gpu_catalog() -> Vec<GpuSpec> {
    vec![
        GpuSpec {
            kind: GpuKind::L20,
            name: "L20".into(),
            price: 1.00,
            mem_gb: 48.0,
            mem_bw_gbps: 864.0,
            tflops: 119.5,
            nic_gbps: 200.0,
            intra_node_gbps: 64.0, // PCIe Gen4 x16
            max_per_node: 8,
        },
        GpuSpec {
            kind: GpuKind::H800,
            name: "H800".into(),
            price: 5.28,
            mem_gb: 80.0,
            mem_bw_gbps: 3430.4,
            tflops: 989.0,
            nic_gbps: 400.0,
            intra_node_gbps: 400.0,
            max_per_node: 8,
        },
        GpuSpec {
            kind: GpuKind::A800,
            name: "A800".into(),
            price: 2.26,
            mem_gb: 80.0,
            mem_bw_gbps: 2039.0,
            tflops: 312.0,
            nic_gbps: 200.0,
            intra_node_gbps: 200.0,
            max_per_node: 8,
        },
        GpuSpec {
            kind: GpuKind::H20,
            name: "H20".into(),
            price: 1.85,
            mem_gb: 96.0,
            mem_bw_gbps: 4096.0,
            tflops: 148.0,
            // §7.1: H20 nodes have 900GB/s NVLink and four 400 Gbps NICs
            // for 8 GPUs => 200 Gbps per GPU.
            nic_gbps: 200.0,
            intra_node_gbps: 450.0,
            max_per_node: 8,
        },
        GpuSpec {
            kind: GpuKind::L40S,
            name: "L40S".into(),
            price: 1.08,
            mem_gb: 48.0,
            mem_bw_gbps: 864.0,
            tflops: 362.0,
            // §7.1: L40S nodes use PCIe intra-node and two 400 Gbps NICs
            // => 100 Gbps per GPU for an 8-GPU node.
            nic_gbps: 100.0,
            intra_node_gbps: 64.0,
            max_per_node: 8,
        },
        GpuSpec {
            kind: GpuKind::Ampere80G,
            name: "Ampere-80GB".into(),
            // Same class as A800 price-wise; used for the homogeneous
            // testbed where only *per-GPU* throughput matters.
            price: 2.26,
            mem_gb: 80.0,
            mem_bw_gbps: 2039.0,
            tflops: 312.0,
            nic_gbps: 200.0,
            intra_node_gbps: 400.0, // §7.1: 400GB/s NVLink
            max_per_node: 8,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_per_cost_columns() {
        // Check the "Performance per Cost" columns of Table 3 exactly.
        let h20 = GpuSpec::of(GpuKind::H20);
        assert!((h20.gb_per_cost() - 51.9).abs() < 0.1);
        assert!((h20.bw_per_cost() - 2214.1).abs() < 0.5);
        assert!((h20.tflops_per_cost() - 80.0).abs() < 0.1);

        let l40s = GpuSpec::of(GpuKind::L40S);
        assert!((l40s.gb_per_cost() - 44.4).abs() < 0.1);
        assert!((l40s.bw_per_cost() - 800.0).abs() < 0.5);
        assert!((l40s.tflops_per_cost() - 335.2).abs() < 0.1);

        let h800 = GpuSpec::of(GpuKind::H800);
        assert!((h800.gb_per_cost() - 15.2).abs() < 0.1);
        assert!((h800.bw_per_cost() - 649.7).abs() < 0.5);
        assert!((h800.tflops_per_cost() - 187.3).abs() < 0.1);

        let a800 = GpuSpec::of(GpuKind::A800);
        assert!((a800.gb_per_cost() - 35.4).abs() < 0.1);
        assert!((a800.bw_per_cost() - 902.2).abs() < 0.5);
        assert!((a800.tflops_per_cost() - 138.1).abs() < 0.1);
    }

    #[test]
    fn a100_roofline_batch_is_156() {
        // §2.3: "For an A100 GPU, the batch size at least needs to be 156
        // tokens (312 TFLOPS / 2 TB/s)". Our Ampere part uses 2039 GB/s,
        // giving 153 — the paper rounds 2 TB/s.
        let amp = GpuSpec::of(GpuKind::Ampere80G);
        let b = amp.roofline_batch();
        assert!((150.0..160.0).contains(&b), "roofline batch {b}");
    }

    #[test]
    fn h20_best_attention_l40s_best_expert() {
        // §4.3 intuition: H20 maximizes memory capacity+bandwidth per cost,
        // L40S maximizes compute per cost.
        let cat = gpu_catalog();
        let best_bw = cat
            .iter()
            .max_by(|a, b| a.bw_per_cost().total_cmp(&b.bw_per_cost()))
            .unwrap();
        assert_eq!(best_bw.kind, GpuKind::H20);
        let best_comp = cat
            .iter()
            .max_by(|a, b| a.tflops_per_cost().total_cmp(&b.tflops_per_cost()))
            .unwrap();
        assert_eq!(best_comp.kind, GpuKind::L40S);
    }
}
