//! Cluster descriptions: which GPU types are available for attention and
//! expert pools, and in what quantity.

use super::hardware::{GpuKind, GpuSpec};

/// One homogeneous group of nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    /// GPU type of this node group.
    pub gpu: GpuKind,
    /// GPUs per physical node.
    pub gpus_per_node: usize,
    /// Number of nodes available (None = unbounded, plan search sizes it).
    pub nodes: Option<usize>,
}

/// A (possibly heterogeneous) cluster: the hardware offered to the plan
/// search for attention nodes and expert nodes respectively.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// GPU type used for attention nodes.
    pub attention: NodeSpec,
    /// GPU type used for expert nodes.
    pub expert: NodeSpec,
}

impl ClusterSpec {
    /// Homogeneous cluster of a single GPU type (the paper's first testbed:
    /// 8 nodes x 8 Ampere-80GB GPUs).
    pub fn homogeneous(gpu: GpuKind) -> Self {
        let spec = GpuSpec::of(gpu);
        let node = NodeSpec {
            gpu,
            gpus_per_node: spec.max_per_node,
            nodes: None,
        };
        Self {
            attention: node.clone(),
            expert: node,
        }
    }

    /// The paper's heterogeneous testbed: H20 for attention, L40S for
    /// experts (§4.3, §7.2).
    pub fn heterogeneous_h20_l40s() -> Self {
        Self {
            attention: NodeSpec {
                gpu: GpuKind::H20,
                gpus_per_node: 8,
                nodes: None,
            },
            expert: NodeSpec {
                gpu: GpuKind::L40S,
                gpus_per_node: 8,
                nodes: None,
            },
        }
    }

    /// Spec of the attention-pool GPU type.
    pub fn attention_gpu(&self) -> GpuSpec {
        GpuSpec::of(self.attention.gpu)
    }

    /// Spec of the expert-pool GPU type.
    pub fn expert_gpu(&self) -> GpuSpec {
        GpuSpec::of(self.expert.gpu)
    }

    /// Whether the pools use different GPU types (§4.3).
    pub fn is_heterogeneous(&self) -> bool {
        self.attention.gpu != self.expert.gpu
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_cluster() {
        let c = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        assert!(!c.is_heterogeneous());
        assert_eq!(c.attention.gpus_per_node, 8);
    }

    #[test]
    fn heterogeneous_cluster() {
        let c = ClusterSpec::heterogeneous_h20_l40s();
        assert!(c.is_heterogeneous());
        assert_eq!(c.attention.gpu, GpuKind::H20);
        assert_eq!(c.expert.gpu, GpuKind::L40S);
    }

    #[test]
    fn gpu_spec_lookup() {
        let c = ClusterSpec::heterogeneous_h20_l40s();
        assert_eq!(c.attention_gpu().name, "H20");
        assert_eq!(c.expert_gpu().name, "L40S");
    }
}
