//! MoE model configurations (paper Table 4) and derived size quantities.

use crate::util::json::Json;

/// Bytes per element for bfloat16, the datatype used throughout the paper
/// for weights, activations and KV cache.
pub const DTYPE_BYTES: f64 = 2.0;

/// Architecture description of a Transformer MoE model.
///
/// Mirrors the notation of paper Table 1 / Table 4: `h` (hidden size), `h'`
/// (FFN intermediate size), `E` (#experts), `K` (top-k), `L` (#layers), and
/// GQA group structure.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Human-readable name, e.g. `"Mixtral-8x22B"`.
    pub name: String,
    /// Number of transformer layers (`L`).
    pub layers: usize,
    /// Hidden dimension (`h`).
    pub hidden: usize,
    /// FFN intermediate dimension (`h'`).
    pub intermediate: usize,
    /// Number of experts per MoE layer (`E`).
    pub experts: usize,
    /// Number of experts selected per token (`K`).
    pub top_k: usize,
    /// Number of query attention heads.
    pub q_heads: usize,
    /// Number of KV heads (GQA). `g = q_heads / kv_heads` query heads per group.
    pub kv_heads: usize,
    /// Per-head dimension; `q_heads * head_dim == hidden` for all paper models.
    pub head_dim: usize,
}

impl ModelConfig {
    /// Query heads per GQA group (`g` in the paper).
    pub fn gqa_group(&self) -> usize {
        self.q_heads / self.kv_heads
    }

    /// Parameter count of one layer's attention module (QKV projection +
    /// output projection), in elements.
    ///
    /// QKV projection is `h × h(1 + 2/g)` and the output projection `h × h`
    /// (paper Table 2).
    pub fn attn_params_per_layer(&self) -> f64 {
        let h = self.hidden as f64;
        let g = self.gqa_group() as f64;
        h * h * (1.0 + 2.0 / g) + h * h
    }

    /// Parameter count of a single expert in one layer, in elements.
    ///
    /// All three paper models use gated (SwiGLU) FFNs with **three**
    /// matrices: `w1, w3 : h×h'` and `w2 : h'×h`. This accounting
    /// reproduces the published totals exactly (141B / 132B / 317B);
    /// the paper's Table 2 lists the two GEMM *shapes*, of which the
    /// up-projection shape occurs twice.
    pub fn expert_params_per_layer(&self) -> f64 {
        self.ffn_matrices() as f64 * self.hidden as f64 * self.intermediate as f64
    }

    /// Number of weight matrices per expert FFN (3 for SwiGLU).
    pub fn ffn_matrices(&self) -> usize {
        3
    }

    /// Total attention parameter bytes across all layers (bf16).
    pub fn attn_param_bytes(&self) -> f64 {
        self.attn_params_per_layer() * self.layers as f64 * DTYPE_BYTES
    }

    /// Total parameter bytes for ONE expert across all layers (bf16).
    pub fn expert_param_bytes(&self) -> f64 {
        self.expert_params_per_layer() * self.layers as f64 * DTYPE_BYTES
    }

    /// Total parameter count (attention + all experts + gating), in elements.
    pub fn total_params(&self) -> f64 {
        let gating = (self.hidden * self.experts) as f64;
        (self.attn_params_per_layer()
            + self.expert_params_per_layer() * self.experts as f64
            + gating)
            * self.layers as f64
    }

    /// KV-cache bytes per token across all layers (bf16):
    /// `2 (K and V) * kv_heads * head_dim * L * 2 bytes`.
    ///
    /// Equivalent to the paper's Eq. 8 term `4·s·h·L/g` per token.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * (self.kv_heads * self.head_dim * self.layers) as f64 * DTYPE_BYTES
    }

    /// Paper Table 4 row: Mixtral 8x22B (141B total params).
    pub fn mixtral_8x22b() -> Self {
        Self {
            name: "Mixtral-8x22B".into(),
            layers: 56,
            hidden: 6144,
            intermediate: 16384,
            experts: 8,
            top_k: 2,
            q_heads: 48,
            kv_heads: 8,
            head_dim: 128,
        }
    }

    /// Paper Table 4 row: DBRX (132B total params).
    pub fn dbrx() -> Self {
        Self {
            name: "DBRX".into(),
            layers: 40,
            hidden: 6144,
            intermediate: 10752,
            experts: 16,
            top_k: 4,
            q_heads: 48,
            kv_heads: 8,
            head_dim: 128,
        }
    }

    /// Paper Table 4 row: Scaled-MoE (317B total params).
    pub fn scaled_moe() -> Self {
        Self {
            name: "Scaled-MoE".into(),
            layers: 48,
            hidden: 8192,
            intermediate: 8192,
            experts: 32,
            top_k: 4,
            q_heads: 64,
            kv_heads: 8,
            head_dim: 128,
        }
    }

    /// The tiny MoE used for the *executable* end-to-end path (PJRT on CPU).
    /// Structure matches the big models (GQA + top-k gating + SwiGLU experts)
    /// at a size a CPU can decode interactively.
    pub fn tiny() -> Self {
        Self {
            name: "Tiny-MoE".into(),
            layers: 4,
            hidden: 256,
            intermediate: 512,
            experts: 8,
            top_k: 2,
            q_heads: 8,
            kv_heads: 2,
            head_dim: 32,
        }
    }

    /// All three paper evaluation models in Table 4 order.
    pub fn paper_models() -> Vec<Self> {
        vec![Self::mixtral_8x22b(), Self::dbrx(), Self::scaled_moe()]
    }

    /// JSON serialization (in-tree [`Json`], serde is unavailable offline).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("layers", self.layers)
            .set("hidden", self.hidden)
            .set("intermediate", self.intermediate)
            .set("experts", self.experts)
            .set("top_k", self.top_k)
            .set("q_heads", self.q_heads)
            .set("kv_heads", self.kv_heads)
            .set("head_dim", self.head_dim)
    }

    /// Parse the [`Self::to_json`] rendering.
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            layers: v.get("layers")?.as_usize()?,
            hidden: v.get("hidden")?.as_usize()?,
            intermediate: v.get("intermediate")?.as_usize()?,
            experts: v.get("experts")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            q_heads: v.get("q_heads")?.as_usize()?,
            kv_heads: v.get("kv_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_configs() {
        let m = ModelConfig::mixtral_8x22b();
        assert_eq!((m.layers, m.hidden, m.experts, m.top_k), (56, 6144, 8, 2));
        assert_eq!(m.intermediate, 16384);
        let d = ModelConfig::dbrx();
        assert_eq!((d.layers, d.hidden, d.experts, d.top_k), (40, 6144, 16, 4));
        let s = ModelConfig::scaled_moe();
        assert_eq!((s.layers, s.hidden, s.experts, s.top_k), (48, 8192, 32, 4));
    }

    #[test]
    fn total_params_match_paper_sizes() {
        // Paper: "They contain 141B, 132B, and 317B parameters".
        // SwiGLU 3-matrix accounting reproduces these within ~2%.
        let m = ModelConfig::mixtral_8x22b().total_params() / 1e9;
        assert!((m - 141.0).abs() < 4.0, "Mixtral params {m}B");
        let d = ModelConfig::dbrx().total_params() / 1e9;
        assert!((d - 132.0).abs() < 4.0, "DBRX params {d}B");
        let s = ModelConfig::scaled_moe().total_params() / 1e9;
        assert!((s - 317.0).abs() < 6.0, "Scaled-MoE params {s}B");
    }

    #[test]
    fn gqa_group_size() {
        assert_eq!(ModelConfig::mixtral_8x22b().gqa_group(), 6);
        assert_eq!(ModelConfig::dbrx().gqa_group(), 6);
        assert_eq!(ModelConfig::scaled_moe().gqa_group(), 8);
        assert_eq!(ModelConfig::tiny().gqa_group(), 4);
    }

    #[test]
    fn kv_bytes_per_token_matches_eq8() {
        // Eq. 8: KV bytes per token = 4*h*L/g (bf16).
        let m = ModelConfig::mixtral_8x22b();
        let eq8 = 4.0 * m.hidden as f64 * m.layers as f64 / m.gqa_group() as f64;
        assert!((m.kv_bytes_per_token() - eq8).abs() < 1e-6);
    }

    #[test]
    fn serde_roundtrip() {
        let m = ModelConfig::dbrx();
        let s = m.to_json().to_string();
        let back = ModelConfig::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(m, back);
    }
}
