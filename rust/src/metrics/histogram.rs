//! A log-bucketed streaming histogram with exact small-sample fallback.
//!
//! For the M2N figures we need median and P99 of latency distributions with
//! hundreds of thousands of samples; a log-bucketed histogram gives
//! percentiles within ~1% relative error at O(1) memory. Below a threshold
//! we keep exact samples so unit tests on tiny inputs are exact.

/// Streaming histogram over positive values (seconds, bytes, ...).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Exact samples kept until `EXACT_LIMIT` is reached.
    exact: Vec<f64>,
    /// Log-spaced bucket counts covering [min_value, max_value).
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Non-finite / negative samples rejected by `record` (release builds
    /// skip them instead of asserting).
    skipped: u64,
}

const EXACT_LIMIT: usize = 4096;
/// Buckets per decade: relative bucket width ~ 10^(1/96) - 1 ≈ 2.4%.
const BUCKETS_PER_DECADE: f64 = 96.0;
/// Smallest representable value; anything smaller clamps into bucket 0.
const MIN_VALUE: f64 = 1e-12;
const DECADES: f64 = 24.0; // up to 1e12

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self {
            exact: Vec::new(),
            buckets: vec![0; (BUCKETS_PER_DECADE * DECADES) as usize],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            skipped: 0,
        }
    }

    fn bucket_index(v: f64) -> usize {
        let v = v.max(MIN_VALUE);
        let idx = ((v / MIN_VALUE).log10() * BUCKETS_PER_DECADE) as usize;
        idx.min((BUCKETS_PER_DECADE * DECADES) as usize - 1)
    }

    /// Value at fractional position `frac` ∈ [0, 1] through bucket `idx`
    /// (geometric interpolation; `frac = 0.5` is the bucket midpoint).
    fn bucket_value_at(idx: usize, frac: f64) -> f64 {
        MIN_VALUE * 10f64.powf((idx as f64 + frac) / BUCKETS_PER_DECADE)
    }

    /// Fractional position of `v` inside its bucket (0 at the lower edge,
    /// approaching 1 at the upper edge), consistent with `bucket_index`.
    fn position_in_bucket(v: f64, idx: usize) -> f64 {
        let v = v.max(MIN_VALUE);
        ((v / MIN_VALUE).log10() * BUCKETS_PER_DECADE - idx as f64).clamp(0.0, 1.0)
    }

    /// Record one observation. Zero clamps to the smallest bucket;
    /// non-finite and negative samples are rejected — a `debug_assert` in
    /// debug builds, silently skipped (and counted in
    /// [`Histogram::skipped_samples`]) in release builds. Before this
    /// guard, a NaN or negative sample aliased into bucket 0 through the
    /// `as usize` cast while still polluting `sum`/`min`/`max`.
    pub fn record(&mut self, v: f64) {
        debug_assert!(
            v.is_finite() && v >= 0.0,
            "histogram sample must be finite and non-negative, got {v}"
        );
        if !(v.is_finite() && v >= 0.0) {
            self.skipped += 1;
            return;
        }
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if self.exact.len() < EXACT_LIMIT {
            self.exact.push(v);
        }
        self.buckets[Self::bucket_index(v)] += 1;
    }

    /// Record `n` observations of the same value, bit-identically to `n`
    /// consecutive [`Histogram::record`] calls. The macro-step fast path
    /// uses this for run-length-grouped samples (e.g. a completion burst
    /// whose requests share one end-to-end latency), so the summary JSON
    /// must not move by a single bit versus per-sample recording: the
    /// running `sum` is advanced by `n` separate `+= v` additions (float
    /// addition does not distribute over multiplication — `sum + n·v`
    /// rounds differently), and the exact reservoir takes the same prefix
    /// it would have taken sample-by-sample.
    // msi-lint: hot
    pub fn record_n(&mut self, v: f64, n: u64) {
        debug_assert!(
            v.is_finite() && v >= 0.0,
            "histogram sample must be finite and non-negative, got {v}"
        );
        if n == 0 {
            return;
        }
        if !(v.is_finite() && v >= 0.0) {
            self.skipped += n;
            return;
        }
        self.count += n;
        for _ in 0..n {
            self.sum += v;
        }
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        let room = EXACT_LIMIT.saturating_sub(self.exact.len());
        let take = (n as usize).min(room);
        for _ in 0..take {
            self.exact.push(v);
        }
        self.buckets[Self::bucket_index(v)] += n;
    }

    /// Samples rejected by [`Histogram::record`] (non-finite or negative).
    /// Always 0 in debug builds, where rejection asserts instead.
    pub fn skipped_samples(&self) -> u64 {
        self.skipped
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest observation (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest observation (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Percentile in [0, 100]. Exact (linearly interpolated between
    /// adjacent order statistics) while the sample count is <= 4096,
    /// bucketed (≤ ~2.4% relative error) beyond that. Both paths use the
    /// same fractional rank `p/100 · (count-1)`, so the answer moves by at
    /// most one bucket width as the count crosses the exact limit — the
    /// nearest-rank exact path used to jump discontinuously against the
    /// interpolated bucketed path at that boundary.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (self.count as f64 - 1.0);
        if self.count as usize <= EXACT_LIMIT {
            let mut v = self.exact.clone();
            v.sort_by(|a, b| a.total_cmp(b));
            let lo = rank.floor() as usize;
            let hi = (lo + 1).min(v.len() - 1);
            let frac = rank - lo as f64;
            return v[lo] + (v[hi] - v[lo]) * frac;
        }
        let mut seen = 0u64;
        let target = rank.floor() as u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 && seen + c > target {
                // Interpolate within the bucket: spread its c observations
                // evenly through the bucket's span (consistent with the
                // linear interpolation in `fraction_below`), keeping the
                // fractional part of the rank for continuity.
                let frac = (((rank - seen as f64) + 0.5) / c as f64).clamp(0.0, 1.0);
                return Self::bucket_value_at(i, frac).clamp(self.min, self.max);
            }
            seen += c;
        }
        self.max
    }

    /// The 50th percentile.
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// The 99th percentile.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Fraction of recorded observations at or below `threshold` — the SLO
    /// attainment query. Exact while the sample count is small, bucketed
    /// (≤ ~2.4% relative threshold error) beyond that.
    ///
    /// ```
    /// use megascale_infer::metrics::Histogram;
    ///
    /// let mut lat = Histogram::new();
    /// for seconds in [0.050, 0.080, 0.120, 0.300] {
    ///     lat.record(seconds);
    /// }
    /// // 3 of 4 decode iterations met a 150 ms TPOT SLO.
    /// assert_eq!(lat.fraction_below(0.150), 0.75);
    /// assert_eq!(lat.fraction_below(1.0), 1.0);
    /// ```
    pub fn fraction_below(&self, threshold: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if self.count as usize <= EXACT_LIMIT {
            let n = self.exact.iter().filter(|&&v| v <= threshold).count();
            return n as f64 / self.count as f64;
        }
        // Outside the observed range the answer is exact — interpolation
        // inside the max's (or min's) bucket must not turn a fully-attained
        // SLO into a fractional one.
        if threshold >= self.max {
            return 1.0;
        }
        if threshold < self.min {
            return 0.0;
        }
        let idx = Self::bucket_index(threshold);
        let below: u64 = self.buckets[..idx].iter().sum();
        // Count only the partial share of the bucket the threshold falls
        // in — taking the whole bucket overstated SLO attainment by up to
        // one full bucket (~2.4% of the mass near the threshold).
        let partial = self.buckets[idx] as f64 * Self::position_in_bucket(threshold, idx);
        (below as f64 + partial) / self.count as f64
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        self.skipped += other.skipped;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for v in &other.exact {
            if self.exact.len() < EXACT_LIMIT {
                self.exact.push(*v);
            }
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_n_is_bit_identical_to_repeated_record() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        // Irrational-ish values so float-accumulation order matters, and
        // enough repeats to cross the exact-sample reservoir limit.
        for (v, n) in [(0.1234567, 2000u64), (3.9e-3, 1700), (0.1234567, 900)] {
            a.record_n(v, n);
            for _ in 0..n {
                b.record(v);
            }
        }
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean().to_bits(), b.mean().to_bits());
        assert_eq!(a.min().to_bits(), b.min().to_bits());
        assert_eq!(a.max().to_bits(), b.max().to_bits());
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(a.percentile(p).to_bits(), b.percentile(p).to_bits());
        }
    }

    #[test]
    fn exact_small_sample() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            h.record(v);
        }
        assert_eq!(h.median(), 3.0);
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 5.0);
        assert!((h.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bucketed_large_sample_accuracy() {
        let mut h = Histogram::new();
        // Uniform 1..100_000 microseconds.
        for i in 1..=100_000u64 {
            h.record(i as f64 * 1e-6);
        }
        let med = h.median();
        assert!(
            (med - 0.05).abs() / 0.05 < 0.03,
            "median {med} should be ~0.05 within 3%"
        );
        let p99 = h.p99();
        assert!(
            (p99 - 0.099).abs() / 0.099 < 0.03,
            "p99 {p99} should be ~0.099 within 3%"
        );
    }

    #[test]
    fn fraction_below_exact_and_bucketed() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.record(v);
        }
        assert_eq!(h.fraction_below(0.5), 0.0);
        assert_eq!(h.fraction_below(2.0), 0.5);
        assert_eq!(h.fraction_below(10.0), 1.0);
        // Bucketed regime: uniform 1..10_000 ms, threshold at the median.
        let mut big = Histogram::new();
        for i in 1..=10_000u64 {
            big.record(i as f64 * 1e-3);
        }
        let f = big.fraction_below(5.0);
        assert!((f - 0.5).abs() < 0.03, "fraction {f}");
    }

    #[test]
    fn bucketed_fraction_below_interpolates_partial_bucket() {
        // Regression: the bucketed path used to count the ENTIRE bucket
        // containing the threshold, overstating SLO attainment by up to a
        // full bucket (~2.4-5% of the mass here). With the partial bucket
        // linearly interpolated, the estimate tracks the true CDF closely
        // at every threshold, including ones just past a bucket edge.
        let n = 20_000usize;
        let mut h = Histogram::new();
        for i in 0..n {
            h.record(1.0 + (i as f64 + 0.5) / n as f64); // uniform on [1, 2]
        }
        for k in 0..=100 {
            let t = 1.0 + k as f64 / 100.0;
            let truth = (t - 1.0).clamp(0.0, 1.0);
            let got = h.fraction_below(t);
            assert!(
                (got - truth).abs() < 0.01,
                "threshold {t}: estimated {got} vs true {truth}"
            );
        }
        assert_eq!(h.fraction_below(0.5), 0.0);
        assert_eq!(h.fraction_below(10.0), 1.0);
        // Boundary exactness: at/above the recorded max the answer is
        // exactly 1 (a fully-attained SLO must not render as fractional
        // just because the threshold shares the max's bucket); strictly
        // below the min it is exactly 0.
        let lo = 1.0 + 0.5 / n as f64;
        let hi = 2.0 - 0.5 / n as f64;
        assert_eq!(h.fraction_below(hi), 1.0);
        assert_eq!(h.fraction_below(hi + 1e-6), 1.0);
        assert_eq!(h.fraction_below(lo - 1e-6), 0.0);
    }

    #[test]
    fn bucketed_percentile_interpolates_within_bucket() {
        // Regression: the bucketed path used to return the bucket geometric
        // midpoint (up to ~1.2% relative error); interpolating the rank's
        // position within the bucket tracks exact order statistics tightly.
        let n = 50_000usize;
        let mut h = Histogram::new();
        for i in 0..n {
            h.record(1.0 + 9.0 * (i as f64 + 0.5) / n as f64); // uniform [1, 10]
        }
        for k in 0..14 {
            let p = 1.0 + 7.0 * k as f64; // 1, 8, ..., 92
            let exact = 1.0 + 9.0 * p / 100.0;
            let est = h.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.007, "p{p}: estimated {est} vs exact {exact} (rel {rel})");
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "finite and non-negative")]
    fn nan_sample_asserts_in_debug() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "finite and non-negative")]
    fn negative_sample_asserts_in_debug() {
        let mut h = Histogram::new();
        h.record(-1.0);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn invalid_samples_skipped_in_release() {
        // Regression: NaN and negative samples used to alias into bucket 0
        // via the `as usize` cast while polluting sum/min/max.
        let mut h = Histogram::new();
        h.record(1.0);
        h.record(f64::NAN);
        h.record(-5.0);
        h.record(f64::INFINITY);
        h.record(3.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.skipped_samples(), 3);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 3.0);
        assert!((h.mean() - 2.0).abs() < 1e-12, "mean unpolluted");
    }

    #[test]
    fn zero_sample_still_accepted() {
        let mut h = Histogram::new();
        h.record(0.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.skipped_samples(), 0);
        assert_eq!(h.min(), 0.0);
    }

    #[test]
    fn merge_carries_skipped_counter() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1.0);
        b.record(2.0);
        a.merge(&b);
        assert_eq!(a.skipped_samples(), 0);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn exact_even_count_median_interpolates() {
        // With linear interpolation between order statistics, the median
        // of an even-count exact histogram is the midpoint of the two
        // central samples (it used to snap to one of them by nearest rank).
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.record(v);
        }
        assert_eq!(h.median(), 2.5);
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 4.0);
    }

    #[test]
    fn percentile_continuous_across_exact_limit() {
        // Regression: the exact path used nearest-rank while the bucketed
        // path interpolated, so percentiles jumped discontinuously as the
        // count crossed EXACT_LIMIT. Record the same log-uniform shape at
        // EXACT_LIMIT - 1, EXACT_LIMIT, and EXACT_LIMIT + 1 samples: every
        // percentile must agree within ~one bucket width (~2.4% relative).
        let shapes: Vec<Histogram> = [EXACT_LIMIT - 1, EXACT_LIMIT, EXACT_LIMIT + 1]
            .iter()
            .map(|&n| {
                let mut h = Histogram::new();
                for i in 0..n {
                    // Log-uniform over [1e-3, 1e3].
                    h.record(10f64.powf(6.0 * (i as f64 + 0.5) / n as f64 - 3.0));
                }
                h
            })
            .collect();
        for p in [1.0, 5.0, 25.0, 50.0, 75.0, 95.0, 99.0] {
            let below = shapes[0].percentile(p); // exact path
            let at = shapes[1].percentile(p); // exact path, at the limit
            let above = shapes[2].percentile(p); // bucketed path
            for (name, v) in [("at-limit", at), ("above-limit", above)] {
                let rel = (v - below).abs() / below;
                assert!(
                    rel < 0.03,
                    "p{p}: {name} {v} vs below-limit {below} (rel {rel})"
                );
            }
        }
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1.0);
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 3.0);
    }

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.median(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }
}
