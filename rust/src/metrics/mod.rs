//! Lightweight metrics: streaming histograms with percentile queries,
//! throughput counters, and utilization gauges.
//!
//! Used by both the discrete-event simulators (latency distributions for the
//! M2N figures) and the real PJRT serving path (TPOT / throughput report).

mod histogram;

pub use histogram::Histogram;

/// Simple wall-or-virtual-clock throughput counter.
#[derive(Debug, Default, Clone)]
pub struct Throughput {
    events: u64,
    /// Weighted units (e.g. tokens, bytes).
    units: f64,
    start: Option<f64>,
    end: f64,
}

impl Throughput {
    /// An empty counter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `units` of work completed at time `now` (seconds).
    pub fn record(&mut self, now: f64, units: f64) {
        if self.start.is_none() {
            self.start = Some(now);
        }
        self.end = self.end.max(now);
        self.events += 1;
        self.units += units;
    }

    /// Recorded events.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Total units recorded.
    pub fn units(&self) -> f64 {
        self.units
    }

    /// Units per second over the observed window; 0 if the window is empty.
    pub fn rate(&self) -> f64 {
        match self.start {
            Some(s) if self.end > s => self.units / (self.end - s),
            _ => 0.0,
        }
    }
}

/// Busy-time tracker for a resource: accumulates busy intervals and reports
/// utilization over a horizon. Used for per-node GPU utilization reports.
#[derive(Debug, Default, Clone)]
pub struct Utilization {
    busy: f64,
    horizon: f64,
}

impl Utilization {
    /// A tracker with zero busy time and an empty horizon.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate `dur` seconds of busy time.
    pub fn add_busy(&mut self, dur: f64) {
        self.busy += dur;
    }

    /// Extend the observation horizon to at least `t` seconds.
    pub fn set_horizon(&mut self, t: f64) {
        self.horizon = self.horizon.max(t);
    }

    /// Fraction of the horizon spent busy, clamped to [0, 1].
    pub fn fraction(&self) -> f64 {
        if self.horizon <= 0.0 {
            0.0
        } else {
            (self.busy / self.horizon).clamp(0.0, 1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_rate() {
        let mut t = Throughput::new();
        t.record(0.0, 10.0);
        t.record(1.0, 10.0);
        t.record(2.0, 10.0);
        assert_eq!(t.events(), 3);
        assert!((t.rate() - 15.0).abs() < 1e-9); // 30 units over 2 s
    }

    #[test]
    fn throughput_empty() {
        let t = Throughput::new();
        assert_eq!(t.rate(), 0.0);
    }

    #[test]
    fn utilization_clamps() {
        let mut u = Utilization::new();
        u.add_busy(5.0);
        u.set_horizon(4.0);
        assert_eq!(u.fraction(), 1.0);
        u.set_horizon(10.0);
        assert_eq!(u.fraction(), 0.5);
    }
}
