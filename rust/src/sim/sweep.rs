//! Scenario-grid sweeps over the cluster engine, plus the simulator's
//! self-throughput benchmark.
//!
//! The streaming arrival engine makes a single cell cheap; this module
//! makes *grids* cheap: the cartesian product of arrival rate × expert
//! popularity skew × micro-batch count (the plan axis) × tenant mix ×
//! serving system (disaggregated vs colocated baseline fleets — the
//! `msi compare` pairing as a grid dimension) is fanned out across
//! `std::thread` workers. Every cell derives its own seed
//! deterministically from the base seed and its grid position, and
//! results are collected by cell index, so the JSON/CSV report is
//! byte-identical across runs regardless of worker count or scheduling.
//!
//! The self-throughput benchmark ([`run_sim_bench`]) answers "how many
//! simulated output tokens does the simulator itself produce per
//! wall-clock second?" at million-request scale: it calibrates a service
//! rate with a short closed-loop run, then streams the full
//! generator-backed workload (memory bounded by in-flight requests) and
//! reports wall time, simulated tokens/s, and the in-flight high-water
//! marks to `BENCH_sim.json` so CI can track the perf trajectory per PR.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::baselines::{ColocatedPlan, SystemKind};
use crate::config::{ClusterSpec, GpuKind, ModelConfig};
use crate::coordinator::RoutePolicy;
use crate::perf_model::DEFAULT_PREFILL_CHUNK;
use crate::plan::{DeploymentPlan, PlanSearcher};
use crate::sim::cluster::{ClusterSim, ClusterSimConfig, ExpertPopularity, Transport};
use crate::sim::engine::{ClusterEngine, EngineScratch};
use crate::util::json::Json;
use crate::workload::{Request, RequestStream, TenantClass, TraceSource, WorkloadSpec};

/// The sweep's cartesian grid: scenario axes plus the shared base
/// configuration every cell starts from.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Model served in every cell.
    pub model: ModelConfig,
    /// Hardware every cell runs on.
    pub cluster: ClusterSpec,
    /// Base deployment plan; each cell overrides `m` from `micro_batches`.
    pub plan: DeploymentPlan,
    /// Base workload shape; each cell overrides arrival rate and tenants.
    pub spec: WorkloadSpec,
    /// Requests generated (streamed) per cell.
    pub requests: usize,
    /// Base seed every cell seed derives from.
    pub base_seed: u64,
    /// Arrival rates in requests/s; 0 = closed loop (all arrive at t=0).
    pub rates: Vec<f64>,
    /// Zipf popularity skews; 0 = uniform popularity.
    pub skews: Vec<f64>,
    /// Micro-batch counts (the deployment-plan axis).
    pub micro_batches: Vec<usize>,
    /// Prompt lengths (median input tokens; 0 = the base spec's median):
    /// the prefill axis — long prompts shift TTFT into its prefill
    /// component and load the prefill pool / inline chunked prefill. The
    /// deployment plan (including its prefill-pool size) is held fixed
    /// across the axis, so cells show how one deployment degrades as
    /// prompts grow.
    pub prompt_lens: Vec<f64>,
    /// Tenant mixes; an empty inner list = single-tenant traffic.
    pub tenant_mixes: Vec<Vec<TenantClass>>,
    /// Serving systems (the `msi compare` axis): the disaggregated plan
    /// and/or colocated baseline fleets sized to match its GPU count. The
    /// `skew` and `m` axes apply to the disaggregated system only — a
    /// colocated fleet runs `m = 1` with balanced experts, so it gets ONE
    /// canonical cell (reported as `skew = 0`, `m = 1`) per (rate, mix)
    /// instead of redundant identical runs across those axes.
    pub systems: Vec<SystemKind>,
}

/// One simulated grid cell: its coordinates plus the report scalars.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Cell arrival rate (requests/s; 0 = closed loop).
    pub rate: f64,
    /// Cell Zipf popularity skew (0 = uniform).
    pub skew: f64,
    /// Cell micro-batch count.
    pub m: usize,
    /// Cell median prompt length (0 = the base spec's median).
    pub prompt_len: f64,
    /// Index into [`SweepGrid::tenant_mixes`].
    pub tenant_mix: usize,
    /// Which serving system the cell ran ([`SystemKind::name`]).
    pub system: &'static str,
    /// The cell's derived deterministic seed.
    pub seed: u64,
    /// Requests fully decoded.
    pub completed: u64,
    /// Output tokens generated.
    pub tokens: u64,
    /// Virtual time elapsed (seconds).
    pub simulated_seconds: f64,
    /// Output tokens per second.
    pub throughput: f64,
    /// Output tokens per second per GPU.
    pub per_gpu_throughput: f64,
    /// Median time to first token (seconds).
    pub ttft_p50: f64,
    /// 99th-percentile time to first token (seconds).
    pub ttft_p99: f64,
    /// Median TTFT prefill component (seconds; 0 when prefill is off).
    pub ttft_prefill_p50: f64,
    /// Median per-iteration decode latency (seconds).
    pub tpot_p50: f64,
    /// Median end-to-end latency (seconds).
    pub e2e_p50: f64,
    /// 99th-percentile end-to-end latency (seconds).
    pub e2e_p99: f64,
    /// Attention-pool busy fraction.
    pub attn_utilization: f64,
    /// Expert-pool busy fraction.
    pub expert_utilization: f64,
    /// Front-door admission-control rejections.
    pub rejected: u64,
    /// Feasible work cut off by a horizon (0 at quiescence).
    pub unserved_queued: u64,
    /// High-water mark of concurrently in-flight requests.
    pub peak_in_flight: u64,
    /// Per-tenant `(name, SLO attainment)` pairs.
    pub tenants: Vec<(String, f64)>,
}

impl SweepCell {
    /// JSON rendering (one cell of the sweep report).
    pub fn to_json(&self) -> Json {
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|(name, att)| {
                Json::obj()
                    .set("name", name.as_str())
                    .set("attainment", *att)
            })
            .collect();
        Json::obj()
            .set("rate", self.rate)
            .set("skew", self.skew)
            .set("micro_batches", self.m)
            .set("prompt_len", self.prompt_len)
            .set("tenant_mix", self.tenant_mix)
            .set("system", self.system)
            .set("seed", self.seed)
            .set("completed", self.completed)
            .set("tokens", self.tokens)
            .set("simulated_seconds", self.simulated_seconds)
            .set("throughput", self.throughput)
            .set("per_gpu_throughput", self.per_gpu_throughput)
            .set("ttft_p50_s", self.ttft_p50)
            .set("ttft_p99_s", self.ttft_p99)
            .set("ttft_prefill_p50_s", self.ttft_prefill_p50)
            .set("tpot_p50_s", self.tpot_p50)
            .set("e2e_p50_s", self.e2e_p50)
            .set("e2e_p99_s", self.e2e_p99)
            .set("attn_utilization", self.attn_utilization)
            .set("expert_utilization", self.expert_utilization)
            .set("rejected", self.rejected)
            .set("unserved_queued", self.unserved_queued)
            .set("peak_in_flight", self.peak_in_flight)
            .set("tenants", Json::Arr(tenants))
    }
}

/// Derive a cell's seed from the base seed and its grid position — a
/// SplitMix64-style finalizer so adjacent cells get unrelated streams while
/// the mapping stays deterministic.
fn cell_seed(base: u64, idx: u64) -> u64 {
    let mut z = base
        ^ idx
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(0xd1b5_4a32_d192_ed03);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Run one cell to completion through the streaming engine. `scratch`
/// carries the engine's heap-backed working state (request table,
/// pipeline core, queues) from the worker's previous cell, so a grid of
/// thousands of cells allocates that state once per worker instead of
/// once per cell.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    grid: &SweepGrid,
    idx: usize,
    rate: f64,
    skew: f64,
    m: usize,
    prompt_len: f64,
    mix: usize,
    system: SystemKind,
    scratch: &mut EngineScratch,
) -> SweepCell {
    let seed = cell_seed(grid.base_seed, idx as u64);
    let tenants = grid.tenant_mixes.get(mix).cloned().unwrap_or_default();
    let spec = WorkloadSpec {
        arrival_rate: (rate > 0.0).then_some(rate),
        median_input: if prompt_len > 0.0 {
            prompt_len
        } else {
            grid.spec.median_input
        },
        tenants: tenants.clone(),
        ..grid.spec.clone()
    };
    let cfg = match system.baseline() {
        // A colocated baseline fleet sized to the disaggregated plan's GPU
        // count (the `msi compare` pairing, swept over the traffic axes).
        Some(kind) => ClusterSimConfig {
            seed,
            tenants,
            ..ClusterSimConfig::colocated(
                grid.model.clone(),
                grid.cluster.clone(),
                ColocatedPlan::sized_to_match(
                    kind,
                    &grid.model,
                    &grid.cluster,
                    grid.plan.total_gpus(),
                ),
            )
        },
        None => {
            let mut plan = grid.plan.clone();
            plan.m = m.max(1);
            let popularity = if skew > 0.0 {
                ExpertPopularity::Zipf(skew)
            } else {
                ExpertPopularity::Uniform
            };
            let prefill_nodes = plan.n_p;
            ClusterSimConfig {
                model: grid.model.clone(),
                cluster: grid.cluster.clone(),
                plan,
                route: RoutePolicy::LeastLoaded,
                popularity,
                transport: Transport::Analytic,
                seed,
                tenants,
                rebalance_period: None,
                max_sim_seconds: None,
                prefill_nodes,
                prefill_chunk: DEFAULT_PREFILL_CHUNK,
                mode: crate::sim::cluster::EngineMode::Disaggregated,
                fuse: true,
                macro_step: true,
                injections: Vec::new(),
            }
        }
    };
    // Decorrelate the workload generator from the engine's gating stream
    // (the engine does the same for its expert-permutation RNG): feeding
    // both SimRngs the identical seed would make request lengths track the
    // expert-gating draws sample for sample.
    let wl_seed = seed ^ 0xa076_1d64_78bd_642f;
    let rep = ClusterEngine::new(cfg, Box::new(RequestStream::new(spec, grid.requests, wl_seed)))
        .run_recycled(scratch);
    SweepCell {
        rate,
        skew,
        m,
        prompt_len,
        tenant_mix: mix,
        system: system.name(),
        seed,
        completed: rep.completed,
        tokens: rep.tokens,
        simulated_seconds: rep.elapsed,
        throughput: rep.throughput,
        per_gpu_throughput: rep.per_gpu_throughput,
        ttft_p50: rep.ttft.median(),
        ttft_p99: rep.ttft.p99(),
        ttft_prefill_p50: rep.ttft_prefill.median(),
        tpot_p50: rep.tpot.median(),
        e2e_p50: rep.e2e.median(),
        e2e_p99: rep.e2e.p99(),
        attn_utilization: rep.attn_utilization,
        expert_utilization: rep.expert_utilization,
        rejected: rep.rejected,
        unserved_queued: rep.unserved_queued,
        peak_in_flight: rep.peak_in_flight,
        tenants: rep
            .tenants
            .iter()
            .map(|t| (t.name.clone(), t.attainment()))
            .collect(),
    }
}

/// The system axis actually swept: an empty [`SweepGrid::systems`] means
/// "disaggregated only" — resolved in ONE place so the cells that run and
/// the report metadata can never disagree.
fn effective_systems(grid: &SweepGrid) -> &[SystemKind] {
    const DEFAULT_SYSTEMS: &[SystemKind] = &[SystemKind::Disaggregated];
    if grid.systems.is_empty() {
        DEFAULT_SYSTEMS
    } else {
        &grid.systems
    }
}

/// The prompt-length axis actually swept: empty means "the base spec's
/// median" (one canonical 0 entry).
fn effective_prompt_lens(grid: &SweepGrid) -> &[f64] {
    const DEFAULT_PROMPTS: &[f64] = &[0.0];
    if grid.prompt_lens.is_empty() {
        DEFAULT_PROMPTS
    } else {
        &grid.prompt_lens
    }
}

/// Run the whole grid across `workers` OS threads. Cells are claimed from a
/// shared counter and written back by index, so the result order (and
/// therefore the serialized report) is independent of scheduling.
pub fn run_sweep(grid: &SweepGrid, workers: usize) -> Vec<SweepCell> {
    let systems = effective_systems(grid);
    let prompts = effective_prompt_lens(grid);
    let mut coords: Vec<(f64, f64, usize, f64, usize, SystemKind)> = Vec::new();
    for &rate in &grid.rates {
        for (si, &skew) in grid.skews.iter().enumerate() {
            for (mi, &m) in grid.micro_batches.iter().enumerate() {
                for &prompt in prompts {
                    for mix in 0..grid.tenant_mixes.len().max(1) {
                        for &system in systems {
                            if system.baseline().is_some() {
                                // Colocated fleets ignore the skew and
                                // micro-batch axes (balanced experts, m=1):
                                // one canonical cell per (rate, prompt,
                                // mix) instead of redundant identical runs
                                // — the report's coordinates say what
                                // actually ran. The prompt axis DOES apply:
                                // it drives the inline chunked prefill.
                                if si == 0 && mi == 0 {
                                    coords.push((rate, 0.0, 1, prompt, mix, system));
                                }
                            } else {
                                coords.push((rate, skew, m, prompt, mix, system));
                            }
                        }
                    }
                }
            }
        }
    }
    let n = coords.len();
    let results: Vec<Mutex<Option<SweepCell>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = workers.clamp(1, n.max(1));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                // One scratch per worker: each cell's engine adopts the
                // previous cell's request table / pipeline core / queues
                // instead of reallocating them (reports stay byte-identical
                // — `sweep_is_deterministic_across_worker_counts` pins it).
                let mut scratch = EngineScratch::default();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let (rate, skew, m, prompt, mix, system) = coords[i];
                    let cell = run_cell(grid, i, rate, skew, m, prompt, mix, system, &mut scratch);
                    *results[i].lock().unwrap() = Some(cell);
                }
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every cell ran"))
        .collect()
}

/// Serialize a sweep into the machine-readable report
/// (`msi sweep --json`). Deterministic: object keys are sorted and the
/// cell order is the grid order.
pub fn sweep_to_json(grid: &SweepGrid, cells: &[SweepCell]) -> Json {
    let meta = Json::obj()
        .set("model", grid.model.name.as_str())
        .set("requests_per_cell", grid.requests)
        .set("base_seed", grid.base_seed)
        .set("rates", grid.rates.clone())
        .set("skews", grid.skews.clone())
        .set(
            "micro_batches",
            Json::Arr(grid.micro_batches.iter().map(|&m| Json::from(m)).collect()),
        )
        .set("prompt_lens", effective_prompt_lens(grid).to_vec())
        .set("tenant_mixes", grid.tenant_mixes.len())
        .set(
            "systems",
            Json::Arr(
                effective_systems(grid)
                    .iter()
                    .map(|s| Json::from(s.name()))
                    .collect(),
            ),
        )
        .set("cells", cells.len());
    Json::obj()
        .set("grid", meta)
        .set(
            "cells",
            Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
        )
}

/// Serialize a sweep as CSV (one row per cell, header first). Per-tenant
/// attainments are folded into one `name=value;...` column. Rows are
/// `write!`-formatted straight into the one output `String` — no per-row
/// or per-column intermediate allocations.
pub fn sweep_to_csv(cells: &[SweepCell]) -> String {
    use std::fmt::Write as _;
    let mut s = String::from(
        "rate,skew,micro_batches,prompt_len,tenant_mix,system,seed,completed,tokens,\
         simulated_seconds,throughput,per_gpu_throughput,ttft_p50_s,ttft_p99_s,\
         ttft_prefill_p50_s,tpot_p50_s,e2e_p50_s,\
         e2e_p99_s,attn_utilization,expert_utilization,rejected,unserved_queued,\
         peak_in_flight,attainments\n",
    );
    for c in cells {
        // Writing into a String is infallible: `fmt::Write` for `String`
        // never errors.
        let _ = write!(
            s,
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},",
            c.rate,
            c.skew,
            c.m,
            c.prompt_len,
            c.tenant_mix,
            c.system,
            c.seed,
            c.completed,
            c.tokens,
            c.simulated_seconds,
            c.throughput,
            c.per_gpu_throughput,
            c.ttft_p50,
            c.ttft_p99,
            c.ttft_prefill_p50,
            c.tpot_p50,
            c.e2e_p50,
            c.e2e_p99,
            c.attn_utilization,
            c.expert_utilization,
            c.rejected,
            c.unserved_queued,
            c.peak_in_flight,
        );
        for (i, (name, a)) in c.tenants.iter().enumerate() {
            let sep = if i == 0 { "" } else { ";" };
            let _ = write!(s, "{sep}{name}={a}");
        }
        s.push('\n');
    }
    s
}

/// The simulator self-throughput benchmark: stream `requests`
/// generator-backed requests through the engine at a calibrated
/// open-loop arrival rate and measure simulated output tokens per
/// wall-clock second. Memory stays bounded by in-flight requests — this is
/// the scale check the streaming arrival engine exists for.
///
/// Two more legs ride along in the report:
/// * `scenario_library_wall_seconds` — wall time to run every `.msc`
///   scenario under `scenario_dir` once (0.0 when the directory is absent,
///   e.g. when the bench runs outside the repo root), so CI can gate
///   regressions on the committed scenario library, not just the
///   synthetic stream.
/// * the `diurnal_*` fields from [`diurnal_bench`] — the long-horizon
///   macro-stepping benchmark and its built-in exactness assertion.
pub fn run_sim_bench(requests: usize, seed: u64, scenario_dir: Option<&str>) -> Json {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let spec = WorkloadSpec::tiny_bench();
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len())
        .search()
        .expect("tiny plan");
    let cfg = |seed: u64| ClusterSimConfig {
        // Ideal popularity: the bench measures the engine's event
        // machinery, not the RNG cost of per-token gating draws.
        popularity: ExpertPopularity::Ideal,
        seed,
        ..ClusterSimConfig::new(model.clone(), cluster.clone(), plan.clone())
    };

    // Phase 1 — calibrate: a short closed-loop run measures the service
    // rate so the timed run can stream near (below) saturation, keeping
    // the in-flight set small and the queues stable.
    let cal_n = 4096.min(requests.max(1));
    let cal = ClusterSim::new(cfg(seed)).run_streaming(Box::new(RequestStream::new(
        spec.clone(),
        cal_n,
        seed,
    )));
    let rate = 0.85 * (cal.throughput / spec.mean_output()).max(1.0);

    // Phase 2 — the timed streaming run. Engine construction (which sizes
    // the KV allocators via a capped generator replay) happens OUTSIDE the
    // timed window so the reported tokens/wall-second measures the event
    // machinery itself.
    let open = WorkloadSpec {
        arrival_rate: Some(rate),
        ..spec
    };
    let engine = ClusterEngine::new(
        cfg(seed ^ 0x6d5a_11),
        Box::new(RequestStream::new(open, requests, seed)),
    );
    // msi-lint: allow(wall-clock-in-sim) -- the self-throughput bench measures wall time by design; never feeds a report
    let t0 = std::time::Instant::now();
    let rep = engine.run();
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let diurnal = diurnal_bench(seed);
    Json::obj()
        .set("requests", requests)
        .set("completed", rep.completed)
        .set("simulated_tokens", rep.tokens)
        .set("simulated_seconds", rep.elapsed)
        .set("iterations", rep.iterations)
        .set("wall_seconds", wall)
        .set("tokens_per_wall_second", rep.tokens as f64 / wall)
        .set("requests_per_wall_second", requests as f64 / wall)
        .set("peak_in_flight", rep.peak_in_flight)
        .set("peak_queue_events", rep.peak_queue_events)
        .set("calibrated_arrival_rate_rps", rate)
        .set(
            "scenario_library_wall_seconds",
            scenario_dir.map_or(0.0, scenario_library_wall),
        )
        .set("diurnal_simulated_seconds", diurnal.simulated_seconds)
        .set("diurnal_iterations", diurnal.iterations)
        .set("diurnal_wall_seconds", diurnal.wall_macro)
        .set("diurnal_wall_seconds_no_macro", diurnal.wall_no_macro)
        .set(
            "diurnal_macro_speedup",
            diurnal.wall_no_macro / diurnal.wall_macro,
        )
}

/// Wall seconds to run the committed scenario library once: every `.msc`
/// file under `dir`, sorted path order, unsharded, default engine knobs.
/// Returns 0.0 when the directory is missing or holds no scenarios — the
/// CI regression gate skips on 0.
fn scenario_library_wall(dir: &str) -> f64 {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return 0.0;
    };
    let mut files: Vec<std::path::PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "msc"))
        .collect();
    files.sort();
    if files.is_empty() {
        return 0.0;
    }
    // msi-lint: allow(wall-clock-in-sim) -- bench wall timing by design; never feeds a report
    let t0 = std::time::Instant::now();
    for path in &files {
        let scenario = crate::sim::scenario::load(&path.to_string_lossy())
            .unwrap_or_else(|e| panic!("scenario library bench: {e}"));
        let _ = scenario.run();
    }
    t0.elapsed().as_secs_f64()
}

/// Result of [`diurnal_bench`]: one simulated day, run twice.
struct DiurnalBench {
    simulated_seconds: f64,
    iterations: u64,
    wall_macro: f64,
    wall_no_macro: f64,
}

/// The long-horizon macro-stepping benchmark: a day-shaped trace — a dense
/// surge of long uniform decodes at t = 0, then a sparse overnight trickle
/// pacing the clock out to four simulated hours. Between external events
/// the decode batch is externally quiet, so under macro-stepping the wall
/// time scales with the external-event count instead of the iteration
/// count; the same trace re-run with macro-stepping off provides the
/// denominator for `diurnal_macro_speedup`. The two reports are asserted
/// byte-identical, so the bench doubles as an end-to-end exactness check
/// at a batch size and horizon the unit tests don't reach.
fn diurnal_bench(seed: u64) -> DiurnalBench {
    const SURGE: usize = 4096;
    const TRICKLE: usize = 36;
    const HORIZON_S: f64 = 4.0 * 3600.0;

    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    // sigma 0: uniform decode lengths keep the whole surge one span (no
    // early completions splitting it) — the externally-quiet shape the
    // macro path exists to collapse.
    let spec = WorkloadSpec {
        median_input: 32.0,
        median_output: 4096.0,
        sigma: 0.0,
        ..Default::default()
    };
    let mut reqs: Vec<Request> = RequestStream::new(spec.clone(), SURGE, seed).collect();
    for i in 0..TRICKLE {
        reqs.push(Request {
            id: (SURGE + i) as u64,
            arrival: (i as f64 + 1.0) * HORIZON_S / (TRICKLE as f64 + 1.0),
            input_len: 32,
            // Short overnight decodes: a near-empty batch costs about the
            // same with or without macro-stepping, so long solo decodes
            // would only dilute the measured ratio.
            output_len: 64,
            tenant: 0,
        });
    }

    let mut plan = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len())
        .search()
        .expect("tiny plan");
    // One attention node, one micro-batch, batch = the whole surge, no
    // prefill pool: the bench isolates decode boundary-work scaling from
    // scheduler packing and prefill-pass events.
    plan.n_a = 1;
    plan.m = 1;
    plan.global_batch = SURGE;
    plan.n_p = 0;
    let cfg = |macro_step: bool| ClusterSimConfig {
        // Ideal popularity for the same reason as the streaming bench: the
        // target is event machinery, not per-token gating draws.
        popularity: ExpertPopularity::Ideal,
        seed,
        macro_step,
        ..ClusterSimConfig::new(model.clone(), cluster.clone(), plan.clone())
    };
    let timed = |macro_step: bool| {
        let engine = ClusterEngine::new(cfg(macro_step), Box::new(TraceSource::new(reqs.clone())));
        // msi-lint: allow(wall-clock-in-sim) -- bench wall timing by design; never feeds a report
        let t0 = std::time::Instant::now();
        let rep = engine.run();
        (t0.elapsed().as_secs_f64().max(1e-9), rep)
    };
    let (wall_macro, rep) = timed(true);
    let (wall_no_macro, rep_no) = timed(false);
    assert_eq!(
        rep.to_json().to_string(),
        rep_no.to_json().to_string(),
        "macro-stepped diurnal report must be byte-identical to --no-macro"
    );
    DiurnalBench {
        simulated_seconds: rep.elapsed,
        iterations: rep.iterations,
        wall_macro,
        wall_no_macro,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid() -> SweepGrid {
        let model = ModelConfig::tiny();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let spec = WorkloadSpec {
            median_input: 48.0,
            median_output: 6.0,
            sigma: 0.3,
            ..Default::default()
        };
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len())
            .search()
            .expect("tiny plan");
        SweepGrid {
            model,
            cluster,
            plan,
            spec,
            requests: 48,
            base_seed: 7,
            rates: vec![0.0, 400.0],
            skews: vec![0.0, 1.2],
            micro_batches: vec![1, 2],
            prompt_lens: vec![0.0],
            tenant_mixes: vec![Vec::new()],
            systems: vec![SystemKind::Disaggregated],
        }
    }

    #[test]
    fn sweep_is_deterministic_across_worker_counts() {
        let grid = tiny_grid();
        let serial = run_sweep(&grid, 1);
        let parallel = run_sweep(&grid, 4);
        assert_eq!(serial.len(), 8);
        let a = sweep_to_json(&grid, &serial).to_string();
        let b = sweep_to_json(&grid, &parallel).to_string();
        assert_eq!(a, b, "byte-identical report regardless of workers");
        assert_eq!(sweep_to_csv(&serial), sweep_to_csv(&parallel));
        for c in &serial {
            assert_eq!(c.completed, 48, "cell completes its workload");
            assert!(c.throughput > 0.0);
        }
    }

    #[test]
    fn system_axis_runs_colocated_baselines() {
        let grid = SweepGrid {
            rates: vec![0.0],
            skews: vec![0.0],
            micro_batches: vec![2],
            systems: vec![
                SystemKind::Disaggregated,
                SystemKind::Vllm,
                SystemKind::TrtLlm,
            ],
            ..tiny_grid()
        };
        let cells = run_sweep(&grid, 2);
        assert_eq!(cells.len(), 3);
        let names: Vec<&str> = cells.iter().map(|c| c.system).collect();
        assert_eq!(names, vec!["megascale", "vllm", "trtllm"]);
        for c in &cells {
            assert_eq!(c.completed, 48, "system {} completes", c.system);
            assert!(c.throughput > 0.0);
        }
        // Colocated cells report the matched-fleet per-GPU metric, and the
        // CSV carries the system and prefill columns.
        let csv = sweep_to_csv(&cells);
        assert!(csv.starts_with("rate,skew,micro_batches,prompt_len,tenant_mix,system,"));
        assert!(csv.contains("ttft_prefill_p50_s"));
        assert!(csv.contains(",vllm,") && csv.contains(",trtllm,"));
    }

    #[test]
    fn prompt_length_axis_loads_prefill() {
        // The prompt axis reshapes the workload per cell; longer prompts
        // push TTFT into its prefill component on every system.
        let grid = SweepGrid {
            rates: vec![0.0],
            skews: vec![0.0],
            micro_batches: vec![2],
            prompt_lens: vec![32.0, 512.0],
            requests: 24,
            systems: vec![SystemKind::Disaggregated, SystemKind::Vllm],
            ..tiny_grid()
        };
        let cells = run_sweep(&grid, 2);
        assert_eq!(cells.len(), 4, "2 prompts x 2 systems");
        for system in ["megascale", "vllm"] {
            let cell = |p: f64| {
                cells
                    .iter()
                    .find(|c| c.system == system && c.prompt_len == p)
                    .unwrap_or_else(|| panic!("{system} cell at prompt {p}"))
            };
            let (short, long) = (cell(32.0), cell(512.0));
            assert_eq!(short.completed, 24);
            assert_eq!(long.completed, 24);
            assert!(
                long.ttft_prefill_p50 > short.ttft_prefill_p50,
                "{system}: prefill p50 {} vs {}",
                long.ttft_prefill_p50,
                short.ttft_prefill_p50
            );
            assert!(long.ttft_prefill_p50 > 0.0);
        }
    }

    #[test]
    fn colocated_cells_collapse_to_one_canonical_cell_per_rate_and_mix() {
        // The skew/m axes do not apply to colocated fleets: instead of
        // redundant identical runs, each baseline gets exactly one cell per
        // (rate, mix), reported at the canonical (skew 0, m 1) coordinates.
        let grid = SweepGrid {
            rates: vec![0.0],
            skews: vec![0.0, 1.2],
            micro_batches: vec![1, 2],
            systems: vec![SystemKind::Disaggregated, SystemKind::Vllm],
            ..tiny_grid()
        };
        let cells = run_sweep(&grid, 2);
        let disagg = cells.iter().filter(|c| c.system == "megascale").count();
        let vllm: Vec<_> = cells.iter().filter(|c| c.system == "vllm").collect();
        assert_eq!(disagg, 4, "disaggregated covers the full skew x m grid");
        assert_eq!(vllm.len(), 1, "one canonical colocated cell");
        assert_eq!((vllm[0].skew, vllm[0].m), (0.0, 1));
        assert_eq!(vllm[0].completed, 48);
    }

    #[test]
    fn cell_seeds_differ_across_cells_and_stay_fixed() {
        let s: Vec<u64> = (0..8).map(|i| cell_seed(42, i)).collect();
        let mut uniq = s.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), s.len(), "distinct per-cell seeds");
        assert_eq!(s, (0..8).map(|i| cell_seed(42, i)).collect::<Vec<u64>>());
    }
}
