//! The event-driven cluster engine: pluggable components on one virtual
//! clock.
//!
//! The previous generation of the end-to-end simulator
//! (`ClusterSim::run`) was a single lockstep loop with one global `now` —
//! every scenario it modeled was artificially synchronized, and neither
//! per-component timing nor mid-iteration behavior was expressible. This
//! module decomposes it into an event-driven engine built on
//! [`crate::sim::EventQueue`]:
//!
//! Requests walk an explicit lifecycle state machine, tracked per slot in
//! the [`RequestTable`]:
//!
//! ```text
//!   Queued ──► Prefill ──► KvTransfer ──► Decode ──► Done
//! ```
//!
//! and the event graph mirrors it:
//!
//! ```text
//!   Event::Arrive ──► front door (admission control) ──► PrefillPool
//!                                                        (packed chunked
//!        Event::PrefillPass ◄── per-node pass clock ──── passes, FIFO)
//!             │ prompts done → RouterFront places on a decode node
//!             ▼
//!   Event::Place ──KV ships over the inter-pool link──► Event::KvArrive
//!                                                        │ batcher submit
//!   Event::IterBegin ◄── (armed by KV arrivals /         ▼ admission at
//!                          end-of-iteration)      continuous batching
//!                                                     + paged KV
//!        │ kicks off the shared ping-pong core
//!        ▼
//!   Event::Pipe(PipeEvent::*) — the per-(micro-batch, layer) shuttle:
//!     AttnReady/AttnDone        → AttentionPool   (per-node clocks)
//!     Dispatch/Combine          → M2nLink         (Eq. 6 or simnet,
//!                                                  token conservation)
//!     ExpertReady/ExpertDone    → ExpertPool      (per-rank clocks,
//!                                                  gating + §6 balance)
//!   Event::Rebalance ──► ExpertPool  (periodic §6 re-placement from
//!                                     observed loads, drifting Zipf)
//! ```
//!
//! With prefill modeling off (`prefill_nodes = 0` / `prefill_chunk = 0`)
//! the Prefill and KvTransfer phases are zero-length and placement happens
//! at arrival — the legacy instant-KV behavior. In
//! [`EngineMode::Colocated`] there is no separate pool: each serving group
//! chunk-prefills its own backlog INSIDE decode iterations (vLLM-style
//! chunked prefill), so prefill work visibly inflates the baseline's TPOT
//! while the disaggregated pool leaves decode iterations untouched.
//!
//! Each pool component implements [`Component`]: handle an event addressed
//! to it, mutate local state, and emit future `(time, event)` pairs. All
//! cross-component interaction flows through events and the shared
//! [`SimCtx`], so arrivals, prefill passes, pipeline hops and re-balancing
//! interleave on a single deterministic queue. The ping-pong scheduling
//! itself is the shared [`PipelineCore`] state machine — the same code
//! that backs [`crate::coordinator::PingPongEngine`] and
//! [`crate::plan::simulate_plan_des`], which are thin layers over it.
//!
//! Arrivals are *pulled*, not preloaded: the engine draws requests one at a
//! time from an [`ArrivalSource`] (trace- or generator-backed) and keeps
//! exactly one future `Arrive` event outstanding, so the event queue and
//! the in-flight [`RequestTable`] are O(in-flight requests) — a
//! million-request (or unbounded generator) run never materializes its
//! whole trace.

use std::collections::VecDeque;

use crate::baselines::ColocatedModel;
use crate::config::GpuSpec;
use crate::coordinator::{
    balance_experts, build_dispatch, BlockAllocator, ContinuousBatcher, ExpertPlacement,
    KvCacheConfig, Router, SchedulerConfig,
};
use crate::m2n::{LibraryProfile, TransferModel};
use crate::metrics::{Histogram, Utilization};
use crate::perf_model::{bandwidth_util, prefill_node_gpus, PerfModel, PrefillModel};
use crate::sim::cluster::{
    draw_gating, popularity_weights, ClusterReport, ClusterSimConfig, EngineMode,
    ExpertPopularity, FaultKind, TenantReport, Transport,
};
use crate::sim::pipeline::{FusedQueue, PipeEvent, PipelineCore, PipelineStats, StageTimes};
use crate::sim::{EventQueue, SimRng};
use crate::workload::{ArrivalSource, Request};

/// Paged-KV block size in tokens (vLLM default) — shared by the attention
/// nodes' allocators, the front door's block-granular admission bound, and
/// the arrival sources' per-request demand rounding.
pub const KV_BLOCK: u64 = 16;

/// Engine event. Each variant is owned by exactly one component (plus the
/// engine itself for `IterBegin`); `Pipe` events additionally pass through
/// the link/expert conservation observers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// The request in table slot `i` reaches the front door.
    Arrive(usize),
    /// A prefill node finished one packed chunked pass over its queue.
    PrefillPass { node: usize },
    /// Router decision: place the request in slot `req` on decode
    /// attention node `node` (its prompt KV then ships over the link).
    Place { req: usize, node: usize },
    /// Prompt KV for slot `req` landed on decode attention node `node`.
    KvArrive { req: usize, node: usize },
    /// Begin a decode iteration: admission + pipeline kickoff.
    IterBegin,
    /// Periodic §6 online re-balancing from observed expert loads.
    Rebalance,
    /// One ping-pong pipeline hop (shared core).
    Pipe(PipeEvent),
    /// A fused iteration completes: the fast path computed the whole
    /// ping-pong traversal analytically inside `IterBegin` and scheduled
    /// this single event at the completion time instead of ~3·m·L `Pipe`
    /// hops (never emitted with `fuse` off).
    IterEnd,
    /// The scheduled fault/elasticity injection `cfg.injections[i]` fires.
    /// All injections are scheduled up front in `prime`, so their
    /// insertion sequence precedes every runtime event — at a timestamp
    /// tie they pop first in both fused and stepwise modes.
    Inject(usize),
}

/// Lifecycle phase of an in-flight request — the explicit state machine
/// `Queued → Prefill → KvTransfer → Decode → Done` the [`RequestTable`]
/// tracks (`Done` is momentary: the slot is recycled immediately after).
/// Transition timestamps feed the report's TTFT decomposition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestPhase {
    /// Past admission control, waiting for prefill capacity (or, with
    /// prefill modeling off, for a decode placement).
    Queued,
    /// Prompt being chunk-prefilled — on the dedicated pool, or inline on
    /// a colocated serving group's backlog.
    Prefill,
    /// Prompt KV in flight from the prefill node to the assigned decode
    /// attention node (includes any wait for a decode placement).
    KvTransfer,
    /// Submitted to a decode attention node: batcher waiting queue, then
    /// the live continuous batch until the last output token.
    Decode,
    /// Fully decoded; the table slot is freed in the same event.
    Done,
}

/// Per-slot lifecycle metadata, kept in its own dense array alongside the
/// request payloads (structure-of-arrays): the hot end-of-iteration path
/// reads phases and transition timestamps for many slots, and packing them
/// without the `Request` payload (or a discriminant per slot) keeps those
/// reads on a few cache lines. A vacant (recycled) slot is marked by
/// `RequestPhase::Done`.
#[derive(Debug, Clone, Copy)]
struct SlotMeta {
    /// Current lifecycle phase (`Done` doubles as the vacancy marker).
    phase: RequestPhase,
    /// Attention node the router placed the request on (`u32::MAX` while
    /// unplaced — node counts are far below the sentinel).
    placed: u32,
    /// When the first prefill chunk started (end of `Queued`).
    prefill_start: f64,
    /// When the last prefill chunk finished (start of `KvTransfer`).
    prefill_end: f64,
    /// When the prompt KV reached the decode node (start of `Decode`).
    decode_entry: f64,
}

const UNPLACED: u32 = u32::MAX;

/// Dense free-list table of in-flight requests. A request occupies a slot
/// from the moment the engine pulls it off the [`ArrivalSource`] until it
/// fully decodes; slots are recycled, so memory is O(in-flight), not
/// O(trace length). Everything downstream of the source — events, the
/// router's overflow FIFO, the batchers' live ids — refers to requests by
/// slot.
pub struct RequestTable {
    /// Request payloads, indexed by slot (parallel to `meta`).
    reqs: Vec<Request>,
    /// Lifecycle metadata, indexed by slot (parallel to `reqs`).
    meta: Vec<SlotMeta>,
    free: Vec<usize>,
    live: usize,
    peak: usize,
}

impl Default for RequestTable {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestTable {
    /// An empty table (slots are allocated lazily and recycled).
    pub fn new() -> Self {
        Self {
            reqs: Vec::new(),
            meta: Vec::new(),
            free: Vec::new(),
            live: 0,
            peak: 0,
        }
    }

    /// Return the table to its empty state, keeping the slot allocations
    /// for reuse. Semantically identical to a fresh [`RequestTable::new`]
    /// — `insert` refills from the cleared free list exactly as it pushes
    /// onto empty vectors — so `msi sweep` can recycle one table across
    /// grid cells without re-growing it per cell.
    pub fn reset(&mut self) {
        self.reqs.clear();
        self.meta.clear();
        self.free.clear();
        self.live = 0;
        self.peak = 0;
    }

    /// Claim a slot for a newly-pulled request.
    pub fn insert(&mut self, req: Request) -> usize {
        let meta = SlotMeta {
            phase: RequestPhase::Queued,
            placed: UNPLACED,
            prefill_start: 0.0,
            prefill_end: 0.0,
            decode_entry: 0.0,
        };
        let slot = match self.free.pop() {
            Some(s) => {
                self.reqs[s] = req;
                self.meta[s] = meta;
                s
            }
            None => {
                self.reqs.push(req);
                self.meta.push(meta);
                self.reqs.len() - 1
            }
        };
        self.live += 1;
        self.peak = self.peak.max(self.live);
        slot
    }

    /// The request occupying `slot` (the engine never holds a slot id past
    /// completion; debug builds still catch a dead-slot read via the
    /// `Done`-as-vacancy marker).
    pub fn get(&self, slot: usize) -> &Request {
        debug_assert!(
            self.meta[slot].phase != RequestPhase::Done,
            "dead request slot"
        );
        &self.reqs[slot]
    }

    /// Current lifecycle phase of the request in `slot`.
    pub fn phase(&self, slot: usize) -> RequestPhase {
        self.meta[slot].phase
    }

    /// Advance the slot's lifecycle phase ONE step along
    /// `Queued → Prefill → KvTransfer → Decode → Done`, stamping the
    /// transition time the TTFT decomposition reads back at first-token
    /// time. Skipped stages are driven through with zero duration by the
    /// callers (e.g. no-prefill placement), never jumped over.
    fn advance(&mut self, slot: usize, to: RequestPhase, now: f64) {
        let e = &mut self.meta[slot];
        debug_assert!(
            matches!(
                (e.phase, to),
                (RequestPhase::Queued, RequestPhase::Prefill)
                    | (RequestPhase::Prefill, RequestPhase::KvTransfer)
                    | (RequestPhase::KvTransfer, RequestPhase::Decode)
                    | (RequestPhase::Decode, RequestPhase::Done)
            ),
            "illegal phase transition {:?} -> {:?}",
            e.phase,
            to
        );
        match to {
            RequestPhase::Prefill => e.prefill_start = now,
            RequestPhase::KvTransfer => e.prefill_end = now,
            RequestPhase::Decode => e.decode_entry = now,
            RequestPhase::Queued | RequestPhase::Done => {}
        }
        e.phase = to;
    }

    /// Phase-transition timestamps `(prefill_start, prefill_end,
    /// decode_entry)` of a request that reached the `Decode` phase.
    fn timings(&self, slot: usize) -> (f64, f64, f64) {
        let e = &self.meta[slot];
        (e.prefill_start, e.prefill_end, e.decode_entry)
    }

    fn set_placed(&mut self, slot: usize, node: usize) {
        self.meta[slot].placed = node as u32;
    }

    fn take_placed(&mut self, slot: usize) -> Option<usize> {
        let p = self.meta[slot].placed;
        self.meta[slot].placed = UNPLACED;
        (p != UNPLACED).then_some(p as usize)
    }

    /// Reset a fault-displaced request to `Queued` for re-admission — the
    /// one sanctioned jump backwards in the otherwise one-step lifecycle
    /// (a node failure loses the KV, so the request re-earns its whole
    /// prefill → transfer → decode walk; the TTFT decomposition restamps
    /// from the retry).
    fn reset_for_retry(&mut self, slot: usize) {
        let e = &mut self.meta[slot];
        debug_assert!(e.phase != RequestPhase::Done, "retry of a dead slot");
        e.phase = RequestPhase::Queued;
        e.placed = UNPLACED;
        e.prefill_start = 0.0;
        e.prefill_end = 0.0;
        e.decode_entry = 0.0;
    }

    /// Release a completed request's slot for reuse.
    pub fn remove(&mut self, slot: usize) -> Request {
        debug_assert!(self.live > 0, "remove on an empty table");
        self.meta[slot].phase = RequestPhase::Done;
        self.free.push(slot);
        self.live -= 1;
        self.reqs[slot]
    }

    /// Requests currently in flight.
    pub fn len(&self) -> usize {
        self.live
    }

    /// No requests in flight.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// High-water mark of concurrently in-flight requests.
    pub fn peak(&self) -> usize {
        self.peak
    }
}

/// Cross-component shared state: the in-flight requests, the random stream,
/// and the per-iteration stage context.
pub struct SimCtx {
    /// Free-list table of in-flight requests — the only request storage the
    /// engine keeps; events and components refer to requests by slot.
    pub table: RequestTable,
    /// Gating / popularity random stream.
    pub rng: SimRng,
    /// Stage-time context of the in-flight iteration (None while idle).
    pub stage: Option<StageCtx>,
    /// A decode iteration is in flight.
    pub in_iteration: bool,
    /// An `IterBegin` event is already scheduled.
    pub iter_pending: bool,
    // Running sums of the effective stage times fed to the pipeline (the
    // DES-vs-Eq.5 cross-check anchors here).
    /// Running sum of effective attention-stage times.
    pub sum_t_a: f64,
    /// Running sum of effective expert-stage times.
    pub sum_t_e: f64,
    /// Running sum of effective one-way transfer times.
    pub sum_t_c: f64,
    /// Stage-time samples accumulated (one per (micro-batch, layer) hop).
    pub stage_samples: u64,
}

/// Stage-time provider for one decode iteration: the disaggregated
/// `T_a`/`T_e`/`T_c` models, or the colocated per-layer model in baseline
/// mode (where the whole layer runs as one serial stage and the expert
/// stage and M2N link contribute zero time).
pub enum StageModel {
    /// Disaggregated pools: the paper's Eq. 4–6 substrate.
    Disaggregated(PerfModel),
    /// A colocated serving group: full layer time on the (sole) serial
    /// stage ([`ColocatedModel::layer_time`]).
    Colocated(ColocatedModel),
}

impl StageModel {
    /// Attention-stage time for a micro-batch of `b` tokens (in colocated
    /// mode: the whole layer — attention, all experts, TP collectives).
    pub fn t_a(&self, b: f64) -> f64 {
        match self {
            StageModel::Disaggregated(pm) => pm.t_a(b),
            StageModel::Colocated(cm) => cm.layer_time(b),
        }
    }

    /// Expert-stage time for `b_e` tokens (zero when colocated: expert
    /// compute is already inside the layer time).
    pub fn t_e(&self, b_e: f64) -> f64 {
        match self {
            StageModel::Disaggregated(pm) => pm.t_e(b_e),
            StageModel::Colocated(_) => 0.0,
        }
    }

    /// One-direction M2N transfer time (zero when colocated: the
    /// unoverlapped all-to-all is folded into the layer's kernel
    /// efficiency).
    pub fn t_c(&self, b_a: f64, b_e: f64) -> f64 {
        match self {
            StageModel::Disaggregated(pm) => pm.t_c(b_a, b_e),
            StageModel::Colocated(_) => 0.0,
        }
    }

    /// Bytes one attention GPU hands the M2N link per micro-batch (for the
    /// simnet-calibrated transfer path; zero when colocated).
    pub fn send_bytes(&self, b_a: f64) -> f64 {
        match self {
            StageModel::Disaggregated(pm) => pm.comm.send_bytes(b_a),
            StageModel::Colocated(_) => 0.0,
        }
    }

    /// The expert model's per-layer weight-load floor `k4` (for the extra
    /// charge when one expert node hosts several experts; zero when
    /// colocated).
    fn expert_weight_floor(&self) -> f64 {
        match self {
            StageModel::Disaggregated(pm) => pm.expert.k4,
            StageModel::Colocated(_) => 0.0,
        }
    }

    /// Per-layer time of an inline chunked-prefill pass mixed into a
    /// decode iteration (colocated groups only — the disaggregated path
    /// prefills on its dedicated pool, outside decode iterations). The
    /// engine passes in its once-built roofline `prefill` model.
    pub fn prefill_layer_time(&self, prefill: &PrefillModel, tokens: f64, ctx: f64) -> f64 {
        match self {
            StageModel::Disaggregated(_) => 0.0,
            StageModel::Colocated(cm) => cm.prefill_layer_time(prefill, tokens, ctx),
        }
    }
}

/// Per-iteration stage-time inputs derived from the live batch composition.
pub struct StageCtx {
    /// This iteration's stage-time provider (rebuilt per iteration at the
    /// live average sequence length).
    pub pm: StageModel,
    /// Per-node micro-batch token shares: `share[node][mb]`.
    pub share: Vec<Vec<usize>>,
    /// Paced attention micro-batch size (max share across nodes).
    pub b_a: Vec<f64>,
    /// Total tokens per micro-batch across the pool.
    pub tok: Vec<usize>,
    /// Extra k4 weight-load floors when a node hosts several experts.
    pub extra_weight_loads: f64,
    /// Decode tokens are present this iteration (a colocated iteration can
    /// be pure inline prefill; such iterations record no TPOT sample).
    pub has_decode: bool,
    /// Per-node inline chunked-prefill time charged on this iteration's
    /// first hop (colocated groups; all-zero on the disaggregated path).
    pub prefill_node_time: Vec<f64>,
    /// Per-node requests whose prompts finish prefilling when this
    /// iteration ends — they join the decode batcher at end-of-iteration.
    pub prefill_finish: Vec<Vec<usize>>,
    /// Prompt tokens chunked through this iteration (inline prefill).
    pub prefill_tokens: u64,
}

impl StageCtx {
    /// Cold-start stage context. Runs once per engine (and again only
    /// after a mode switch); every later iteration recycles the spare, so
    /// the empty buffers grown here are the decode loop's only allocation
    /// site — keeping them out of the `hot`-marked `begin_iteration`.
    fn cold(pm: StageModel) -> Self {
        StageCtx {
            pm,
            share: Vec::new(),
            b_a: Vec::new(),
            tok: Vec::new(),
            extra_weight_loads: 0.0,
            has_decode: false,
            prefill_node_time: Vec::new(),
            prefill_finish: Vec::new(),
            prefill_tokens: 0,
        }
    }
}

/// A simulation component: consumes an event addressed to it, mutates its
/// local state, and emits scheduled `(time, event)` follow-ups.
pub trait Component {
    /// Handle one event at virtual time `now`, pushing any follow-up
    /// `(time, event)` pairs into `out` for the engine to schedule.
    fn handle(&mut self, now: f64, ev: &Event, ctx: &mut SimCtx, out: &mut Vec<(f64, Event)>);
}

// ---------------------------------------------------------------- router --

/// Front-door router component: KV-aware request placement with a strictly
/// FIFO overflow queue (a request that does not fit *right now* blocks
/// later arrivals from jumping into freed capacity). Requests that could
/// never fit — KV footprint beyond a whole node's budget — are rejected at
/// arrival: letting one clog the FIFO head would starve every later
/// request AND grow the in-flight table without bound as the stream keeps
/// queueing behind it.
pub struct RouterFront {
    router: Router,
    /// Block-rounded per-node KV capacity — `floor(budget / KV_BLOCK)`
    /// blocks worth of tokens, the most KV a node's allocator can actually
    /// hold (the admission-control bound).
    usable_kv_tokens: u64,
    /// FIFO of request slots the fleet could not place yet.
    overflow: VecDeque<usize>,
    /// Requests rejected at the front door (could never be placed).
    rejected: u64,
}

impl RouterFront {
    fn new(router: Router, node_kv_tokens: u64) -> Self {
        Self {
            router,
            usable_kv_tokens: (node_kv_tokens / KV_BLOCK) * KV_BLOCK,
            overflow: VecDeque::new(),
            rejected: 0,
        }
    }

    /// Completion callback: release the request's routing accounting.
    fn complete(&mut self, node: usize, r: &Request) {
        self.router.complete(node, r);
    }

    /// Fault injection: exclude (or re-include) `node` from placement.
    fn set_node_down(&mut self, node: usize, down: bool) {
        self.router.set_down(node, down);
    }

    /// Front-door admission control: returns true when the request could
    /// never be served (KV footprint beyond any node's usable budget) and
    /// was rejected, its slot recycled.
    fn reject_if_infeasible(&mut self, req: usize, ctx: &mut SimCtx) -> bool {
        // The bound is block-granular: a node's allocator holds only
        // `floor(budget/KV_BLOCK)` whole blocks, so comparing against the
        // raw token budget would admit requests whose prompt can never be
        // block-admitted (permanent waiting-queue stall) or whose last few
        // decode tokens would not fit. `need <= usable` also implies the
        // prompt fits in whole blocks: `ceil(input/B) <= usable/B` because
        // `input <= need`.
        let need = {
            let r = ctx.table.get(req);
            (r.input_len + r.output_len) as u64
        };
        if need > self.usable_kv_tokens {
            self.rejected += 1;
            ctx.table.remove(req);
            return true;
        }
        false
    }

    /// Route a (prefilled) request to a decode node, or park it in the
    /// strictly-FIFO overflow queue until completions free capacity — a
    /// request that does not fit *right now* blocks later ones from
    /// jumping into freed capacity.
    fn place_or_queue(
        &mut self,
        now: f64,
        req: usize,
        ctx: &mut SimCtx,
        out: &mut Vec<(f64, Event)>,
    ) {
        if !self.overflow.is_empty() {
            // Preserve FIFO admission behind a temporarily-unplaceable head.
            self.overflow.push_back(req);
            return;
        }
        match self.router.route(ctx.table.get(req)) {
            Some(node) => {
                ctx.table.set_placed(req, node);
                out.push((now, Event::Place { req, node }));
            }
            None => self.overflow.push_back(req),
        }
    }

    /// FIFO-drain the overflow queue into placements, stopping at the first
    /// request that still does not fit.
    fn drain_overflow(&mut self, now: f64, ctx: &mut SimCtx, out: &mut Vec<(f64, Event)>) {
        while let Some(&req) = self.overflow.front() {
            let Some(node) = self.router.route(ctx.table.get(req)) else {
                break;
            };
            self.overflow.pop_front();
            ctx.table.set_placed(req, node);
            out.push((now, Event::Place { req, node }));
        }
    }

    /// Requests still queued at the front door at the horizon.
    fn pending(&self) -> usize {
        self.overflow.len()
    }

    /// Requests rejected at the front door over the whole run.
    fn rejected(&self) -> u64 {
        self.rejected
    }
}

// ---------------------------------------------------------- prefill pool --

/// Take up to `budget` prompt tokens off a `(slot, remaining)` FIFO,
/// packing across request boundaries — the ONE chunk-assembly rule shared
/// by the dedicated pool and the colocated inline backlogs (the TTFT
/// decomposition and the conservation counters both hang off it). Stamps
/// `Queued → Prefill` on a prompt's first touch, pops finished prompts
/// into `finish`, and returns `(tokens_taken, token-weighted mean
/// attended context)`.
// msi-lint: hot
fn take_prefill_chunk(
    queue: &mut VecDeque<(usize, usize)>,
    budget: usize,
    now: f64,
    table: &mut RequestTable,
    finish: &mut Vec<usize>,
) -> (usize, f64) {
    let mut budget = budget;
    let mut total = 0usize;
    let mut wctx = 0.0f64;
    while budget > 0 {
        let Some(front) = queue.front_mut() else {
            break;
        };
        let (req, remaining) = *front;
        let take = remaining.min(budget);
        if take < remaining {
            front.1 -= take;
        }
        if table.phase(req) == RequestPhase::Queued {
            table.advance(req, RequestPhase::Prefill, now);
        }
        let done = table.get(req).input_len.saturating_sub(remaining);
        wctx += take as f64 * (done as f64 + take as f64 / 2.0);
        budget -= take;
        total += take;
        if take == remaining {
            queue.pop_front();
            finish.push(req);
        }
    }
    let mean_ctx = if total > 0 {
        (wctx / total as f64).max(1.0)
    } else {
        1.0
    };
    (total, mean_ctx)
}

/// One in-flight packed chunked pass on a prefill node.
struct PrefillPass {
    /// Requests whose prompts complete when this pass ends.
    finish: Vec<usize>,
    /// Prompt tokens the pass processes.
    tokens: u64,
}

/// The dedicated prefill pool: `prefill_nodes` full-model instances (each
/// `tp_p` GPUs) running packed chunked prefill. Each node owns a FIFO of
/// prompts; a pass takes up to `chunk` tokens off the FIFO — PACKING
/// across request boundaries, the way real prefill instances batch
/// prompts — prices one pass through all layers at the token-weighted mean
/// attended context, and hands finished prompts to the router for the KV
/// shipment to a decode node. Requests are assigned whole to the node with
/// the fewest pending prompt tokens (ties to the lowest index), so the
/// pool is deterministic and a partially-prefilled prompt never migrates.
pub struct PrefillPool {
    chunk: usize,
    layers: usize,
    model: PrefillModel,
    /// Per-node FIFO of `(slot, prompt tokens still to prefill)`.
    queues: Vec<VecDeque<(usize, usize)>>,
    /// Per-node prompt tokens queued OR in the node's current pass — the
    /// least-loaded assignment key, so a node mid-pass never ties with a
    /// genuinely idle one.
    pending: Vec<u64>,
    /// Per-node pass in flight.
    pass: Vec<Option<PrefillPass>>,
    /// Per-node cumulative busy seconds.
    node_busy: Vec<f64>,
    /// Prompt tokens that completed prefill on the pool (conservation
    /// counter for the prefill→decode handoff).
    pub prefilled_tokens: u64,
}

impl PrefillPool {
    fn new(nodes: usize, chunk: usize, layers: usize, model: PrefillModel) -> Self {
        let n = nodes.max(1);
        Self {
            chunk: chunk.max(1),
            layers: layers.max(1),
            model,
            queues: vec![VecDeque::new(); n],
            pending: vec![0; n],
            pass: (0..n).map(|_| None).collect(),
            node_busy: vec![0.0; n],
            prefilled_tokens: 0,
        }
    }

    /// Enqueue a request on the least-loaded node (by queued + in-pass
    /// prompt tokens) and start a pass if that node is idle. Callers
    /// guarantee a non-empty prompt.
    fn submit(&mut self, now: f64, req: usize, ctx: &mut SimCtx, out: &mut Vec<(f64, Event)>) {
        let tokens = ctx.table.get(req).input_len;
        debug_assert!(tokens > 0, "empty prompts skip the prefill pool");
        let node = (0..self.queues.len())
            .min_by_key(|&i| (self.pending[i], i))
            // msi-lint: allow(unwrap-in-engine) -- PrefillPool::new requires >= 1 node, so the range is never empty
            .expect("at least one prefill node");
        self.queues[node].push_back((req, tokens));
        self.pending[node] += tokens as u64;
        if self.pass[node].is_none() {
            self.start_pass(node, now, ctx, out);
        }
    }

    /// Assemble and launch the next packed pass on `node`, scheduling its
    /// completion. No-op when the node's queue is empty.
    fn start_pass(&mut self, node: usize, now: f64, ctx: &mut SimCtx, out: &mut Vec<(f64, Event)>) {
        debug_assert!(self.pass[node].is_none(), "node already mid-pass");
        let mut finish = Vec::new();
        let (total, ctx_mean) = take_prefill_chunk(
            &mut self.queues[node],
            self.chunk,
            now,
            &mut ctx.table,
            &mut finish,
        );
        if total == 0 {
            return;
        }
        let dur = self.layers as f64 * self.model.chunk_layer_time(total as f64, ctx_mean);
        self.node_busy[node] += dur;
        self.pass[node] = Some(PrefillPass {
            finish,
            tokens: total as u64,
        });
        out.push((now + dur, Event::PrefillPass { node }));
    }

    /// A pass completed: advance its finished prompts into `KvTransfer`
    /// and return them for routing to decode nodes.
    fn finish_pass(&mut self, node: usize, now: f64, ctx: &mut SimCtx) -> Vec<usize> {
        // msi-lint: allow(unwrap-in-engine) -- a PrefillPass event exists only while start_pass has a pass parked here
        let pass = self.pass[node].take().expect("pass in flight");
        // The pass's tokens stop counting toward the node's load only now
        // that they are done.
        self.pending[node] -= pass.tokens;
        self.prefilled_tokens += pass.tokens;
        for &req in &pass.finish {
            ctx.table.advance(req, RequestPhase::KvTransfer, now);
        }
        pass.finish
    }

    /// Requests queued or mid-pass on the pool (horizon accounting).
    fn in_pool(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum::<usize>()
            + self.pass.iter().flatten().map(|p| p.finish.len()).sum::<usize>()
    }
}

// ------------------------------------------------------- attention pool --

/// Per-attention-node serving state.
struct AttnNode {
    batcher: ContinuousBatcher,
    kv: BlockAllocator,
    /// Colocated inline-prefill backlog: `(slot, prompt tokens left)` —
    /// chunked through decode iterations (empty in disaggregated mode).
    backlog: VecDeque<(usize, usize)>,
}

/// What one attention node produced in one decode iteration.
struct NodeIterOutcome {
    /// Requests that decoded their FIRST token this iteration.
    first: Vec<u64>,
    /// Requests that finished.
    done: Vec<u64>,
}

/// Recycled scratch of the macro-step span probe: per-node batch sizes
/// and integer sequence-length sums captured at span start, from which
/// the bulk replay reconstructs every intermediate iteration's average
/// sequence length in closed form (see [`AttentionPool::bulk_avg_seq`]).
#[derive(Default)]
struct SpanScratch {
    /// Per-node live batch size at span start.
    len: Vec<u64>,
    /// Per-node Σ `seq_len` (exact integer) at span start.
    seq_sum: Vec<u64>,
    /// Pool-wide batch size at span start.
    total: u64,
}

/// The attention pool: `n_a` nodes with continuous batching + paged KV,
/// each with its own busy clock (the pool stage is paced by the slowest
/// node of each micro-batch).
pub struct AttentionPool {
    nodes: Vec<AttnNode>,
    /// Per-node cumulative busy seconds (per-node clocks).
    node_busy: Vec<f64>,
    /// Output tokens produced by each node (router spread).
    node_tokens: Vec<u64>,
    /// Total output tokens decoded by the pool.
    decoded_tokens: u64,
    /// Per-node straggler multiplier on the node's stage time (fault
    /// injection; 1.0 = healthy, and multiplying by 1.0 is bit-exact, so
    /// an injection-free run is unchanged).
    slow: Vec<f64>,
}

impl AttentionPool {
    fn new(n_a: usize, node_batch: usize, kv_tokens: u64) -> Self {
        let nodes = (0..n_a)
            .map(|_| AttnNode {
                batcher: ContinuousBatcher::new(SchedulerConfig {
                    max_batch: node_batch,
                }),
                kv: BlockAllocator::new(KvCacheConfig {
                    block_size: KV_BLOCK as usize,
                    num_blocks: (kv_tokens / KV_BLOCK) as usize,
                }),
                backlog: VecDeque::new(),
            })
            .collect();
        Self {
            nodes,
            node_busy: vec![0.0; n_a],
            node_tokens: vec![0u64; n_a],
            decoded_tokens: 0,
            slow: vec![1.0; n_a],
        }
    }

    fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Iteration-boundary admission on every node.
    fn admit_all(&mut self, now: f64) {
        for n in &mut self.nodes {
            n.batcher.admit(&mut n.kv, now);
        }
    }

    fn batch_total(&self) -> usize {
        self.nodes.iter().map(|n| n.batcher.batch.len()).sum()
    }

    fn waiting_total(&self) -> usize {
        self.nodes.iter().map(|n| n.batcher.waiting.len()).sum()
    }

    fn has_work(&self) -> bool {
        self.nodes.iter().any(|n| n.batcher.has_work())
    }

    /// Requests parked on the inline-prefill backlogs (colocated mode).
    fn backlog_requests(&self) -> usize {
        self.nodes.iter().map(|n| n.backlog.len()).sum()
    }

    /// Park a request on `node`'s inline-prefill backlog (callers
    /// guarantee a non-empty prompt).
    fn enqueue_prefill(&mut self, node: usize, req: usize, tokens: usize) {
        debug_assert!(tokens > 0, "empty prompts skip inline prefill");
        self.nodes[node].backlog.push_back((req, tokens));
    }

    /// Submit a prefill-complete request to `node`'s decode batcher.
    fn submit_to(&mut self, node: usize, r: Request) {
        self.nodes[node].batcher.submit(r);
    }

    /// KV blocks currently allocated across the pool (leak accounting).
    fn allocated_kv_blocks(&self) -> u64 {
        self.nodes.iter().map(|n| n.kv.allocated_blocks() as u64).sum()
    }

    /// Colocated inline chunked prefill: take up to `chunk` prompt tokens
    /// off each node's backlog for this iteration (packing across request
    /// boundaries), pricing each node's pass via `time(tokens, mean_ctx)`
    /// — the per-layer chunk cost charged on top of the decode layer time.
    /// Fills the caller's recycled per-node `node_time`/`finish` buffers
    /// (pre-sized and cleared) and returns the tokens taken pool-wide.
    // msi-lint: hot
    fn advance_prefill(
        &mut self,
        chunk: usize,
        now: f64,
        table: &mut RequestTable,
        time: &dyn Fn(f64, f64) -> f64,
        node_time: &mut [f64],
        finish: &mut [Vec<usize>],
    ) -> u64 {
        let mut tokens = 0u64;
        for (nid, node) in self.nodes.iter_mut().enumerate() {
            let (total, mean_ctx) =
                take_prefill_chunk(&mut node.backlog, chunk, now, table, &mut finish[nid]);
            if total > 0 {
                node_time[nid] = time(total as f64, mean_ctx);
                tokens += total as u64;
            }
        }
        tokens
    }

    /// Live-batch mean sequence length, weighted by per-node batch size.
    fn avg_seq(&self) -> f64 {
        let total = self.batch_total();
        if total == 0 {
            return 1.0;
        }
        let sum: f64 = self
            .nodes
            .iter()
            .map(|n| n.batcher.batch.avg_seq_len() * n.batcher.batch.len() as f64)
            .sum();
        (sum / total as f64).max(1.0)
    }

    /// Per-node micro-batch splits for this iteration, written into the
    /// recycled `share` buffers (inner capacity survives across
    /// iterations, so the steady state does not allocate).
    // msi-lint: hot
    fn splits_into(&self, m: usize, share: &mut Vec<Vec<usize>>) {
        // msi-lint: allow(hot-path-alloc) -- grow-once: allocates only on the first iteration after a topology change
        share.resize_with(self.nodes.len(), Vec::new);
        for (n, s) in self.nodes.iter().zip(share.iter_mut()) {
            n.batcher.batch.micro_batch_sizes_into(m, s);
        }
    }

    /// Attention stage time for hop `mb`: the slowest node paces the pool;
    /// each node's own clock is charged its actual share. Hop 0 of a
    /// colocated iteration additionally carries the iteration's inline
    /// chunked-prefill passes: decode and chunk run back to back on each
    /// group, so the pace is the per-node max of `t_a(share) + chunk
    /// time` (not the sum of the two maxima — the slowest decode node and
    /// the heaviest chunk may be different groups).
    // msi-lint: hot
    fn hop_t_a(&mut self, stage: &StageCtx, mb: usize) -> f64 {
        // Empty-micro-batch floor: a hop with b_a = 0 still paces at k2
        // while any decode is live (the historical behavior the Eq. 4–6
        // anchors pin); per-node totals can only raise this.
        let mut pace = if stage.has_decode {
            stage.pm.t_a(stage.b_a[mb])
        } else {
            0.0
        };
        for (n, busy) in self.node_busy.iter_mut().enumerate() {
            let share = stage.share[n][mb];
            let extra = if mb == 0 { stage.prefill_node_time[n] } else { 0.0 };
            let mut t = extra;
            if share > 0 {
                t += stage.pm.t_a(share as f64);
            }
            // Injected straggler: the node's own work runs `slow[n]`×
            // slower (its clock is charged the slowed time, and a slow
            // node can pace the whole pool — exactly the fault mode §6's
            // re-balancing cannot fix on the attention side).
            t *= self.slow[n];
            if t > 0.0 {
                *busy += t;
            }
            pace = pace.max(t);
        }
        pace
    }

    /// Fault injection: tear down `node`, pushing every request it held —
    /// live decode batch, admission queue, and inline-prefill backlog —
    /// onto `slots` for re-admission. The batch's KV blocks are released
    /// (the waiting queue and backlog hold none). Returns `(lost KV
    /// blocks, lost decoded tokens)` for the conservation counters.
    fn drain_node(&mut self, nid: usize, slots: &mut Vec<usize>) -> (u64, u64) {
        let node = &mut self.nodes[nid];
        let mut lost_blocks = 0u64;
        let mut lost_tokens = 0u64;
        for r in node.batcher.batch.requests.drain(..) {
            lost_tokens += r.decoded as u64;
            lost_blocks += node.kv.release(r.id) as u64;
            slots.push(r.id as usize);
        }
        for r in node.batcher.waiting.drain(..) {
            slots.push(r.id as usize);
        }
        for (slot, _) in node.backlog.drain(..) {
            slots.push(slot);
        }
        (lost_blocks, lost_tokens)
    }

    /// Probe whether the pool can macro-step: returns the number of
    /// consecutive decode iterations guaranteed to produce **no**
    /// externally-visible per-request event (no admission, no first
    /// token, no completion, no KV out-of-memory), filling `scratch`
    /// with the per-node batch sizes and integer sequence-length sums
    /// the closed-form average-sequence replay reads. Returns 0 when no
    /// such span exists.
    ///
    /// The span bound is a min-scan over remaining output tokens: a
    /// request with `remaining = r` completes at the end of the `r`-th
    /// iteration from here, so the first `min(remaining) - 1` iterations
    /// are completion-free. Everything else the boundary does per
    /// iteration is provably inert over that window: admission needs a
    /// non-empty waiting queue, first-token accounting needs a request
    /// with `decoded == 0`, and the KV appends cannot fail when the free
    /// list covers the whole span's block growth up front.
    // msi-lint: hot
    fn span_probe(&self, scratch: &mut SpanScratch) -> u64 {
        scratch.len.clear();
        scratch.seq_sum.clear();
        scratch.total = 0;
        if self.waiting_total() != 0 || self.backlog_requests() != 0 {
            return 0;
        }
        let mut r_min = usize::MAX;
        for node in &self.nodes {
            let mut sum = 0u64;
            for r in &node.batcher.batch.requests {
                if r.decoded == 0 {
                    // First token due next iteration: TTFT must record.
                    return 0;
                }
                r_min = r_min.min(r.remaining);
                sum += r.seq_len as u64;
            }
            scratch.len.push(node.batcher.batch.len() as u64);
            scratch.seq_sum.push(sum);
            scratch.total += node.batcher.batch.len() as u64;
        }
        if scratch.total == 0 || r_min < 2 {
            return 0;
        }
        let k = (r_min - 1) as u64;
        for (len, sum) in scratch.len.iter().zip(&scratch.seq_sum) {
            // The closed-form replay casts `sum + len·i` to f64; above
            // 2^52 that cast could round where the stepwise per-request
            // f64 summation would not. Unreachable for any realistic
            // batch, but refuse to arm rather than risk a ULP.
            if sum + len * k >= (1u64 << 52) {
                return 0;
            }
        }
        for node in &self.nodes {
            let mut extra = 0usize;
            for r in &node.batcher.batch.requests {
                let Some(tokens) = node.kv.tokens_of(r.id) else {
                    return 0;
                };
                extra += node.kv.extra_blocks_for(tokens, k as usize);
            }
            if extra > node.kv.free_blocks() {
                // The span could run a node out of KV blocks; stepwise
                // append-OOM behavior (silently tolerated per iteration)
                // must be reproduced exactly, so step instead.
                return 0;
            }
        }
        k
    }

    /// Closed-form [`AttentionPool::avg_seq`] after `advanced` un-flushed
    /// macro-stepped iterations: every live request's sequence grows by
    /// exactly one token per iteration, so node `n` averages `(S_n +
    /// len_n·advanced) / len_n`. Integer sums below 2^52 cast to f64
    /// exactly, and f64 summation of integer-valued terms is exact, so
    /// this is bit-identical to scanning the (hypothetically advanced)
    /// batch — the probe guarantees the magnitude bound.
    // msi-lint: hot
    fn bulk_avg_seq(&self, scratch: &SpanScratch, advanced: u64) -> f64 {
        debug_assert!(scratch.total > 0, "armed span over an empty batch");
        let mut sum = 0.0f64;
        for (len, s0) in scratch.len.iter().zip(&scratch.seq_sum) {
            if *len == 0 {
                // An empty node contributes `0.0 * 0.0 = +0.0`, which is
                // bit-neutral on the non-negative running sum.
                continue;
            }
            let a = (s0 + len * advanced) as f64 / *len as f64;
            sum += a * *len as f64;
        }
        (sum / scratch.total as f64).max(1.0)
    }

    /// Apply `k` macro-stepped iterations' per-request effects in bulk:
    /// each live request decodes `k` tokens (sequence, decoded and
    /// remaining counters move by `k`), its KV grows `k` tokens (the
    /// probe prechecked the block headroom), and the per-node token
    /// counters advance by `batch·k` — element-for-element what `k`
    /// passes of [`AttentionPool::finish_node_iteration`] would do to a
    /// completion-free batch. Block *identities* can differ from the
    /// stepwise interleaving (the free list pops in a different order);
    /// identities never reach any report, only counts do.
    // msi-lint: hot
    fn flush_span(&mut self, k: u64) {
        if k == 0 {
            return;
        }
        for (nid, node) in self.nodes.iter_mut().enumerate() {
            let AttnNode { batcher, kv, .. } = node;
            let len = batcher.batch.len() as u64;
            for r in &mut batcher.batch.requests {
                r.seq_len += k as usize;
                r.decoded += k as usize;
                debug_assert!(r.remaining > k as usize, "span crossed a completion");
                r.remaining -= k as usize;
                let ok = kv.bulk_append(r.id, k as usize);
                debug_assert!(ok, "span precheck guarantees block headroom");
            }
            self.node_tokens[nid] += len * k;
            self.decoded_tokens += len * k;
        }
    }

    /// End-of-iteration bookkeeping for one node: extend KV, retire
    /// finished requests, report first-token and completion ids.
    // msi-lint: hot
    fn finish_node_iteration(&mut self, nid: usize) -> NodeIterOutcome {
        let node = &mut self.nodes[nid];
        let tokens = node.batcher.batch.len() as u64;
        let first: Vec<u64> = node
            .batcher
            .batch
            .requests
            .iter()
            .filter(|r| r.decoded == 0)
            .map(|r| r.id)
            // msi-lint: allow(hot-path-alloc) -- bounded by new admissions this iteration; empty (no alloc) in steady-state decode
            .collect();
        let done = node.batcher.complete_iteration(&mut node.kv);
        self.node_tokens[nid] += tokens;
        self.decoded_tokens += tokens;
        NodeIterOutcome { first, done }
    }
}

impl Component for AttentionPool {
    fn handle(&mut self, now: f64, ev: &Event, ctx: &mut SimCtx, out: &mut Vec<(f64, Event)>) {
        let Event::KvArrive { req, node } = *ev else { return };
        ctx.table.advance(req, RequestPhase::Decode, now);
        // The clone the batcher owns carries the table *slot* as its live
        // id, so KV accounting and completion callbacks come back
        // slot-keyed; slots are unique among in-flight requests and only
        // recycled after completion.
        let mut r = *ctx.table.get(req);
        r.id = req as u64;
        self.nodes[node].batcher.submit(r);
        // A KV arrival while the pool is idle re-arms the iteration clock.
        if !ctx.in_iteration && !ctx.iter_pending {
            ctx.iter_pending = true;
            out.push((now, Event::IterBegin));
        }
    }
}

// ------------------------------------------------------------- M2N link --

/// The M2N transfer component: analytic Eq. 6 bandwidth model or the
/// simnet-calibrated affine [`TransferModel`], plus end-to-end token-copy
/// conservation counters (every dispatched copy must come back).
pub struct M2nLink {
    transfer: Option<TransferModel>,
    top_k: usize,
    /// NIC-degradation multiplier on every transfer time over this link —
    /// M2N dispatch/combine and the prefill→decode KV shipment (fault
    /// injection; 1.0 = healthy, bit-exact no-op).
    degrade: f64,
    /// Token copies handed to the link on the dispatch direction.
    pub dispatched_copies: u64,
    /// Token copies handed back on the combine direction.
    pub combined_copies: u64,
}

impl M2nLink {
    fn new(transfer: Option<TransferModel>, top_k: usize) -> Self {
        Self {
            transfer,
            top_k,
            degrade: 1.0,
            dispatched_copies: 0,
            combined_copies: 0,
        }
    }

    /// One-direction transfer time for hop `mb` given the hottest expert
    /// node's token load.
    // msi-lint: hot
    fn hop_t_c(&self, stage: &StageCtx, mb: usize, hot_tokens: f64) -> f64 {
        let base = match &self.transfer {
            None => stage.pm.t_c(stage.b_a[mb], hot_tokens),
            Some(tm) => {
                let pair_bytes = stage.pm.send_bytes(stage.b_a[mb]) / tm.receivers as f64;
                tm.latency(pair_bytes)
            }
        };
        base * self.degrade
    }
}

impl Component for M2nLink {
    fn handle(&mut self, _now: f64, ev: &Event, ctx: &mut SimCtx, _out: &mut Vec<(f64, Event)>) {
        let Event::Pipe(pe) = ev else { return };
        let Some(stage) = ctx.stage.as_ref() else {
            return;
        };
        match *pe {
            PipeEvent::Dispatch { mb, .. } => {
                self.dispatched_copies += (stage.tok[mb] * self.top_k) as u64;
            }
            PipeEvent::Combine { mb, .. } => {
                self.combined_copies += (stage.tok[mb] * self.top_k) as u64;
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------- expert pool --

/// The expert pool: per-rank clocks, popularity-driven gating draws
/// through the production `softmax_topk`/`build_dispatch` path, static or
/// re-balanced expert placement, and §6 greedy redundancy balancing.
pub struct ExpertPool {
    experts: usize,
    n_e: usize,
    top_k: usize,
    popularity: ExpertPopularity,
    /// Base popularity weights (None for `Ideal` round-robin placement).
    weights: Option<Vec<f64>>,
    /// Scratch for the (possibly drifted) weights of the current draw.
    scratch: Vec<f64>,
    /// §6 oracle: re-balance every micro-batch from the observed loads.
    oracle_balance: bool,
    /// Periodic re-balancing placement (None = static expert->node map).
    placement: Option<ExpertPlacement>,
    /// Observed per-expert token loads since the last rebalance.
    observed: Vec<f64>,
    /// Per-expert-node cumulative busy seconds (per-rank clocks).
    node_busy: Vec<f64>,
    /// Recycled per-hop scratch: per-expert token loads of the current draw.
    loads: Vec<f64>,
    /// Recycled per-hop scratch: per-node token loads of the current draw.
    node_load: Vec<f64>,
    /// Token copies that completed expert compute.
    pub processed_copies: u64,
    /// Number of `Rebalance` events applied.
    pub rebalances: u64,
    /// Number of elastic pool resizes applied (fault injection).
    pub resizes: u64,
}

impl ExpertPool {
    fn new(
        experts: usize,
        n_e: usize,
        top_k: usize,
        popularity: ExpertPopularity,
        weights: Option<Vec<f64>>,
        oracle_balance: bool,
    ) -> Self {
        Self {
            experts,
            n_e,
            top_k,
            popularity,
            weights,
            scratch: Vec::with_capacity(experts),
            oracle_balance,
            placement: None,
            observed: vec![0.0; experts],
            node_busy: vec![0.0; n_e],
            loads: Vec::with_capacity(experts),
            node_load: vec![0.0; n_e],
            processed_copies: 0,
            rebalances: 0,
            resizes: 0,
        }
    }

    /// Elastic shrink/grow of the expert pool to `n_e` nodes, with an
    /// immediate §6 greedy re-placement over the new node count (the same
    /// rule the periodic `Rebalance` handler applies): from the loads
    /// observed since the last re-placement when there are any, else from
    /// uniform weights — experts must land *somewhere* on the resized
    /// pool even before traffic has been seen. Ideal (round-robin)
    /// popularity keeps its implicit `e % n_e` map and only changes the
    /// divisor. Node clocks of surviving ranks are preserved; new ranks
    /// start cold.
    /// `counted` gates the `resizes` report counter: in a sharded run
    /// every shard resizes its slice of the pool, but only one copy of
    /// the broadcast injection counts, so merged totals match unsharded.
    fn resize(&mut self, n_e: usize, counted: bool) {
        let n_e = n_e.max(1);
        self.n_e = n_e;
        self.node_busy.resize(n_e, 0.0);
        self.node_load.resize(n_e, 0.0);
        if self.weights.is_some() {
            let total: f64 = self.observed.iter().sum();
            if total > 0.0 {
                let cold = 0.1 * total / self.experts as f64;
                self.placement = Some(balance_experts(&self.observed, n_e, cold));
            } else {
                let uniform = vec![1.0; self.experts];
                self.placement = Some(balance_experts(&uniform, n_e, 0.0));
            }
            for o in &mut self.observed {
                *o = 0.0;
            }
        }
        if counted {
            self.resizes += 1;
        }
    }

    /// Fill `scratch` with the popularity weights in effect at virtual time
    /// `now` (drifting Zipf rotates which experts are hot as time passes).
    // msi-lint: hot
    fn refresh_weights(&mut self, now: f64) {
        // msi-lint: allow(unwrap-in-engine) -- hop_t_e calls this only behind its weights.is_none() early return
        let w = self.weights.as_ref().expect("weighted popularity");
        let rot = match self.popularity {
            ExpertPopularity::ZipfDrifting { period, .. } if period > 0.0 => {
                (now / period) as usize % self.experts
            }
            _ => 0,
        };
        self.scratch.clear();
        self.scratch
            .extend((0..self.experts).map(|i| w[(i + rot) % self.experts]));
    }

    /// Expert stage time for hop `mb`: the hottest expert node paces the
    /// stage; per-rank clocks charge each node its own share. Returns
    /// `(stage_time, hot_tokens)` — the latter also feeds the M2N model.
    // msi-lint: hot
    fn hop_t_e(
        &mut self,
        stage: &StageCtx,
        rng: &mut SimRng,
        now: f64,
        mb: usize,
    ) -> (f64, f64) {
        let tok = stage.tok[mb];
        let dispatched = tok * self.top_k;
        if self.weights.is_none() {
            // Ideal: exact round-robin balance across expert nodes.
            let hot = dispatched.div_ceil(self.n_e) as f64;
            let dur = stage.pm.t_e(hot) + stage.extra_weight_loads;
            for busy in &mut self.node_busy {
                *busy += dur;
            }
            return (dur, hot);
        }
        self.refresh_weights(now);
        let g = draw_gating(rng, tok, &self.scratch, self.top_k);
        let dp = build_dispatch(&g, self.experts);
        // Recycled scratch: `loads`/`node_load` keep their capacity across
        // hops, so the per-hop gating draw stays allocation-free.
        self.loads.clear();
        self.loads
            .extend((0..self.experts).map(|e| dp.expert_load(e) as f64));
        for (o, l) in self.observed.iter_mut().zip(&self.loads) {
            *o += *l;
        }
        self.node_load.clear();
        self.node_load.resize(self.n_e, 0.0);
        match &self.placement {
            Some(p) => p.node_loads_into(&self.loads, &mut self.node_load),
            None => {
                for (e, l) in self.loads.iter().enumerate() {
                    self.node_load[e % self.n_e] += *l;
                }
            }
        }
        let hot = if self.oracle_balance {
            let mean = self.node_load.iter().sum::<f64>() / self.n_e as f64;
            balance_experts(&self.node_load, self.n_e, 0.1 * mean).makespan
        } else {
            self.node_load.iter().copied().fold(0.0, f64::max)
        };
        for (j, busy) in self.node_busy.iter_mut().enumerate() {
            if self.node_load[j] > 0.0 {
                *busy += stage.pm.t_e(self.node_load[j]) + stage.extra_weight_loads;
            }
        }
        (stage.pm.t_e(hot) + stage.extra_weight_loads, hot)
    }
}

impl Component for ExpertPool {
    fn handle(&mut self, _now: f64, ev: &Event, ctx: &mut SimCtx, _out: &mut Vec<(f64, Event)>) {
        match ev {
            Event::Rebalance => {
                // §6 greedy redundancy re-placement from the loads observed
                // since the previous rebalance (the online analogue of the
                // per-micro-batch oracle).
                let total: f64 = self.observed.iter().sum();
                if total > 0.0 {
                    let cold = 0.1 * total / self.experts as f64;
                    self.placement = Some(balance_experts(&self.observed, self.n_e, cold));
                    self.rebalances += 1;
                    for o in &mut self.observed {
                        *o = 0.0;
                    }
                }
            }
            Event::Pipe(PipeEvent::ExpertDone { mb, .. }) => {
                if let Some(stage) = ctx.stage.as_ref() {
                    self.processed_copies += (stage.tok[*mb] * self.top_k) as u64;
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------- engine --

/// Per-tenant accumulator.
struct TenantAcc {
    completed: u64,
    ttft: Histogram,
    ttft_queue: Histogram,
    ttft_prefill: Histogram,
    ttft_transfer: Histogram,
    ttft_decode: Histogram,
    e2e: Histogram,
}

impl TenantAcc {
    fn new() -> Self {
        Self {
            completed: 0,
            ttft: Histogram::new(),
            ttft_queue: Histogram::new(),
            ttft_prefill: Histogram::new(),
            ttft_transfer: Histogram::new(),
            ttft_decode: Histogram::new(),
            e2e: Histogram::new(),
        }
    }
}

/// Outcome of one [`ClusterEngine::begin_iteration_once`] pass, driving
/// the macro-step loop in [`ClusterEngine::begin_iteration`].
enum IterOutcome {
    /// The engine went idle, the stepwise path scheduled its hops, or a
    /// horizon overrun parked the iteration: return to the event queue.
    Yield,
    /// A fused iteration completed at the carried time with its stats
    /// parked: the driver may process its `IterEnd` inline.
    Fused(f64),
}

/// Outcome of a [`ClusterEngine::bulk_span`] attempt.
enum SpanExit {
    /// Events were scheduled (span-ending `IterEnd`, horizon overrun):
    /// return to the event queue.
    Yield,
    /// No span was armed, or the span committed fully and flushed: the
    /// driver continues with a full iteration pass.
    Continue,
}

/// Reusable engine allocations for back-to-back runs — the `msi sweep`
/// cell loop keeps one per worker thread so every cell recycles the
/// request-table slab, the pipeline core and the engine's scratch
/// vectors instead of reallocating them. Adoption is behavior-neutral:
/// each buffer is reset to its `new()` state (only capacity survives),
/// so recycled and fresh runs produce byte-identical reports — pinned by
/// `sweep_is_deterministic_across_worker_counts` and the alloc-counter
/// harness.
#[derive(Default)]
pub struct EngineScratch {
    table: RequestTable,
    core: Option<PipelineCore>,
    fused: FusedQueue,
    span: SpanScratch,
    pipe: Vec<(f64, PipeEvent)>,
    out: Vec<(f64, Event)>,
    requeue: Vec<usize>,
}

/// The end-to-end cluster engine: components wired onto one event queue,
/// pulling arrivals one at a time from an [`ArrivalSource`].
pub struct ClusterEngine {
    cfg: ClusterSimConfig,
    source: Box<dyn ArrivalSource>,
    q: EventQueue<Event>,
    ctx: SimCtx,
    router: RouterFront,
    /// Dedicated prefill pool (None = prefill modeling off, or colocated
    /// mode where groups prefill inline).
    prefill: Option<PrefillPool>,
    /// GPUs per prefill node (the per-GPU-throughput divisor includes the
    /// pool).
    prefill_tp: usize,
    /// Once-built roofline for colocated inline chunked-prefill passes
    /// (None when inline prefill can never run). Hoisted out of the
    /// per-iteration `ColocatedModel` rebuild: it does not depend on the
    /// live batch.
    inline_prefill_model: Option<PrefillModel>,
    /// Aggregate NIC bandwidth of the narrower end of the prefill→decode
    /// KV link, bytes/s.
    kv_link_bw: f64,
    /// KV transfers currently on the wire.
    in_transfer: usize,
    /// Prompt tokens shipped over the prefill→decode link.
    kv_transferred_tokens: u64,
    /// Prompt tokens chunk-prefilled inline on colocated groups.
    inline_prefilled_tokens: u64,
    attention: AttentionPool,
    link: M2nLink,
    experts: ExpertPool,
    pipeline: Option<PipelineCore>,
    /// Recycled pipeline core: a completed iteration parks its core here
    /// so the next `IterBegin` resets it in place instead of reallocating
    /// the per-(micro-batch, layer) state.
    spare: Option<PipelineCore>,
    /// Recycled stage context — its per-iteration buffers (`share`,
    /// `b_a`, `tok`, prefill lists) keep their capacity across iterations.
    stage_spare: Option<StageCtx>,
    /// Reusable iteration-stats buffer: the stepwise path fills it on the
    /// last hop, the fused path at `IterBegin` (it then carries the
    /// pending stats until the `IterEnd` pop); `end_iteration` borrows it.
    iter_stats: Option<PipelineStats>,
    /// Local replay queue of the fused fast path (reused every iteration).
    fused: FusedQueue,
    /// Recycled macro-step span scratch (per-node sums at span start).
    span: SpanScratch,
    /// Reusable buffer for pipe events emitted by the core.
    pipe_scratch: Vec<(f64, PipeEvent)>,
    /// Cached attention-GPU spec ([`ClusterSpec::attention_gpu`] clones a
    /// name `String`; the per-iteration `set_avg_seq` refresh must not).
    ///
    /// [`ClusterSpec::attention_gpu`]: crate::config::ClusterSpec::attention_gpu
    attn_gpu: GpuSpec,
    /// Engine-internal events (`Pipe`, `Rebalance`, `IterEnd`) currently
    /// in the queue — subtracted from the peak-events sample so the
    /// metric counts workload-driven events only and is identical between
    /// fused and stepwise runs.
    internal: usize,
    /// High-water mark of workload-driven events in the queue.
    peak_events: usize,
    /// Reusable scratch buffer for events emitted by component handlers —
    /// held on the engine (rather than rebuilt per step batch) so
    /// steady-state event dispatch does not allocate.
    out: Vec<(f64, Event)>,
    /// The run hit its `max_sim_seconds` horizon: stepping is over even
    /// though events may remain queued.
    cut: bool,
    // fault / elasticity injection
    /// Per-attention-node down flags (mirrors the router's placement
    /// exclusion; also intercepts KV arrivals to a dead node).
    node_down: Vec<bool>,
    /// Injection indices that fired mid-iteration, deferred to the next
    /// iteration boundary: the fused path replays a whole iteration
    /// inside `IterBegin`, so mutating pool state between hops would
    /// desync it from stepwise — quantizing every injection to the
    /// boundary keeps the two modes byte-identical.
    pending_inject: Vec<usize>,
    /// Recycled scratch for slots drained off a failed node.
    requeue_scratch: Vec<usize>,
    /// Injections applied (deferred firings count when applied).
    injections_applied: u64,
    /// Attention-node failures applied (redundant fails are no-ops).
    node_failures: u64,
    /// Attention-node recoveries applied (redundant recovers are no-ops).
    node_recoveries: u64,
    /// Requests re-admitted through the front door after losing their
    /// node (or their in-flight KV shipment's destination).
    requeued_requests: u64,
    /// KV blocks freed by failures — `kv_blocks_in_use_at_end` stays a
    /// pure leak detector because lost blocks are released on the spot.
    lost_kv_blocks: u64,
    /// Output tokens that had been decoded by requests a failure
    /// displaced (`tokens = Σ output_len(completed) + lost_decode_tokens`
    /// at quiescence).
    lost_decode_tokens: u64,
    /// Prompt tokens queued for a second prefill after a failure
    /// (`prefilled_tokens = Σ input_len(completed) + re_prefilled_tokens`
    /// at quiescence with the dedicated pool on).
    re_prefilled_tokens: u64,
    // metrics
    ttft: Histogram,
    ttft_queue: Histogram,
    ttft_prefill: Histogram,
    ttft_transfer: Histogram,
    ttft_decode: Histogram,
    tpot: Histogram,
    e2e: Histogram,
    attn_util: Utilization,
    expert_util: Utilization,
    tenant_stats: Vec<TenantAcc>,
    completed: u64,
    iterations: u64,
    next_rebalance: f64,
    elapsed: f64,
}

impl ClusterEngine {
    /// KV-token capacity of one attention node (Eq. 8 budget) — or, in
    /// colocated mode, of one monolithic serving group (whose memory also
    /// holds every expert's parameters).
    fn node_kv_tokens(cfg: &ClusterSimConfig) -> u64 {
        if let EngineMode::Colocated(cp) = &cfg.mode {
            return cp.group_kv_tokens(&cfg.model, &cfg.cluster);
        }
        let gpu = cfg.cluster.attention_gpu();
        let budget = cfg.plan.tp_a as f64 * gpu.mem_bytes() - cfg.model.attn_param_bytes();
        (budget.max(0.0) / cfg.model.kv_bytes_per_token()).floor() as u64
    }

    /// Build the engine over a pull-based arrival stream. The engine never
    /// materializes the stream: it holds only in-flight requests.
    pub fn new(mut cfg: ClusterSimConfig, source: Box<dyn ArrivalSource>) -> Self {
        // A non-positive interval would never advance the rebalance clock,
        // and a non-positive horizon would silently drop every event —
        // both degrade to "off".
        cfg.rebalance_period = cfg.rebalance_period.filter(|p| *p > 0.0);
        cfg.max_sim_seconds = cfg.max_sim_seconds.filter(|h| *h > 0.0);
        // A zero chunk budget disables prefill modeling entirely; a
        // zero-node pool likewise (legacy instant-KV admission).
        if cfg.prefill_chunk == 0 {
            cfg.prefill_nodes = 0;
        }
        // Colocated baselines have no separate expert stage or M2N link:
        // expert compute and the (unoverlapped) all-to-all live inside the
        // layer time, so popularity draws, simnet transport and §6
        // re-balancing do not apply — normalize them off so same-seed runs
        // are identical however the caller filled those fields. Prefill
        // runs INLINE on the serving groups (keyed off `prefill_chunk`),
        // never on a dedicated pool.
        if matches!(cfg.mode, EngineMode::Colocated(_)) {
            cfg.popularity = ExpertPopularity::Ideal;
            cfg.transport = Transport::Analytic;
            cfg.rebalance_period = None;
            cfg.prefill_nodes = 0;
            // Fault injection targets the disaggregated pools (attention
            // nodes, the M2N/KV links, the elastic expert pool); none of
            // those exist as separate entities in a colocated group, and
            // a half-prefilled backlog prompt would break the re-prefill
            // conservation identity — normalize injections off.
            cfg.injections.clear();
        }
        let n_a = cfg.plan.n_a.max(1);
        let n_e = cfg.plan.n_e.max(1);
        let experts = cfg.model.experts.max(1);
        let top_k = cfg.model.top_k.clamp(1, experts);

        // --- deterministic random streams -------------------------------
        let mut perm_rng = SimRng::new(cfg.seed ^ 0x5bd1_e995_u64);
        let rng = SimRng::new(cfg.seed);
        let (weights, oracle_balance) = match cfg.popularity {
            ExpertPopularity::Ideal => (None, false),
            ExpertPopularity::Uniform => {
                (Some(popularity_weights(experts, 0.0, &mut perm_rng)), false)
            }
            ExpertPopularity::Zipf(a) => {
                (Some(popularity_weights(experts, a, &mut perm_rng)), false)
            }
            ExpertPopularity::ZipfBalanced(a) => {
                (Some(popularity_weights(experts, a, &mut perm_rng)), true)
            }
            ExpertPopularity::ZipfDrifting { alpha, .. } => {
                (Some(popularity_weights(experts, alpha, &mut perm_rng)), false)
            }
        };

        // --- transport --------------------------------------------------
        let transfer = match cfg.transport {
            Transport::Analytic => None,
            Transport::Simnet(kind) => Some(TransferModel::calibrate(
                &LibraryProfile::of(kind),
                (n_a * cfg.plan.tp_a).max(1),
                (n_e * cfg.plan.tp_e).max(1),
                cfg.seed,
            )),
        };

        // --- attention pool + router ------------------------------------
        // Eq. 8 capacity, capped at the stream's total demand (plus one
        // block per request for partial-block rounding): capacity beyond
        // what the whole workload can ever occupy is unreachable, and not
        // materializing it keeps the block allocator small. Sources report
        // the demand without materializing the stream (generators replay
        // their RNG, stopping once the hardware budget is reached), so a
        // trace and a generator yielding the same requests size the
        // allocator identically.
        let node_kv = Self::node_kv_tokens(&cfg);
        let kv_tokens = node_kv.min(source.kv_demand(node_kv).max(16));
        let router = Router::new(cfg.route, &vec![kv_tokens; n_a]);
        let node_batch = cfg.plan.global_batch.div_ceil(n_a).max(1);

        let tenant_stats = cfg.tenants.iter().map(|_| TenantAcc::new()).collect();

        // --- prefill pool + KV link -------------------------------------
        let attn_gpu = cfg.cluster.attention_gpu();
        let prefill_tp = if cfg.plan.tp_p > 0 {
            cfg.plan.tp_p
        } else {
            prefill_node_gpus(&cfg.model, &cfg.cluster)
        };
        let prefill = (cfg.prefill_nodes > 0).then(|| {
            PrefillPool::new(
                cfg.prefill_nodes,
                cfg.prefill_chunk,
                cfg.model.layers.max(1),
                PrefillModel::new(&cfg.model, &attn_gpu, prefill_tp),
            )
        });
        let inline_prefill_model = match &cfg.mode {
            EngineMode::Colocated(cp) if cfg.prefill_chunk > 0 => {
                Some(ColocatedModel::prefill_model(cp, &cfg.model, &cfg.cluster))
            }
            _ => None,
        };
        // The KV shipment bottleneck is the narrower end of the link: the
        // sending prefill node's or the receiving decode node's aggregate
        // NIC rate (per-request transfers are independent; cross-request
        // wire contention is not modeled).
        let kv_link_bw =
            attn_gpu.nic_gbps * 1e9 / 8.0 * cfg.plan.tp_a.max(1).min(prefill_tp) as f64;

        Self {
            source,
            router: RouterFront::new(router, kv_tokens),
            prefill,
            prefill_tp,
            inline_prefill_model,
            kv_link_bw,
            in_transfer: 0,
            kv_transferred_tokens: 0,
            inline_prefilled_tokens: 0,
            attention: AttentionPool::new(n_a, node_batch, kv_tokens),
            link: M2nLink::new(transfer, top_k),
            experts: ExpertPool::new(experts, n_e, top_k, cfg.popularity, weights, oracle_balance),
            ctx: SimCtx {
                table: RequestTable::new(),
                rng,
                stage: None,
                in_iteration: false,
                iter_pending: false,
                sum_t_a: 0.0,
                sum_t_e: 0.0,
                sum_t_c: 0.0,
                stage_samples: 0,
            },
            q: EventQueue::new(),
            pipeline: None,
            spare: None,
            stage_spare: None,
            iter_stats: Some(PipelineStats::default()),
            fused: FusedQueue::new(),
            span: SpanScratch::default(),
            pipe_scratch: Vec::new(),
            attn_gpu,
            internal: 0,
            peak_events: 0,
            out: Vec::new(),
            cut: false,
            node_down: vec![false; n_a],
            pending_inject: Vec::new(),
            requeue_scratch: Vec::new(),
            injections_applied: 0,
            node_failures: 0,
            node_recoveries: 0,
            requeued_requests: 0,
            lost_kv_blocks: 0,
            lost_decode_tokens: 0,
            re_prefilled_tokens: 0,
            ttft: Histogram::new(),
            ttft_queue: Histogram::new(),
            ttft_prefill: Histogram::new(),
            ttft_transfer: Histogram::new(),
            ttft_decode: Histogram::new(),
            tpot: Histogram::new(),
            e2e: Histogram::new(),
            attn_util: Utilization::new(),
            expert_util: Utilization::new(),
            tenant_stats,
            completed: 0,
            iterations: 0,
            next_rebalance: cfg.rebalance_period.unwrap_or(f64::INFINITY),
            elapsed: 0.0,
            cfg,
        }
    }

    /// Run the engine to quiescence and report.
    pub fn run(mut self) -> ClusterReport {
        self.prime();
        self.step_until(f64::INFINITY);
        self.finalize()
    }

    /// Run to quiescence while recycling allocations through `scratch` —
    /// the `msi sweep` per-worker cell loop. Byte-identical to
    /// [`ClusterEngine::run`]: adopted buffers are reset to fresh state
    /// (only their capacity survives) and stashed back for the next run.
    pub fn run_recycled(mut self, scratch: &mut EngineScratch) -> ClusterReport {
        self.adopt_scratch(scratch);
        self.prime();
        self.step_until(f64::INFINITY);
        let report = self.build_report();
        self.stash_scratch(scratch);
        report
    }

    /// Swap `scratch`'s recycled buffers into the freshly-built engine
    /// (resetting each to its `new()` state first). Call before
    /// [`ClusterEngine::prime`].
    fn adopt_scratch(&mut self, scratch: &mut EngineScratch) {
        scratch.table.reset();
        scratch.fused.clear();
        scratch.pipe.clear();
        scratch.out.clear();
        scratch.requeue.clear();
        std::mem::swap(&mut self.ctx.table, &mut scratch.table);
        std::mem::swap(&mut self.fused, &mut scratch.fused);
        std::mem::swap(&mut self.span, &mut scratch.span);
        std::mem::swap(&mut self.pipe_scratch, &mut scratch.pipe);
        std::mem::swap(&mut self.out, &mut scratch.out);
        std::mem::swap(&mut self.requeue_scratch, &mut scratch.requeue);
        if let Some(core) = scratch.core.take() {
            // `begin_iteration` resets the spare core to this run's
            // (m, layers) in place before first use.
            self.spare = Some(core);
        }
    }

    /// Return the recycled buffers to `scratch` for the next run. Call
    /// only after [`ClusterEngine::build_report`] — the report reads the
    /// table's high-water mark.
    fn stash_scratch(&mut self, scratch: &mut EngineScratch) {
        std::mem::swap(&mut self.ctx.table, &mut scratch.table);
        std::mem::swap(&mut self.fused, &mut scratch.fused);
        std::mem::swap(&mut self.span, &mut scratch.span);
        std::mem::swap(&mut self.pipe_scratch, &mut scratch.pipe);
        std::mem::swap(&mut self.out, &mut scratch.out);
        std::mem::swap(&mut self.requeue_scratch, &mut scratch.requeue);
        if let Some(core) = self.spare.take().or_else(|| self.pipeline.take()) {
            scratch.core = Some(core);
        }
    }

    /// Prime the arrival chain: exactly one future Arrive is outstanding
    /// at any time; each firing pulls and schedules the next, so the
    /// queue never holds the whole trace. Call once before stepping.
    pub(crate) fn prime(&mut self) {
        // Injections first: their insertion sequences precede every
        // runtime event, so at a timestamp tie an `Inject` pops before
        // the hop/IterEnd that shares its time — identically in fused
        // and stepwise modes (both then defer it to the boundary).
        for i in 0..self.cfg.injections.len() {
            let at = self.cfg.injections[i].at.max(0.0);
            // msi-lint: allow(raw-schedule) -- compile-validated non-negative injection times into the engine's own queue
            self.q.schedule_at(at, Event::Inject(i));
        }
        if let Some(r) = self.source.next_request() {
            let at = r.arrival.max(0.0);
            let slot = self.ctx.table.insert(r);
            // msi-lint: allow(raw-schedule) -- engine-owned queue starting at t=0 with arrivals clamped to >= 0 (PR-6 audit)
            self.q.schedule_at(at, Event::Arrive(slot));
        }
    }

    /// Process every queued event with timestamp <= `until` (and within
    /// the configured `max_sim_seconds` horizon). Returns the timestamp of
    /// the earliest still-pending event beyond `until`, or `None` when the
    /// engine is done (quiescent or horizon-cut). The sharded runner steps
    /// engines epoch by epoch through this; `run` calls it once with an
    /// infinite epoch — both paths execute the identical event sequence.
    // msi-lint: hot
    pub(crate) fn step_until(&mut self, until: f64) -> Option<f64> {
        if self.cut {
            return None;
        }
        let mut out = std::mem::take(&mut self.out);
        let horizon = self.cfg.max_sim_seconds.unwrap_or(f64::INFINITY);
        let next = loop {
            let Some(t) = self.q.peek_time() else {
                break None;
            };
            if t > until {
                break Some(t);
            }
            // msi-lint: allow(unwrap-in-engine) -- peek_time returned Some on this queue two lines up; nothing popped since
            let (now, ev) = self.q.pop().expect("peeked event pops");
            if matches!(ev, Event::Pipe(_) | Event::Rebalance | Event::IterEnd) {
                // The event left the queue — decrement before the horizon
                // check so a cut does not strand the counter.
                self.internal -= 1;
            }
            if now > horizon {
                // Horizon cutoff: the popped event is dropped (matching
                // the original run loop) and whatever is still queued
                // reports as `unserved_queued` in the final accounting.
                self.cut = true;
                break None;
            }
            self.elapsed = self.elapsed.max(now);
            match ev {
                Event::Arrive(slot) => self.on_arrive(now, slot, &mut out),
                Event::PrefillPass { node } => self.on_prefill_pass(now, node, &mut out),
                Event::Place { req, node } => self.on_place(now, req, node, &mut out),
                Event::KvArrive { req, node } => self.on_kv_arrive(now, req, node, true, &mut out),
                Event::Rebalance => self.experts.handle(now, &ev, &mut self.ctx, &mut out),
                Event::IterBegin => self.begin_iteration(now, &mut out),
                Event::Pipe(pe) => self.on_pipe(now, pe, &mut out),
                Event::IterEnd => {
                    // msi-lint: allow(unwrap-in-engine) -- IterEnd is only emitted by paths that parked iter_stats first
                    let st = self.iter_stats.take().expect("fused stats pending");
                    self.end_iteration(now, &st, &mut out);
                    self.iter_stats = Some(st);
                }
                Event::Inject(i) => self.on_inject(now, i, &mut out),
            }
            for (at, e) in out.drain(..) {
                if matches!(e, Event::Pipe(_) | Event::Rebalance | Event::IterEnd) {
                    self.internal += 1;
                }
                // msi-lint: allow(raw-schedule) -- handler outputs are now + nonnegative durations into the engine's own queue (PR-6 audit)
                self.q.schedule_at(at, e);
            }
            self.peak_events = self.peak_events.max(self.q.len() - self.internal);
        };
        self.out = out;
        next
    }

    /// One arrival fired: run it through the front door, absorb every
    /// queued arrival sharing its timestamp (this preserves the event
    /// order a preloaded closed-loop burst would have produced), then
    /// schedule the next future arrival to continue the chain.
    fn on_arrive(&mut self, now: f64, slot: usize, out: &mut Vec<(f64, Event)>) {
        self.front_door(now, slot, out);
        while let Some(r) = self.source.next_request() {
            // Sources yield non-decreasing arrival times; clamp defensively
            // so a mis-sorted trace degrades to "arrives now" instead of
            // scheduling into the past.
            let at = r.arrival.max(0.0).max(now);
            let s = self.ctx.table.insert(r);
            if at <= now {
                self.front_door(now, s, out);
            } else {
                out.push((at, Event::Arrive(s)));
                break;
            }
        }
    }

    /// Colocated groups chunk-prefill inline on their own backlogs (no
    /// dedicated pool) — the single source of truth for that predicate.
    fn inline_prefill(&self) -> bool {
        matches!(self.cfg.mode, EngineMode::Colocated(_)) && self.cfg.prefill_chunk > 0
    }

    /// The front door: admission-control reject, then hand the request to
    /// the prefill pool — or straight to the router when prefill runs
    /// inline (colocated), is off, or the prompt is empty (a hand-written
    /// trace can carry `input_len: 0`; there is nothing to prefill, and a
    /// phantom token would break the conservation counters).
    fn front_door(&mut self, now: f64, slot: usize, out: &mut Vec<(f64, Event)>) {
        if self.router.reject_if_infeasible(slot, &mut self.ctx) {
            return;
        }
        match self.prefill.as_mut() {
            Some(pool) if self.ctx.table.get(slot).input_len > 0 => {
                pool.submit(now, slot, &mut self.ctx, out)
            }
            _ => self.router.place_or_queue(now, slot, &mut self.ctx, out),
        }
    }

    /// A prefill node finished a packed pass: route the completed prompts
    /// toward decode nodes and start the node's next pass.
    fn on_prefill_pass(&mut self, now: f64, node: usize, out: &mut Vec<(f64, Event)>) {
        // msi-lint: allow(unwrap-in-engine) -- PrefillPass events are only scheduled when the dedicated pool exists
        let pool = self.prefill.as_mut().expect("prefill pass without a pool");
        let finished = pool.finish_pass(node, now, &mut self.ctx);
        for req in finished {
            self.router.place_or_queue(now, req, &mut self.ctx, out);
        }
        // msi-lint: allow(unwrap-in-engine) -- the pool is engine-owned and never dropped mid-run
        let pool = self.prefill.as_mut().expect("pool still present");
        pool.start_pass(node, now, &mut self.ctx, out);
    }

    /// Router placement decided: run the prompt-KV handoff leg. With the
    /// dedicated pool on, the KV ships over the inter-pool link; colocated
    /// groups instead park the request on the node's inline-prefill
    /// backlog; with prefill modeling off the request reaches the batcher
    /// immediately (zero-length Prefill/KvTransfer phases).
    fn on_place(&mut self, now: f64, req: usize, node: usize, out: &mut Vec<(f64, Event)>) {
        let input_len = self.ctx.table.get(req).input_len;
        if self.inline_prefill() && input_len > 0 {
            self.attention.enqueue_prefill(node, req, input_len);
            if !self.ctx.in_iteration && !self.ctx.iter_pending {
                self.ctx.iter_pending = true;
                out.push((now, Event::IterBegin));
            }
            return;
        }
        if self.ctx.table.phase(req) == RequestPhase::Queued {
            // No prefill ahead of this placement (prefill off, or an empty
            // prompt): zero-length Prefill and KvTransfer phases keep the
            // TTFT decomposition exact.
            self.ctx.table.advance(req, RequestPhase::Prefill, now);
            self.ctx.table.advance(req, RequestPhase::KvTransfer, now);
        }
        if self.prefill.is_some() && input_len > 0 {
            let dur = self.kv_transfer_time(input_len);
            self.in_transfer += 1;
            out.push((now + dur, Event::KvArrive { req, node }));
        } else {
            self.on_kv_arrive(now, req, node, false, out);
        }
    }

    /// Prompt KV landed on the decode node: submit to its batcher.
    fn on_kv_arrive(
        &mut self,
        now: f64,
        req: usize,
        node: usize,
        from_wire: bool,
        out: &mut Vec<(f64, Event)>,
    ) {
        if from_wire {
            self.in_transfer -= 1;
            self.kv_transferred_tokens += self.ctx.table.get(req).input_len as u64;
        }
        if self.node_down[node] {
            // The destination died between placement and KV arrival: the
            // shipment is lost with the node, and the request re-enters
            // the lifecycle at the front door.
            self.requeue(now, req, out);
            return;
        }
        let ev = Event::KvArrive { req, node };
        self.attention.handle(now, &ev, &mut self.ctx, out);
    }

    /// Wire time of one prompt-KV shipment over the prefill→decode link:
    /// the simnet-calibrated [`TransferModel`] when the scenario runs
    /// simnet transport (the same link model the M2N dispatch/combine path
    /// uses), or the analytic NIC bandwidth-utilization curve otherwise.
    fn kv_transfer_time(&self, input_len: usize) -> f64 {
        let bytes = (input_len.max(1) as f64) * self.cfg.model.kv_bytes_per_token();
        let base = match &self.link.transfer {
            Some(tm) => tm.latency(bytes),
            None => {
                bytes / (self.kv_link_bw * bandwidth_util(bytes, self.kv_link_bw, 6e-6)).max(1e-9)
            }
        };
        // An injected NIC degradation slows the KV shipment along with
        // the M2N traffic (same physical links).
        base * self.link.degrade
    }

    // ------------------------------------------- fault / elasticity --

    /// A scheduled injection fired. Outside an iteration it applies on
    /// the spot; mid-iteration it is deferred to the next
    /// `begin_iteration` (before admission) so the fused and stepwise
    /// paths — which interleave hops differently in wall-clock order but
    /// identically in virtual time — observe the state change at the
    /// same point in the event sequence.
    fn on_inject(&mut self, now: f64, idx: usize, out: &mut Vec<(f64, Event)>) {
        if self.ctx.in_iteration {
            self.pending_inject.push(idx);
            return;
        }
        self.apply_injection(now, idx, out);
    }

    /// Apply one injection (always at an iteration boundary or while
    /// idle — never between hops). A sharded run localizes each scenario
    /// injection and marks exactly one shard's copy `counted`, so the
    /// merged `injections_applied`/resize counters match the unsharded
    /// run; the state change itself applies on every receiving shard.
    fn apply_injection(&mut self, now: f64, idx: usize, out: &mut Vec<(f64, Event)>) {
        let inj = self.cfg.injections[idx];
        if inj.counted {
            self.injections_applied += 1;
        }
        match inj.kind {
            FaultKind::FailAttention { node } => self.fail_attention(now, node, out),
            FaultKind::RecoverAttention { node } => {
                if self.node_down[node] {
                    self.node_down[node] = false;
                    self.router.set_node_down(node, false);
                    self.node_recoveries += 1;
                    // The recovered node re-opens placement capacity for
                    // the overflow FIFO right away.
                    self.router.drain_overflow(now, &mut self.ctx, out);
                }
            }
            FaultKind::StraggleAttention { node, factor } => {
                self.attention.slow[node] = factor;
            }
            FaultKind::DegradeNic { factor } => {
                self.link.degrade = factor;
            }
            FaultKind::ResizeExperts { n_e } => {
                self.experts.resize(n_e, inj.counted);
            }
        }
    }

    /// Tear down attention node `node` (idempotent): exclude it from
    /// placement, release its KV, and push every request it held back
    /// through the front door — they re-enter the lifecycle at `Queued`
    /// and (with the dedicated pool on) re-prefill their lost prompt KV.
    fn fail_attention(&mut self, now: f64, node: usize, out: &mut Vec<(f64, Event)>) {
        if self.node_down[node] {
            return;
        }
        self.node_down[node] = true;
        self.router.set_node_down(node, true);
        self.node_failures += 1;
        let mut slots = std::mem::take(&mut self.requeue_scratch);
        slots.clear();
        let (blocks, tokens) = self.attention.drain_node(node, &mut slots);
        self.lost_kv_blocks += blocks;
        self.lost_decode_tokens += tokens;
        for &slot in &slots {
            self.requeue(now, slot, out);
        }
        slots.clear();
        self.requeue_scratch = slots;
    }

    /// Re-admit a fault-displaced request: release its routing
    /// accounting, reset its lifecycle to `Queued`, and walk it through
    /// the front door again. Admission control cannot re-reject it (its
    /// KV footprint was feasible the first time and the bound is static),
    /// so `requeued_requests` never leaks into `rejected`.
    fn requeue(&mut self, now: f64, slot: usize, out: &mut Vec<(f64, Event)>) {
        if let Some(node) = self.ctx.table.take_placed(slot) {
            self.router.complete(node, self.ctx.table.get(slot));
        }
        self.ctx.table.reset_for_retry(slot);
        self.requeued_requests += 1;
        if self.prefill.is_some() && self.ctx.table.get(slot).input_len > 0 {
            self.re_prefilled_tokens += self.ctx.table.get(slot).input_len as u64;
        }
        self.front_door(now, slot, out);
    }

    /// Iteration boundary: one [`ClusterEngine::begin_iteration_once`]
    /// pass, then — when the macro-step fast-forward is on and nothing in
    /// the global queue can interleave — the loop that keeps iterating
    /// WITHOUT returning to the event queue. Two tiers:
    ///
    /// 1. When a fused iteration completes at `done_at` with nothing
    ///    scheduled and no queued event at or before `done_at`, its
    ///    `IterEnd` is processed inline (the queue would pop it next
    ///    anyway), and if that schedules exactly the next `IterBegin`,
    ///    the loop continues in place — saving two global-queue
    ///    round-trips per decode iteration.
    /// 2. Before each full pass, [`ClusterEngine::bulk_span`] tries to
    ///    fast-forward a whole externally-quiet span of iterations with
    ///    bulk per-request accounting (see its doc for the argument).
    ///
    /// Every inline continuation re-checks the queue, so any external
    /// event (arrival, prefill pass, KV arrival, injection, shard-epoch
    /// boundary — which only bounds pops, never this loop's virtual
    /// clock) regains control at exactly the virtual time it would have
    /// under `--no-macro`; reports are byte-identical either way.
    // msi-lint: hot
    fn begin_iteration(&mut self, now: f64, out: &mut Vec<(f64, Event)>) {
        let mut now = now;
        loop {
            let done_at = match self.begin_iteration_once(now, out) {
                IterOutcome::Yield => return,
                IterOutcome::Fused(t) => t,
            };
            let macro_on =
                self.cfg.macro_step && matches!(self.cfg.mode, EngineMode::Disaggregated);
            if !macro_on || !out.is_empty() || self.q.peek_time().is_some_and(|t| t <= done_at) {
                // Something else must interleave (injection follow-ups,
                // an external event due first — at a timestamp tie the
                // queued event holds the earlier insertion seq and pops
                // first): schedule the IterEnd and let the queue order it.
                out.push((done_at, Event::IterEnd));
                return;
            }
            // Inline the IterEnd the queue would pop next anyway.
            // msi-lint: allow(unwrap-in-engine) -- the Fused outcome parks the stats two calls up
            let st = self.iter_stats.take().expect("fused stats pending");
            self.end_iteration(done_at, &st, out);
            self.iter_stats = Some(st);
            match out.as_slice() {
                [(at, Event::IterBegin)] => {
                    debug_assert_eq!(*at, done_at, "IterBegin at the boundary");
                    out.clear();
                    // The stepwise trace's high-water sample at this point:
                    // the queue plus the IterBegin it would have held.
                    self.peak_events = self.peak_events.max(self.q.len() - self.internal + 1);
                }
                // Quiescent, or follow-ups (overflow placements, deferred
                // injections) the global queue must order.
                _ => return,
            }
            now = done_at;
            match self.bulk_span(&mut now, out) {
                SpanExit::Yield => return,
                SpanExit::Continue => {}
            }
        }
    }

    /// Fast-forward an externally-quiet span of decode iterations without
    /// per-iteration per-request work. Armed by
    /// [`AttentionPool::span_probe`] (no admission, first token,
    /// completion, or KV out-of-memory possible for `k` iterations), each
    /// span iteration still replays the full fused ping-pong traversal —
    /// per-hop stage times, per-node busy clocks and gating RNG draws are
    /// float-order-dependent and must accrue in stepwise order — but the
    /// O(batch) boundary work (admission scan, average-sequence scan,
    /// per-request counter/KV updates) collapses to O(nodes) per
    /// iteration plus one O(batch) flush at span exit. Every iteration
    /// re-checks the global queue and yields (with the span flushed and
    /// its own `IterEnd` scheduled) the moment anything is due, so the
    /// event interleaving matches `--no-macro` exactly.
    // msi-lint: hot
    fn bulk_span(&mut self, now: &mut f64, out: &mut Vec<(f64, Event)>) -> SpanExit {
        debug_assert!(out.is_empty(), "span entered with follow-ups pending");
        if !self.pending_inject.is_empty() {
            return SpanExit::Continue;
        }
        let k = self.attention.span_probe(&mut self.span);
        if k == 0 {
            return SpanExit::Continue;
        }
        // The span refreshes the recycled disaggregated stage bundle in
        // place; anything else (cold start, mode switch) steps normally.
        let Some(mut sc) = self.stage_spare.take() else {
            return SpanExit::Continue;
        };
        if !matches!(sc.pm, StageModel::Disaggregated(_)) {
            self.stage_spare = Some(sc);
            return SpanExit::Continue;
        }
        let m = self.cfg.plan.m.max(1);
        let tp_a = self.cfg.plan.tp_a;
        let layers = self.cfg.model.layers.max(1);
        let n_e = self.experts.n_e.max(1);
        let experts = self.cfg.model.experts.max(1);
        // Batch membership is frozen for the whole span, so the splits,
        // paced micro-batch sizes and token totals are loop constants;
        // only the average sequence length (and with it the attention
        // stage times) drifts, one token per request per iteration.
        let n_nodes = self.attention.len();
        sc.prefill_node_time.clear();
        sc.prefill_node_time.resize(n_nodes, 0.0);
        // msi-lint: allow(hot-path-alloc) -- grow-once: allocates only on the first iteration after a topology change
        sc.prefill_finish.resize_with(n_nodes, Vec::new);
        for f in &mut sc.prefill_finish {
            f.clear();
        }
        sc.prefill_tokens = 0;
        self.attention.splits_into(m, &mut sc.share);
        {
            let share = &sc.share;
            sc.b_a.clear();
            sc.b_a
                .extend((0..m).map(|j| share.iter().map(|s| s[j]).max().unwrap_or(0) as f64));
            sc.tok.clear();
            sc.tok
                .extend((0..m).map(|j| share.iter().map(|s| s[j]).sum::<usize>()));
        }
        sc.extra_weight_loads =
            (experts.div_ceil(n_e).saturating_sub(1)) as f64 * sc.pm.expert_weight_floor();
        sc.has_decode = true;
        self.ctx.stage = Some(sc);
        let horizon = self.cfg.max_sim_seconds.unwrap_or(f64::INFINITY);
        let mut advanced = 0u64;
        loop {
            // Periodic §6 re-balancing, inline as the fused path applies it.
            if let Some(period) = self.cfg.rebalance_period {
                if *now >= self.next_rebalance {
                    self.experts.handle(*now, &Event::Rebalance, &mut self.ctx, out);
                    while self.next_rebalance <= *now {
                        self.next_rebalance += period;
                    }
                }
            }
            let avg_seq = self.attention.bulk_avg_seq(&self.span, advanced);
            {
                // msi-lint: allow(unwrap-in-engine) -- installed above; arming checked the disaggregated model
                let sc = self.ctx.stage.as_mut().expect("span stage installed");
                let StageModel::Disaggregated(pm) = &mut sc.pm else {
                    unreachable!("span arming checked the stage model")
                };
                pm.set_avg_seq(&self.cfg.model, &self.attn_gpu, tp_a, avg_seq);
            }
            self.ctx.in_iteration = true;
            let mut core = match self.spare.take() {
                Some(mut c) => {
                    c.reset(m, layers);
                    c
                }
                None => PipelineCore::new(m, layers),
            };
            let mut pipe_out = std::mem::take(&mut self.pipe_scratch);
            pipe_out.clear();
            core.start(*now, &mut pipe_out);
            self.fused.clear();
            for (at, pe) in pipe_out.drain(..) {
                self.fused.push(at, pe);
            }
            let mut done_at = *now;
            let mut finished = false;
            while let Some((t, pe)) = self.fused.pop() {
                if t > horizon {
                    done_at = t;
                    break;
                }
                self.elapsed = self.elapsed.max(t);
                let ev = Event::Pipe(pe);
                self.link.handle(t, &ev, &mut self.ctx, out);
                self.experts.handle(t, &ev, &mut self.ctx, out);
                let done = {
                    let ctx = &mut self.ctx;
                    let attention = &mut self.attention;
                    let experts = &mut self.experts;
                    let link = &mut self.link;
                    core.on_event_done(
                        t,
                        pe,
                        &mut |tt, mb, layer| hop_times(attention, experts, link, ctx, tt, mb, layer),
                        &mut pipe_out,
                    )
                };
                for (at, e) in pipe_out.drain(..) {
                    self.fused.push(at, e);
                }
                if done {
                    done_at = t;
                    finished = true;
                    break;
                }
            }
            self.pipe_scratch = pipe_out;
            if !finished {
                // Horizon overrun mid-span: park the core with the
                // iteration in flight (identical to the full path) and
                // let the queued IterEnd trip the cut.
                debug_assert!(done_at > horizon, "fused queue drained without completion");
                self.pipeline = Some(core);
                self.attention.flush_span(advanced);
                out.push((done_at, Event::IterEnd));
                return SpanExit::Yield;
            }
            debug_assert!(self.fused.is_empty(), "hops past iteration completion");
            // msi-lint: allow(unwrap-in-engine) -- the span loop takes and restores the stats every iteration
            let mut st = self.iter_stats.take().expect("one iteration in flight");
            core.stats_into(&mut st);
            self.iter_stats = Some(st);
            self.spare = Some(core);
            if self.q.peek_time().is_some_and(|t| t <= done_at) {
                // An external event is due first: flush the committed
                // iterations and schedule this one's IterEnd so the queue
                // pops them in stepwise order — the event's handlers run
                // mid-iteration (`in_iteration` is still set), then the
                // real `end_iteration` does this iteration's boundary.
                self.attention.flush_span(advanced);
                out.push((done_at, Event::IterEnd));
                return SpanExit::Yield;
            }
            self.end_iteration_bulk();
            advanced += 1;
            *now = done_at;
            if advanced == k {
                self.attention.flush_span(advanced);
                // Park the stage exactly as `end_iteration` would; the
                // driver's next full pass re-admits, re-scans and handles
                // the span-bounding completion iteration normally.
                self.ctx.in_iteration = false;
                self.stage_spare = self.ctx.stage.take();
                return SpanExit::Continue;
            }
        }
    }

    /// The boundary bookkeeping a macro-stepped span iteration cannot
    /// skip: utilization busy-time, the TPOT sample (the span always
    /// decodes), and the iteration counter — the values
    /// [`ClusterEngine::end_iteration`] would have produced, read off the
    /// same parked stats. Everything per-request is provably a no-op
    /// inside a span (see [`AttentionPool::span_probe`]) and the overflow
    /// drain cannot progress without a completion, so nothing else moves.
    // msi-lint: hot
    fn end_iteration_bulk(&mut self) {
        // msi-lint: allow(unwrap-in-engine) -- the span loop parked the stats right before calling this
        let st = self.iter_stats.as_ref().expect("span stats parked");
        let t_iter = st.total_time;
        self.attn_util.add_busy(st.attn_utilization * t_iter);
        self.expert_util.add_busy(st.expert_utilization * t_iter);
        self.tpot.record(t_iter);
        self.iterations += 1;
        self.ctx.in_iteration = false;
        // The stepwise trace samples the queue high-water at every
        // IterEnd pop with the follow-up IterBegin scheduled.
        self.peak_events = self.peak_events.max(self.q.len() - self.internal + 1);
    }

    /// One iteration boundary: admission on every node, inline-prefill
    /// chunk selection (colocated), stage-context build, pipeline
    /// kickoff. A boundary with neither decode nor backlog work simply
    /// goes idle — the next KV arrival or placement re-arms the clock.
    // msi-lint: hot
    fn begin_iteration_once(&mut self, now: f64, out: &mut Vec<(f64, Event)>) -> IterOutcome {
        self.ctx.iter_pending = false;
        // Deferred injections first, in firing order, BEFORE admission:
        // a node that died mid-iteration must not admit new work, and a
        // resized expert pool must price this iteration's hops.
        if !self.pending_inject.is_empty() {
            let mut pending = std::mem::take(&mut self.pending_inject);
            for &idx in &pending {
                self.apply_injection(now, idx, out);
            }
            pending.clear();
            self.pending_inject = pending;
        }
        self.attention.admit_all(now);
        let has_backlog = self.inline_prefill() && self.attention.backlog_requests() > 0;
        if self.attention.batch_total() == 0 && !has_backlog {
            return IterOutcome::Yield;
        }
        // Periodic §6 online re-balancing, applied before this iteration's
        // hops draw their expert loads. The stepwise path schedules the
        // event (it pops before the first hop: same timestamp, earlier
        // insertion seq); the fused path applies it inline — the handler
        // reads only expert-pool state and emits nothing, so the two
        // orders are indistinguishable.
        if let Some(period) = self.cfg.rebalance_period {
            if now >= self.next_rebalance {
                if self.cfg.fuse {
                    self.experts.handle(now, &Event::Rebalance, &mut self.ctx, out);
                } else {
                    out.push((now, Event::Rebalance));
                }
                while self.next_rebalance <= now {
                    self.next_rebalance += period;
                }
            }
        }

        let plan = &self.cfg.plan;
        let m = plan.m.max(1);
        let layers = self.cfg.model.layers.max(1);
        // Live pool size, not the plan's: elastic shrink/grow injections
        // change how many nodes stream expert weight panels.
        let n_e = self.experts.n_e.max(1);
        let experts = self.cfg.model.experts.max(1);

        let avg_seq = self.attention.avg_seq();
        // Recycle the previous iteration's stage context: the buffers keep
        // their capacity, and the disaggregated perf-model bundle only
        // needs its attention side refreshed at the live mean sequence
        // length (`set_avg_seq` is bit-identical to a fresh build and
        // keeps the expert model's memoized roofline table warm).
        let mut sc = match self.stage_spare.take() {
            Some(mut sc) => {
                let refreshed = match (&mut sc.pm, &self.cfg.mode) {
                    (StageModel::Disaggregated(pm), EngineMode::Disaggregated) => {
                        pm.set_avg_seq(&self.cfg.model, &self.attn_gpu, plan.tp_a, avg_seq);
                        true
                    }
                    _ => false,
                };
                if !refreshed {
                    sc.pm = self.build_stage_model(avg_seq);
                }
                sc
            }
            None => StageCtx::cold(self.build_stage_model(avg_seq)),
        };
        let n_nodes = self.attention.len();
        sc.prefill_node_time.clear();
        sc.prefill_node_time.resize(n_nodes, 0.0);
        // msi-lint: allow(hot-path-alloc) -- grow-once: allocates only on the first iteration after a topology change
        sc.prefill_finish.resize_with(n_nodes, Vec::new);
        for f in &mut sc.prefill_finish {
            f.clear();
        }
        sc.prefill_tokens = 0;
        // Colocated inline chunked prefill: take this iteration's chunk
        // off each node's backlog; the per-node pass times ride on hop 0
        // and the finished prompts join the batchers at end-of-iteration.
        if has_backlog {
            let ipm = self
                .inline_prefill_model
                .as_ref()
                // msi-lint: allow(unwrap-in-engine) -- has_backlog is only true when the colocated config installed the model
                .expect("inline prefill implies a colocated prefill model");
            let pm = &sc.pm;
            sc.prefill_tokens = self.attention.advance_prefill(
                self.cfg.prefill_chunk,
                now,
                &mut self.ctx.table,
                &|tokens, ctx| pm.prefill_layer_time(ipm, tokens, ctx),
                &mut sc.prefill_node_time,
                &mut sc.prefill_finish,
            );
        }

        self.attention.splits_into(m, &mut sc.share);
        {
            let share = &sc.share;
            sc.b_a.clear();
            sc.b_a
                .extend((0..m).map(|j| share.iter().map(|s| s[j]).max().unwrap_or(0) as f64));
            sc.tok.clear();
            sc.tok
                .extend((0..m).map(|j| share.iter().map(|s| s[j]).sum::<usize>()));
        }
        // The T_e model (k3·b_e + k4) is calibrated per *expert*; a node
        // hosting several experts streams each one's weight panels, so
        // charge the extra k4 floors when n_e < experts.
        sc.extra_weight_loads =
            (experts.div_ceil(n_e).saturating_sub(1)) as f64 * sc.pm.expert_weight_floor();
        sc.has_decode = self.attention.batch_total() > 0;
        self.ctx.stage = Some(sc);
        self.ctx.in_iteration = true;

        let mut core = match self.spare.take() {
            Some(mut c) => {
                c.reset(m, layers);
                c
            }
            None => PipelineCore::new(m, layers),
        };
        let mut pipe_out = std::mem::take(&mut self.pipe_scratch);
        pipe_out.clear();
        core.start(now, &mut pipe_out);

        if !self.cfg.fuse {
            for (at, pe) in pipe_out.drain(..) {
                out.push((at, Event::Pipe(pe)));
            }
            self.pipe_scratch = pipe_out;
            self.pipeline = Some(core);
            return IterOutcome::Yield;
        }

        // Fused fast path: within an iteration the per-hop stage times are
        // state-independent (the `hop_times` providers mutate only pool
        // busy clocks and the gating RNG — never pipeline state — and no
        // mid-iteration external event touches either), so the whole
        // ping-pong traversal is replayed here on a local queue with the
        // global queue's exact (time, insertion-seq) pop discipline. The
        // gating draws happen in the identical order the stepwise path
        // would make them: once per (micro-batch, layer), at first need,
        // through the core's stage-time memo. One `IterEnd` event lands on
        // the global queue instead of ~3·m·layers `Pipe` hops.
        let horizon = self.cfg.max_sim_seconds.unwrap_or(f64::INFINITY);
        self.fused.clear();
        for (at, pe) in pipe_out.drain(..) {
            self.fused.push(at, pe);
        }
        let mut done_at = now;
        let mut finished = false;
        while let Some((t, pe)) = self.fused.pop() {
            if t > horizon {
                // The stepwise path would pop this hop off the global
                // queue and cut the run; schedule the (internal) IterEnd
                // at the same time so the global pop trips the identical
                // cut, and park the core with the iteration still in
                // flight — `finalize` counts its pending prefill finishes.
                done_at = t;
                break;
            }
            self.elapsed = self.elapsed.max(t);
            // Conservation observers see every hop, as in stepwise mode
            // (they read the stage context and never emit events).
            let ev = Event::Pipe(pe);
            self.link.handle(t, &ev, &mut self.ctx, out);
            self.experts.handle(t, &ev, &mut self.ctx, out);
            let done = {
                let ctx = &mut self.ctx;
                let attention = &mut self.attention;
                let experts = &mut self.experts;
                let link = &mut self.link;
                core.on_event_done(
                    t,
                    pe,
                    &mut |tt, mb, layer| hop_times(attention, experts, link, ctx, tt, mb, layer),
                    &mut pipe_out,
                )
            };
            for (at, e) in pipe_out.drain(..) {
                self.fused.push(at, e);
            }
            if done {
                // Capture the exact completion time of the last hop:
                // recomputing it as `now + total_time` would round-trip
                // through a float subtraction and not bit-match stepwise.
                done_at = t;
                finished = true;
                break;
            }
        }
        self.pipe_scratch = pipe_out;
        if finished {
            debug_assert!(self.fused.is_empty(), "hops past iteration completion");
            // msi-lint: allow(unwrap-in-engine) -- IterBegin parked the stats; the fused drain completes at most one iteration
            let mut st = self.iter_stats.take().expect("one iteration in flight");
            core.stats_into(&mut st);
            self.iter_stats = Some(st);
            self.spare = Some(core);
            // The driver decides whether this iteration's IterEnd goes
            // through the queue or is processed inline (macro-stepping).
            return IterOutcome::Fused(done_at);
        }
        debug_assert!(done_at > horizon, "fused queue drained without completion");
        self.pipeline = Some(core);
        out.push((done_at, Event::IterEnd));
        IterOutcome::Yield
    }

    /// This iteration's stage-time provider, built fresh (the recycled
    /// disaggregated bundle instead refreshes in place via
    /// [`PerfModel::set_avg_seq`]).
    fn build_stage_model(&self, avg_seq: f64) -> StageModel {
        match &self.cfg.mode {
            EngineMode::Disaggregated => StageModel::Disaggregated(PerfModel::new(
                &self.cfg.model,
                &self.cfg.cluster,
                self.cfg.plan.tp_a,
                self.cfg.plan.tp_e,
                avg_seq,
            )),
            EngineMode::Colocated(cp) => StageModel::Colocated(ColocatedModel::new(
                cp,
                &self.cfg.model,
                &self.cfg.cluster,
                avg_seq,
            )),
        }
    }

    /// One pipeline hop (stepwise mode): conservation observers first, then
    /// the shared scheduling core with the components as the stage-time
    /// providers.
    // msi-lint: hot
    fn on_pipe(&mut self, now: f64, pe: PipeEvent, out: &mut Vec<(f64, Event)>) {
        let ev = Event::Pipe(pe);
        self.link.handle(now, &ev, &mut self.ctx, out);
        self.experts.handle(now, &ev, &mut self.ctx, out);

        let Some(mut core) = self.pipeline.take() else {
            return;
        };
        let mut pipe_out = std::mem::take(&mut self.pipe_scratch);
        pipe_out.clear();
        let done = {
            let ctx = &mut self.ctx;
            let attention = &mut self.attention;
            let experts = &mut self.experts;
            let link = &mut self.link;
            core.on_event_done(
                now,
                pe,
                &mut |t, mb, layer| hop_times(attention, experts, link, ctx, t, mb, layer),
                &mut pipe_out,
            )
        };
        for (at, e) in pipe_out.drain(..) {
            out.push((at, Event::Pipe(e)));
        }
        self.pipe_scratch = pipe_out;
        if done {
            // msi-lint: allow(unwrap-in-engine) -- IterBegin parked the stats before any Pipe event could complete the iteration
            let mut st = self.iter_stats.take().expect("one iteration in flight");
            core.stats_into(&mut st);
            self.spare = Some(core);
            self.end_iteration(now, &st, out);
            self.iter_stats = Some(st);
        } else {
            self.pipeline = Some(core);
        }
    }

    /// End of an iteration: latency/utilization metrics, inline-prefill
    /// completions into the batchers, per-node token accounting,
    /// completions back to the router, FIFO overflow drain into the freed
    /// capacity, and the next iteration boundary.
    // msi-lint: hot
    fn end_iteration(&mut self, now: f64, stats: &PipelineStats, out: &mut Vec<(f64, Event)>) {
        // msi-lint: allow(unwrap-in-engine) -- begin_iteration installs the stage context before any path can reach here
        let stage = self.ctx.stage.take().expect("iteration stage context");
        let t_iter = stats.total_time;
        self.attn_util.add_busy(stats.attn_utilization * t_iter);
        self.expert_util.add_busy(stats.expert_utilization * t_iter);
        // A pure inline-prefill iteration decodes nothing: no TPOT sample.
        // Mixed iterations DO count — chunked-prefill interference is
        // exactly what inflates the colocated baseline's TPOT.
        if stage.has_decode {
            self.tpot.record(t_iter);
        }
        self.iterations += 1;
        self.ctx.in_iteration = false;

        // Inline-prefill completions: the prompts whose last chunk ran this
        // iteration join their node's batcher (admitted at the next
        // boundary), crossing Prefill → KvTransfer → Decode with a
        // zero-length transfer (the KV never leaves the group).
        self.inline_prefilled_tokens += stage.prefill_tokens;
        for (nid, slots) in stage.prefill_finish.iter().enumerate() {
            for &slot in slots {
                self.ctx.table.advance(slot, RequestPhase::KvTransfer, now);
                self.ctx.table.advance(slot, RequestPhase::Decode, now);
                let mut r = *self.ctx.table.get(slot);
                r.id = slot as u64;
                self.attention.submit_to(nid, r);
            }
        }

        for nid in 0..self.attention.len() {
            let outcome = self.attention.finish_node_iteration(nid);
            // Batcher-side ids are table slots (the engine threads requests
            // by slot); the table maps them back to arrival/tenant state.
            for id in outcome.first {
                let slot = id as usize;
                let (p_start, p_end, d_entry) = self.ctx.table.timings(slot);
                let (arrival, tenant) = {
                    let r = self.ctx.table.get(slot);
                    (r.arrival, r.tenant)
                };
                // The four components telescope to the TTFT exactly,
                // request by request (the decomposition invariant the
                // regression suite pins).
                let ttft = now - arrival;
                let queue = p_start - arrival;
                let prefill = p_end - p_start;
                let transfer = d_entry - p_end;
                let decode = now - d_entry;
                debug_assert!(
                    ((queue + prefill + transfer + decode) - ttft).abs()
                        <= 1e-9 * ttft.abs().max(1.0),
                    "TTFT components must sum to TTFT"
                );
                self.ttft.record(ttft);
                self.ttft_queue.record(queue);
                self.ttft_prefill.record(prefill);
                self.ttft_transfer.record(transfer);
                self.ttft_decode.record(decode);
                if !self.cfg.tenants.is_empty() {
                    let t = tenant.min(self.cfg.tenants.len() - 1);
                    let acc = &mut self.tenant_stats[t];
                    acc.ttft.record(ttft);
                    acc.ttft_queue.record(queue);
                    acc.ttft_prefill.record(prefill);
                    acc.ttft_transfer.record(transfer);
                    acc.ttft_decode.record(decode);
                }
            }
            // Completion bursts share a finish time and — closed-loop
            // batches arriving together — often an arrival time, so runs
            // of bit-equal latencies within a tenant collapse into one
            // bulk histogram record (`record_n` is bit-identical to
            // repeated `record`; the interleaved router/table work never
            // touches the histograms, so deferring a run's record to its
            // end changes nothing).
            let mut run_latency = 0.0f64;
            let mut run_tenant = usize::MAX;
            let mut run_n = 0u64;
            for id in outcome.done {
                let slot = id as usize;
                let (latency, tenant) = {
                    let r = self.ctx.table.get(slot);
                    (now - r.arrival, r.tenant)
                };
                if run_n > 0 && latency.to_bits() == run_latency.to_bits() && tenant == run_tenant
                {
                    run_n += 1;
                } else {
                    if run_n > 0 {
                        self.record_completions(run_latency, run_tenant, run_n);
                    }
                    run_latency = latency;
                    run_tenant = tenant;
                    run_n = 1;
                }
                if let Some(node) = self.ctx.table.take_placed(slot) {
                    self.router.complete(node, self.ctx.table.get(slot));
                }
                // Completion frees the slot for reuse by later arrivals.
                self.ctx.table.advance(slot, RequestPhase::Done, now);
                self.ctx.table.remove(slot);
            }
            if run_n > 0 {
                self.record_completions(run_latency, run_tenant, run_n);
            }
        }

        // Freed KV first, then strictly-FIFO admission of queued arrivals.
        self.router.drain_overflow(now, &mut self.ctx, out);
        let inline_pending = self.inline_prefill() && self.attention.backlog_requests() > 0;
        // A deferred injection with no decode work still needs the next
        // boundary to fire so it gets applied.
        if (self.attention.has_work() || inline_pending || !self.pending_inject.is_empty())
            && !self.ctx.iter_pending
        {
            self.ctx.iter_pending = true;
            out.push((now, Event::IterBegin));
        }
        // Park the stage context for the next iteration to recycle.
        self.stage_spare = Some(stage);
    }

    /// Record `n` completions sharing one bit-identical E2E latency and
    /// raw tenant id. Bulk [`Histogram::record_n`] is defined to be
    /// bit-identical to `n` repeated `record` calls, so run-length
    /// grouping in [`ClusterEngine::end_iteration`] never changes a
    /// report.
    // msi-lint: hot
    fn record_completions(&mut self, latency: f64, tenant: usize, n: u64) {
        self.completed += n;
        self.e2e.record_n(latency, n);
        if !self.cfg.tenants.is_empty() {
            let t = tenant.min(self.cfg.tenants.len() - 1);
            let acc = &mut self.tenant_stats[t];
            acc.completed += n;
            acc.e2e.record_n(latency, n);
        }
    }

    /// Fold the engine's terminal state into a [`ClusterReport`].
    pub(crate) fn finalize(mut self) -> ClusterReport {
        self.build_report()
    }

    /// [`ClusterEngine::finalize`] body on `&mut self`: moves the metric
    /// state (histograms, tenant accumulators) into the report and leaves
    /// the engine a husk, so [`ClusterEngine::run_recycled`] can still
    /// stash the recycled buffers afterwards.
    fn build_report(&mut self) -> ClusterReport {
        let now = self.elapsed;
        self.attn_util.set_horizon(now);
        self.expert_util.set_horizon(now);
        let plan = &self.cfg.plan;
        let gpus = (plan.tp_a * plan.n_a.max(1)
            + plan.tp_e * plan.n_e.max(1)
            + self.prefill_tp * self.cfg.prefill_nodes) as f64;
        let tokens = self.attention.decoded_tokens;
        let throughput = if now > 0.0 { tokens as f64 / now } else { 0.0 };
        // The leftover split: `rejected` counts front-door admission-control
        // rejections (KV footprint beyond any node's usable budget — the
        // fleet could never serve them); everything still in the prefill
        // pool, on the KV wire, queued at the router, on an inline-prefill
        // backlog, waiting on a node, or mid-decode is feasible work a
        // `max_sim_seconds` horizon cut off (`unserved_queued`) — at
        // quiescence all these sets are empty. Arrivals pulled off the
        // stream but scheduled past the horizon are excluded: they never
        // arrived within the simulated window.
        let rejected = self.router.rejected();
        // A horizon cut mid-iteration can strand prompts whose last inline
        // chunk ran in the still-in-flight iteration: they are already off
        // their node's backlog but not yet in a batcher (end_iteration
        // never ran), so count the stage's finish lists too.
        let in_flight_prefill = self
            .ctx
            .stage
            .as_ref()
            .map_or(0, |s| s.prefill_finish.iter().map(Vec::len).sum());
        let unserved_queued = (self.prefill.as_ref().map_or(0, |p| p.in_pool())
            + self.in_transfer
            + self.router.pending()
            + self.attention.backlog_requests()
            + self.attention.waiting_total()
            + self.attention.batch_total()
            + in_flight_prefill) as u64;
        let samples = self.ctx.stage_samples.max(1) as f64;
        let frac = |busy: &f64| {
            if now > 0.0 {
                (busy / now).clamp(0.0, 1.0)
            } else {
                0.0
            }
        };
        let per_node_attn_busy: Vec<f64> = self.attention.node_busy.iter().map(frac).collect();
        let per_node_expert_busy: Vec<f64> = self.experts.node_busy.iter().map(frac).collect();
        let per_node_prefill_busy: Vec<f64> = self
            .prefill
            .as_ref()
            .map(|p| p.node_busy.iter().map(frac).collect())
            .unwrap_or_default();
        let prefilled_tokens = self.inline_prefilled_tokens
            + self.prefill.as_ref().map_or(0, |p| p.prefilled_tokens);
        let tenants: Vec<TenantReport> = self
            .cfg
            .tenants
            .iter()
            .zip(std::mem::take(&mut self.tenant_stats))
            .map(|(tc, acc)| TenantReport {
                name: tc.name.clone(),
                slo_e2e: tc.slo_e2e,
                completed: acc.completed,
                ttft: acc.ttft,
                ttft_queue: acc.ttft_queue,
                ttft_prefill: acc.ttft_prefill,
                ttft_transfer: acc.ttft_transfer,
                ttft_decode: acc.ttft_decode,
                e2e: acc.e2e,
            })
            .collect();
        ClusterReport {
            completed: self.completed,
            tokens,
            elapsed: now,
            iterations: self.iterations,
            throughput,
            per_gpu_throughput: throughput / gpus.max(1.0),
            ttft: std::mem::take(&mut self.ttft),
            ttft_queue: std::mem::take(&mut self.ttft_queue),
            ttft_prefill: std::mem::take(&mut self.ttft_prefill),
            ttft_transfer: std::mem::take(&mut self.ttft_transfer),
            ttft_decode: std::mem::take(&mut self.ttft_decode),
            tpot: std::mem::take(&mut self.tpot),
            e2e: std::mem::take(&mut self.e2e),
            attn_utilization: self.attn_util.fraction(),
            expert_utilization: self.expert_util.fraction(),
            per_node_tokens: self.attention.node_tokens.clone(),
            per_node_attn_busy,
            per_node_expert_busy,
            per_node_prefill_busy,
            prefilled_tokens,
            kv_transferred_tokens: self.kv_transferred_tokens,
            kv_blocks_in_use_at_end: self.attention.allocated_kv_blocks(),
            rejected,
            unserved_queued,
            peak_in_flight: self.ctx.table.peak() as u64,
            peak_queue_events: self.peak_events as u64,
            mean_t_a: self.ctx.sum_t_a / samples,
            mean_t_e: self.ctx.sum_t_e / samples,
            mean_t_c: self.ctx.sum_t_c / samples,
            dispatched_copies: self.link.dispatched_copies,
            combined_copies: self.link.combined_copies,
            processed_copies: self.experts.processed_copies,
            rebalances: self.experts.rebalances,
            injections_applied: self.injections_applied,
            node_failures: self.node_failures,
            node_recoveries: self.node_recoveries,
            requeued_requests: self.requeued_requests,
            lost_kv_blocks: self.lost_kv_blocks,
            lost_decode_tokens: self.lost_decode_tokens,
            re_prefilled_tokens: self.re_prefilled_tokens,
            expert_resizes: self.experts.resizes,
            clamped_past_schedules: self.q.clamped_past_schedules(),
            tenants,
        }
    }
}

/// Compose the components' duration models into the per-hop stage times the
/// pipeline core memoizes. Consulted exactly once per (micro-batch, layer),
/// in deterministic event order.
// msi-lint: hot
fn hop_times(
    attention: &mut AttentionPool,
    experts: &mut ExpertPool,
    link: &mut M2nLink,
    ctx: &mut SimCtx,
    now: f64,
    mb: usize,
    layer: usize,
) -> StageTimes {
    let _ = layer; // hops differ per layer only through the stochastic draw
    let SimCtx {
        stage,
        rng,
        sum_t_a,
        sum_t_e,
        sum_t_c,
        stage_samples,
        ..
    } = ctx;
    // msi-lint: allow(unwrap-in-engine) -- Pipe handlers only run between IterBegin and IterEnd, which bound the stage context
    let stage = stage.as_ref().expect("pipeline hop outside an iteration");
    let t_a = attention.hop_t_a(stage, mb);
    let (t_e, hot_tokens) = experts.hop_t_e(stage, rng, now, mb);
    let t_c = link.hop_t_c(stage, mb, hot_tokens);
    *sum_t_a += t_a;
    *sum_t_e += t_e;
    *sum_t_c += t_c;
    *stage_samples += 1;
    StageTimes { t_a, t_e, t_c }
}
