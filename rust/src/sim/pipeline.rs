//! The ping-pong pipeline scheduling core — the ONE implementation of the
//! paper's §4.1 micro-batch shuttle, shared by every simulation path.
//!
//! `m` micro-batches traverse `L` MoE layers, alternating between two
//! serially-reused stage resources ([`Stage`]): the attention pool and the
//! expert pool. Dispatch and combine transfers each take `t_c` and overlap
//! with compute. The core is expressed as a pure event-handling state
//! machine over [`PipeEvent`]s: it never owns an event queue. Callers pop
//! events from their own [`crate::sim::EventQueue`] and feed them in, which
//! is what lets the trace-driven [`crate::sim::engine::ClusterEngine`]
//! interleave pipeline hops with request arrivals and re-balancing on a
//! single virtual clock, while [`crate::coordinator::PingPongEngine`] runs
//! the same machine standalone as a scheduling policy.
//!
//! Stage times come from a caller-supplied provider, consulted exactly once
//! per (micro-batch, layer) hop and memoized, so stateful providers
//! (RNG-backed gating draws) stay deterministic.

use std::collections::VecDeque;

/// Per-stage/per-run statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PipelineStats {
    /// Completion time of the last micro-batch, relative to pipeline start
    /// (seconds).
    pub total_time: f64,
    /// Attention-stage busy time / total time.
    pub attn_utilization: f64,
    /// Expert-stage busy time / total time.
    pub expert_utilization: f64,
    /// Per-micro-batch completion times (relative to pipeline start).
    pub mb_done: Vec<f64>,
}

/// Stage times for one (micro-batch, layer) traversal.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageTimes {
    /// Attention compute time for this micro-batch at this layer.
    pub t_a: f64,
    /// Expert compute time for this micro-batch at this layer.
    pub t_e: f64,
    /// One-direction communication time (applies to both the dispatch to
    /// the expert pool and the combine back to the attention pool).
    pub t_c: f64,
}

/// Events of one ping-pong pipeline pass. `mb` is the micro-batch index,
/// `layer` the MoE layer being traversed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipeEvent {
    /// Micro-batch ready to start attention of `layer`.
    AttnReady { mb: usize, layer: usize },
    /// Attention of (mb, layer) finished computing.
    AttnDone { mb: usize, layer: usize },
    /// Tokens handed to the M2N link for dispatch to the expert pool.
    Dispatch { mb: usize, layer: usize },
    /// Micro-batch arrived at the expert stage.
    ExpertReady { mb: usize, layer: usize },
    /// Expert compute finished.
    ExpertDone { mb: usize, layer: usize },
    /// Expert outputs handed to the M2N link for the combine transfer.
    Combine { mb: usize, layer: usize },
    /// Aggregated tokens arrived back at the attention nodes.
    BackAtAttn { mb: usize, layer: usize },
}

/// A serially-reused stage resource (one pool of GPUs acting as a single
/// pipeline stage): a busy-until clock, cumulative busy time, and a FIFO of
/// hops that are ready but waiting for the resource.
#[derive(Debug, Clone, Default)]
pub struct Stage {
    free_at: f64,
    busy: f64,
    ready: VecDeque<(usize, usize)>,
}

impl Stage {
    /// Queue a (mb, layer) hop as ready to run on this stage.
    pub fn offer(&mut self, mb: usize, layer: usize) {
        self.ready.push_back((mb, layer));
    }

    /// Whether the resource is idle at `now` (a completion at exactly `now`
    /// counts as idle — the resource frees at its busy-until instant).
    pub fn is_idle(&self, now: f64) -> bool {
        self.free_at <= now
    }

    /// Pop the next ready hop, if any.
    pub fn pop_ready(&mut self) -> Option<(usize, usize)> {
        self.ready.pop_front()
    }

    /// Occupy the resource for `dur` starting at `now`; returns the
    /// completion time.
    pub fn begin(&mut self, now: f64, dur: f64) -> f64 {
        self.free_at = now + dur;
        self.busy += dur;
        self.free_at
    }

    /// Cumulative busy seconds.
    pub fn busy_time(&self) -> f64 {
        self.busy
    }

    /// Return the stage to its initial state, keeping the ready-FIFO's
    /// allocation for reuse.
    fn reset(&mut self) {
        self.free_at = 0.0;
        self.busy = 0.0;
        self.ready.clear();
    }
}

/// A reusable local event queue for fused in-place pipeline traversals.
///
/// Replays the EXACT pop discipline of the global [`crate::sim::EventQueue`]
/// — strictly increasing insertion sequence numbers, pops ordered by
/// `(time, seq)` with `f64::total_cmp` on time — on a flat `Vec`, so a
/// whole ping-pong pass can be stepped without touching the global
/// calendar. At most ~2·m+2 events are ever pending at once, so a linear
/// min-scan beats heap or calendar bookkeeping, and the buffer is reused
/// across iterations (zero steady-state allocation).
#[derive(Debug, Clone, Default)]
pub struct FusedQueue {
    items: Vec<(f64, u64, PipeEvent)>,
    seq: u64,
}

impl FusedQueue {
    /// An empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop all pending events (keeps the allocation).
    pub fn clear(&mut self) {
        self.items.clear();
        self.seq = 0;
    }

    /// Schedule `ev` at virtual time `at`.
    // msi-lint: hot
    pub fn push(&mut self, at: f64, ev: PipeEvent) {
        debug_assert!(at.is_finite(), "fused schedule at non-finite time {at}");
        self.items.push((at, self.seq, ev));
        self.seq += 1;
    }

    /// Pop the earliest event: smallest time, FIFO within a time tie —
    /// exactly the global queue's ordering contract.
    // msi-lint: hot
    pub fn pop(&mut self) -> Option<(f64, PipeEvent)> {
        if self.items.is_empty() {
            return None;
        }
        let mut best = 0;
        for i in 1..self.items.len() {
            let (t, s, _) = self.items[i];
            let (bt, bs, _) = self.items[best];
            if t.total_cmp(&bt).then(s.cmp(&bs)).is_lt() {
                best = i;
            }
        }
        let (t, _, ev) = self.items.swap_remove(best);
        Some((t, ev))
    }

    /// Pending event count.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// The ping-pong scheduling policy over two stage resources and a link.
///
/// Owns no queue: [`PipelineCore::start`] and [`PipelineCore::on_event`]
/// emit `(at, event)` pairs into `out`, and the caller schedules them on
/// whatever event queue drives the simulation.
#[derive(Debug, Clone)]
pub struct PipelineCore {
    /// Micro-batches in flight.
    pub m: usize,
    /// MoE layers each micro-batch traverses.
    pub layers: usize,
    attn: Stage,
    expert: Stage,
    /// Memoized per-(mb, layer) stage times: the provider is consulted
    /// once per hop, in deterministic event order.
    cache: Vec<Option<StageTimes>>,
    mb_done: Vec<f64>,
    remaining: usize,
    started_at: f64,
}

impl PipelineCore {
    /// A fresh pass of `m` micro-batches over `layers` layers.
    pub fn new(m: usize, layers: usize) -> Self {
        assert!(m >= 1 && layers >= 1);
        Self {
            m,
            layers,
            attn: Stage::default(),
            expert: Stage::default(),
            cache: vec![None; m * layers],
            mb_done: vec![0.0; m],
            remaining: m,
            started_at: 0.0,
        }
    }

    /// Re-arm an already-constructed core for a fresh pass of `m`
    /// micro-batches over `layers` layers, reusing every internal
    /// allocation. Equivalent to `*self = PipelineCore::new(m, layers)`
    /// without the four heap allocations — the engine recycles one core
    /// across iterations so the steady-state decode loop stays alloc-free.
    // msi-lint: hot
    pub fn reset(&mut self, m: usize, layers: usize) {
        assert!(m >= 1 && layers >= 1);
        self.m = m;
        self.layers = layers;
        self.attn.reset();
        self.expert.reset();
        self.cache.clear();
        self.cache.resize(m * layers, None);
        self.mb_done.clear();
        self.mb_done.resize(m, 0.0);
        self.remaining = m;
        self.started_at = 0.0;
    }

    /// Inject the `m` micro-batches at virtual time `at`.
    pub fn start(&mut self, at: f64, out: &mut Vec<(f64, PipeEvent)>) {
        self.started_at = at;
        self.remaining = self.m;
        for mb in 0..self.m {
            out.push((at, PipeEvent::AttnReady { mb, layer: 0 }));
        }
    }

    // msi-lint: hot
    fn times_of(
        &mut self,
        now: f64,
        mb: usize,
        layer: usize,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
    ) -> StageTimes {
        let idx = mb * self.layers + layer;
        if let Some(t) = self.cache[idx] {
            return t;
        }
        let t = times(now, mb, layer);
        self.cache[idx] = Some(t);
        t
    }

    // msi-lint: hot
    fn try_start_attn(
        &mut self,
        now: f64,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
        out: &mut Vec<(f64, PipeEvent)>,
    ) {
        if !self.attn.is_idle(now) {
            return;
        }
        let Some((mb, layer)) = self.attn.pop_ready() else {
            return;
        };
        let dur = self.times_of(now, mb, layer, times).t_a;
        let end = self.attn.begin(now, dur);
        out.push((end, PipeEvent::AttnDone { mb, layer }));
    }

    // msi-lint: hot
    fn try_start_expert(
        &mut self,
        now: f64,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
        out: &mut Vec<(f64, PipeEvent)>,
    ) {
        if !self.expert.is_idle(now) {
            return;
        }
        let Some((mb, layer)) = self.expert.pop_ready() else {
            return;
        };
        let dur = self.times_of(now, mb, layer, times).t_e;
        let end = self.expert.begin(now, dur);
        out.push((end, PipeEvent::ExpertDone { mb, layer }));
    }

    /// Handle one pipeline event at virtual time `now`, emitting follow-up
    /// events into `out`. Returns `Some(stats)` when the last micro-batch
    /// completes its final layer.
    pub fn on_event(
        &mut self,
        now: f64,
        ev: PipeEvent,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
        out: &mut Vec<(f64, PipeEvent)>,
    ) -> Option<PipelineStats> {
        self.on_event_done(now, ev, times, out).then(|| self.stats())
    }

    /// Allocation-free variant of [`PipelineCore::on_event`]: returns
    /// `true` when the last micro-batch completes its final layer; read
    /// the pass statistics with [`PipelineCore::stats_into`]. The engine's
    /// hot loop uses this so completing an iteration never clones
    /// `mb_done`.
    // msi-lint: hot
    pub fn on_event_done(
        &mut self,
        now: f64,
        ev: PipeEvent,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
        out: &mut Vec<(f64, PipeEvent)>,
    ) -> bool {
        match ev {
            PipeEvent::AttnReady { mb, layer } => {
                self.attn.offer(mb, layer);
                self.try_start_attn(now, times, out);
            }
            PipeEvent::AttnDone { mb, layer } => {
                out.push((now, PipeEvent::Dispatch { mb, layer }));
                self.try_start_attn(now, times, out);
            }
            PipeEvent::Dispatch { mb, layer } => {
                let t_c = self.times_of(now, mb, layer, times).t_c;
                out.push((now + t_c, PipeEvent::ExpertReady { mb, layer }));
            }
            PipeEvent::ExpertReady { mb, layer } => {
                self.expert.offer(mb, layer);
                self.try_start_expert(now, times, out);
            }
            PipeEvent::ExpertDone { mb, layer } => {
                out.push((now, PipeEvent::Combine { mb, layer }));
                self.try_start_expert(now, times, out);
            }
            PipeEvent::Combine { mb, layer } => {
                let t_c = self.times_of(now, mb, layer, times).t_c;
                out.push((now + t_c, PipeEvent::BackAtAttn { mb, layer }));
            }
            PipeEvent::BackAtAttn { mb, layer } => {
                if layer + 1 < self.layers {
                    out.push((now, PipeEvent::AttnReady { mb, layer: layer + 1 }));
                } else {
                    self.mb_done[mb] = now - self.started_at;
                    self.remaining -= 1;
                    if self.remaining == 0 {
                        return true;
                    }
                }
            }
        }
        false
    }

    /// Write the completed pass's statistics into `out`, reusing its
    /// `mb_done` buffer (no allocation once the buffer has capacity `m`).
    // msi-lint: hot
    pub fn stats_into(&self, out: &mut PipelineStats) {
        let total_time = self.mb_done.iter().copied().fold(0.0, f64::max);
        // A zero-duration pass (every stage time 0, e.g. a degenerate
        // scenario sweep cell) must report 0 utilization, not NaN — the
        // NaN would propagate into ClusterReport and its JSON rendering.
        let util = |busy: f64| {
            if total_time > 0.0 {
                busy / total_time
            } else {
                0.0
            }
        };
        out.total_time = total_time;
        out.attn_utilization = util(self.attn.busy_time());
        out.expert_utilization = util(self.expert.busy_time());
        out.mb_done.clear();
        out.mb_done.extend_from_slice(&self.mb_done);
    }

    fn stats(&self) -> PipelineStats {
        let mut s = PipelineStats::default();
        self.stats_into(&mut s);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EventQueue;

    fn drive(m: usize, layers: usize, st: StageTimes) -> PipelineStats {
        let mut core = PipelineCore::new(m, layers);
        let mut q: EventQueue<PipeEvent> = EventQueue::new();
        let mut out = Vec::new();
        core.start(0.0, &mut out);
        for (at, e) in out.drain(..) {
            q.schedule_at(at, e);
        }
        while let Some((now, ev)) = q.pop() {
            if let Some(stats) = core.on_event(now, ev, &mut |_, _, _| st, &mut out) {
                return stats;
            }
            for (at, e) in out.drain(..) {
                q.schedule_at(at, e);
            }
        }
        panic!("pipeline drained without completing");
    }

    #[test]
    fn single_hop_is_full_round_trip() {
        let st = StageTimes {
            t_a: 1.0,
            t_e: 2.0,
            t_c: 0.5,
        };
        let stats = drive(1, 1, st);
        assert!((stats.total_time - 4.0).abs() < 1e-12, "{}", stats.total_time);
        assert_eq!(stats.mb_done, vec![4.0]);
    }

    #[test]
    fn stage_serializes_micro_batches() {
        // Two micro-batches, one layer, zero comm: attention serializes
        // (1, then 1 more), expert likewise; makespan = 1 + 1 + 1 = 3.
        let st = StageTimes {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.0,
        };
        let stats = drive(2, 1, st);
        assert!((stats.total_time - 3.0).abs() < 1e-12, "{}", stats.total_time);
    }

    #[test]
    fn zero_duration_iteration_reports_zero_utilization_not_nan() {
        // Regression: busy/total was 0/0 = NaN when every stage time is 0.
        let st = StageTimes {
            t_a: 0.0,
            t_e: 0.0,
            t_c: 0.0,
        };
        let stats = drive(2, 3, st);
        assert_eq!(stats.total_time, 0.0);
        assert_eq!(stats.attn_utilization, 0.0, "no NaN: {stats:?}");
        assert_eq!(stats.expert_utilization, 0.0, "no NaN: {stats:?}");
        assert!(stats.mb_done.iter().all(|&t| t == 0.0));
    }

    /// Drive the same pass on a [`FusedQueue`] instead of the global
    /// [`EventQueue`] — the two must agree exactly (the fused fast path's
    /// correctness hinges on the identical `(time, seq)` pop discipline).
    fn drive_fused(core: &mut PipelineCore, at: f64, st: StageTimes) -> PipelineStats {
        let mut q = FusedQueue::new();
        let mut out = Vec::new();
        core.start(at, &mut out);
        for (t, e) in out.drain(..) {
            q.push(t, e);
        }
        while let Some((now, ev)) = q.pop() {
            if core.on_event_done(now, ev, &mut |_, _, _| st, &mut out) {
                let mut stats = PipelineStats::default();
                core.stats_into(&mut stats);
                return stats;
            }
            for (t, e) in out.drain(..) {
                q.push(t, e);
            }
        }
        panic!("fused pipeline drained without completing");
    }

    #[test]
    fn fused_queue_matches_global_queue_exactly() {
        for (m, layers) in [(1, 1), (2, 8), (3, 4), (4, 2)] {
            let st = StageTimes {
                t_a: 1.0e-3,
                t_e: 1.4e-3,
                t_c: 0.2e-3,
            };
            let reference = drive(m, layers, st);
            let mut core = PipelineCore::new(m, layers);
            let fused = drive_fused(&mut core, 0.0, st);
            assert_eq!(reference, fused, "m={m} layers={layers}");
        }
    }

    #[test]
    fn reset_reuses_like_fresh() {
        let st = StageTimes {
            t_a: 0.7e-3,
            t_e: 1.1e-3,
            t_c: 0.3e-3,
        };
        let mut core = PipelineCore::new(4, 6);
        let first = drive_fused(&mut core, 0.0, st);
        // Re-arm with a DIFFERENT shape: must match a brand-new core,
        // including relative completion times at a nonzero start offset.
        core.reset(2, 8);
        let reused = drive_fused(&mut core, 42.0, st);
        let fresh = drive(2, 8, st);
        assert_eq!(fresh.mb_done.len(), 2);
        for (a, b) in reused.mb_done.iter().zip(&fresh.mb_done) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert!((reused.total_time - fresh.total_time).abs() < 1e-9);
        assert!((reused.attn_utilization - fresh.attn_utilization).abs() < 1e-12);
        assert!((reused.expert_utilization - fresh.expert_utilization).abs() < 1e-12);
        assert_eq!(first.mb_done.len(), 4);
    }

    #[test]
    fn fused_queue_breaks_time_ties_by_insertion_order() {
        let mut q = FusedQueue::new();
        q.push(1.0, PipeEvent::AttnReady { mb: 0, layer: 0 });
        q.push(0.5, PipeEvent::AttnReady { mb: 1, layer: 0 });
        q.push(0.5, PipeEvent::AttnReady { mb: 2, layer: 0 });
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some((0.5, PipeEvent::AttnReady { mb: 1, layer: 0 })));
        assert_eq!(q.pop(), Some((0.5, PipeEvent::AttnReady { mb: 2, layer: 0 })));
        assert_eq!(q.pop(), Some((1.0, PipeEvent::AttnReady { mb: 0, layer: 0 })));
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn relative_times_independent_of_start_offset() {
        let st = StageTimes {
            t_a: 0.7,
            t_e: 1.3,
            t_c: 0.2,
        };
        let run_at = |t0: f64| {
            let mut core = PipelineCore::new(3, 4);
            let mut q: EventQueue<PipeEvent> = EventQueue::new();
            let mut out = Vec::new();
            core.start(t0, &mut out);
            for (at, e) in out.drain(..) {
                q.schedule_at(at, e);
            }
            loop {
                let (now, ev) = q.pop().expect("incomplete pipeline");
                if let Some(stats) = core.on_event(now, ev, &mut |_, _, _| st, &mut out) {
                    return stats;
                }
                for (at, e) in out.drain(..) {
                    q.schedule_at(at, e);
                }
            }
        };
        let a = run_at(0.0);
        let b = run_at(123.456);
        // Relative to pipeline start, up to float rounding from the offset.
        assert!(
            (a.total_time - b.total_time).abs() < 1e-9,
            "{} vs {}",
            a.total_time,
            b.total_time
        );
        for (x, y) in a.mb_done.iter().zip(&b.mb_done) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
        assert!((a.attn_utilization - b.attn_utilization).abs() < 1e-9);
        assert!((a.expert_utilization - b.expert_utilization).abs() < 1e-9);
    }
}
