//! The ping-pong pipeline scheduling core — the ONE implementation of the
//! paper's §4.1 micro-batch shuttle, shared by every simulation path.
//!
//! `m` micro-batches traverse `L` MoE layers, alternating between two
//! serially-reused stage resources ([`Stage`]): the attention pool and the
//! expert pool. Dispatch and combine transfers each take `t_c` and overlap
//! with compute. The core is expressed as a pure event-handling state
//! machine over [`PipeEvent`]s: it never owns an event queue. Callers pop
//! events from their own [`crate::sim::EventQueue`] and feed them in, which
//! is what lets the trace-driven [`crate::sim::engine::ClusterEngine`]
//! interleave pipeline hops with request arrivals and re-balancing on a
//! single virtual clock, while [`crate::coordinator::PingPongEngine`] runs
//! the same machine standalone as a scheduling policy.
//!
//! Stage times come from a caller-supplied provider, consulted exactly once
//! per (micro-batch, layer) hop and memoized, so stateful providers
//! (RNG-backed gating draws) stay deterministic.

use std::collections::VecDeque;

/// Per-stage/per-run statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineStats {
    /// Completion time of the last micro-batch, relative to pipeline start
    /// (seconds).
    pub total_time: f64,
    /// Attention-stage busy time / total time.
    pub attn_utilization: f64,
    /// Expert-stage busy time / total time.
    pub expert_utilization: f64,
    /// Per-micro-batch completion times (relative to pipeline start).
    pub mb_done: Vec<f64>,
}

/// Stage times for one (micro-batch, layer) traversal.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageTimes {
    /// Attention compute time for this micro-batch at this layer.
    pub t_a: f64,
    /// Expert compute time for this micro-batch at this layer.
    pub t_e: f64,
    /// One-direction communication time (applies to both the dispatch to
    /// the expert pool and the combine back to the attention pool).
    pub t_c: f64,
}

/// Events of one ping-pong pipeline pass. `mb` is the micro-batch index,
/// `layer` the MoE layer being traversed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipeEvent {
    /// Micro-batch ready to start attention of `layer`.
    AttnReady { mb: usize, layer: usize },
    /// Attention of (mb, layer) finished computing.
    AttnDone { mb: usize, layer: usize },
    /// Tokens handed to the M2N link for dispatch to the expert pool.
    Dispatch { mb: usize, layer: usize },
    /// Micro-batch arrived at the expert stage.
    ExpertReady { mb: usize, layer: usize },
    /// Expert compute finished.
    ExpertDone { mb: usize, layer: usize },
    /// Expert outputs handed to the M2N link for the combine transfer.
    Combine { mb: usize, layer: usize },
    /// Aggregated tokens arrived back at the attention nodes.
    BackAtAttn { mb: usize, layer: usize },
}

/// A serially-reused stage resource (one pool of GPUs acting as a single
/// pipeline stage): a busy-until clock, cumulative busy time, and a FIFO of
/// hops that are ready but waiting for the resource.
#[derive(Debug, Clone, Default)]
pub struct Stage {
    free_at: f64,
    busy: f64,
    ready: VecDeque<(usize, usize)>,
}

impl Stage {
    /// Queue a (mb, layer) hop as ready to run on this stage.
    pub fn offer(&mut self, mb: usize, layer: usize) {
        self.ready.push_back((mb, layer));
    }

    /// Whether the resource is idle at `now` (a completion at exactly `now`
    /// counts as idle — the resource frees at its busy-until instant).
    pub fn is_idle(&self, now: f64) -> bool {
        self.free_at <= now
    }

    /// Pop the next ready hop, if any.
    pub fn pop_ready(&mut self) -> Option<(usize, usize)> {
        self.ready.pop_front()
    }

    /// Occupy the resource for `dur` starting at `now`; returns the
    /// completion time.
    pub fn begin(&mut self, now: f64, dur: f64) -> f64 {
        self.free_at = now + dur;
        self.busy += dur;
        self.free_at
    }

    /// Cumulative busy seconds.
    pub fn busy_time(&self) -> f64 {
        self.busy
    }
}

/// The ping-pong scheduling policy over two stage resources and a link.
///
/// Owns no queue: [`PipelineCore::start`] and [`PipelineCore::on_event`]
/// emit `(at, event)` pairs into `out`, and the caller schedules them on
/// whatever event queue drives the simulation.
#[derive(Debug, Clone)]
pub struct PipelineCore {
    /// Micro-batches in flight.
    pub m: usize,
    /// MoE layers each micro-batch traverses.
    pub layers: usize,
    attn: Stage,
    expert: Stage,
    /// Memoized per-(mb, layer) stage times: the provider is consulted
    /// once per hop, in deterministic event order.
    cache: Vec<Option<StageTimes>>,
    mb_done: Vec<f64>,
    remaining: usize,
    started_at: f64,
}

impl PipelineCore {
    /// A fresh pass of `m` micro-batches over `layers` layers.
    pub fn new(m: usize, layers: usize) -> Self {
        assert!(m >= 1 && layers >= 1);
        Self {
            m,
            layers,
            attn: Stage::default(),
            expert: Stage::default(),
            cache: vec![None; m * layers],
            mb_done: vec![0.0; m],
            remaining: m,
            started_at: 0.0,
        }
    }

    /// Inject the `m` micro-batches at virtual time `at`.
    pub fn start(&mut self, at: f64, out: &mut Vec<(f64, PipeEvent)>) {
        self.started_at = at;
        self.remaining = self.m;
        for mb in 0..self.m {
            out.push((at, PipeEvent::AttnReady { mb, layer: 0 }));
        }
    }

    fn times_of(
        &mut self,
        now: f64,
        mb: usize,
        layer: usize,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
    ) -> StageTimes {
        let idx = mb * self.layers + layer;
        if self.cache[idx].is_none() {
            self.cache[idx] = Some(times(now, mb, layer));
        }
        self.cache[idx].unwrap()
    }

    fn try_start_attn(
        &mut self,
        now: f64,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
        out: &mut Vec<(f64, PipeEvent)>,
    ) {
        if !self.attn.is_idle(now) {
            return;
        }
        let Some((mb, layer)) = self.attn.pop_ready() else {
            return;
        };
        let dur = self.times_of(now, mb, layer, times).t_a;
        let end = self.attn.begin(now, dur);
        out.push((end, PipeEvent::AttnDone { mb, layer }));
    }

    fn try_start_expert(
        &mut self,
        now: f64,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
        out: &mut Vec<(f64, PipeEvent)>,
    ) {
        if !self.expert.is_idle(now) {
            return;
        }
        let Some((mb, layer)) = self.expert.pop_ready() else {
            return;
        };
        let dur = self.times_of(now, mb, layer, times).t_e;
        let end = self.expert.begin(now, dur);
        out.push((end, PipeEvent::ExpertDone { mb, layer }));
    }

    /// Handle one pipeline event at virtual time `now`, emitting follow-up
    /// events into `out`. Returns `Some(stats)` when the last micro-batch
    /// completes its final layer.
    pub fn on_event(
        &mut self,
        now: f64,
        ev: PipeEvent,
        times: &mut dyn FnMut(f64, usize, usize) -> StageTimes,
        out: &mut Vec<(f64, PipeEvent)>,
    ) -> Option<PipelineStats> {
        match ev {
            PipeEvent::AttnReady { mb, layer } => {
                self.attn.offer(mb, layer);
                self.try_start_attn(now, times, out);
            }
            PipeEvent::AttnDone { mb, layer } => {
                out.push((now, PipeEvent::Dispatch { mb, layer }));
                self.try_start_attn(now, times, out);
            }
            PipeEvent::Dispatch { mb, layer } => {
                let t_c = self.times_of(now, mb, layer, times).t_c;
                out.push((now + t_c, PipeEvent::ExpertReady { mb, layer }));
            }
            PipeEvent::ExpertReady { mb, layer } => {
                self.expert.offer(mb, layer);
                self.try_start_expert(now, times, out);
            }
            PipeEvent::ExpertDone { mb, layer } => {
                out.push((now, PipeEvent::Combine { mb, layer }));
                self.try_start_expert(now, times, out);
            }
            PipeEvent::Combine { mb, layer } => {
                let t_c = self.times_of(now, mb, layer, times).t_c;
                out.push((now + t_c, PipeEvent::BackAtAttn { mb, layer }));
            }
            PipeEvent::BackAtAttn { mb, layer } => {
                if layer + 1 < self.layers {
                    out.push((now, PipeEvent::AttnReady { mb, layer: layer + 1 }));
                } else {
                    self.mb_done[mb] = now - self.started_at;
                    self.remaining -= 1;
                    if self.remaining == 0 {
                        return Some(self.stats());
                    }
                }
            }
        }
        None
    }

    fn stats(&self) -> PipelineStats {
        let total_time = self.mb_done.iter().copied().fold(0.0, f64::max);
        // A zero-duration pass (every stage time 0, e.g. a degenerate
        // scenario sweep cell) must report 0 utilization, not NaN — the
        // NaN would propagate into ClusterReport and its JSON rendering.
        let util = |busy: f64| {
            if total_time > 0.0 {
                busy / total_time
            } else {
                0.0
            }
        };
        PipelineStats {
            total_time,
            attn_utilization: util(self.attn.busy_time()),
            expert_utilization: util(self.expert.busy_time()),
            mb_done: self.mb_done.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EventQueue;

    fn drive(m: usize, layers: usize, st: StageTimes) -> PipelineStats {
        let mut core = PipelineCore::new(m, layers);
        let mut q: EventQueue<PipeEvent> = EventQueue::new();
        let mut out = Vec::new();
        core.start(0.0, &mut out);
        for (at, e) in out.drain(..) {
            q.schedule_at(at, e);
        }
        while let Some((now, ev)) = q.pop() {
            if let Some(stats) = core.on_event(now, ev, &mut |_, _, _| st, &mut out) {
                return stats;
            }
            for (at, e) in out.drain(..) {
                q.schedule_at(at, e);
            }
        }
        panic!("pipeline drained without completing");
    }

    #[test]
    fn single_hop_is_full_round_trip() {
        let st = StageTimes {
            t_a: 1.0,
            t_e: 2.0,
            t_c: 0.5,
        };
        let stats = drive(1, 1, st);
        assert!((stats.total_time - 4.0).abs() < 1e-12, "{}", stats.total_time);
        assert_eq!(stats.mb_done, vec![4.0]);
    }

    #[test]
    fn stage_serializes_micro_batches() {
        // Two micro-batches, one layer, zero comm: attention serializes
        // (1, then 1 more), expert likewise; makespan = 1 + 1 + 1 = 3.
        let st = StageTimes {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.0,
        };
        let stats = drive(2, 1, st);
        assert!((stats.total_time - 3.0).abs() < 1e-12, "{}", stats.total_time);
    }

    #[test]
    fn zero_duration_iteration_reports_zero_utilization_not_nan() {
        // Regression: busy/total was 0/0 = NaN when every stage time is 0.
        let st = StageTimes {
            t_a: 0.0,
            t_e: 0.0,
            t_c: 0.0,
        };
        let stats = drive(2, 3, st);
        assert_eq!(stats.total_time, 0.0);
        assert_eq!(stats.attn_utilization, 0.0, "no NaN: {stats:?}");
        assert_eq!(stats.expert_utilization, 0.0, "no NaN: {stats:?}");
        assert!(stats.mb_done.iter().all(|&t| t == 0.0));
    }

    #[test]
    fn relative_times_independent_of_start_offset() {
        let st = StageTimes {
            t_a: 0.7,
            t_e: 1.3,
            t_c: 0.2,
        };
        let run_at = |t0: f64| {
            let mut core = PipelineCore::new(3, 4);
            let mut q: EventQueue<PipeEvent> = EventQueue::new();
            let mut out = Vec::new();
            core.start(t0, &mut out);
            for (at, e) in out.drain(..) {
                q.schedule_at(at, e);
            }
            loop {
                let (now, ev) = q.pop().expect("incomplete pipeline");
                if let Some(stats) = core.on_event(now, ev, &mut |_, _, _| st, &mut out) {
                    return stats;
                }
                for (at, e) in out.drain(..) {
                    q.schedule_at(at, e);
                }
            }
        };
        let a = run_at(0.0);
        let b = run_at(123.456);
        // Relative to pipeline start, up to float rounding from the offset.
        assert!(
            (a.total_time - b.total_time).abs() < 1e-9,
            "{} vs {}",
            a.total_time,
            b.total_time
        );
        for (x, y) in a.mb_done.iter().zip(&b.mb_done) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
        assert!((a.attn_utilization - b.attn_utilization).abs() < 1e-9);
        assert!((a.expert_utilization - b.expert_utilization).abs() < 1e-9);
    }
}
