//! Deterministic random source for the simulators — self-contained
//! (the `rand`/`rand_distr` crates are unavailable offline).
//!
//! Core generator: xoshiro256++ seeded via SplitMix64. Distributions: the
//! draws the network and workload models need — exponential inter-arrivals,
//! log-normal message jitter and length distributions (Box–Muller), Pareto
//! tails for GPU-sync/OS-noise stalls (inverse transform).

/// Seeded RNG with named draws for every stochastic element of the sims.
pub struct SimRng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl SimRng {
    /// Seed the generator (SplitMix64-expanded into xoshiro state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Next raw 64-bit value (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe for log().
    fn uniform_pos(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-53·n).
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.uniform_pos();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with the given mean (inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        -mean * self.uniform_pos().ln()
    }

    /// Log-normal parameterized by the *median* and sigma: median of
    /// LogNormal(mu, sigma) is exp(mu).
    pub fn lognormal_median(&mut self, median: f64, sigma: f64) -> f64 {
        debug_assert!(median > 0.0);
        (median.ln() + sigma * self.normal()).exp()
    }

    /// Pareto tail: minimum `scale`, shape `alpha` (heavy-tailed stalls;
    /// smaller alpha = heavier tail).
    pub fn pareto(&mut self, scale: f64, alpha: f64) -> f64 {
        debug_assert!(scale > 0.0 && alpha > 0.0);
        scale * self.uniform_pos().powf(-1.0 / alpha)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p.clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SimRng::new(7);
        let mut b = SimRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SimRng::new(1);
        let mut b = SimRng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = SimRng::new(3);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SimRng::new(4);
        let n = 100_000;
        let v: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = SimRng::new(1);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn lognormal_median_close() {
        let mut r = SimRng::new(2);
        let n = 20_001;
        let mut v: Vec<f64> = (0..n).map(|_| r.lognormal_median(571.0, 0.8)).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        let med = v[n / 2];
        assert!((med - 571.0).abs() / 571.0 < 0.05, "median {med}");
    }

    #[test]
    fn pareto_min_and_tail() {
        let mut r = SimRng::new(3);
        let mut over10 = 0;
        for _ in 0..20_000 {
            let p = r.pareto(1.5, 2.0);
            assert!(p >= 1.5);
            if p > 15.0 {
                over10 += 1;
            }
        }
        // P(X > 15) = (1.5/15)^2 = 1% — heavy tail present.
        assert!(over10 > 100, "tail draws {over10}");
    }

    #[test]
    fn below_bounds() {
        let mut r = SimRng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = SimRng::new(5);
        assert!(!r.chance(0.0));
        assert!(r.chance(1.0));
    }
}
