//! Deterministic sharded execution of the cluster engine.
//!
//! A disaggregated deployment at scale is a union of *independent*
//! node-groups: each shard owns a slice of the attention, prefill and
//! expert pools plus a slice of the aggregate decode batch, and there are
//! no cross-shard M2N edges. [`run_sharded`] exploits that: the scenario
//! is partitioned into `K` sub-clusters, the arrival stream is strided
//! across them ([`crate::workload::StridedSource`]), and each sub-cluster
//! runs its own [`ClusterEngine`] — stepped in lockstep virtual-time
//! *epochs* on a pool of `std::thread` workers and merged into one
//! [`ClusterReport`] at the end.
//!
//! # Determinism
//!
//! Reports are byte-identical for any worker count (and any epoch width)
//! because
//!
//! * shards share no mutable state: each engine owns its event queue, its
//!   RNG streams (seeded per shard through a SplitMix64 finalizer) and its
//!   arrival source, so a shard's event sequence is a pure function of its
//!   config — threads never exchange data mid-run;
//! * epoch boundaries only *batch* work, they cannot reorder it: within a
//!   shard, the engine's `step_until` pops events in exactly the order
//!   the unbounded run would, and the next boundary is derived from the
//!   minimum pending timestamp across shards (engine state), never from
//!   thread scheduling;
//! * the final merge folds per-shard reports in shard-index order, and
//!   [`crate::metrics::Histogram`] merging is order-deterministic.
//!
//! Worker count therefore changes only wall-clock time. The epoch
//! boundary exists purely so worker threads are joined at deterministic
//! points; with fully independent shards any width gives the same answer,
//! so [`DEFAULT_EPOCH`] is tuned for batching, not correctness.

use std::thread;

use crate::perf_model::prefill_node_gpus;
use crate::workload::ArrivalSource;

use super::cluster::{
    ClusterReport, ClusterSimConfig, EngineMode, FaultInjection, FaultKind, TenantReport,
};
use super::engine::ClusterEngine;

/// Default epoch width in virtual seconds — coarse enough that each worker
/// round carries thousands of events, fine enough to keep all workers busy.
/// Purely a batching knob: any width yields the same report.
pub const DEFAULT_EPOCH: f64 = 0.25;

/// Sharding parameters for [`run_sharded`].
#[derive(Debug, Clone, Copy)]
pub struct ShardPlan {
    /// Requested sub-cluster count. Clamped by [`effective_shards`] so
    /// every shard keeps at least one attention node, one expert node and
    /// — when the prefill pool is on — one prefill node.
    pub shards: usize,
    /// Worker threads stepping shards each epoch (clamped to the shard
    /// count; 1 = serial, still epoch-stepped, byte-identical results).
    pub workers: usize,
    /// Epoch width in virtual seconds (non-positive or non-finite =
    /// [`DEFAULT_EPOCH`]). A pure batching knob: any width yields the
    /// same report.
    pub epoch: f64,
}

impl ShardPlan {
    /// `shards` sub-clusters stepped by all available cores.
    pub fn new(shards: usize) -> Self {
        Self {
            shards,
            workers: thread::available_parallelism().map_or(1, |n| n.get()),
            epoch: DEFAULT_EPOCH,
        }
    }

    /// Override the worker-thread count.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }
}

/// Derive shard `i`'s seed from the scenario seed (SplitMix64 finalizer —
/// avalanches every bit so shard streams are uncorrelated even for
/// adjacent base seeds).
fn shard_seed(base: u64, shard: usize) -> u64 {
    let mut z = base ^ (shard as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Largest usable shard count for `cfg` given `requested`: every shard
/// must keep ≥1 attention node, ≥1 expert node and — when the prefill
/// pool is on — ≥1 prefill node. Colocated scenarios never shard: their
/// facade plan has no expert pool and the per-group inline-prefill path
/// is already a single serving group per node.
pub fn effective_shards(cfg: &ClusterSimConfig, requested: usize) -> usize {
    if matches!(cfg.mode, EngineMode::Colocated(_)) {
        return 1;
    }
    // Fault/elasticity injections DO shard: `shard_config` rewrites each
    // one against the shard's local pool slice (node-targeted kinds go to
    // the owning shard with a localized index, pool-wide kinds broadcast),
    // and `run_sharded` aligns epoch boundaries on injection instants.
    let mut s = requested
        .max(1)
        .min(cfg.plan.n_a.max(1))
        .min(cfg.plan.n_e.max(1));
    if cfg.prefill_nodes > 0 && cfg.prefill_chunk > 0 {
        s = s.min(cfg.prefill_nodes);
    }
    s.max(1)
}

/// Shard `shard`-of-`shards` sub-scenario: the node pools and the
/// aggregate decode batch split as evenly as possible (remainders going to
/// low-index shards), with an independent derived seed. Everything else —
/// model, hardware, routing, popularity, transport, tenants, horizon —
/// is inherited verbatim.
///
/// Fault/elasticity injections are rewritten against the shard's slice:
/// node-targeted kinds (fail/recover/straggle attention) survive only on
/// the shard owning the global node index, with the index localized to the
/// shard's pool; pool-wide kinds broadcast to every shard (`ResizeExperts`
/// with the width split the same way the expert pool itself is). Exactly
/// one surviving copy keeps `counted` so the merged report's injection
/// counters equal the unsharded run's — see
/// [`crate::sim::cluster::FaultInjection::counted`].
pub fn shard_config(cfg: &ClusterSimConfig, shard: usize, shards: usize) -> ClusterSimConfig {
    assert!(shard < shards, "shard {shard} of {shards}");
    let split = |total: usize| total / shards + usize::from(shard < total % shards);
    let mut c = cfg.clone();
    c.plan.n_a = split(cfg.plan.n_a.max(1)).max(1);
    c.plan.n_e = split(cfg.plan.n_e.max(1)).max(1);
    c.plan.n_p = split(cfg.plan.n_p);
    c.plan.global_batch = split(cfg.plan.global_batch).max(1);
    c.prefill_nodes = split(cfg.prefill_nodes);
    c.seed = shard_seed(cfg.seed, shard);
    if !cfg.injections.is_empty() {
        // This shard owns global attention nodes [start, start + count):
        // the same even split (remainders to low-index shards) as
        // `plan.n_a` above, expressed as a prefix-sum.
        let n_a = cfg.plan.n_a.max(1);
        let (base, rem) = (n_a / shards, n_a % shards);
        let start = shard * base + shard.min(rem);
        let count = base + usize::from(shard < rem);
        let localize =
            |node: usize| (node >= start && node < start + count).then_some(node - start);
        c.injections = cfg
            .injections
            .iter()
            .filter_map(|inj| {
                let (kind, owner) = match inj.kind {
                    FaultKind::FailAttention { node } => {
                        (FaultKind::FailAttention { node: localize(node)? }, true)
                    }
                    FaultKind::RecoverAttention { node } => {
                        (FaultKind::RecoverAttention { node: localize(node)? }, true)
                    }
                    FaultKind::StraggleAttention { node, factor } => (
                        FaultKind::StraggleAttention {
                            node: localize(node)?,
                            factor,
                        },
                        true,
                    ),
                    FaultKind::DegradeNic { factor } => {
                        (FaultKind::DegradeNic { factor }, shard == 0)
                    }
                    FaultKind::ResizeExperts { n_e } => (
                        FaultKind::ResizeExperts {
                            n_e: (n_e / shards + usize::from(shard < n_e % shards)).max(1),
                        },
                        shard == 0,
                    ),
                };
                Some(FaultInjection {
                    at: inj.at,
                    kind,
                    counted: inj.counted && owner,
                })
            })
            .collect();
    }
    c
}

/// GPUs a scenario occupies — mirrors the engine's per-GPU-throughput
/// divisor, including its normalization of the prefill pool (off when
/// `prefill_chunk == 0` or the mode is colocated, default node width from
/// the model footprint when `tp_p == 0`).
fn gpu_count(cfg: &ClusterSimConfig) -> f64 {
    let plan = &cfg.plan;
    let prefill_nodes = if cfg.prefill_chunk == 0 || matches!(cfg.mode, EngineMode::Colocated(_)) {
        0
    } else {
        cfg.prefill_nodes
    };
    let prefill_tp = if plan.tp_p > 0 {
        plan.tp_p
    } else {
        prefill_node_gpus(&cfg.model, &cfg.cluster)
    };
    (plan.tp_a * plan.n_a.max(1) + plan.tp_e * plan.n_e.max(1) + prefill_tp * prefill_nodes) as f64
}

/// Run `cfg` as `plan.shards` independent sub-clusters on `plan.workers`
/// threads and merge their reports. `make_source(shard, shards)` builds
/// each shard's arrival stream — typically a
/// [`crate::workload::StridedSource`] over the scenario's stream, so the
/// union of shard streams is exactly the unsharded workload.
///
/// With one effective shard this degrades to a plain
/// [`ClusterEngine::run`]; otherwise the report is byte-identical for any
/// worker count (see the module docs for the determinism argument).
pub fn run_sharded<F>(cfg: &ClusterSimConfig, plan: ShardPlan, make_source: F) -> ClusterReport
where
    F: Fn(usize, usize) -> Box<dyn ArrivalSource>,
{
    let shards = effective_shards(cfg, plan.shards);
    if shards == 1 {
        return ClusterEngine::new(cfg.clone(), make_source(0, 1)).run();
    }
    let configs: Vec<ClusterSimConfig> =
        (0..shards).map(|i| shard_config(cfg, i, shards)).collect();
    let mut engines: Vec<ClusterEngine> = configs
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let mut e = ClusterEngine::new(c.clone(), make_source(i, shards));
            e.prime();
            e
        })
        .collect();
    let workers = plan.workers.clamp(1, shards);
    let epoch = if plan.epoch.is_finite() && plan.epoch > 0.0 {
        plan.epoch
    } else {
        DEFAULT_EPOCH
    };
    // Injection instants are epoch barriers: every shard crosses each
    // scenario injection in the same worker round, at the identical
    // virtual time, so fault application stays aligned across shards —
    // derived from the config alone, never from thread scheduling.
    let mut barriers: Vec<f64> = cfg.injections.iter().map(|i| i.at).collect();
    barriers.sort_by(f64::total_cmp);
    barriers.dedup_by(|a, b| a.to_bits() == b.to_bits());
    let mut prev = 0.0;
    let mut end = epoch;
    loop {
        if let Some(&b) = barriers.iter().find(|&&b| b > prev && b < end) {
            end = b;
        }
        let min_next = step_round(&mut engines, end, workers);
        if !min_next.is_finite() {
            break; // every shard quiescent (or horizon-cut)
        }
        prev = end;
        // Next boundary: the epoch-grid point strictly after the earliest
        // pending event, so idle stretches are skipped in one jump while
        // boundaries stay deterministic (engine state only, no clocks).
        end = ((min_next / epoch).floor() * epoch + epoch).max(end + epoch);
    }
    let reports: Vec<ClusterReport> = engines.into_iter().map(ClusterEngine::finalize).collect();
    merge_reports(&configs, reports)
}

/// Step every engine up to the `until` boundary, striping engines across
/// `workers` scoped threads; returns the minimum pending timestamp across
/// shards (infinity when all are done). The per-thread fold and the final
/// join-order reduction are both min-reductions, so the result does not
/// depend on scheduling.
fn step_round(engines: &mut [ClusterEngine], until: f64, workers: usize) -> f64 {
    if workers <= 1 || engines.len() <= 1 {
        let mut min_next = f64::INFINITY;
        for e in engines.iter_mut() {
            if let Some(t) = e.step_until(until) {
                min_next = min_next.min(t);
            }
        }
        return min_next;
    }
    let chunk = engines.len().div_ceil(workers);
    let mut min_next = f64::INFINITY;
    thread::scope(|s| {
        let handles: Vec<_> = engines
            .chunks_mut(chunk)
            .map(|group| {
                s.spawn(move || {
                    let mut m = f64::INFINITY;
                    for e in group {
                        if let Some(t) = e.step_until(until) {
                            m = m.min(t);
                        }
                    }
                    m
                })
            })
            .collect();
        for h in handles {
            min_next = min_next.min(h.join().expect("shard worker panicked"));
        }
    });
    min_next
}

/// Fold per-shard reports (in shard-index order) into one aggregate.
///
/// Counters sum; `elapsed` is the max; rates are recomputed from the
/// merged totals; pool utilizations and mean stage times are weighted
/// means (by pool-GPU-seconds and by iterations respectively); histograms
/// merge in shard order; per-node vectors concatenate in shard order;
/// tenant slices zip-merge by index (every shard reports the same class
/// list).
fn merge_reports(configs: &[ClusterSimConfig], mut reports: Vec<ClusterReport>) -> ClusterReport {
    let gpus: f64 = configs.iter().map(gpu_count).sum();
    let elapsed = reports.iter().map(|r| r.elapsed).fold(0.0_f64, f64::max);
    let (mut attn_num, mut attn_den) = (0.0, 0.0);
    let (mut exp_num, mut exp_den) = (0.0, 0.0);
    let (mut ta_num, mut te_num, mut tc_num, mut t_den) = (0.0, 0.0, 0.0, 0.0);
    for (c, r) in configs.iter().zip(&reports) {
        let wa = c.plan.n_a.max(1) as f64 * r.elapsed;
        attn_num += r.attn_utilization * wa;
        attn_den += wa;
        let we = c.plan.n_e.max(1) as f64 * r.elapsed;
        exp_num += r.expert_utilization * we;
        exp_den += we;
        let wi = r.iterations as f64;
        ta_num += r.mean_t_a * wi;
        te_num += r.mean_t_e * wi;
        tc_num += r.mean_t_c * wi;
        t_den += wi;
    }
    let mut acc = reports.remove(0);
    for r in reports {
        acc.completed += r.completed;
        acc.tokens += r.tokens;
        acc.iterations += r.iterations;
        acc.ttft.merge(&r.ttft);
        acc.ttft_queue.merge(&r.ttft_queue);
        acc.ttft_prefill.merge(&r.ttft_prefill);
        acc.ttft_transfer.merge(&r.ttft_transfer);
        acc.ttft_decode.merge(&r.ttft_decode);
        acc.tpot.merge(&r.tpot);
        acc.e2e.merge(&r.e2e);
        acc.per_node_tokens.extend(r.per_node_tokens);
        acc.per_node_attn_busy.extend(r.per_node_attn_busy);
        acc.per_node_expert_busy.extend(r.per_node_expert_busy);
        acc.per_node_prefill_busy.extend(r.per_node_prefill_busy);
        acc.prefilled_tokens += r.prefilled_tokens;
        acc.kv_transferred_tokens += r.kv_transferred_tokens;
        acc.kv_blocks_in_use_at_end += r.kv_blocks_in_use_at_end;
        acc.rejected += r.rejected;
        acc.unserved_queued += r.unserved_queued;
        acc.peak_in_flight += r.peak_in_flight;
        acc.peak_queue_events += r.peak_queue_events;
        acc.dispatched_copies += r.dispatched_copies;
        acc.combined_copies += r.combined_copies;
        acc.processed_copies += r.processed_copies;
        acc.rebalances += r.rebalances;
        acc.injections_applied += r.injections_applied;
        acc.node_failures += r.node_failures;
        acc.node_recoveries += r.node_recoveries;
        acc.requeued_requests += r.requeued_requests;
        acc.lost_kv_blocks += r.lost_kv_blocks;
        acc.lost_decode_tokens += r.lost_decode_tokens;
        acc.re_prefilled_tokens += r.re_prefilled_tokens;
        acc.expert_resizes += r.expert_resizes;
        acc.clamped_past_schedules += r.clamped_past_schedules;
        debug_assert_eq!(acc.tenants.len(), r.tenants.len(), "tenant lists align");
        for (a, b) in acc.tenants.iter_mut().zip(r.tenants) {
            merge_tenant(a, b);
        }
    }
    acc.elapsed = elapsed;
    acc.throughput = if elapsed > 0.0 {
        acc.tokens as f64 / elapsed
    } else {
        0.0
    };
    acc.per_gpu_throughput = acc.throughput / gpus.max(1.0);
    acc.attn_utilization = if attn_den > 0.0 { attn_num / attn_den } else { 0.0 };
    acc.expert_utilization = if exp_den > 0.0 { exp_num / exp_den } else { 0.0 };
    acc.mean_t_a = if t_den > 0.0 { ta_num / t_den } else { 0.0 };
    acc.mean_t_e = if t_den > 0.0 { te_num / t_den } else { 0.0 };
    acc.mean_t_c = if t_den > 0.0 { tc_num / t_den } else { 0.0 };
    acc
}

fn merge_tenant(a: &mut TenantReport, b: TenantReport) {
    debug_assert_eq!(a.name, b.name, "tenant order matches across shards");
    a.completed += b.completed;
    a.ttft.merge(&b.ttft);
    a.ttft_queue.merge(&b.ttft_queue);
    a.ttft_prefill.merge(&b.ttft_prefill);
    a.ttft_transfer.merge(&b.ttft_transfer);
    a.ttft_decode.merge(&b.ttft_decode);
    a.e2e.merge(&b.e2e);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, GpuKind, ModelConfig};
    use crate::plan::PlanSearcher;
    use crate::workload::{RequestStream, StridedSource, WorkloadSpec};

    fn shardable_setup() -> ClusterSimConfig {
        let model = ModelConfig::tiny();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
            .search()
            .expect("tiny plan");
        let mut cfg = ClusterSimConfig {
            seed: 11,
            ..ClusterSimConfig::new(model, cluster, plan)
        };
        // Enough pool width to split four ways.
        cfg.plan.n_a = 4;
        cfg.plan.n_e = 4;
        cfg.plan.global_batch = cfg.plan.global_batch.max(8);
        cfg.prefill_nodes = 4;
        cfg.plan.n_p = 4;
        cfg
    }

    fn spec() -> WorkloadSpec {
        WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.3,
            arrival_rate: Some(120.0),
            ..Default::default()
        }
    }

    fn source_factory(
        spec: WorkloadSpec,
        n: usize,
        seed: u64,
    ) -> impl Fn(usize, usize) -> Box<dyn ArrivalSource> {
        move |shard, shards| {
            Box::new(StridedSource::new(
                RequestStream::new(spec.clone(), n, seed),
                shard,
                shards,
            ))
        }
    }

    #[test]
    fn effective_shards_respects_pool_widths() {
        let mut cfg = shardable_setup();
        assert_eq!(effective_shards(&cfg, 4), 4);
        assert_eq!(effective_shards(&cfg, 99), 4, "clamped to pool width");
        assert_eq!(effective_shards(&cfg, 0), 1);
        cfg.plan.n_e = 2;
        assert_eq!(effective_shards(&cfg, 4), 2, "expert pool limits");
        cfg.prefill_nodes = 1;
        assert_eq!(effective_shards(&cfg, 4), 1, "prefill pool limits");
        cfg.prefill_chunk = 0; // prefill off: its width no longer binds
        assert_eq!(effective_shards(&cfg, 4), 2);
    }

    #[test]
    fn shard_config_splits_pools_and_derives_seeds() {
        let cfg = shardable_setup();
        let parts: Vec<ClusterSimConfig> = (0..3).map(|i| shard_config(&cfg, i, 3)).collect();
        assert_eq!(parts.iter().map(|c| c.plan.n_a).sum::<usize>(), 4);
        assert_eq!(parts.iter().map(|c| c.plan.n_e).sum::<usize>(), 4);
        assert_eq!(parts.iter().map(|c| c.prefill_nodes).sum::<usize>(), 4);
        assert_eq!(
            parts.iter().map(|c| c.plan.global_batch).sum::<usize>(),
            cfg.plan.global_batch
        );
        // Remainders go to low-index shards.
        assert!(parts[0].plan.n_a >= parts[2].plan.n_a);
        // Seeds are derived, distinct, and deterministic.
        assert_ne!(parts[0].seed, parts[1].seed);
        assert_ne!(parts[1].seed, parts[2].seed);
        assert_eq!(parts[0].seed, shard_config(&cfg, 0, 3).seed);
    }

    #[test]
    fn worker_count_never_changes_the_report() {
        let cfg = shardable_setup();
        let n = 160;
        let base = run_sharded(
            &cfg,
            ShardPlan {
                shards: 4,
                workers: 1,
                epoch: DEFAULT_EPOCH,
            },
            source_factory(spec(), n, cfg.seed),
        );
        assert_eq!(base.completed, n as u64, "sharded run serves everything");
        for workers in [2, 4, 7] {
            let rep = run_sharded(
                &cfg,
                ShardPlan {
                    shards: 4,
                    workers,
                    epoch: DEFAULT_EPOCH,
                },
                source_factory(spec(), n, cfg.seed),
            );
            assert_eq!(
                rep.to_json().to_string(),
                base.to_json().to_string(),
                "byte-identical report with {workers} workers"
            );
        }
    }

    #[test]
    fn epoch_width_never_changes_the_report() {
        let cfg = shardable_setup();
        let n = 120;
        let mk = |epoch| {
            run_sharded(
                &cfg,
                ShardPlan {
                    shards: 2,
                    workers: 2,
                    epoch,
                },
                source_factory(spec(), n, cfg.seed),
            )
        };
        let base = mk(DEFAULT_EPOCH).to_json().to_string();
        assert_eq!(mk(0.01).to_json().to_string(), base);
        assert_eq!(mk(5.0).to_json().to_string(), base);
        assert_eq!(mk(-1.0).to_json().to_string(), base, "invalid width → default");
    }

    #[test]
    fn single_shard_matches_unsharded_run() {
        let cfg = shardable_setup();
        let n = 80;
        let sharded = run_sharded(&cfg, ShardPlan::new(1), source_factory(spec(), n, cfg.seed));
        let plain = ClusterEngine::new(
            cfg.clone(),
            Box::new(RequestStream::new(spec(), n, cfg.seed)),
        )
        .run();
        assert_eq!(sharded.to_json().to_string(), plain.to_json().to_string());
    }

    #[test]
    fn merged_totals_conserve_the_workload() {
        let cfg = shardable_setup();
        let n = 200;
        let rep = run_sharded(
            &cfg,
            ShardPlan {
                shards: 4,
                workers: 4,
                epoch: DEFAULT_EPOCH,
            },
            source_factory(spec(), n, cfg.seed),
        );
        let want: u64 = RequestStream::new(spec(), n, cfg.seed)
            .map(|r| r.output_len as u64)
            .sum();
        assert_eq!(rep.completed, n as u64);
        assert_eq!(rep.tokens, want, "every output token accounted once");
        assert_eq!(rep.ttft.count(), n as u64);
        assert_eq!(rep.e2e.count(), n as u64);
        assert_eq!(rep.per_node_tokens.len(), 4, "per-node vectors concatenate");
        assert!(rep.throughput > 0.0);
        assert!(rep.per_gpu_throughput > 0.0);
        assert!(rep.elapsed > 0.0);
    }

    #[test]
    fn fused_and_stepwise_sharded_reports_are_byte_identical() {
        // The fused fast path changes the step_until return values at epoch
        // boundaries (one IterEnd timestamp instead of many Pipe hops), but
        // epoch boundaries are a pure batching knob — so the merged report
        // must not move, fused or stepwise, sharded or not.
        let cfg = shardable_setup();
        assert!(cfg.fuse, "fast path is the default");
        let n = 160;
        let plan = ShardPlan {
            shards: 4,
            workers: 4,
            epoch: DEFAULT_EPOCH,
        };
        let fused = run_sharded(&cfg, plan, source_factory(spec(), n, cfg.seed));
        let mut scfg = cfg.clone();
        scfg.fuse = false;
        let stepwise = run_sharded(&scfg, plan, source_factory(spec(), n, cfg.seed));
        assert_eq!(
            fused.to_json().to_string(),
            stepwise.to_json().to_string(),
            "fused and stepwise sharded runs must agree byte-for-byte"
        );
    }

    #[test]
    fn shard_config_localizes_injections() {
        let mut cfg = shardable_setup();
        cfg.injections = vec![
            FaultInjection {
                at: 0.1,
                kind: FaultKind::FailAttention { node: 3 },
                counted: true,
            },
            FaultInjection {
                at: 0.2,
                kind: FaultKind::DegradeNic { factor: 2.0 },
                counted: true,
            },
            FaultInjection {
                at: 0.3,
                kind: FaultKind::ResizeExperts { n_e: 4 },
                counted: true,
            },
        ];
        assert_eq!(effective_shards(&cfg, 2), 2, "injections no longer clamp");
        let s0 = shard_config(&cfg, 0, 2);
        let s1 = shard_config(&cfg, 1, 2);
        // Shard 0 owns global attention nodes [0, 2): the node-targeted
        // failure on node 3 lands only on shard 1, localized to index 1.
        assert_eq!(s0.injections.len(), 2, "broadcasts only");
        assert_eq!(s1.injections.len(), 3);
        assert_eq!(
            s1.injections[0].kind,
            FaultKind::FailAttention { node: 1 },
            "global node 3 → shard-1 local node 1"
        );
        assert!(s1.injections[0].counted, "owner counts the failure");
        // Broadcasts reach both shards but only shard 0 counts them, and
        // the resize target splits like the expert pool itself (4 → 2+2).
        for (i, kind) in [
            (0, FaultKind::DegradeNic { factor: 2.0 }),
            (1, FaultKind::ResizeExperts { n_e: 2 }),
        ] {
            assert_eq!(s0.injections[i].kind, kind);
            assert!(s0.injections[i].counted);
            assert_eq!(s1.injections[i + 1].kind, kind);
            assert!(!s1.injections[i + 1].counted);
        }
        // Exactly one counted copy per scenario injection, shards summed.
        let counted = |c: &ClusterSimConfig| c.injections.iter().filter(|i| i.counted).count();
        assert_eq!(counted(&s0) + counted(&s1), cfg.injections.len());
    }

    #[test]
    fn injected_sharded_run_is_worker_invariant() {
        let mut cfg = shardable_setup();
        cfg.injections = vec![
            FaultInjection {
                at: 0.05,
                kind: FaultKind::FailAttention { node: 3 },
                counted: true,
            },
            FaultInjection {
                at: 0.1,
                kind: FaultKind::DegradeNic { factor: 1.5 },
                counted: true,
            },
            FaultInjection {
                at: 0.25,
                kind: FaultKind::RecoverAttention { node: 3 },
                counted: true,
            },
        ];
        let n = 160;
        // Two shards of two attention nodes each: the failure hits shard
        // 1's second node, so the shard keeps a live node throughout.
        let base = run_sharded(
            &cfg,
            ShardPlan {
                shards: 2,
                workers: 1,
                epoch: DEFAULT_EPOCH,
            },
            source_factory(spec(), n, cfg.seed),
        );
        assert_eq!(
            base.injections_applied,
            cfg.injections.len() as u64,
            "each scenario injection counted exactly once across shards"
        );
        assert_eq!(base.node_failures, 1);
        assert_eq!(base.node_recoveries, 1);
        for workers in [2, 4] {
            let rep = run_sharded(
                &cfg,
                ShardPlan {
                    shards: 2,
                    workers,
                    epoch: DEFAULT_EPOCH,
                },
                source_factory(spec(), n, cfg.seed),
            );
            assert_eq!(
                rep.to_json().to_string(),
                base.to_json().to_string(),
                "byte-identical injected report with {workers} workers"
            );
        }
    }

    #[test]
    fn colocated_scenarios_refuse_to_shard() {
        let model = ModelConfig::tiny();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let plan = crate::baselines::ColocatedPlan::sized_to_match(
            crate::baselines::BaselineKind::Vllm,
            &model,
            &cluster,
            8,
        );
        let cfg = ClusterSimConfig::colocated(model, cluster, plan);
        assert_eq!(effective_shards(&cfg, 8), 1);
    }

    /// Wall-clock scaling check (workers 4 vs 1 on a bigger run). Ignored
    /// in the default suite — timing-sensitive; run explicitly with
    /// `cargo test --release -- --ignored shard_speedup`.
    #[test]
    #[ignore]
    fn shard_speedup_with_four_workers() {
        let cfg = shardable_setup();
        let n = 20_000;
        let time = |workers| {
            // msi-lint: allow(wall-clock-in-sim) -- ignored speedup test times real execution; reports are compared bytewise elsewhere
            let t0 = std::time::Instant::now();
            let rep = run_sharded(
                &cfg,
                ShardPlan {
                    shards: 4,
                    workers,
                    epoch: DEFAULT_EPOCH,
                },
                source_factory(spec(), n, cfg.seed),
            );
            assert_eq!(rep.completed, n as u64);
            t0.elapsed().as_secs_f64()
        };
        let serial = time(1);
        let parallel = time(4);
        assert!(
            parallel * 2.0 <= serial,
            "expected ≥2x speedup, got {serial:.3}s → {parallel:.3}s"
        );
    }
}
