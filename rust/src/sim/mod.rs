//! Discrete-event simulation core shared by the M2N network simulator and
//! the coordinator's virtual-time backend, plus the trace-driven end-to-end
//! cluster simulator.
//!
//! Layers:
//!
//! * [`EventQueue`] — the kernel: virtual clock in f64 seconds, an indexed
//!   calendar (bucket) queue for scheduling, deterministic tie-breaking by
//!   insertion sequence so repeated runs are bit-identical;
//! * [`pipeline`] — the shared ping-pong scheduling state machine (one
//!   implementation for every simulation path);
//! * [`engine`] — the event-driven cluster engine: pluggable components
//!   (prefill pool, router front, attention pool, M2N link, expert pool)
//!   wired onto one queue, driving each request through the explicit
//!   `Queued → Prefill → KvTransfer → Decode → Done` lifecycle while
//!   pulling arrivals from a streaming [`crate::workload::ArrivalSource`];
//! * [`cluster`] — scenario configuration + reporting, the public facade;
//! * [`scenario`] — the declarative `.msc` scenario language (`msi
//!   scenario`): phased workload timelines plus fault / elasticity
//!   injection, compiled onto the engine;
//! * [`shard`] — deterministic sharded execution: independent sub-clusters
//!   on worker threads with epoch-merged reports;
//! * [`sweep`] — multi-threaded scenario-grid sweeps and the simulator
//!   self-throughput benchmark.
//!
//! # Event-queue ordering contract
//!
//! [`EventQueue::pop`] always returns the globally earliest event, breaking
//! exact-time ties by insertion sequence. This is the same contract the
//! original `BinaryHeap` kernel had; the calendar layout only changes *how*
//! the minimum is found (O(1) amortized instead of O(log n), with bucket
//! vectors reused as slabs so steady-state scheduling is allocation-free),
//! never *which* event is the minimum. The bucket width and bucket count
//! are pure performance knobs: pops are bit-identical for any setting.

pub mod cluster;
pub mod engine;
pub mod pipeline;
mod rng;
pub mod scenario;
pub mod shard;
pub mod sweep;

pub use cluster::{
    ClusterReport, ClusterSim, ClusterSimConfig, EngineMode, ExpertPopularity, FaultInjection,
    FaultKind, TenantReport, Transport,
};
pub use engine::{
    ClusterEngine, Component, EngineScratch, Event, PrefillPool, RequestPhase, RequestTable,
    StageModel,
};
pub use pipeline::{FusedQueue, PipeEvent, PipelineCore, PipelineStats, StageTimes};
pub use rng::SimRng;
pub use shard::{run_sharded, ShardPlan};
pub use sweep::{run_sim_bench, run_sweep, SweepCell, SweepGrid};

use std::cmp::Ordering;
use std::fmt;

/// Relative epsilon within which a past-time schedule is saturated to `now`
/// instead of rejected. Floating-point service-time arithmetic can land an
/// event a few ulps behind the clock legitimately; anything further in the
/// past is a logic bug in the caller and is reported as a hard error.
const PAST_EPSILON: f64 = 1e-9;

/// Minimum (and initial) number of calendar buckets. Always a power of two.
const MIN_BUCKETS: usize = 16;

/// Initial bucket width in virtual seconds, used until the first rehash
/// measures the live event span and adapts.
const INITIAL_WIDTH: f64 = 1e-3;

/// Pops between periodic rehashes. A rehash re-measures the live event
/// span and re-picks the bucket width, so a queue whose population is
/// stable (no grow/shrink trigger) still tracks the event horizon as the
/// clock advances. Purely a performance knob — see the ordering contract.
const REHASH_INTERVAL: usize = 16_384;

/// An event payload tagged with its due time and insertion sequence.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

/// A schedule request rejected because its timestamp lies in the simulated
/// past (beyond the clamping epsilon) or is NaN.
///
/// Returned by [`EventQueue::try_schedule_at`];
/// [`EventQueue::schedule_at`] panics on it instead.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PastScheduleError {
    /// The rejected timestamp.
    pub at: f64,
    /// The queue's virtual clock at the time of the attempt.
    pub now: f64,
}

impl fmt::Display for PastScheduleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "cannot schedule in the past: at={} is behind now={} by more than epsilon",
            self.at, self.now
        )
    }
}

impl std::error::Error for PastScheduleError {}

/// Event-driven simulator kernel with a virtual clock.
///
/// Internally an indexed calendar queue (R. Brown, CACM 1988): cycle `k`
/// of the virtual calendar (`k = floor(time / width)`) maps to bucket
/// `k & mask`, a cursor drains cycles in order, and a direct-search
/// fallback handles sparse stretches where no event falls within a full
/// calendar rotation of the cursor. Bucket vectors are retained across
/// pops (`swap_remove`), so a steady-state simulation schedules events
/// with no allocation at all.
pub struct EventQueue<E> {
    /// Bucket ring; `buckets.len()` is always a power of two.
    buckets: Vec<Vec<Scheduled<E>>>,
    /// `buckets.len() - 1`, as u64 for masking cycle numbers.
    mask: u64,
    /// Width of one bucket in virtual seconds (performance knob only).
    width: f64,
    /// Calendar cycle the cursor is draining: events with
    /// `cycle_of(time) == cur_k` live in `buckets[(cur_k & mask)]`.
    cur_k: u64,
    len: usize,
    now: f64,
    seq: u64,
    clamped_past: u64,
    pops_since_rehash: usize,
}

/// Bucket count for a queue currently holding `len` events (load factor
/// ~1, power of two, never below [`MIN_BUCKETS`]).
fn target_buckets(len: usize) -> usize {
    len.next_power_of_two().max(MIN_BUCKETS)
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// An empty queue at virtual time 0.
    pub fn new() -> Self {
        let mut buckets = Vec::with_capacity(MIN_BUCKETS);
        buckets.resize_with(MIN_BUCKETS, Vec::new);
        Self {
            buckets,
            mask: (MIN_BUCKETS - 1) as u64,
            width: INITIAL_WIDTH,
            cur_k: 0,
            len: 0,
            now: 0.0,
            seq: 0,
            clamped_past: 0,
            pops_since_rehash: 0,
        }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Past-time schedules saturated to `now` because they fell within the
    /// clamping epsilon (see [`EventQueue::try_schedule_at`]). A non-zero
    /// count is benign floating-point jitter; it is surfaced in
    /// [`cluster::ClusterReport`] so silent clamping is visible.
    pub fn clamped_past_schedules(&self) -> u64 {
        self.clamped_past
    }

    /// Schedule `event` at absolute time `at`.
    ///
    /// Timestamps within a relative epsilon *behind* the clock are
    /// saturated to `now` and counted in
    /// [`EventQueue::clamped_past_schedules`]; anything further in the
    /// past — or NaN — is rejected with [`PastScheduleError`].
    // msi-lint: hot
    pub fn try_schedule_at(&mut self, at: f64, event: E) -> Result<(), PastScheduleError> {
        if at.is_nan() {
            return Err(PastScheduleError { at, now: self.now });
        }
        let time = if at < self.now {
            let eps = PAST_EPSILON * self.now.abs().max(1.0);
            if self.now - at > eps {
                return Err(PastScheduleError { at, now: self.now });
            }
            self.clamped_past += 1;
            self.now
        } else {
            at
        };
        self.push(time, event);
        Ok(())
    }

    /// Schedule `event` at absolute time `at` (must be >= now, up to the
    /// clamping epsilon). Panics where [`EventQueue::try_schedule_at`]
    /// would return an error.
    pub fn schedule_at(&mut self, at: f64, event: E) {
        if let Err(e) = self.try_schedule_at(at, event) {
            panic!("{e}");
        }
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Calendar cycle of timestamp `t`. The saturating cast sends
    /// anything beyond `u64` cycles to the last cycle, where the
    /// direct-search fallback keeps pop order exact.
    fn cycle_of(&self, t: f64) -> u64 {
        (t / self.width).floor() as u64
    }

    // msi-lint: hot
    fn push(&mut self, time: f64, event: E) {
        let seq = self.seq;
        self.seq += 1;
        let k = self.cycle_of(time);
        // An empty queue lets the cursor jump straight to the new event;
        // an insert behind the cursor (legal: `now` can sit mid-cycle
        // after the cursor moved past an empty stretch) pulls it back so
        // no due event is ever skipped.
        if self.len == 0 || k < self.cur_k {
            self.cur_k = k;
        }
        let b = (k & self.mask) as usize;
        self.buckets[b].push(Scheduled { time, seq, event });
        self.len += 1;
        if self.len > self.buckets.len() * 2 {
            self.rehash(target_buckets(self.len));
        }
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    // msi-lint: hot
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let (b, i) = self.find_min()?;
        Some(self.take(b, i))
    }

    /// Timestamp of the earliest event without popping it (the epoch-based
    /// sharded runner uses this to stop exactly at an epoch boundary
    /// without disturbing insertion order). Cursor advancement is the only
    /// state this touches — a pure performance effect.
    pub fn peek_time(&mut self) -> Option<f64> {
        let (b, i) = self.find_min()?;
        Some(self.buckets[b][i].time)
    }

    /// Locate the earliest event as (bucket, slot), advancing the cursor
    /// past verified-empty cycles.
    // msi-lint: hot
    fn find_min(&mut self) -> Option<(usize, usize)> {
        if self.len == 0 {
            return None;
        }
        // Drain cycles in order: scan the cursor's bucket for the minimum
        // (time, seq) among events due this cycle. All events of one cycle
        // share one bucket, and no event of an earlier cycle can remain
        // (the cursor only advances through verified-empty cycles and is
        // pulled back by behind-cursor inserts), so a hit here is the
        // global minimum.
        for _ in 0..self.buckets.len() {
            let b = (self.cur_k & self.mask) as usize;
            let mut best: Option<(f64, u64, usize)> = None;
            for (i, it) in self.buckets[b].iter().enumerate() {
                if self.cycle_of(it.time) != self.cur_k {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some((bt, bs, _)) => {
                        it.time.total_cmp(bt).then(it.seq.cmp(bs)) == Ordering::Less
                    }
                };
                if better {
                    best = Some((it.time, it.seq, i));
                }
            }
            if let Some((_, _, i)) = best {
                return Some((b, i));
            }
            if self.cur_k == u64::MAX {
                break; // saturated tail: only the direct search helps
            }
            self.cur_k += 1;
        }
        // Sparse stretch: nothing due within a full calendar rotation.
        // Find the global minimum directly and jump the cursor to it.
        let mut best: Option<(f64, u64, usize, usize)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, it) in bucket.iter().enumerate() {
                let better = match &best {
                    None => true,
                    Some((bt, bs, _, _)) => {
                        it.time.total_cmp(bt).then(it.seq.cmp(bs)) == Ordering::Less
                    }
                };
                if better {
                    best = Some((it.time, it.seq, b, i));
                }
            }
        }
        // msi-lint: allow(unwrap-in-engine) -- guarded by the len == 0 early return at function entry
        let (time, _, b, i) = best.expect("non-empty queue has a minimum event");
        self.cur_k = self.cycle_of(time);
        Some((b, i))
    }

    /// Remove slot `i` of bucket `b`, advance the clock, and run the
    /// shrink / periodic-rehash policy.
    // msi-lint: hot
    fn take(&mut self, b: usize, i: usize) -> (f64, E) {
        let s = self.buckets[b].swap_remove(i);
        self.len -= 1;
        self.now = s.time;
        self.pops_since_rehash += 1;
        if self.len * 8 < self.buckets.len() && self.buckets.len() > MIN_BUCKETS {
            self.rehash(target_buckets(self.len));
        } else if self.pops_since_rehash >= REHASH_INTERVAL && self.len >= 2 {
            self.rehash(target_buckets(self.len));
        }
        (s.time, s.event)
    }

    /// Re-bucket every live event into `new_len` buckets, re-measuring
    /// the event span to pick a width that spreads ~1 event per bucket.
    fn rehash(&mut self, new_len: usize) {
        let mut items: Vec<Scheduled<E>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            items.append(b);
        }
        if new_len < self.buckets.len() {
            self.buckets.truncate(new_len);
        } else {
            self.buckets.resize_with(new_len, Vec::new);
        }
        self.mask = new_len as u64 - 1;
        if items.len() >= 2 {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for it in &items {
                lo = lo.min(it.time);
                hi = hi.max(it.time);
            }
            let span = hi - lo;
            if span.is_finite() && span > 0.0 {
                self.width = span / items.len() as f64;
            }
        }
        // Remaining events are all >= now, so no live cycle precedes
        // cycle_of(now): restarting the cursor there cannot skip events.
        self.cur_k = self.cycle_of(self.now);
        for it in items {
            let b = (self.cycle_of(it.time) & self.mask) as usize;
            self.buckets[b].push(it);
        }
        self.pops_since_rehash = 0;
    }

    /// No scheduled events remain.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Scheduled events currently outstanding.
    pub fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.schedule_in(1.0, ());
        assert_eq!(q.pop().unwrap().0, 6.0);
    }

    #[test]
    #[should_panic(expected = "cannot schedule in the past")]
    fn far_past_scheduling_panics() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, ());
        q.pop();
        q.schedule_at(1.0, ());
    }

    #[test]
    fn within_epsilon_past_clamps_to_now_and_counts() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, "first");
        q.pop();
        assert_eq!(q.clamped_past_schedules(), 0);
        // 1e-12 behind a clock at 1.0 is within the 1e-9 relative epsilon.
        q.schedule_at(1.0 - 1e-12, "jitter");
        assert_eq!(q.clamped_past_schedules(), 1);
        let (t, e) = q.pop().unwrap();
        assert_eq!(t, 1.0, "clamped event saturates to now");
        assert_eq!(e, "jitter");
        assert_eq!(q.now(), 1.0);
    }

    #[test]
    fn beyond_epsilon_past_is_a_hard_error() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, ());
        q.pop();
        let err = q.try_schedule_at(2.0 - 1e-3, ()).unwrap_err();
        assert_eq!(err.now, 2.0);
        assert_eq!(err.at, 2.0 - 1e-3);
        // The rejected event was not enqueued and did not count as a clamp.
        assert_eq!(q.clamped_past_schedules(), 0);
        assert!(q.is_empty());
    }

    #[test]
    fn nan_schedule_is_rejected() {
        let mut q = EventQueue::new();
        assert!(q.try_schedule_at(f64::NAN, ()).is_err());
        assert!(q.is_empty());
        assert_eq!(q.clamped_past_schedules(), 0);
    }

    #[test]
    fn interleaved_schedule_pop_stays_sorted_across_resizes() {
        // Enough churn to cross grow + shrink thresholds and exercise the
        // sparse direct-search path (far-future outlier).
        let mut q = EventQueue::new();
        let mut rng = SimRng::new(42);
        let mut popped: Vec<f64> = Vec::new();
        let mut scheduled = 0usize;
        for round in 0..200 {
            for _ in 0..40 {
                let t = q.now() + rng.uniform() * 0.01;
                q.schedule_at(t, scheduled);
                scheduled += 1;
            }
            if round == 0 {
                // Outlier an eternity past the working set.
                q.schedule_at(1.0e9, usize::MAX);
                scheduled += 1;
            }
            for _ in 0..30 {
                if let Some((t, _)) = q.pop() {
                    popped.push(t);
                }
            }
        }
        while let Some((t, _)) = q.pop() {
            popped.push(t);
        }
        assert_eq!(popped.len(), scheduled);
        assert!(
            popped.windows(2).all(|w| w[0] <= w[1]),
            "pops are globally time-ordered"
        );
        assert_eq!(*popped.last().unwrap(), 1.0e9);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn same_time_burst_pops_in_insertion_order_after_resize() {
        let mut q = EventQueue::new();
        // A burst far larger than MIN_BUCKETS forces grow rehashes while
        // every event shares one timestamp: order must stay insertion seq.
        for i in 0..500u32 {
            q.schedule_at(7.5, i);
        }
        for expect in 0..500u32 {
            let (t, e) = q.pop().unwrap();
            assert_eq!(t, 7.5);
            assert_eq!(e, expect);
        }
        assert!(q.pop().is_none());
    }
}
