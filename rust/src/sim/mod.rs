//! Discrete-event simulation core shared by the M2N network simulator and
//! the coordinator's virtual-time backend, plus the trace-driven end-to-end
//! cluster simulator.
//!
//! Layers:
//!
//! * [`EventQueue`] — the kernel: virtual clock in f64 seconds, binary-heap
//!   scheduling, deterministic tie-breaking by insertion sequence so
//!   repeated runs are bit-identical;
//! * [`pipeline`] — the shared ping-pong scheduling state machine (one
//!   implementation for every simulation path);
//! * [`engine`] — the event-driven cluster engine: pluggable components
//!   (prefill pool, router front, attention pool, M2N link, expert pool)
//!   wired onto one queue, driving each request through the explicit
//!   `Queued → Prefill → KvTransfer → Decode → Done` lifecycle while
//!   pulling arrivals from a streaming [`crate::workload::ArrivalSource`];
//! * [`cluster`] — scenario configuration + reporting, the public facade;
//! * [`sweep`] — multi-threaded scenario-grid sweeps and the simulator
//!   self-throughput benchmark.

pub mod cluster;
pub mod engine;
pub mod pipeline;
mod rng;
pub mod sweep;

pub use cluster::{
    ClusterReport, ClusterSim, ClusterSimConfig, EngineMode, ExpertPopularity, TenantReport,
    Transport,
};
pub use engine::{
    ClusterEngine, Component, Event, PrefillPool, RequestPhase, RequestTable, StageModel,
};
pub use pipeline::{PipeEvent, PipelineCore, PipelineStats, StageTimes};
pub use rng::SimRng;
pub use sweep::{run_sim_bench, run_sweep, SweepCell, SweepGrid};

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event payload. Generic over the simulation's event type `E`.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, tie-break on
        // sequence for determinism.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Event-driven simulator with a virtual clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: f64,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// An empty queue at virtual time 0.
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
        }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute time `at` (must be >= now).
    pub fn schedule_at(&mut self, at: f64, event: E) {
        debug_assert!(at >= self.now, "cannot schedule in the past");
        self.heap.push(Scheduled {
            time: at.max(self.now),
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            self.now = s.time;
            (s.time, s.event)
        })
    }

    /// No scheduled events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Scheduled events currently outstanding.
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.schedule_in(1.0, ());
        assert_eq!(q.pop().unwrap().0, 6.0);
    }

    #[test]
    #[should_panic(expected = "cannot schedule in the past")]
    #[cfg(debug_assertions)]
    fn past_scheduling_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, ());
        q.pop();
        q.schedule_at(1.0, ());
    }
}
