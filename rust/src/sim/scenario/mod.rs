//! `msi scenario`: a declarative scenario language (`.msc`) for the
//! cluster simulator.
//!
//! A scenario file is the whole experiment as data — deployment knobs,
//! a phased non-stationary workload timeline, and scheduled fault /
//! elasticity injections — replacing ad-hoc CLI flag combinations
//! (ROADMAP item 4: production-scale serving of heavy, shifting traffic):
//!
//! ```text
//! scenario "flash-crowd" {
//!   seed 7
//!   model tiny
//!   gpu ampere
//!   workload {
//!     phase "calm"  { duration 4 rate constant 20 }
//!     phase "spike" { duration 2 rate constant 200 input 120 }
//!     phase "cool"  { duration 6 rate ramp 40 -> 10 }
//!   }
//!   inject {
//!     at 5.0 fail attention 1
//!     at 8.0 recover attention 1
//!   }
//! }
//! ```
//!
//! The pipeline is [`parse`] (hand-rolled lexer + recursive-descent
//! parser, zero dependencies, golden `line:col: expected X, found Y`
//! diagnostics pinned by the fixture corpus) → [`compile`] (name
//! resolution, plan search, semantic validation, folding relative expert
//! elasticity into absolute targets) → [`CompiledScenario::run`] (or the
//! sharded runner). Workload phases lower to
//! [`crate::workload::PhasedSource`]; injections lower to
//! [`crate::sim::cluster::FaultInjection`] events applied by the engine
//! at iteration boundaries, which keeps fused and stepwise runs
//! byte-identical (see `DESIGN.md`).

mod ast;
mod compile;
mod lexer;
mod parser;

pub use ast::{
    ActionAst, InjectAst, PhaseAst, RateAst, ScenarioAst, TenantAst, DEFAULT_INPUT,
    DEFAULT_OUTPUT, DEFAULT_SIGMA,
};
pub use compile::{compile, CompiledScenario};
pub use lexer::ScenarioError;
pub use parser::parse;

/// Read, parse, and compile a scenario file; parse errors are prefixed
/// with the path (`file.msc:line:col: ...`).
pub fn load(path: &str) -> anyhow::Result<CompiledScenario> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let ast = parse(&src).map_err(|e| anyhow::anyhow!("{path}:{e}"))?;
    compile(&ast).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# A kitchen-sink scenario exercising every construct once.
scenario "kitchen-sink" {
  seed 7
  model tiny
  gpu ampere
  horizon 30.0
  micro-batches 2
  prefill 2
  skew 1.2
  rebalance 2.0
  tenant "interactive" weight 3.0 slo 4.0
  tenant "batch" weight 1.0 slo 30.0
  workload {
    phase "calm" {
      duration 4.0
      rate constant 20.0
    }
    phase "spike" {
      duration 2.0
      rate ramp 40.0 -> 200.0
      input 120.0
      output 32.0
      sigma 0.4
      mix 1.0 0.0
    }
    phase "diurnal" {
      duration 8.0
      rate sine 30.0 amplitude 0.8 period 4.0
    }
  }
  inject {
    at 3.0 straggle attention 0 factor 2.5
    at 4.0 fail attention 1
    at 5.0 degrade nic factor 3.0
    at 6.0 shrink experts 1
    at 7.0 grow experts 1
    at 8.0 restore nic
    at 9.0 recover attention 1
    at 9.5 straggle attention 0 factor 1.0
  }
}
"#;

    #[test]
    fn example_parses_compiles_and_round_trips() {
        let ast = parse(EXAMPLE).expect("parse");
        assert_eq!(ast.name, "kitchen-sink");
        assert_eq!(ast.phases.len(), 3);
        assert_eq!(ast.injects.len(), 8);
        let printed = ast.pretty();
        let reparsed = parse(&printed).expect("reparse the pretty-print");
        assert_eq!(ast, reparsed, "pretty-print round-trips");
        let c = compile(&ast).expect("compile");
        assert_eq!(c.cfg.seed, 7);
        assert_eq!(c.cfg.plan.m, 2);
        assert_eq!(c.cfg.prefill_nodes, 2);
        assert_eq!(c.cfg.injections.len(), 8);
        assert_eq!(c.cfg.tenants.len(), 2);
        assert!((c.cfg.max_sim_seconds.unwrap() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn parse_errors_carry_line_and_column() {
        let e = parse("scenario \"x\" {\n  bogus 3\n}").unwrap_err();
        assert_eq!((e.line, e.col), (2, 3));
        assert_eq!(e.to_string(), "2:3: expected a scenario item or `}`, found `bogus`");
    }

    #[test]
    fn elasticity_folds_to_absolute_targets_in_time_order() {
        let src = r#"scenario "x" {
  workload { phase "p" { duration 5.0 rate constant 10.0 } }
  inject {
    at 1.0 shrink experts 2
    at 2.0 shrink experts 1
    at 3.0 grow experts 3
  }
}"#;
        let c = compile(&parse(src).expect("parse")).expect("compile");
        let base = c.cfg.plan.n_e;
        let targets: Vec<usize> = c
            .cfg
            .injections
            .iter()
            .map(|i| match i.kind {
                crate::sim::cluster::FaultKind::ResizeExperts { n_e } => n_e,
                _ => panic!("expected resize"),
            })
            .collect();
        assert_eq!(targets, vec![base - 2, base - 3, base]);
    }

    #[test]
    fn compile_rejects_out_of_range_nodes_and_bad_factors() {
        let mk = |inject: &str| {
            let src = format!(
                "scenario \"x\" {{\n  workload {{ phase \"p\" {{ duration 5.0 \
                 rate constant 10.0 }} }}\n  inject {{ {inject} }}\n}}"
            );
            compile(&parse(&src).expect("parse"))
        };
        assert!(mk("at 1.0 fail attention 99").is_err());
        assert!(mk("at 1.0 straggle attention 0 factor 0.0").is_err());
        assert!(mk("at 1.0 shrink experts 999").is_err());
        assert!(mk("at 2.0 fail attention 0 at 1.0 recover attention 0").is_err());
        assert!(mk("at 1.0 fail attention 0").is_ok());
    }
}
