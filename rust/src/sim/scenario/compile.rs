//! Scenario compiler: AST → a runnable cluster configuration.
//!
//! The compiler resolves model/GPU names, runs the Chapter-5 plan search
//! for the deployment shape (honoring the scenario's overrides), folds
//! relative `shrink`/`grow` expert elasticity into absolute
//! [`FaultKind::ResizeExperts`] targets, and validates every semantic
//! constraint the grammar cannot express (node indices in range, positive
//! factors, tenant-mix arity, time-ordered injections). Validation errors
//! are plain `anyhow` messages — positional diagnostics belong to the
//! parser.

use anyhow::{anyhow, bail, Result};

use crate::config::{ClusterSpec, GpuKind, ModelConfig, NodeSpec};
use crate::plan::PlanSearcher;
use crate::sim::cluster::{
    ClusterSimConfig, EngineMode, ExpertPopularity, FaultInjection, FaultKind,
};
use crate::sim::engine::ClusterEngine;
use crate::sim::ClusterReport;
use crate::workload::{PhaseSpec, PhasedSource, RateCurve, TenantClass, WorkloadSpec};

use super::ast::{ActionAst, RateAst, ScenarioAst};

/// Workload-seed salt: decorrelates the arrival generator from the
/// engine's gating stream, matching `msi sweep`'s discipline.
const WL_SEED_SALT: u64 = 0xa076_1d64_78bd_642f;

/// A compiled, runnable scenario.
#[derive(Debug, Clone)]
pub struct CompiledScenario {
    /// Scenario name (from the file).
    pub name: String,
    /// Full engine configuration, injections included.
    pub cfg: ClusterSimConfig,
    /// Phased workload timeline.
    pub phases: Vec<PhaseSpec>,
    /// Base tenant weights (empty = single tenant).
    pub tenant_mix: Vec<f64>,
    /// Length clamp for the generated requests.
    pub max_len: usize,
}

impl CompiledScenario {
    /// A fresh arrival stream for the scenario (bit-identical each call).
    pub fn source(&self) -> PhasedSource {
        PhasedSource::new(
            self.phases.clone(),
            self.tenant_mix.clone(),
            self.max_len,
            self.cfg.seed ^ WL_SEED_SALT,
        )
    }

    /// Run the scenario single-sharded with the configured engine mode.
    pub fn run(&self) -> ClusterReport {
        ClusterEngine::new(self.cfg.clone(), Box::new(self.source())).run()
    }
}

fn parse_model(name: &str) -> Result<ModelConfig> {
    Ok(match name.to_lowercase().as_str() {
        "mixtral" | "mixtral-8x22b" => ModelConfig::mixtral_8x22b(),
        "dbrx" => ModelConfig::dbrx(),
        "scaled-moe" | "scaled_moe" | "scaled" => ModelConfig::scaled_moe(),
        "tiny" => ModelConfig::tiny(),
        other => bail!("unknown model `{other}`"),
    })
}

fn parse_gpu(name: &str) -> Result<GpuKind> {
    Ok(match name.to_lowercase().as_str() {
        "ampere" | "a100" => GpuKind::Ampere80G,
        "h20" => GpuKind::H20,
        "l40s" => GpuKind::L40S,
        "a800" => GpuKind::A800,
        "h800" => GpuKind::H800,
        "l20" => GpuKind::L20,
        other => bail!("unknown gpu `{other}`"),
    })
}

fn check_finite(what: &str, x: f64) -> Result<()> {
    if !x.is_finite() {
        bail!("{what} must be finite (got {x})");
    }
    Ok(())
}

/// Mean arrival rate of a curve over its phase (used only to weight the
/// plan search's average-sequence estimate).
fn mean_rate(rate: &RateAst) -> f64 {
    match *rate {
        RateAst::Constant(r) => r,
        RateAst::Ramp(from, to) => 0.5 * (from + to),
        RateAst::Sine { mean, .. } => mean,
    }
}

/// Compile a parsed scenario into a runnable configuration.
pub fn compile(ast: &ScenarioAst) -> Result<CompiledScenario> {
    let model = parse_model(&ast.model)?;
    let attn = parse_gpu(&ast.attn_gpu)?;
    let cluster = match &ast.expert_gpu {
        None => ClusterSpec::homogeneous(attn),
        Some(e) => ClusterSpec {
            attention: NodeSpec {
                gpu: attn,
                gpus_per_node: 8,
                nodes: None,
            },
            expert: NodeSpec {
                gpu: parse_gpu(e)?,
                gpus_per_node: 8,
                nodes: None,
            },
        },
    };

    if ast.phases.is_empty() {
        bail!("scenario \"{}\" has no workload phases", ast.name);
    }
    let mut tenants = Vec::new();
    let mut tenant_mix = Vec::new();
    for t in &ast.tenants {
        check_finite("tenant weight", t.weight)?;
        check_finite("tenant slo", t.slo)?;
        if t.weight < 0.0 || t.slo <= 0.0 {
            bail!("tenant \"{}\" needs weight >= 0 and slo > 0", t.name);
        }
        tenant_mix.push(t.weight);
        tenants.push(TenantClass {
            name: t.name.clone(),
            weight: t.weight,
            slo_e2e: t.slo,
        });
    }
    if !tenant_mix.is_empty() && tenant_mix.iter().sum::<f64>() <= 0.0 {
        bail!("tenant weights must not all be zero");
    }

    // Phases: validate and lower to the workload layer, accumulating the
    // request-weighted average sequence length for the plan search.
    let mut phases = Vec::with_capacity(ast.phases.len());
    let (mut wsum, mut wavg) = (0.0f64, 0.0f64);
    for p in &ast.phases {
        let ctx = |what: &str| format!("phase \"{}\": {what}", p.name);
        check_finite(&ctx("duration"), p.duration)?;
        if p.duration <= 0.0 {
            bail!("{}", ctx("duration must be > 0"));
        }
        if !(p.input >= 1.0 && p.input.is_finite()) {
            bail!("{}", ctx("input must be >= 1"));
        }
        if !(p.output >= 1.0 && p.output.is_finite()) {
            bail!("{}", ctx("output must be >= 1"));
        }
        if !(p.sigma >= 0.0 && p.sigma.is_finite()) {
            bail!("{}", ctx("sigma must be >= 0"));
        }
        let rate = match p.rate {
            RateAst::Constant(r) => {
                if !(r >= 0.0 && r.is_finite()) {
                    bail!("{}", ctx("rate must be >= 0"));
                }
                RateCurve::Constant(r)
            }
            RateAst::Ramp(from, to) => {
                if !(from >= 0.0 && to >= 0.0 && from.is_finite() && to.is_finite()) {
                    bail!("{}", ctx("ramp rates must be >= 0"));
                }
                RateCurve::Ramp { from, to }
            }
            RateAst::Sine {
                mean,
                amplitude,
                period,
            } => {
                if !(mean >= 0.0 && mean.is_finite()) {
                    bail!("{}", ctx("sine mean must be >= 0"));
                }
                if !(0.0..=1.0).contains(&amplitude) {
                    bail!("{}", ctx("sine amplitude must be in [0, 1]"));
                }
                if !(period > 0.0 && period.is_finite()) {
                    bail!("{}", ctx("sine period must be > 0"));
                }
                RateCurve::Sine {
                    mean,
                    amplitude,
                    period,
                }
            }
        };
        let mix = match &p.mix {
            None => None,
            Some(m) => {
                if m.len() != tenants.len() {
                    bail!(
                        "{}",
                        ctx(&format!(
                            "mix has {} weights but the scenario declares {} tenants",
                            m.len(),
                            tenants.len()
                        ))
                    );
                }
                if m.iter().any(|&w| !(w >= 0.0) || !w.is_finite()) {
                    bail!("{}", ctx("mix weights must be >= 0"));
                }
                if m.iter().sum::<f64>() <= 0.0 {
                    bail!("{}", ctx("mix weights must not all be zero"));
                }
                Some(m.clone())
            }
        };
        // E[lognormal] = median · exp(σ²/2); steady-state decode holds
        // prompt + half the output on average (WorkloadSpec::avg_seq_len).
        let blowup = (p.sigma * p.sigma / 2.0).exp();
        let w = (p.duration * mean_rate(&p.rate)).max(1e-9);
        wsum += w;
        wavg += w * (p.input * blowup + p.output * blowup / 2.0);
        phases.push(PhaseSpec {
            duration: p.duration,
            rate,
            median_input: p.input,
            median_output: p.output,
            sigma: p.sigma,
            mix,
        });
    }
    let avg_seq = wavg / wsum;

    let mut plan = PlanSearcher::new(model.clone(), cluster.clone(), avg_seq)
        .search()
        .ok_or_else(|| anyhow!("no feasible deployment plan for scenario \"{}\"", ast.name))?;
    if let Some(m) = ast.micro_batches {
        if m == 0 {
            bail!("micro-batches must be >= 1");
        }
        plan.m = m;
    }
    let prefill_nodes = match ast.prefill {
        Some(p) => p,
        None => plan.n_p,
    };

    if let Some(h) = ast.horizon {
        if !(h > 0.0 && h.is_finite()) {
            bail!("horizon must be > 0");
        }
    }
    if let Some(a) = ast.skew {
        if !(a >= 0.0 && a.is_finite()) {
            bail!("skew must be >= 0");
        }
    }
    if let Some(r) = ast.rebalance {
        if !(r > 0.0 && r.is_finite()) {
            bail!("rebalance interval must be > 0");
        }
    }

    // Injections: validate against the plan shape and fold the relative
    // shrink/grow elasticity ops into absolute expert-pool targets, in
    // time order.
    let mut injections = Vec::with_capacity(ast.injects.len());
    let mut last_at = 0.0f64;
    let mut n_e = plan.n_e;
    for inj in &ast.injects {
        check_finite("inject time", inj.at)?;
        if inj.at < 0.0 {
            bail!("inject time must be >= 0 (got {})", inj.at);
        }
        if inj.at < last_at {
            bail!(
                "inject events must be in non-decreasing time order \
                 (at {} after at {last_at})",
                inj.at
            );
        }
        last_at = inj.at;
        let node_ok = |node: usize| -> Result<()> {
            if node >= plan.n_a {
                bail!(
                    "attention node {node} out of range (the plan has {} attention nodes)",
                    plan.n_a
                );
            }
            Ok(())
        };
        let factor_ok = |factor: f64| -> Result<()> {
            if !(factor > 0.0 && factor.is_finite()) {
                bail!("factor must be > 0 (got {factor})");
            }
            Ok(())
        };
        let kind = match inj.action {
            ActionAst::FailAttention(node) => {
                node_ok(node)?;
                FaultKind::FailAttention { node }
            }
            ActionAst::RecoverAttention(node) => {
                node_ok(node)?;
                FaultKind::RecoverAttention { node }
            }
            ActionAst::StraggleAttention { node, factor } => {
                node_ok(node)?;
                factor_ok(factor)?;
                FaultKind::StraggleAttention { node, factor }
            }
            ActionAst::DegradeNic { factor } => {
                factor_ok(factor)?;
                FaultKind::DegradeNic { factor }
            }
            ActionAst::RestoreNic => FaultKind::DegradeNic { factor: 1.0 },
            ActionAst::ShrinkExperts(k) => {
                if k >= n_e {
                    bail!(
                        "shrink experts {k} would leave the {n_e}-node expert pool empty"
                    );
                }
                n_e -= k;
                FaultKind::ResizeExperts { n_e }
            }
            ActionAst::GrowExperts(k) => {
                if n_e + k > plan.n_e {
                    bail!(
                        "grow experts {k} exceeds the provisioned expert pool \
                         ({} of {} nodes in use)",
                        n_e,
                        plan.n_e
                    );
                }
                n_e += k;
                FaultKind::ResizeExperts { n_e }
            }
        };
        injections.push(FaultInjection { at: inj.at, kind, counted: true });
    }

    let cfg = ClusterSimConfig {
        route: crate::coordinator::RoutePolicy::LeastLoaded,
        popularity: match ast.skew {
            Some(a) if a > 0.0 => ExpertPopularity::Zipf(a),
            _ => ExpertPopularity::Uniform,
        },
        seed: ast.seed,
        tenants,
        rebalance_period: ast.rebalance,
        max_sim_seconds: ast.horizon,
        prefill_nodes,
        mode: EngineMode::Disaggregated,
        injections,
        ..ClusterSimConfig::new(model, cluster, plan)
    };

    Ok(CompiledScenario {
        name: ast.name.clone(),
        cfg,
        phases,
        tenant_mix,
        max_len: WorkloadSpec::default().max_len,
    })
}
