//! Recursive-descent parser for `.msc` scenario files.
//!
//! Diagnostics are golden-pinned by the fixture corpus
//! (`rust/tests/fixtures/scenario/`): every error is
//! `line:col: expected X, found Y` (or a `duplicate`/`missing` message
//! with the same position format), so a message change is a deliberate,
//! reviewed event — the msi-lint discipline applied to a language.

use super::ast::{
    ActionAst, InjectAst, PhaseAst, RateAst, ScenarioAst, TenantAst, DEFAULT_INPUT,
    DEFAULT_OUTPUT, DEFAULT_SIGMA,
};
use super::lexer::{lex, ScenarioError, TokKind, Token};

/// Parse one scenario file.
pub fn parse(src: &str) -> Result<ScenarioAst, ScenarioError> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.scenario()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn cur(&self) -> &Token {
        &self.toks[self.pos]
    }

    fn bump(&mut self) -> Token {
        let t = self.toks[self.pos].clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    fn err_at(&self, tok: &Token, msg: String) -> ScenarioError {
        ScenarioError {
            line: tok.line,
            col: tok.col,
            msg,
        }
    }

    fn expected(&self, what: &str) -> ScenarioError {
        let cur = self.cur();
        self.err_at(cur, format!("expected {what}, found {}", cur.describe()))
    }

    /// Consume the keyword `kw` if it is next.
    fn eat_kw(&mut self, kw: &str) -> bool {
        if self.cur().kind == TokKind::Ident && self.cur().text == kw {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect_kw(&mut self, kw: &str) -> Result<(), ScenarioError> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            Err(self.expected(&format!("`{kw}`")))
        }
    }

    fn expect_kind(&mut self, kind: TokKind, what: &str) -> Result<Token, ScenarioError> {
        if self.cur().kind == kind {
            Ok(self.bump())
        } else {
            Err(self.expected(what))
        }
    }

    fn expect_str(&mut self, what: &str) -> Result<String, ScenarioError> {
        Ok(self.expect_kind(TokKind::Str, what)?.text)
    }

    fn expect_num(&mut self, what: &str) -> Result<f64, ScenarioError> {
        Ok(self.expect_kind(TokKind::Num, what)?.num)
    }

    fn expect_int(&mut self, what: &str) -> Result<u64, ScenarioError> {
        let err = self.expected(what);
        let tok = self.expect_kind(TokKind::Num, what)?;
        tok.text.parse::<u64>().map_err(|_| err)
    }

    fn expect_ident(&mut self, what: &str) -> Result<String, ScenarioError> {
        Ok(self.expect_kind(TokKind::Ident, what)?.text)
    }

    /// `seen` guard: error on the second occurrence of a scalar item.
    fn once(&self, tok: &Token, seen: &mut bool) -> Result<(), ScenarioError> {
        if *seen {
            Err(self.err_at(tok, format!("duplicate `{}`", tok.text)))
        } else {
            *seen = true;
            Ok(())
        }
    }

    fn scenario(&mut self) -> Result<ScenarioAst, ScenarioError> {
        self.expect_kw("scenario")?;
        let name = self.expect_str("a scenario name string")?;
        self.expect_kind(TokKind::LBrace, "`{`")?;
        let mut ast = ScenarioAst {
            name,
            seed: 0,
            model: "tiny".into(),
            attn_gpu: "ampere".into(),
            expert_gpu: None,
            horizon: None,
            micro_batches: None,
            prefill: None,
            skew: None,
            rebalance: None,
            tenants: Vec::new(),
            phases: Vec::new(),
            injects: Vec::new(),
        };
        let mut seen = [false; 11];
        loop {
            if self.cur().kind == TokKind::RBrace {
                break;
            }
            if self.cur().kind != TokKind::Ident {
                return Err(self.expected("a scenario item or `}`"));
            }
            let tok = self.cur().clone();
            match tok.text.as_str() {
                "seed" => {
                    self.once(&tok, &mut seen[0])?;
                    self.bump();
                    ast.seed = self.expect_int("an integer seed")?;
                }
                "model" => {
                    self.once(&tok, &mut seen[1])?;
                    self.bump();
                    ast.model = self.expect_ident("a model name")?;
                }
                "gpu" => {
                    self.once(&tok, &mut seen[2])?;
                    self.bump();
                    ast.attn_gpu = self.expect_ident("a gpu name")?;
                    ast.expert_gpu = None;
                }
                "attention-gpu" => {
                    self.once(&tok, &mut seen[2])?;
                    self.bump();
                    ast.attn_gpu = self.expect_ident("a gpu name")?;
                }
                "expert-gpu" => {
                    self.once(&tok, &mut seen[3])?;
                    self.bump();
                    ast.expert_gpu = Some(self.expect_ident("a gpu name")?);
                }
                "horizon" => {
                    self.once(&tok, &mut seen[4])?;
                    self.bump();
                    ast.horizon = Some(self.expect_num("a horizon in seconds")?);
                }
                "micro-batches" => {
                    self.once(&tok, &mut seen[5])?;
                    self.bump();
                    ast.micro_batches = Some(self.expect_int("a micro-batch count")? as usize);
                }
                "prefill" => {
                    self.once(&tok, &mut seen[6])?;
                    self.bump();
                    ast.prefill = Some(self.expect_int("a prefill node count")? as usize);
                }
                "skew" => {
                    self.once(&tok, &mut seen[7])?;
                    self.bump();
                    ast.skew = Some(self.expect_num("a Zipf skew")?);
                }
                "rebalance" => {
                    self.once(&tok, &mut seen[8])?;
                    self.bump();
                    ast.rebalance = Some(self.expect_num("a re-balance interval in seconds")?);
                }
                "tenant" => {
                    self.bump();
                    let name = self.expect_str("a tenant name string")?;
                    self.expect_kw("weight")?;
                    let weight = self.expect_num("a traffic weight")?;
                    self.expect_kw("slo")?;
                    let slo = self.expect_num("an SLO in seconds")?;
                    ast.tenants.push(TenantAst { name, weight, slo });
                }
                "workload" => {
                    self.once(&tok, &mut seen[9])?;
                    self.bump();
                    self.expect_kind(TokKind::LBrace, "`{`")?;
                    while !matches!(self.cur().kind, TokKind::RBrace) {
                        ast.phases.push(self.phase()?);
                    }
                    self.bump();
                }
                "inject" => {
                    self.once(&tok, &mut seen[10])?;
                    self.bump();
                    self.expect_kind(TokKind::LBrace, "`{`")?;
                    while !matches!(self.cur().kind, TokKind::RBrace) {
                        ast.injects.push(self.inject()?);
                    }
                    self.bump();
                }
                _ => return Err(self.expected("a scenario item or `}`")),
            }
        }
        self.bump(); // the scenario `}`
        if self.cur().kind != TokKind::Eof {
            return Err(self.expected("end of input"));
        }
        Ok(ast)
    }

    fn phase(&mut self) -> Result<PhaseAst, ScenarioError> {
        self.expect_kw("phase")?;
        let name = self.expect_str("a phase name string")?;
        self.expect_kind(TokKind::LBrace, "`{`")?;
        let mut duration: Option<f64> = None;
        let mut rate: Option<RateAst> = None;
        let mut input = DEFAULT_INPUT;
        let mut output = DEFAULT_OUTPUT;
        let mut sigma = DEFAULT_SIGMA;
        let mut mix: Option<Vec<f64>> = None;
        let mut seen = [false; 6];
        loop {
            if self.cur().kind == TokKind::RBrace {
                break;
            }
            if self.cur().kind != TokKind::Ident {
                return Err(self.expected("a phase item or `}`"));
            }
            let tok = self.cur().clone();
            match tok.text.as_str() {
                "duration" => {
                    self.once(&tok, &mut seen[0])?;
                    self.bump();
                    duration = Some(self.expect_num("a duration in seconds")?);
                }
                "rate" => {
                    self.once(&tok, &mut seen[1])?;
                    self.bump();
                    rate = Some(self.rate()?);
                }
                "input" => {
                    self.once(&tok, &mut seen[2])?;
                    self.bump();
                    input = self.expect_num("a median prompt length")?;
                }
                "output" => {
                    self.once(&tok, &mut seen[3])?;
                    self.bump();
                    output = self.expect_num("a median output length")?;
                }
                "sigma" => {
                    self.once(&tok, &mut seen[4])?;
                    self.bump();
                    sigma = self.expect_num("a log-normal sigma")?;
                }
                "mix" => {
                    self.once(&tok, &mut seen[5])?;
                    self.bump();
                    let mut weights = vec![self.expect_num("a tenant weight")?];
                    while self.cur().kind == TokKind::Num {
                        weights.push(self.bump().num);
                    }
                    mix = Some(weights);
                }
                _ => return Err(self.expected("a phase item or `}`")),
            }
        }
        let close = self.bump(); // the phase `}`
        let duration = duration
            .ok_or_else(|| self.err_at(&close, format!("phase \"{name}\" is missing `duration`")))?;
        let rate = rate
            .ok_or_else(|| self.err_at(&close, format!("phase \"{name}\" is missing `rate`")))?;
        Ok(PhaseAst {
            name,
            duration,
            rate,
            input,
            output,
            sigma,
            mix,
        })
    }

    fn rate(&mut self) -> Result<RateAst, ScenarioError> {
        if self.eat_kw("constant") {
            Ok(RateAst::Constant(self.expect_num("a rate in requests/s")?))
        } else if self.eat_kw("ramp") {
            let from = self.expect_num("a starting rate")?;
            self.expect_kind(TokKind::Arrow, "`->`")?;
            let to = self.expect_num("an ending rate")?;
            Ok(RateAst::Ramp(from, to))
        } else if self.eat_kw("sine") {
            let mean = self.expect_num("a mean rate")?;
            self.expect_kw("amplitude")?;
            let amplitude = self.expect_num("a relative amplitude")?;
            self.expect_kw("period")?;
            let period = self.expect_num("a period in seconds")?;
            Ok(RateAst::Sine {
                mean,
                amplitude,
                period,
            })
        } else {
            Err(self.expected("`constant`, `ramp`, or `sine`"))
        }
    }

    fn inject(&mut self) -> Result<InjectAst, ScenarioError> {
        self.expect_kw("at")?;
        let at = self.expect_num("a time in seconds")?;
        let action = if self.eat_kw("fail") {
            self.expect_kw("attention")?;
            ActionAst::FailAttention(self.expect_int("an attention-node index")? as usize)
        } else if self.eat_kw("recover") {
            self.expect_kw("attention")?;
            ActionAst::RecoverAttention(self.expect_int("an attention-node index")? as usize)
        } else if self.eat_kw("straggle") {
            self.expect_kw("attention")?;
            let node = self.expect_int("an attention-node index")? as usize;
            self.expect_kw("factor")?;
            let factor = self.expect_num("a slowdown factor")?;
            ActionAst::StraggleAttention { node, factor }
        } else if self.eat_kw("degrade") {
            self.expect_kw("nic")?;
            self.expect_kw("factor")?;
            ActionAst::DegradeNic {
                factor: self.expect_num("a slowdown factor")?,
            }
        } else if self.eat_kw("restore") {
            self.expect_kw("nic")?;
            ActionAst::RestoreNic
        } else if self.eat_kw("shrink") {
            self.expect_kw("experts")?;
            ActionAst::ShrinkExperts(self.expect_int("an expert-node count")? as usize)
        } else if self.eat_kw("grow") {
            self.expect_kw("experts")?;
            ActionAst::GrowExperts(self.expect_int("an expert-node count")? as usize)
        } else {
            return Err(self.expected(
                "`fail`, `recover`, `straggle`, `degrade`, `restore`, `shrink`, or `grow`",
            ));
        };
        Ok(InjectAst { at, action })
    }
}
