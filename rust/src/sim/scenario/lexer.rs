//! Hand-rolled tokenizer for `.msc` scenario files (no dependencies, the
//! `tools/msi-lint` discipline): identifiers, quoted strings, numbers,
//! braces, `->`, and `#` line comments, with 1-based line/column tracking
//! for the golden `line:col: expected X, found Y` diagnostics.

use std::fmt;

/// A parse (or lex) failure with its source position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioError {
    /// 1-based source line.
    pub line: u32,
    /// 1-based source column.
    pub col: u32,
    /// Human-readable message (`expected X, found Y` for parse errors).
    pub msg: String,
}

impl fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ScenarioError {}

/// Token classes of the scenario language.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Keyword or name: `[A-Za-z_][A-Za-z0-9_-]*`.
    Ident,
    /// Double-quoted string (no escapes; names only).
    Str,
    /// Decimal number, optionally signed / fractional / exponent form.
    Num,
    /// `{`
    LBrace,
    /// `}`
    RBrace,
    /// `->`
    Arrow,
    /// End of input (always the final token).
    Eof,
}

/// One token with its source position.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// Class.
    pub kind: TokKind,
    /// Source text (string tokens: the unquoted contents).
    pub text: String,
    /// Numeric value (`Num` tokens only, 0 otherwise).
    pub num: f64,
    /// 1-based line of the first character.
    pub line: u32,
    /// 1-based column of the first character.
    pub col: u32,
}

impl Token {
    /// How the parser names this token in diagnostics.
    pub fn describe(&self) -> String {
        match self.kind {
            TokKind::Ident => format!("`{}`", self.text),
            TokKind::Str => format!("string \"{}\"", self.text),
            TokKind::Num => format!("number `{}`", self.text),
            TokKind::LBrace => "`{`".to_string(),
            TokKind::RBrace => "`}`".to_string(),
            TokKind::Arrow => "`->`".to_string(),
            TokKind::Eof => "end of input".to_string(),
        }
    }
}

/// Tokenize `src`; the result always ends with an `Eof` token carrying
/// the position just past the input.
pub fn lex(src: &str) -> Result<Vec<Token>, ScenarioError> {
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let (mut i, mut line, mut col) = (0usize, 1u32, 1u32);
    let err = |line: u32, col: u32, msg: String| ScenarioError { line, col, msg };
    while i < bytes.len() {
        let c = bytes[i];
        match c {
            b'\n' => {
                i += 1;
                line += 1;
                col = 1;
            }
            b' ' | b'\t' | b'\r' => {
                i += 1;
                col += 1;
            }
            b'#' => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'{' => {
                toks.push(Token {
                    kind: TokKind::LBrace,
                    text: "{".into(),
                    num: 0.0,
                    line,
                    col,
                });
                i += 1;
                col += 1;
            }
            b'}' => {
                toks.push(Token {
                    kind: TokKind::RBrace,
                    text: "}".into(),
                    num: 0.0,
                    line,
                    col,
                });
                i += 1;
                col += 1;
            }
            b'"' => {
                let (sl, sc) = (line, col);
                let start = i + 1;
                let mut j = start;
                while j < bytes.len() && bytes[j] != b'"' && bytes[j] != b'\n' {
                    j += 1;
                }
                if j >= bytes.len() || bytes[j] != b'"' {
                    return Err(err(sl, sc, "unterminated string".into()));
                }
                let text = src[start..j].to_string();
                col += (j + 1 - i) as u32;
                i = j + 1;
                toks.push(Token {
                    kind: TokKind::Str,
                    text,
                    num: 0.0,
                    line: sl,
                    col: sc,
                });
            }
            b'-' if i + 1 < bytes.len() && bytes[i + 1] == b'>' => {
                toks.push(Token {
                    kind: TokKind::Arrow,
                    text: "->".into(),
                    num: 0.0,
                    line,
                    col,
                });
                i += 2;
                col += 2;
            }
            _ if c.is_ascii_digit() || (c == b'-' && i + 1 < bytes.len() && {
                let d = bytes[i + 1];
                d.is_ascii_digit() || d == b'.'
            }) || (c == b'.' && i + 1 < bytes.len() && bytes[i + 1].is_ascii_digit()) =>
            {
                let (sl, sc) = (line, col);
                let start = i;
                let mut j = i + 1;
                while j < bytes.len() {
                    let d = bytes[j];
                    let numeric = d.is_ascii_digit()
                        || d == b'.'
                        || d == b'e'
                        || d == b'E'
                        || ((d == b'+' || d == b'-')
                            && matches!(bytes[j - 1], b'e' | b'E'));
                    if !numeric {
                        break;
                    }
                    j += 1;
                }
                let text = &src[start..j];
                let num: f64 = text
                    .parse()
                    .map_err(|_| err(sl, sc, format!("malformed number `{text}`")))?;
                col += (j - i) as u32;
                i = j;
                toks.push(Token {
                    kind: TokKind::Num,
                    text: text.to_string(),
                    num,
                    line: sl,
                    col: sc,
                });
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                let (sl, sc) = (line, col);
                let start = i;
                let mut j = i + 1;
                while j < bytes.len()
                    && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_' || bytes[j] == b'-')
                {
                    j += 1;
                }
                let text = src[start..j].to_string();
                col += (j - i) as u32;
                i = j;
                toks.push(Token {
                    kind: TokKind::Ident,
                    text,
                    num: 0.0,
                    line: sl,
                    col: sc,
                });
            }
            _ => {
                return Err(err(
                    line,
                    col,
                    format!("unexpected character `{}`", char::from(c)),
                ));
            }
        }
    }
    toks.push(Token {
        kind: TokKind::Eof,
        text: String::new(),
        num: 0.0,
        line,
        col,
    });
    Ok(toks)
}
