//! Abstract syntax of `.msc` scenario files.
//!
//! The AST is a faithful, *resolved* image of the source: optional items
//! carry `Option`, per-phase regime knobs are filled with their documented
//! defaults at parse time, and [`ScenarioAst::pretty`] renders the
//! canonical form. The pair is pinned by a round-trip property:
//! `parse(pretty(ast)) == ast` for every AST the generator can produce
//! (floats print via `{:?}`, Rust's shortest round-trip form).

/// Default median prompt length (tokens) when a phase omits `input` —
/// the paper's §7.1 production median.
pub const DEFAULT_INPUT: f64 = 571.0;
/// Default median output length (tokens) when a phase omits `output`.
pub const DEFAULT_OUTPUT: f64 = 159.0;
/// Default log-normal sigma when a phase omits `sigma`.
pub const DEFAULT_SIGMA: f64 = 0.7;

/// A parsed scenario file.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioAst {
    /// Scenario name (the string after the `scenario` keyword).
    pub name: String,
    /// RNG seed for every stream of the run (`seed`, default 0).
    pub seed: u64,
    /// Model name (`model`, default `tiny`), resolved by the compiler.
    pub model: String,
    /// Attention-side GPU kind (`gpu` / `attention-gpu`, default `ampere`).
    pub attn_gpu: String,
    /// Expert-side GPU kind (`expert-gpu`), `None` = same as attention.
    pub expert_gpu: Option<String>,
    /// Simulation horizon in seconds (`horizon`); `None` = run to
    /// quiescence.
    pub horizon: Option<f64>,
    /// Ping-pong micro-batch override (`micro-batches`).
    pub micro_batches: Option<usize>,
    /// Prefill-pool node-count override (`prefill`).
    pub prefill: Option<usize>,
    /// Zipf expert-popularity skew (`skew`); `None` = uniform.
    pub skew: Option<f64>,
    /// Periodic §6 online re-balance interval in seconds (`rebalance`).
    pub rebalance: Option<f64>,
    /// Traffic classes (`tenant` items, in file order).
    pub tenants: Vec<TenantAst>,
    /// Workload timeline (`workload` block, in file order).
    pub phases: Vec<PhaseAst>,
    /// Fault / elasticity events (`inject` block, in file order; times
    /// must be non-decreasing).
    pub injects: Vec<InjectAst>,
}

/// One `tenant "name" weight W slo S` item.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantAst {
    /// Class name used in reports.
    pub name: String,
    /// Relative traffic share.
    pub weight: f64,
    /// End-to-end SLO in seconds.
    pub slo: f64,
}

/// One `phase "name" { ... }` block.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseAst {
    /// Phase name (reporting only).
    pub name: String,
    /// Phase length in seconds (`duration`, required).
    pub duration: f64,
    /// Arrival-rate curve (`rate`, required).
    pub rate: RateAst,
    /// Median prompt length (`input`, default [`DEFAULT_INPUT`]).
    pub input: f64,
    /// Median output length (`output`, default [`DEFAULT_OUTPUT`]).
    pub output: f64,
    /// Log-normal sigma for both length draws (`sigma`, default
    /// [`DEFAULT_SIGMA`]).
    pub sigma: f64,
    /// Tenant-mix override (`mix`, one weight per declared tenant).
    pub mix: Option<Vec<f64>>,
}

/// A `rate` clause.
#[derive(Debug, Clone, PartialEq)]
pub enum RateAst {
    /// `rate constant R` — R requests/s for the whole phase.
    Constant(f64),
    /// `rate ramp A -> B` — linear from A to B over the phase.
    Ramp(f64, f64),
    /// `rate sine M amplitude A period P` — diurnal-style oscillation.
    Sine {
        /// Mean rate.
        mean: f64,
        /// Relative swing in `[0, 1]`.
        amplitude: f64,
        /// Oscillation period (seconds).
        period: f64,
    },
}

/// One `at T <action>` statement in an `inject` block.
#[derive(Debug, Clone, PartialEq)]
pub struct InjectAst {
    /// Virtual time the event fires (seconds).
    pub at: f64,
    /// What happens.
    pub action: ActionAst,
}

/// Injectable fault / elasticity actions.
#[derive(Debug, Clone, PartialEq)]
pub enum ActionAst {
    /// `fail attention N` — node N fails, its in-flight work requeues.
    FailAttention(usize),
    /// `recover attention N` — node N rejoins the placement set.
    RecoverAttention(usize),
    /// `straggle attention N factor F` — node N runs F× slower (1.0
    /// restores).
    StraggleAttention {
        /// Attention-node index.
        node: usize,
        /// Slowdown multiplier (> 0).
        factor: f64,
    },
    /// `degrade nic factor F` — M2N hops and KV transfers take F× longer.
    DegradeNic {
        /// Slowdown multiplier (> 0).
        factor: f64,
    },
    /// `restore nic` — shorthand for `degrade nic factor 1.0`.
    RestoreNic,
    /// `shrink experts N` — remove N nodes from the expert pool.
    ShrinkExperts(usize),
    /// `grow experts N` — add N nodes back (never past the provisioned
    /// pool).
    GrowExperts(usize),
}

/// Shortest-round-trip float rendering (`{:?}`), so `pretty` → `parse`
/// reproduces every `f64` bit for bit.
fn num(x: f64) -> String {
    format!("{x:?}")
}

impl ScenarioAst {
    /// Canonical rendering: parsing it back yields an identical AST.
    pub fn pretty(&self) -> String {
        let mut s = format!("scenario \"{}\" {{\n", self.name);
        s.push_str(&format!("  seed {}\n", self.seed));
        s.push_str(&format!("  model {}\n", self.model));
        match &self.expert_gpu {
            None => s.push_str(&format!("  gpu {}\n", self.attn_gpu)),
            Some(e) => {
                s.push_str(&format!("  attention-gpu {}\n", self.attn_gpu));
                s.push_str(&format!("  expert-gpu {e}\n"));
            }
        }
        if let Some(h) = self.horizon {
            s.push_str(&format!("  horizon {}\n", num(h)));
        }
        if let Some(m) = self.micro_batches {
            s.push_str(&format!("  micro-batches {m}\n"));
        }
        if let Some(p) = self.prefill {
            s.push_str(&format!("  prefill {p}\n"));
        }
        if let Some(a) = self.skew {
            s.push_str(&format!("  skew {}\n", num(a)));
        }
        if let Some(r) = self.rebalance {
            s.push_str(&format!("  rebalance {}\n", num(r)));
        }
        for t in &self.tenants {
            s.push_str(&format!(
                "  tenant \"{}\" weight {} slo {}\n",
                t.name,
                num(t.weight),
                num(t.slo)
            ));
        }
        s.push_str("  workload {\n");
        for p in &self.phases {
            s.push_str(&format!("    phase \"{}\" {{\n", p.name));
            s.push_str(&format!("      duration {}\n", num(p.duration)));
            let rate = match &p.rate {
                RateAst::Constant(r) => format!("constant {}", num(*r)),
                RateAst::Ramp(a, b) => format!("ramp {} -> {}", num(*a), num(*b)),
                RateAst::Sine {
                    mean,
                    amplitude,
                    period,
                } => format!(
                    "sine {} amplitude {} period {}",
                    num(*mean),
                    num(*amplitude),
                    num(*period)
                ),
            };
            s.push_str(&format!("      rate {rate}\n"));
            s.push_str(&format!("      input {}\n", num(p.input)));
            s.push_str(&format!("      output {}\n", num(p.output)));
            s.push_str(&format!("      sigma {}\n", num(p.sigma)));
            if let Some(mix) = &p.mix {
                let w: Vec<String> = mix.iter().map(|&x| num(x)).collect();
                s.push_str(&format!("      mix {}\n", w.join(" ")));
            }
            s.push_str("    }\n");
        }
        s.push_str("  }\n");
        if !self.injects.is_empty() {
            s.push_str("  inject {\n");
            for i in &self.injects {
                let action = match &i.action {
                    ActionAst::FailAttention(n) => format!("fail attention {n}"),
                    ActionAst::RecoverAttention(n) => format!("recover attention {n}"),
                    ActionAst::StraggleAttention { node, factor } => {
                        format!("straggle attention {node} factor {}", num(*factor))
                    }
                    ActionAst::DegradeNic { factor } => {
                        format!("degrade nic factor {}", num(*factor))
                    }
                    ActionAst::RestoreNic => "restore nic".to_string(),
                    ActionAst::ShrinkExperts(n) => format!("shrink experts {n}"),
                    ActionAst::GrowExperts(n) => format!("grow experts {n}"),
                };
                s.push_str(&format!("    at {} {action}\n", num(i.at)));
            }
            s.push_str("  }\n");
        }
        s.push('}');
        s
    }
}
