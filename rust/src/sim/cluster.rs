//! Trace-driven end-to-end cluster simulator: the whole MegaScale-Infer
//! serving loop on deterministic virtual time.
//!
//! The seed grew each subsystem in isolation — router, continuous batcher,
//! KV allocator, gating/dispatch, M2N network model, ping-pong pipeline
//! DES, analytical perf model. This module composes them into ONE loop, the
//! engine behind the end-to-end figures (8, 9, 12, 13) and the substrate
//! the regression suite drives:
//!
//! ```text
//!            workload::Trace (Poisson/bursty/replayed JSONL)
//!                 │ arrivals
//!                 ▼
//!       coordinator::Router  (least-loaded / round-robin, KV-aware)
//!                 │ per-attention-node queues
//!                 ▼
//!   attention pool: n_a nodes × ContinuousBatcher + BlockAllocator
//!                 │ decode batch split into m micro-batches
//!                 ▼
//!   per (micro-batch, layer):  gating softmax_topk → build_dispatch
//!                 │ per-expert token loads
//!                 ▼
//!   M2N transfer (Eq. 6 analytic or simnet-calibrated TransferModel)
//!                 ▼
//!   expert pool: n_e nodes (hottest node paces the stage; optional §6
//!                greedy redundancy re-balancing)
//!                 ▼
//!   coordinator::PingPongEngine — stepwise ping-pong DES over all layers
//!                 │ iteration latency
//!                 ▼
//!   metrics: TTFT / TPOT / E2E histograms, per-pool utilization,
//!            tokens/s/GPU
//! ```
//!
//! Everything is seeded through [`SimRng`]; two runs with the same
//! configuration and seed produce bit-identical reports.

use std::collections::{HashMap, VecDeque};

use crate::config::{ClusterSpec, ModelConfig};
use crate::coordinator::{
    balance_experts, build_dispatch, softmax_topk, BlockAllocator, ContinuousBatcher,
    GatingOutput, KvCacheConfig, PingPongEngine, RoutePolicy, Router, SchedulerConfig,
    StageTimes,
};
use crate::m2n::{LibraryKind, LibraryProfile, TransferModel};
use crate::metrics::{Histogram, Utilization};
use crate::perf_model::PerfModel;
use crate::plan::DeploymentPlan;
use crate::sim::SimRng;
use crate::workload::Request;

/// Expert-popularity model driving the synthetic gating logits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExpertPopularity {
    /// Deterministic round-robin token placement: expert loads are exactly
    /// balanced every micro-batch. This is the perf-model assumption and
    /// the right setting for validating the DES against Eq. 4–6.
    Ideal,
    /// IID uniform routing through the real gating path (multinomial load
    /// noise included).
    Uniform,
    /// Zipf(alpha) popularity over a seed-derived expert permutation with
    /// static one-expert-per-node placement: the expert stage runs at the
    /// pace of the hottest node (paper §6 motivation).
    Zipf(f64),
    /// Same skew, but the §6 greedy redundancy balancer re-places experts
    /// every micro-batch from the observed loads.
    ZipfBalanced(f64),
}

/// How M2N transfer time is obtained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Transport {
    /// Eq. 6 bandwidth-utilization model ([`crate::perf_model::CommModel`]).
    Analytic,
    /// Affine latency calibrated from the message-level simnet for the
    /// given library ([`TransferModel`]).
    Simnet(LibraryKind),
}

/// Full scenario description.
#[derive(Debug, Clone)]
pub struct ClusterSimConfig {
    pub model: ModelConfig,
    /// Possibly heterogeneous hardware (attention vs expert GPU kinds).
    pub cluster: ClusterSpec,
    /// Deployment shape: `tp_a`, `tp_e`, `n_a` (attention:expert pool-size
    /// ratio), `m` (micro-batch count), `global_batch`. Override fields to
    /// sweep scenarios the plan search would not pick.
    pub plan: DeploymentPlan,
    pub route: RoutePolicy,
    pub popularity: ExpertPopularity,
    pub transport: Transport,
    pub seed: u64,
}

/// Aggregate report of one simulated run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Requests fully decoded.
    pub completed: u64,
    /// Output tokens generated.
    pub tokens: u64,
    /// Virtual time elapsed (seconds).
    pub elapsed: f64,
    /// Decode iterations executed.
    pub iterations: u64,
    /// Output tokens per second.
    pub throughput: f64,
    /// Output tokens per second per GPU.
    pub per_gpu_throughput: f64,
    /// Time to first token (admission wait + first decode iteration).
    pub ttft: Histogram,
    /// Per-decode-iteration latency (time per output token).
    pub tpot: Histogram,
    /// Request end-to-end latency (arrival → last token).
    pub e2e: Histogram,
    /// Attention-pool busy fraction over the whole run (idle gaps count).
    pub attn_utilization: f64,
    /// Expert-pool busy fraction over the whole run.
    pub expert_utilization: f64,
    /// Output tokens produced by each attention node (router spread).
    pub per_node_tokens: Vec<u64>,
    /// Requests left unserved (KV capacity could never admit them).
    pub rejected: u64,
    /// Mean effective per-(micro-batch, layer) stage times actually fed to
    /// the pipeline engine — the DES-vs-Eq.5 cross-check anchors here.
    pub mean_t_a: f64,
    pub mean_t_e: f64,
    pub mean_t_c: f64,
}

impl ClusterReport {
    /// Deterministic multi-line rendering (diffable across runs).
    pub fn summary(&self) -> String {
        format!(
            "completed {} requests | {} output tokens in {:.3}s over {} iterations\n\
             throughput {:.1} tok/s | {:.3} tok/s/GPU\n\
             TTFT  p50 {:.1} ms  p99 {:.1} ms\n\
             TPOT  p50 {:.1} ms  p99 {:.1} ms\n\
             E2E   p50 {:.2} s   p99 {:.2} s\n\
             utilization: attention {:.1}%  expert {:.1}%\n\
             stage times: T_a {:.3} ms  T_e {:.3} ms  T_c {:.3} ms | rejected {}",
            self.completed,
            self.tokens,
            self.elapsed,
            self.iterations,
            self.throughput,
            self.per_gpu_throughput,
            self.ttft.median() * 1e3,
            self.ttft.p99() * 1e3,
            self.tpot.median() * 1e3,
            self.tpot.p99() * 1e3,
            self.e2e.median(),
            self.e2e.p99(),
            self.attn_utilization * 100.0,
            self.expert_utilization * 100.0,
            self.mean_t_a * 1e3,
            self.mean_t_e * 1e3,
            self.mean_t_c * 1e3,
            self.rejected,
        )
    }
}

/// Normalized Zipf(alpha) popularity over a randomly-rotated expert order.
/// `alpha = 0` degenerates to uniform.
pub fn popularity_weights(experts: usize, alpha: f64, rng: &mut SimRng) -> Vec<f64> {
    assert!(experts >= 1);
    let mut w: Vec<f64> = (0..experts)
        .map(|i| ((i + 1) as f64).powf(-alpha))
        .collect();
    let rot = rng.below(experts);
    w.rotate_left(rot);
    let sum: f64 = w.iter().sum();
    for x in &mut w {
        *x /= sum;
    }
    w
}

/// Draw a gating decision for `tokens` tokens whose expert preference
/// follows `weights`: Gumbel-top-k perturbed log-weights run through the
/// REAL `softmax_topk` kernel, so dispatch-table construction, weight
/// renormalization and load accounting all exercise the production path.
pub fn draw_gating(rng: &mut SimRng, tokens: usize, weights: &[f64], k: usize) -> GatingOutput {
    let e = weights.len();
    let k = k.clamp(1, e);
    let mut logits = vec![0f32; tokens * e];
    for t in 0..tokens {
        for (i, &w) in weights.iter().enumerate() {
            let u = rng.uniform().clamp(1e-12, 1.0 - 1e-12);
            let gumbel = -(-(u.ln())).ln();
            logits[t * e + i] = (w.max(1e-300).ln() + gumbel) as f32;
        }
    }
    softmax_topk(&logits, e, k)
}

/// Per-attention-node serving state.
struct AttnNode {
    batcher: ContinuousBatcher,
    kv: BlockAllocator,
}

/// The end-to-end cluster simulator.
pub struct ClusterSim {
    pub cfg: ClusterSimConfig,
}

impl ClusterSim {
    pub fn new(cfg: ClusterSimConfig) -> Self {
        Self { cfg }
    }

    /// KV-token capacity of one attention node (Eq. 8 budget).
    fn node_kv_tokens(&self) -> u64 {
        let gpu = self.cfg.cluster.attention_gpu();
        let budget =
            self.cfg.plan.tp_a as f64 * gpu.mem_bytes() - self.cfg.model.attn_param_bytes();
        (budget.max(0.0) / self.cfg.model.kv_bytes_per_token()).floor() as u64
    }

    /// Simulate serving `requests` to completion. Closed loop when every
    /// arrival is 0, open loop (trace replay) otherwise.
    pub fn run(&self, requests: &[Request]) -> ClusterReport {
        let cfg = &self.cfg;
        let model = &cfg.model;
        let plan = &cfg.plan;
        let n_a = plan.n_a.max(1);
        let n_e = plan.n_e.max(1);
        let m = plan.m.max(1);
        let layers = model.layers.max(1);
        let experts = model.experts.max(1);
        let top_k = model.top_k.clamp(1, experts);

        // --- deterministic random streams -------------------------------
        let mut perm_rng = SimRng::new(cfg.seed ^ 0x5bd1_e995_u64);
        let mut rng = SimRng::new(cfg.seed);
        let (pop, balanced) = match cfg.popularity {
            ExpertPopularity::Ideal => (None, false),
            ExpertPopularity::Uniform => {
                (Some(popularity_weights(experts, 0.0, &mut perm_rng)), false)
            }
            ExpertPopularity::Zipf(a) => {
                (Some(popularity_weights(experts, a, &mut perm_rng)), false)
            }
            ExpertPopularity::ZipfBalanced(a) => {
                (Some(popularity_weights(experts, a, &mut perm_rng)), true)
            }
        };

        // --- transport --------------------------------------------------
        let transfer = match cfg.transport {
            Transport::Analytic => None,
            Transport::Simnet(kind) => Some(TransferModel::calibrate(
                &LibraryProfile::of(kind),
                (n_a * plan.tp_a).max(1),
                (n_e * plan.tp_e).max(1),
                cfg.seed,
            )),
        };
        // --- attention pool + router ------------------------------------
        // Eq. 8 capacity, capped at the trace's total demand (plus one
        // block per request for partial-block rounding): capacity beyond
        // what the whole workload can ever occupy is unreachable, and not
        // materializing it keeps the block allocator small.
        let demand: u64 = requests
            .iter()
            .map(|r| (r.input_len + r.output_len + 16) as u64)
            .sum();
        let kv_tokens = self.node_kv_tokens().min(demand.max(16));
        let mut router = Router::new(cfg.route, &vec![kv_tokens; n_a]);
        let node_batch = plan.global_batch.div_ceil(n_a).max(1);
        let mut nodes: Vec<AttnNode> = (0..n_a)
            .map(|_| AttnNode {
                batcher: ContinuousBatcher::new(SchedulerConfig {
                    max_batch: node_batch,
                }),
                kv: BlockAllocator::new(KvCacheConfig {
                    block_size: 16,
                    num_blocks: (kv_tokens / 16) as usize,
                }),
            })
            .collect();

        // --- arrival stream ----------------------------------------------
        let mut arrivals: Vec<Request> = requests.to_vec();
        arrivals.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        let by_id: HashMap<u64, Request> =
            arrivals.iter().map(|r| (r.id, r.clone())).collect();
        let mut next_arrival = 0usize;
        // Requests the router could not place yet (fleet KV full).
        let mut overflow: VecDeque<Request> = VecDeque::new();
        // request id -> attention node (for completion accounting).
        let mut placed_on: HashMap<u64, usize> = HashMap::new();

        // --- metrics ------------------------------------------------------
        let mut ttft = Histogram::new();
        let mut tpot = Histogram::new();
        let mut e2e = Histogram::new();
        let mut attn_util = Utilization::new();
        let mut expert_util = Utilization::new();
        let mut per_node_tokens = vec![0u64; n_a];
        let mut tokens = 0u64;
        let mut completed = 0u64;
        let mut iterations = 0u64;
        let (mut sum_t_a, mut sum_t_e, mut sum_t_c) = (0.0f64, 0.0f64, 0.0f64);
        let mut stage_samples = 0u64;

        let mut now = 0.0f64;
        loop {
            // 1. Route arrivals due by `now`, strictly FIFO: drain the
            //    overflow queue head-first and stop at the first request
            //    that still does not fit — later arrivals queue behind it
            //    rather than jumping into freed capacity.
            loop {
                let Some(r) = overflow.front() else { break };
                let Some(nid) = router.route(r) else { break };
                let r = overflow.pop_front().unwrap();
                placed_on.insert(r.id, nid);
                nodes[nid].batcher.submit(r);
            }
            while next_arrival < arrivals.len() && arrivals[next_arrival].arrival <= now {
                let r = arrivals[next_arrival].clone();
                next_arrival += 1;
                if !overflow.is_empty() {
                    overflow.push_back(r);
                    continue;
                }
                match router.route(&r) {
                    Some(nid) => {
                        placed_on.insert(r.id, nid);
                        nodes[nid].batcher.submit(r);
                    }
                    None => overflow.push_back(r),
                }
            }

            // 2. Iteration-boundary admission on every node.
            for node in nodes.iter_mut() {
                node.batcher.admit(&mut node.kv, now);
            }

            // 3. Idle handling: jump to the next arrival, or stop.
            let batch_total: usize = nodes.iter().map(|n| n.batcher.batch.len()).sum();
            if batch_total == 0 {
                if next_arrival < arrivals.len() {
                    now = arrivals[next_arrival].arrival.max(now);
                    continue;
                }
                // No active work and no future arrivals: anything still
                // waiting can never be admitted (nothing will free KV).
                break;
            }

            // 4. Build the per-(micro-batch, layer) stage-time matrix from
            //    the live batch composition.
            let avg_seq = {
                let sum: f64 = nodes
                    .iter()
                    .map(|n| n.batcher.batch.avg_seq_len() * n.batcher.batch.len() as f64)
                    .sum();
                (sum / batch_total as f64).max(1.0)
            };
            let pm = PerfModel::new(model, &cfg.cluster, plan.tp_a, plan.tp_e, avg_seq);
            let splits: Vec<Vec<usize>> = nodes
                .iter()
                .map(|n| n.batcher.batch.micro_batch_sizes(m))
                .collect();

            let mut times = vec![
                vec![
                    StageTimes {
                        t_a: 0.0,
                        t_e: 0.0,
                        t_c: 0.0
                    };
                    layers
                ];
                m
            ];
            // The T_e model (k3·b_e + k4) is calibrated per *expert*; a node
            // hosting several experts streams each one's weight panels, so
            // charge the extra k4 floors when n_e < experts.
            let extra_weight_loads =
                (experts.div_ceil(n_e).saturating_sub(1)) as f64 * pm.expert.k4;
            for (j, times_j) in times.iter_mut().enumerate() {
                // Slowest attention node paces the attention stage.
                let b_a = splits.iter().map(|s| s[j]).max().unwrap_or(0) as f64;
                let tok_j: usize = splits.iter().map(|s| s[j]).sum();
                for times_jl in times_j.iter_mut() {
                    // Gating + dispatch for this hop: per-expert-node loads.
                    let hot_tokens = match &pop {
                        None => {
                            // Ideal: exact round-robin balance.
                            let dispatched = tok_j * top_k;
                            dispatched.div_ceil(n_e) as f64
                        }
                        Some(weights) => {
                            let g = draw_gating(&mut rng, tok_j, weights, top_k);
                            let dp = build_dispatch(&g, experts);
                            let mut node_load = vec![0.0f64; n_e];
                            for e in 0..experts {
                                node_load[e % n_e] += dp.expert_load(e) as f64;
                            }
                            if balanced {
                                let mean =
                                    node_load.iter().sum::<f64>() / n_e as f64;
                                balance_experts(&node_load, n_e, 0.1 * mean).makespan
                            } else {
                                node_load.iter().copied().fold(0.0, f64::max)
                            }
                        }
                    };
                    let t_a = pm.t_a(b_a);
                    let t_e = pm.t_e(hot_tokens) + extra_weight_loads;
                    let t_c = match &transfer {
                        None => pm.t_c(b_a, hot_tokens),
                        Some(tm) => {
                            let pair_bytes =
                                pm.comm.send_bytes(b_a) / tm.receivers as f64;
                            tm.latency(pair_bytes)
                        }
                    };
                    sum_t_a += t_a;
                    sum_t_e += t_e;
                    sum_t_c += t_c;
                    stage_samples += 1;
                    *times_jl = StageTimes { t_a, t_e, t_c };
                }
            }

            // 5. Shuttle the micro-batches through all layers.
            let stats =
                PingPongEngine { m, layers }.run(|mb, layer| times[mb][layer]);
            let t_iter = stats.total_time;
            let end = now + t_iter;
            attn_util.add_busy(stats.attn_utilization * t_iter);
            expert_util.add_busy(stats.expert_utilization * t_iter);
            tpot.record(t_iter);
            iterations += 1;

            // 6. Account the iteration: one token per active request.
            for (nid, node) in nodes.iter_mut().enumerate() {
                let b = node.batcher.batch.len() as u64;
                tokens += b;
                per_node_tokens[nid] += b;
                // Requests decoding their FIRST token this iteration.
                for r in &node.batcher.batch.requests {
                    if r.decoded == 0 {
                        if let Some(q) = by_id.get(&r.id) {
                            ttft.record(end - q.arrival);
                        }
                    }
                }
                for id in node.batcher.complete_iteration(&mut node.kv) {
                    completed += 1;
                    if let Some(q) = by_id.get(&id) {
                        e2e.record(end - q.arrival);
                        if let Some(nid2) = placed_on.remove(&id) {
                            router.complete(nid2, q);
                        }
                    }
                }
            }
            now = end;
        }

        attn_util.set_horizon(now);
        expert_util.set_horizon(now);
        let gpus = (plan.tp_a * n_a + plan.tp_e * n_e) as f64;
        let throughput = if now > 0.0 { tokens as f64 / now } else { 0.0 };
        let rejected =
            (overflow.len() + nodes.iter().map(|n| n.batcher.waiting.len()).sum::<usize>())
                as u64;
        let samples = stage_samples.max(1) as f64;
        ClusterReport {
            completed,
            tokens,
            elapsed: now,
            iterations,
            throughput,
            per_gpu_throughput: throughput / gpus.max(1.0),
            ttft,
            tpot,
            e2e,
            attn_utilization: attn_util.fraction(),
            expert_utilization: expert_util.fraction(),
            per_node_tokens,
            rejected,
            mean_t_a: sum_t_a / samples,
            mean_t_e: sum_t_e / samples,
            mean_t_c: sum_t_c / samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;
    use crate::plan::PlanSearcher;
    use crate::workload::WorkloadSpec;

    fn tiny_setup() -> ClusterSimConfig {
        let model = ModelConfig::tiny();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
            .search()
            .expect("tiny plan");
        ClusterSimConfig {
            model,
            cluster,
            plan,
            route: RoutePolicy::LeastLoaded,
            popularity: ExpertPopularity::Uniform,
            transport: Transport::Analytic,
            seed: 11,
        }
    }

    #[test]
    fn closed_loop_completes_everything() {
        let cfg = tiny_setup();
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.3,
            ..Default::default()
        }
        .generate(48, 5);
        let rep = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(rep.completed, 48);
        let want: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        assert_eq!(rep.tokens, want, "every output token accounted once");
        assert_eq!(rep.rejected, 0);
        assert!(rep.throughput > 0.0);
        assert_eq!(rep.ttft.count(), 48, "one TTFT sample per request");
        assert_eq!(rep.e2e.count(), 48);
    }

    #[test]
    fn open_loop_ttft_includes_queueing() {
        let cfg = tiny_setup();
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.3,
            arrival_rate: Some(50.0),
            ..Default::default()
        }
        .generate(64, 9);
        let rep = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(rep.completed, 64);
        assert!(rep.ttft.min() > 0.0, "TTFT strictly positive");
        // E2E of any request is at least its decode time ≥ TTFT sample min.
        assert!(rep.e2e.min() >= rep.ttft.min());
        assert!(rep.elapsed >= reqs.last().unwrap().arrival);
    }

    #[test]
    fn router_spreads_tokens_across_nodes() {
        let mut cfg = tiny_setup();
        cfg.plan.n_a = 4;
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 12.0,
            sigma: 0.2,
            ..Default::default()
        }
        .generate(160, 3);
        let rep = ClusterSim::new(cfg).run(&reqs);
        let max = *rep.per_node_tokens.iter().max().unwrap() as f64;
        let mean = rep.per_node_tokens.iter().sum::<u64>() as f64
            / rep.per_node_tokens.len() as f64;
        assert!(mean > 0.0);
        assert!(max / mean < 1.35, "per-node tokens {:?}", rep.per_node_tokens);
    }

    #[test]
    fn skew_hurts_and_balancing_recovers() {
        // Needs a compute-bound expert stage: at tiny scale the weight-load
        // floor (k4) hides imbalance entirely, so use the Mixtral operating
        // point with a saturated planned batch (paper §6 setting).
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
            .search()
            .expect("mixtral plan");
        let reqs = WorkloadSpec {
            median_output: 12.0,
            sigma: 0.1,
            ..Default::default()
        }
        .generate(plan.global_batch.min(8192), 7);
        let run = |pop| {
            ClusterSim::new(ClusterSimConfig {
                model: model.clone(),
                cluster: cluster.clone(),
                plan: plan.clone(),
                route: RoutePolicy::LeastLoaded,
                popularity: pop,
                transport: Transport::Analytic,
                seed: 9,
            })
            .run(&reqs)
            .throughput
        };
        let uniform = run(ExpertPopularity::Uniform);
        let skewed = run(ExpertPopularity::Zipf(1.2));
        let balanced = run(ExpertPopularity::ZipfBalanced(1.2));
        assert!(
            skewed < uniform * 0.9,
            "skew should hurt: {skewed} vs {uniform}"
        );
        assert!(
            balanced > skewed * 1.05,
            "balancing should recover: {balanced} vs {skewed}"
        );
        // Fractional balancing can slightly beat uniform-with-noise (whose
        // hottest expert sits ~2σ above the mean), but not by much.
        assert!(balanced <= uniform * 1.15, "cannot beat uniform by much");
    }

    #[test]
    fn heterogeneous_pools_run() {
        let model = ModelConfig::tiny();
        let cluster = ClusterSpec::heterogeneous_h20_l40s();
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
            .search()
            .expect("hetero plan");
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.2,
            ..Default::default()
        }
        .generate(32, 2);
        let rep = ClusterSim::new(ClusterSimConfig {
            model,
            cluster,
            plan,
            route: RoutePolicy::RoundRobin,
            popularity: ExpertPopularity::Uniform,
            transport: Transport::Analytic,
            seed: 4,
        })
        .run(&reqs);
        assert_eq!(rep.completed, 32);
        assert!(rep.attn_utilization > 0.0 && rep.attn_utilization <= 1.0);
        assert!(rep.expert_utilization > 0.0 && rep.expert_utilization <= 1.0);
    }

    #[test]
    fn simnet_transport_slower_than_free_wire_but_finite() {
        let mut cfg = tiny_setup();
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.2,
            ..Default::default()
        }
        .generate(32, 6);
        cfg.transport = Transport::Simnet(LibraryKind::MegaScale);
        let rep = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(rep.completed, 32);
        assert!(rep.mean_t_c > 0.0);
    }

    #[test]
    fn gating_draw_follows_popularity() {
        let mut rng = SimRng::new(1);
        let mut perm = SimRng::new(2);
        let w = popularity_weights(8, 1.5, &mut perm);
        let g = draw_gating(&mut rng, 4000, &w, 2);
        let loads = g.expert_loads(8);
        assert_eq!(loads.iter().sum::<usize>(), 8000);
        // The most popular expert receives more top-k traffic than the
        // least popular one.
        let hot = (0..8).max_by(|&a, &b| w[a].total_cmp(&w[b])).unwrap();
        let cold = (0..8).min_by(|&a, &b| w[a].total_cmp(&w[b])).unwrap();
        assert!(
            loads[hot] > loads[cold] * 2,
            "hot {} cold {}",
            loads[hot],
            loads[cold]
        );
    }
}
