//! Trace-driven end-to-end cluster simulation: scenario configuration and
//! reporting for the event-driven [`crate::sim::engine::ClusterEngine`].
//!
//! The seed grew each subsystem in isolation — router, continuous batcher,
//! KV allocator, gating/dispatch, M2N network model, ping-pong pipeline
//! DES, analytical perf model. The engine composes them as pluggable
//! [`crate::sim::engine::Component`]s on ONE event queue — the substrate
//! behind the end-to-end figures (8, 9, 12, 13) and the regression suite:
//!
//! ```text
//!            workload::Trace (Poisson/bursty/replayed JSONL,
//!                             optional multi-tenant classes)
//!                 │ Arrive events (front-door admission control)
//!                 ▼
//!   PrefillPool: n_p full-model nodes, packed chunked-prefill passes
//!                (requests: Queued → Prefill; colocated groups instead
//!                chunk prompts inline through decode iterations)
//!                 │ prompts done
//!                 ▼
//!       RouterFront (least-loaded / round-robin, KV-aware, FIFO overflow)
//!                 │ Place events → prompt-KV transfer → KvArrive
//!                 ▼
//!   AttentionPool: n_a nodes × ContinuousBatcher + BlockAllocator,
//!                  per-node clocks; decode batch split into m micro-batches
//!                 │ Pipe events (shared ping-pong core)
//!                 ▼
//!   per (micro-batch, layer):  gating softmax_topk → build_dispatch
//!                 │ per-expert token loads
//!                 ▼
//!   M2nLink (Eq. 6 analytic or simnet-calibrated TransferModel,
//!            token-copy conservation counters)
//!                 ▼
//!   ExpertPool: n_e nodes, per-rank clocks (hottest node paces the
//!               stage); §6 balancing — per-hop oracle, or periodic online
//!               re-placement under drifting popularity (Rebalance events)
//!                 ▼
//!   metrics: TTFT / TPOT / E2E histograms, per-pool + per-node
//!            utilization, per-tenant SLO attainment, tokens/s/GPU
//! ```
//!
//! Everything is seeded through [`SimRng`]; two runs with the same
//! configuration and seed produce bit-identical reports.

use crate::baselines::ColocatedPlan;
use crate::config::{ClusterSpec, ModelConfig};
use crate::coordinator::{softmax_topk, GatingOutput, RoutePolicy};
use crate::m2n::LibraryKind;
use crate::metrics::Histogram;
use crate::perf_model::DEFAULT_PREFILL_CHUNK;
use crate::plan::{DeploymentPlan, PlanMetrics};
use crate::sim::engine::ClusterEngine;
use crate::sim::SimRng;
use crate::util::json::Json;
use crate::workload::{ArrivalSource, Request, TenantClass, TraceSource};

/// Which serving architecture the engine simulates.
///
/// The same event-driven substrate (router, continuous batching + paged KV,
/// pipeline machine, conservation counters) runs both; only the deployment
/// shape and the per-hop stage-time model differ, so measured differences
/// between modes come from *architecture* — the paper's §7.2 comparison.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineMode {
    /// MegaScale-Infer: disaggregated attention/expert pools with ping-pong
    /// micro-batch pipelining (the default).
    Disaggregated,
    /// A monolithic vLLM-/TRT-LLM-style fleet: attention and experts
    /// colocated on independent serving groups, no ping-pong overlap
    /// (`m = 1`), decode batches never aggregated across replicas. Expert
    /// popularity is forced to `Ideal` (balanced experts — favoring the
    /// baseline) and transport to `Analytic` (the all-to-all cost is folded
    /// into the layer time via `kernel_efficiency`).
    Colocated(ColocatedPlan),
}

/// Expert-popularity model driving the synthetic gating logits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExpertPopularity {
    /// Deterministic round-robin token placement: expert loads are exactly
    /// balanced every micro-batch. This is the perf-model assumption and
    /// the right setting for validating the DES against Eq. 4–6.
    Ideal,
    /// IID uniform routing through the real gating path (multinomial load
    /// noise included).
    Uniform,
    /// Zipf(alpha) popularity over a seed-derived expert permutation with
    /// static one-expert-per-node placement: the expert stage runs at the
    /// pace of the hottest node (paper §6 motivation).
    Zipf(f64),
    /// Same skew, but the §6 greedy redundancy balancer re-places experts
    /// every micro-batch from the observed loads (an oracle upper bound).
    ZipfBalanced(f64),
    /// Time-varying skew: Zipf(alpha) whose hot experts rotate through the
    /// expert set every `period` virtual seconds. Pair with
    /// [`ClusterSimConfig::rebalance_period`] for periodic §6 online
    /// re-placement from observed loads.
    ZipfDrifting { alpha: f64, period: f64 },
}

/// How M2N transfer time is obtained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Transport {
    /// Eq. 6 bandwidth-utilization model ([`crate::perf_model::CommModel`]).
    Analytic,
    /// Affine latency calibrated from the message-level simnet for the
    /// given library ([`crate::m2n::TransferModel`]).
    Simnet(LibraryKind),
}

/// One scheduled fault or elasticity event, applied by the engine at
/// virtual time `at`. Injections are quantized to iteration boundaries:
/// an injection popping mid-iteration is deferred to the top of the next
/// `IterBegin`, so the fused fast path and the stepwise reference path
/// observe state changes at exactly the same points and reports stay
/// byte-identical across `--no-fuse`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultInjection {
    /// Virtual time (seconds) the event fires.
    pub at: f64,
    /// What happens.
    pub kind: FaultKind,
    /// Whether this shard's engine counts the injection toward the
    /// report's `injections_applied` / failure / resize counters. Sharded
    /// runs localize each scenario injection to the shard(s) it affects
    /// but mark exactly ONE copy `counted` (the owning shard for
    /// node-targeted kinds, shard 0 for broadcasts), so merged counters
    /// equal the unsharded run's. Unsharded configs always set `true`.
    pub counted: bool,
}

/// The fault / elasticity event kinds the engine can inject mid-run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Attention node `node` fails: its in-flight KV is lost, every
    /// request it held (live decode batch, admission queue, or KV in
    /// flight toward it) re-enters the lifecycle at `Queued` and is
    /// re-prefilled; the router stops placing on the node.
    FailAttention {
        /// Attention-node index (global, pre-sharding).
        node: usize,
    },
    /// A previously failed attention node rejoins the placement set.
    RecoverAttention {
        /// Attention-node index (global, pre-sharding).
        node: usize,
    },
    /// Attention node `node` runs its per-node clock `factor`× slower
    /// (a straggler; `factor = 1.0` restores full speed). The whole
    /// decode stage paces on the slowest node, per the pipeline model.
    StraggleAttention {
        /// Attention-node index (global, pre-sharding).
        node: usize,
        /// Per-node slowdown multiplier (> 0; 1.0 = healthy).
        factor: f64,
    },
    /// All M2N dispatch/combine hops and prefill→decode KV transfers
    /// take `factor`× longer (NIC degradation; `factor = 1.0` restores).
    DegradeNic {
        /// Link slowdown multiplier (> 0; 1.0 = healthy).
        factor: f64,
    },
    /// The expert pool shrinks or grows to `n_e` nodes and immediately
    /// re-places experts over the new pool with the §6 greedy balancer
    /// (from observed loads when it has any, uniformly otherwise).
    ResizeExperts {
        /// New expert-pool width (absolute node count, ≥ 1).
        n_e: usize,
    },
}

/// Full scenario description.
#[derive(Debug, Clone)]
pub struct ClusterSimConfig {
    /// The MoE model being served.
    pub model: ModelConfig,
    /// Possibly heterogeneous hardware (attention vs expert GPU kinds).
    pub cluster: ClusterSpec,
    /// Deployment shape: `tp_a`, `tp_e`, `n_a` (attention:expert pool-size
    /// ratio), `m` (micro-batch count), `global_batch`. Override fields to
    /// sweep scenarios the plan search would not pick.
    pub plan: DeploymentPlan,
    /// Router placement policy.
    pub route: RoutePolicy,
    /// Expert-popularity model driving the gating draws.
    pub popularity: ExpertPopularity,
    /// How M2N transfer time is obtained.
    pub transport: Transport,
    /// Seed for every random stream of the run.
    pub seed: u64,
    /// Traffic classes for per-tenant SLO reporting (empty = single
    /// tenant). `Request::tenant` indexes into this list.
    pub tenants: Vec<TenantClass>,
    /// Interval (virtual seconds) of periodic §6 online re-balancing from
    /// observed expert loads (None = static placement unless the
    /// popularity model is the per-micro-batch oracle).
    pub rebalance_period: Option<f64>,
    /// Simulation horizon (virtual seconds): events past it are not
    /// processed, so feasible work still queued reports as
    /// `unserved_queued`. None = run to quiescence (serve everything).
    pub max_sim_seconds: Option<f64>,
    /// Prefill-pool size for the disaggregated mode: full-model nodes
    /// (each `plan.tp_p` GPUs) running packed chunked prefill ahead of the
    /// decode pools. Defaults to the plan's sized pool (`plan.n_p`); 0
    /// disables prefill modeling (legacy instant-KV admission, TTFT = pure
    /// queue wait). Ignored by colocated mode, which prefills inline.
    pub prefill_nodes: usize,
    /// Chunked-prefill token budget: per pass on a prefill node, and per
    /// iteration per colocated serving group (vLLM-style chunked prefill,
    /// interfering with decode). 0 disables prefill modeling in BOTH modes.
    pub prefill_chunk: usize,
    /// Serving architecture: disaggregated (default) or a colocated
    /// monolithic baseline fleet (`msi compare`).
    pub mode: EngineMode,
    /// Fused-iteration fast path (default on): compute each decode
    /// iteration's whole ping-pong traversal analytically at the
    /// iteration boundary and schedule ONE completion event, instead of
    /// ~`3·m·layers` per-hop events through the global queue. Reports are
    /// byte-identical either way (the fast path replays the global
    /// queue's exact pop and RNG-draw order); `false` (`msi replay
    /// --no-fuse`) keeps the stepwise reference path for A/B checks.
    pub fuse: bool,
    /// Macro-step fast-forward (default on): when the span until the next
    /// external event (arrival, prefill pass, KV arrival, rebalance tick,
    /// injection, horizon cutoff) contains several decode iterations whose
    /// stage times are state-independent, the engine advances them without
    /// returning to the global event queue, bulk-updating per-request
    /// counters and histograms with values identical to per-iteration
    /// stepping. Requires `fuse`; `false` (`--no-macro`) keeps the
    /// one-iteration-per-event reference path for A/B checks.
    pub macro_step: bool,
    /// Scheduled fault / elasticity events (`msi scenario` `inject`
    /// blocks). Node indices are global; sharded runs localize each
    /// injection to the owning shard (see [`crate::sim::shard_config`]).
    pub injections: Vec<FaultInjection>,
}

impl ClusterSimConfig {
    /// A scenario with the default knobs: least-loaded routing, uniform
    /// popularity, analytic transport, single tenant, no re-balancing.
    pub fn new(model: ModelConfig, cluster: ClusterSpec, plan: DeploymentPlan) -> Self {
        let prefill_nodes = plan.n_p;
        Self {
            model,
            cluster,
            plan,
            route: RoutePolicy::LeastLoaded,
            popularity: ExpertPopularity::Uniform,
            transport: Transport::Analytic,
            seed: 0,
            tenants: Vec::new(),
            rebalance_period: None,
            max_sim_seconds: None,
            prefill_nodes,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            mode: EngineMode::Disaggregated,
            fuse: true,
            macro_step: true,
            injections: Vec::new(),
        }
    }

    /// A colocated-baseline scenario: the monolithic fleet described by
    /// `plan` served through the same engine substrate. The facade
    /// [`DeploymentPlan`] encodes the fleet shape the engine reads —
    /// `n_a` = replicas, `tp_a` = GPUs per group, no expert pool GPUs,
    /// `m = 1` (no ping-pong), per-group scheduler caps — with zeroed
    /// analytic metrics (a baseline's numbers come from the simulation).
    pub fn colocated(model: ModelConfig, cluster: ClusterSpec, plan: ColocatedPlan) -> Self {
        let facade = DeploymentPlan {
            model: model.name.clone(),
            tp_a: plan.gpus_per_group(),
            tp_e: 0,
            n_a: plan.replicas.max(1),
            n_e: 0,
            // No separate prefill pool: colocated groups chunk-prefill
            // inline, interleaved with decode iterations.
            n_p: 0,
            tp_p: 0,
            m: 1,
            global_batch: plan.replicas.max(1) * plan.max_batch_per_group(),
            metrics: PlanMetrics::zeroed(),
        };
        Self {
            popularity: ExpertPopularity::Ideal,
            mode: EngineMode::Colocated(plan),
            ..Self::new(model, cluster, facade)
        }
    }
}

/// Per-tenant slice of the report.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Class name (from the workload's tenant list).
    pub name: String,
    /// The class's end-to-end SLO (seconds).
    pub slo_e2e: f64,
    /// Requests of this class fully decoded.
    pub completed: u64,
    /// Time-to-first-token distribution of the class.
    pub ttft: Histogram,
    /// TTFT queue component of the class (arrival → first prefill chunk).
    pub ttft_queue: Histogram,
    /// TTFT prefill component of the class (chunked prompt compute).
    pub ttft_prefill: Histogram,
    /// TTFT KV-transfer component of the class (prefill→decode handoff).
    pub ttft_transfer: Histogram,
    /// TTFT first-decode component of the class (decode admission wait +
    /// first decode iteration).
    pub ttft_decode: Histogram,
    /// End-to-end latency distribution of the class.
    pub e2e: Histogram,
}

impl TenantReport {
    /// Fraction of completed requests that met the class SLO (the
    /// [`Histogram::fraction_below`] query against the E2E distribution).
    pub fn attainment(&self) -> f64 {
        self.e2e.fraction_below(self.slo_e2e)
    }
}

/// Aggregate report of one simulated run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Requests fully decoded.
    pub completed: u64,
    /// Output tokens generated.
    pub tokens: u64,
    /// Virtual time elapsed (seconds).
    pub elapsed: f64,
    /// Engine iterations executed (decode, and — in colocated mode —
    /// iterations carrying inline chunked-prefill passes, mixed or pure).
    pub iterations: u64,
    /// Output tokens per second.
    pub throughput: f64,
    /// Output tokens per second per GPU.
    pub per_gpu_throughput: f64,
    /// Time to first token: `queue + prefill + transfer + first decode`
    /// per request (the four components are the `ttft_*` histograms, which
    /// sum to this exactly request by request).
    pub ttft: Histogram,
    /// TTFT component: arrival → first prefill chunk (front-door + prefill
    /// queueing). With prefill modeling off this is arrival → placement.
    pub ttft_queue: Histogram,
    /// TTFT component: chunked prompt prefill (zero when prefill modeling
    /// is off).
    pub ttft_prefill: Histogram,
    /// TTFT component: prompt-KV shipping from the prefill node to the
    /// assigned decode attention node, including any wait for a decode
    /// placement (zero in colocated mode — the KV never moves).
    pub ttft_transfer: Histogram,
    /// TTFT component: decode admission wait + the first decode iteration.
    pub ttft_decode: Histogram,
    /// Per-decode-iteration latency (time per output token; colocated
    /// iterations that mix in prefill chunks count — that interference is
    /// the vLLM-style chunked-prefill cost).
    pub tpot: Histogram,
    /// Request end-to-end latency (arrival → last token).
    pub e2e: Histogram,
    /// Attention-pool busy fraction over the whole run (idle gaps count).
    pub attn_utilization: f64,
    /// Expert-pool busy fraction over the whole run.
    pub expert_utilization: f64,
    /// Output tokens produced by each attention node (router spread).
    pub per_node_tokens: Vec<u64>,
    /// Per-attention-node busy fraction (per-node clocks).
    pub per_node_attn_busy: Vec<f64>,
    /// Per-expert-node busy fraction (per-rank clocks).
    pub per_node_expert_busy: Vec<f64>,
    /// Per-prefill-node busy fraction (empty when the disaggregated
    /// prefill pool is off or the mode is colocated).
    pub per_node_prefill_busy: Vec<f64>,
    /// Prompt tokens that completed (chunked) prefill — on the dedicated
    /// pool or inline on colocated groups. Conservation: at quiescence with
    /// prefill on this equals the summed `input_len` of completed requests.
    pub prefilled_tokens: u64,
    /// Prompt tokens whose KV was shipped over the prefill→decode link
    /// (disaggregated mode only; colocated KV never moves).
    pub kv_transferred_tokens: u64,
    /// KV blocks still allocated across the decode attention nodes when the
    /// run ended — 0 at quiescence (no leaked blocks across the
    /// prefill→decode handoff); nonzero only for horizon-cut runs, where it
    /// accounts exactly for the requests still mid-decode.
    pub kv_blocks_in_use_at_end: u64,
    /// Requests whose KV footprint exceeds every node's whole budget — the
    /// fleet can *never* admit them (truly rejected).
    pub rejected: u64,
    /// Feasible requests the run ended on: still in the front-door FIFO,
    /// waiting on a node, or mid-decode — distinct from `rejected`.
    /// Nonzero only when a [`ClusterSimConfig::max_sim_seconds`] horizon
    /// cuts the run short; without one the engine runs to quiescence and
    /// serves every admitted request.
    pub unserved_queued: u64,
    /// High-water mark of concurrently in-flight requests (the engine's
    /// request table is O(this), not O(trace length)).
    pub peak_in_flight: u64,
    /// High-water mark of workload-driven events in the queue —
    /// engine-internal events (pipeline hops, rebalances, fused iteration
    /// ends) are excluded, so the metric is O(in-flight) by construction
    /// (exactly one future Arrive event is outstanding at any time) and
    /// identical between fused and stepwise runs.
    pub peak_queue_events: u64,
    /// Mean effective per-(micro-batch, layer) stage times actually fed to
    /// the pipeline engine — the DES-vs-Eq.5 cross-check anchors here.
    pub mean_t_a: f64,
    /// Mean effective expert-stage time (see `mean_t_a`).
    pub mean_t_e: f64,
    /// Mean effective one-way transfer time (see `mean_t_a`).
    pub mean_t_c: f64,
    /// Token copies handed to the M2N link toward the expert pool.
    pub dispatched_copies: u64,
    /// Token copies handed back toward the attention pool.
    pub combined_copies: u64,
    /// Token copies that completed expert compute.
    pub processed_copies: u64,
    /// Periodic §6 re-placements applied during the run.
    pub rebalances: u64,
    /// Scheduled fault / elasticity injections actually applied.
    pub injections_applied: u64,
    /// Attention-node failures applied (idempotent per node: failing an
    /// already-down node is a no-op and does not count).
    pub node_failures: u64,
    /// Attention-node recoveries applied (idempotent, like failures).
    pub node_recoveries: u64,
    /// Requests sent back to `Queued` because their node failed or their
    /// in-flight KV arrived at a failed node. Each re-enters through the
    /// front door and — with prefill on — re-prefills its prompt.
    pub requeued_requests: u64,
    /// KV blocks freed from failed nodes (the lost in-flight KV).
    pub lost_kv_blocks: u64,
    /// Decode tokens already produced by requests that were mid-decode on
    /// a failed node; those tokens are discarded and re-generated, so at
    /// quiescence `tokens = Σ output_len(completed) + lost_decode_tokens`.
    pub lost_decode_tokens: u64,
    /// Prompt tokens prefilled a second (or later) time for requeued
    /// requests; at quiescence with prefill on
    /// `prefilled_tokens = Σ input_len(completed) + re_prefilled_tokens`.
    pub re_prefilled_tokens: u64,
    /// Expert-pool shrink/grow events applied (each with a §6
    /// re-placement over the new pool width).
    pub expert_resizes: u64,
    /// Event schedules that landed within the event-queue's epsilon
    /// *behind* the virtual clock and were saturated to `now` (see
    /// [`crate::sim::EventQueue::clamped_past_schedules`]). Nonzero counts
    /// are benign floating-point jitter; past-time schedules beyond the
    /// epsilon abort the run instead of being silently clamped.
    pub clamped_past_schedules: u64,
    /// Per-tenant SLO slices (empty when single-tenant).
    pub tenants: Vec<TenantReport>,
}

impl ClusterReport {
    /// Deterministic multi-line rendering (diffable across runs).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "completed {} requests | {} output tokens in {:.3}s over {} iterations\n\
             throughput {:.1} tok/s | {:.3} tok/s/GPU\n\
             TTFT  p50 {:.1} ms  p99 {:.1} ms  \
             (p50 split: queue {:.1} + prefill {:.1} + xfer {:.1} + decode {:.1} ms)\n\
             TPOT  p50 {:.1} ms  p99 {:.1} ms\n\
             E2E   p50 {:.2} s   p99 {:.2} s\n\
             utilization: attention {:.1}%  expert {:.1}%\n\
             stage times: T_a {:.3} ms  T_e {:.3} ms  T_c {:.3} ms | \
             rejected {}  unserved {} | peak in-flight {}",
            self.completed,
            self.tokens,
            self.elapsed,
            self.iterations,
            self.throughput,
            self.per_gpu_throughput,
            self.ttft.median() * 1e3,
            self.ttft.p99() * 1e3,
            self.ttft_queue.median() * 1e3,
            self.ttft_prefill.median() * 1e3,
            self.ttft_transfer.median() * 1e3,
            self.ttft_decode.median() * 1e3,
            self.tpot.median() * 1e3,
            self.tpot.p99() * 1e3,
            self.e2e.median(),
            self.e2e.p99(),
            self.attn_utilization * 100.0,
            self.expert_utilization * 100.0,
            self.mean_t_a * 1e3,
            self.mean_t_e * 1e3,
            self.mean_t_c * 1e3,
            self.rejected,
            self.unserved_queued,
            self.peak_in_flight,
        );
        if self.prefilled_tokens > 0 {
            s.push_str(&format!(
                "\nprefill: {} prompt tokens chunk-prefilled | {} shipped to decode | \
                 {} pool nodes",
                self.prefilled_tokens,
                self.kv_transferred_tokens,
                self.per_node_prefill_busy.len(),
            ));
        }
        if self.rebalances > 0 {
            s.push_str(&format!("\nonline re-balances: {}", self.rebalances));
        }
        if self.injections_applied > 0 {
            s.push_str(&format!(
                "\ninjections: {} applied | {} node failures / {} recoveries | \
                 {} expert resizes\nfault cost: {} requests requeued | \
                 {} KV blocks lost | {} decode tokens lost | \
                 {} prompt tokens re-prefilled",
                self.injections_applied,
                self.node_failures,
                self.node_recoveries,
                self.expert_resizes,
                self.requeued_requests,
                self.lost_kv_blocks,
                self.lost_decode_tokens,
                self.re_prefilled_tokens,
            ));
        }
        for t in &self.tenants {
            s.push_str(&format!(
                "\ntenant {:<12} {} done | E2E p50 {:.2} s  p99 {:.2} s | \
                 SLO {:.2} s attained {:.1}%",
                t.name,
                t.completed,
                t.e2e.median(),
                t.e2e.p99(),
                t.slo_e2e,
                t.attainment() * 100.0,
            ));
        }
        s
    }

    /// Machine-readable report (the `msi replay --json` payload).
    pub fn to_json(&self) -> Json {
        let hist = |h: &Histogram| {
            Json::obj()
                .set("count", h.count())
                .set("mean", h.mean())
                .set("p50", h.median())
                .set("p90", h.percentile(90.0))
                .set("p99", h.p99())
        };
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj()
                    .set("name", t.name.as_str())
                    .set("slo_e2e_s", t.slo_e2e)
                    .set("completed", t.completed)
                    .set("attainment", t.attainment())
                    .set("ttft", hist(&t.ttft))
                    .set("ttft_queue", hist(&t.ttft_queue))
                    .set("ttft_prefill", hist(&t.ttft_prefill))
                    .set("ttft_transfer", hist(&t.ttft_transfer))
                    .set("ttft_decode", hist(&t.ttft_decode))
                    .set("e2e", hist(&t.e2e))
            })
            .collect();
        Json::obj()
            .set("completed", self.completed)
            .set("tokens", self.tokens)
            .set("elapsed_s", self.elapsed)
            .set("iterations", self.iterations)
            .set("throughput", self.throughput)
            .set("per_gpu_throughput", self.per_gpu_throughput)
            .set("ttft", hist(&self.ttft))
            .set("ttft_queue", hist(&self.ttft_queue))
            .set("ttft_prefill", hist(&self.ttft_prefill))
            .set("ttft_transfer", hist(&self.ttft_transfer))
            .set("ttft_decode", hist(&self.ttft_decode))
            .set("tpot", hist(&self.tpot))
            .set("e2e", hist(&self.e2e))
            .set("attn_utilization", self.attn_utilization)
            .set("expert_utilization", self.expert_utilization)
            .set("per_node_tokens", Json::Arr(
                self.per_node_tokens.iter().map(|&t| Json::from(t)).collect(),
            ))
            .set("per_node_attn_busy", self.per_node_attn_busy.clone())
            .set("per_node_expert_busy", self.per_node_expert_busy.clone())
            .set("per_node_prefill_busy", self.per_node_prefill_busy.clone())
            .set("prefilled_tokens", self.prefilled_tokens)
            .set("kv_transferred_tokens", self.kv_transferred_tokens)
            .set("kv_blocks_in_use_at_end", self.kv_blocks_in_use_at_end)
            .set("rejected", self.rejected)
            .set("unserved_queued", self.unserved_queued)
            .set("peak_in_flight", self.peak_in_flight)
            .set("peak_queue_events", self.peak_queue_events)
            .set("mean_t_a_ms", self.mean_t_a * 1e3)
            .set("mean_t_e_ms", self.mean_t_e * 1e3)
            .set("mean_t_c_ms", self.mean_t_c * 1e3)
            .set("dispatched_copies", self.dispatched_copies)
            .set("combined_copies", self.combined_copies)
            .set("processed_copies", self.processed_copies)
            .set("rebalances", self.rebalances)
            .set("injections_applied", self.injections_applied)
            .set("node_failures", self.node_failures)
            .set("node_recoveries", self.node_recoveries)
            .set("requeued_requests", self.requeued_requests)
            .set("lost_kv_blocks", self.lost_kv_blocks)
            .set("lost_decode_tokens", self.lost_decode_tokens)
            .set("re_prefilled_tokens", self.re_prefilled_tokens)
            .set("expert_resizes", self.expert_resizes)
            .set("clamped_past_schedules", self.clamped_past_schedules)
            .set("tenants", Json::Arr(tenants))
    }
}

/// Normalized Zipf(alpha) popularity over a randomly-rotated expert order.
/// `alpha = 0` degenerates to uniform.
pub fn popularity_weights(experts: usize, alpha: f64, rng: &mut SimRng) -> Vec<f64> {
    assert!(experts >= 1);
    let mut w: Vec<f64> = (0..experts)
        .map(|i| ((i + 1) as f64).powf(-alpha))
        .collect();
    let rot = rng.below(experts);
    w.rotate_left(rot);
    let sum: f64 = w.iter().sum();
    for x in &mut w {
        *x /= sum;
    }
    w
}

/// Draw a gating decision for `tokens` tokens whose expert preference
/// follows `weights`: Gumbel-top-k perturbed log-weights run through the
/// REAL `softmax_topk` kernel, so dispatch-table construction, weight
/// renormalization and load accounting all exercise the production path.
pub fn draw_gating(rng: &mut SimRng, tokens: usize, weights: &[f64], k: usize) -> GatingOutput {
    let e = weights.len();
    let k = k.clamp(1, e);
    let mut logits = vec![0f32; tokens * e];
    for t in 0..tokens {
        for (i, &w) in weights.iter().enumerate() {
            let u = rng.uniform().clamp(1e-12, 1.0 - 1e-12);
            let gumbel = -(-(u.ln())).ln();
            logits[t * e + i] = (w.max(1e-300).ln() + gumbel) as f32;
        }
    }
    softmax_topk(&logits, e, k)
}

/// The end-to-end cluster simulator: a thin facade that wires the scenario
/// into the event-driven [`ClusterEngine`].
pub struct ClusterSim {
    /// The scenario being simulated.
    pub cfg: ClusterSimConfig,
}

impl ClusterSim {
    /// Wrap a scenario configuration.
    pub fn new(cfg: ClusterSimConfig) -> Self {
        Self { cfg }
    }

    /// Simulate serving `requests` to completion. Closed loop when every
    /// arrival is 0, open loop (trace replay) otherwise. This materializes
    /// the list once inside a [`TraceSource`]; the engine itself still only
    /// holds in-flight requests.
    pub fn run(&self, requests: &[Request]) -> ClusterReport {
        self.run_streaming(Box::new(TraceSource::new(requests.to_vec())))
    }

    /// Pull-based run over any [`ArrivalSource`] (e.g. a generator-backed
    /// [`crate::workload::RequestStream`]): memory stays bounded by the
    /// in-flight request count no matter how long the stream is.
    pub fn run_streaming(&self, source: Box<dyn ArrivalSource>) -> ClusterReport {
        ClusterEngine::new(self.cfg.clone(), source).run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;
    use crate::plan::PlanSearcher;
    use crate::workload::WorkloadSpec;

    fn tiny_setup() -> ClusterSimConfig {
        let model = ModelConfig::tiny();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
            .search()
            .expect("tiny plan");
        ClusterSimConfig {
            seed: 11,
            ..ClusterSimConfig::new(model, cluster, plan)
        }
    }

    #[test]
    fn closed_loop_completes_everything() {
        let cfg = tiny_setup();
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.3,
            ..Default::default()
        }
        .generate(48, 5);
        let rep = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(rep.completed, 48);
        let want: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        assert_eq!(rep.tokens, want, "every output token accounted once");
        assert_eq!(rep.rejected, 0);
        assert!(rep.throughput > 0.0);
        assert_eq!(rep.ttft.count(), 48, "one TTFT sample per request");
        assert_eq!(rep.e2e.count(), 48);
    }

    #[test]
    fn open_loop_ttft_includes_queueing() {
        let cfg = tiny_setup();
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.3,
            arrival_rate: Some(50.0),
            ..Default::default()
        }
        .generate(64, 9);
        let rep = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(rep.completed, 64);
        assert!(rep.ttft.min() > 0.0, "TTFT strictly positive");
        // E2E of any request is at least its decode time ≥ TTFT sample min.
        assert!(rep.e2e.min() >= rep.ttft.min());
        assert!(rep.elapsed >= reqs.last().unwrap().arrival);
    }

    #[test]
    fn router_spreads_tokens_across_nodes() {
        let mut cfg = tiny_setup();
        cfg.plan.n_a = 4;
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 12.0,
            sigma: 0.2,
            ..Default::default()
        }
        .generate(160, 3);
        let rep = ClusterSim::new(cfg).run(&reqs);
        let max = *rep.per_node_tokens.iter().max().unwrap() as f64;
        let mean = rep.per_node_tokens.iter().sum::<u64>() as f64
            / rep.per_node_tokens.len() as f64;
        assert!(mean > 0.0);
        assert!(max / mean < 1.35, "per-node tokens {:?}", rep.per_node_tokens);
    }

    #[test]
    fn skew_hurts_and_balancing_recovers() {
        // Needs a compute-bound expert stage: at tiny scale the weight-load
        // floor (k4) hides imbalance entirely, so use the Mixtral operating
        // point with a saturated planned batch (paper §6 setting).
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
            .search()
            .expect("mixtral plan");
        let reqs = WorkloadSpec {
            median_output: 12.0,
            sigma: 0.1,
            ..Default::default()
        }
        .generate(plan.global_batch.min(8192), 7);
        let run = |pop| {
            ClusterSim::new(ClusterSimConfig {
                popularity: pop,
                seed: 9,
                // Decode-stage anchor: prefill off, so the identical prefill
                // phase cannot compress the popularity-driven gaps.
                prefill_nodes: 0,
                ..ClusterSimConfig::new(model.clone(), cluster.clone(), plan.clone())
            })
            .run(&reqs)
            .throughput
        };
        let uniform = run(ExpertPopularity::Uniform);
        let skewed = run(ExpertPopularity::Zipf(1.2));
        let balanced = run(ExpertPopularity::ZipfBalanced(1.2));
        assert!(
            skewed < uniform * 0.9,
            "skew should hurt: {skewed} vs {uniform}"
        );
        assert!(
            balanced > skewed * 1.05,
            "balancing should recover: {balanced} vs {skewed}"
        );
        // Fractional balancing can slightly beat uniform-with-noise (whose
        // hottest expert sits ~2σ above the mean), but not by much.
        assert!(balanced <= uniform * 1.15, "cannot beat uniform by much");
    }

    #[test]
    fn heterogeneous_pools_run() {
        let model = ModelConfig::tiny();
        let cluster = ClusterSpec::heterogeneous_h20_l40s();
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
            .search()
            .expect("hetero plan");
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.2,
            ..Default::default()
        }
        .generate(32, 2);
        let rep = ClusterSim::new(ClusterSimConfig {
            route: RoutePolicy::RoundRobin,
            seed: 4,
            ..ClusterSimConfig::new(model, cluster, plan)
        })
        .run(&reqs);
        assert_eq!(rep.completed, 32);
        assert!(rep.attn_utilization > 0.0 && rep.attn_utilization <= 1.0);
        assert!(rep.expert_utilization > 0.0 && rep.expert_utilization <= 1.0);
    }

    #[test]
    fn simnet_transport_slower_than_free_wire_but_finite() {
        let mut cfg = tiny_setup();
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.2,
            ..Default::default()
        }
        .generate(32, 6);
        cfg.transport = Transport::Simnet(LibraryKind::MegaScale);
        let rep = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(rep.completed, 32);
        assert!(rep.mean_t_c > 0.0);
    }

    #[test]
    fn gating_draw_follows_popularity() {
        let mut rng = SimRng::new(1);
        let mut perm = SimRng::new(2);
        let w = popularity_weights(8, 1.5, &mut perm);
        let g = draw_gating(&mut rng, 4000, &w, 2);
        let loads = g.expert_loads(8);
        assert_eq!(loads.iter().sum::<usize>(), 8000);
        // The most popular expert receives more top-k traffic than the
        // least popular one.
        let hot = (0..8).max_by(|&a, &b| w[a].total_cmp(&w[b])).unwrap();
        let cold = (0..8).min_by(|&a, &b| w[a].total_cmp(&w[b])).unwrap();
        assert!(
            loads[hot] > loads[cold] * 2,
            "hot {} cold {}",
            loads[hot],
            loads[cold]
        );
    }

    #[test]
    fn token_copies_conserved_across_the_link() {
        let cfg = tiny_setup();
        let layers = cfg.model.layers.max(1) as u64;
        let top_k = cfg.model.top_k.max(1) as u64;
        let reqs = WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.3,
            ..Default::default()
        }
        .generate(40, 13);
        let rep = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(rep.completed, 40);
        // Every decoded token traverses every layer as top_k copies, and
        // every copy that crosses the link comes back.
        assert_eq!(rep.dispatched_copies, rep.tokens * layers * top_k);
        assert_eq!(rep.dispatched_copies, rep.processed_copies);
        assert_eq!(rep.dispatched_copies, rep.combined_copies);
    }
}
