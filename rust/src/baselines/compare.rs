//! The simulated end-to-end comparison (`msi compare`): disaggregated
//! MegaScale-Infer vs colocated vLLM-/TRT-LLM-style fleets on the **same
//! workload** through the **same** event-driven engine — the reproduction
//! of the paper's Figure 8 under arbitrary traffic.
//!
//! For a model/cluster/workload, [`run_compare`]:
//!
//! 1. picks the disaggregated plan — Algorithm 1's analytic winner, or the
//!    sim-validated winner when `validate_top` is set
//!    ([`crate::plan::validate_top_k`]);
//! 2. sizes each baseline fleet to at least the plan's GPU count
//!    ([`ColocatedPlan::sized_to_match`]) so per-GPU throughput is compared
//!    at comparable scale;
//! 3. serves one identical request list through all three systems via
//!    [`ClusterSim`] (the baselines in
//!    [`crate::sim::cluster::EngineMode::Colocated`]);
//! 4. reports per-GPU decode throughput, the Figure-8 ratios, and
//!    TTFT/TPOT/E2E/SLO-attainment per system, as text, JSON, or CSV.
//!
//! Everything is seeded: two runs with the same configuration produce
//! byte-identical JSON (pinned by `tests/compare.rs`).

use anyhow::{anyhow, bail, Result};

use crate::config::{ClusterSpec, ModelConfig};
use crate::plan::{validate_top_k, DeploymentPlan, PlanSearcher, PromptShape, ValidationConfig};
use crate::sim::cluster::{ClusterReport, ClusterSim, ClusterSimConfig, ExpertPopularity};
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

use super::{BaselineKind, ColocatedPlan};

/// Salt decorrelating the workload generator from the engines' gating
/// streams (mirrors `sim::sweep`).
const WORKLOAD_SALT: u64 = 0xa076_1d64_78bd_642f;

/// The serving systems a comparison (or a sweep's `system` axis) can run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// MegaScale-Infer: disaggregated pools + ping-pong pipelining.
    Disaggregated,
    /// vLLM-style colocated baseline.
    Vllm,
    /// TensorRT-LLM-style colocated baseline.
    TrtLlm,
}

impl SystemKind {
    /// Stable short name used in reports and CLI axis lists.
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Disaggregated => "megascale",
            SystemKind::Vllm => "vllm",
            SystemKind::TrtLlm => "trtllm",
        }
    }

    /// Parse a CLI token (`megascale`/`disagg`, `vllm`, `trtllm`/`trt`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim().to_lowercase().as_str() {
            "megascale" | "disagg" | "disaggregated" | "msi" => SystemKind::Disaggregated,
            "vllm" => SystemKind::Vllm,
            "trtllm" | "trt" | "trt-llm" | "tensorrt-llm" => SystemKind::TrtLlm,
            other => bail!("unknown system {other:?} (megascale|vllm|trtllm)"),
        })
    }

    /// The colocated baseline this system maps to (None for disaggregated).
    pub fn baseline(&self) -> Option<BaselineKind> {
        match self {
            SystemKind::Disaggregated => None,
            SystemKind::Vllm => Some(BaselineKind::Vllm),
            SystemKind::TrtLlm => Some(BaselineKind::TrtLlm),
        }
    }
}

/// Inputs of one comparison run.
#[derive(Debug, Clone)]
pub struct CompareConfig {
    /// The MoE model served by all three systems.
    pub model: ModelConfig,
    /// Hardware offered to the plan search; the baselines run on the
    /// attention GPU type (monolithic fleets are single-GPU-kind).
    pub cluster: ClusterSpec,
    /// Workload shape (lengths, arrival process, tenant classes) shared by
    /// every system.
    pub spec: WorkloadSpec,
    /// Requests to serve. `0` = auto-size so every system saturates: twice
    /// the disaggregated global batch, and at least each baseline fleet's
    /// aggregate scheduler cap.
    pub requests: usize,
    /// Seed for the workload draw and every engine run.
    pub seed: u64,
    /// TPOT SLO for the plan search and the per-system TPOT-attainment
    /// metric (seconds; paper: 0.150).
    pub slo: f64,
    /// Expert popularity for the disaggregated system. Default `Ideal`
    /// (balanced experts) — the Figure-8 setting, and the assumption the
    /// colocated layer-time model makes for the baselines, so the
    /// comparison isolates architecture. Set a Zipf variant to explore
    /// skewed regimes (the baselines keep their balanced-expert model,
    /// which *favors* them).
    pub popularity: ExpertPopularity,
    /// When `Some(k)`, pick the disaggregated plan by sim-validated goodput
    /// over the top-`k` analytic candidates instead of the analytic winner.
    pub validate_top: Option<usize>,
    /// Optional simulation horizon forwarded to every system's engine run.
    pub max_sim_seconds: Option<f64>,
}

impl CompareConfig {
    /// Defaults: paper workload shape, auto-sized request count, 150 ms
    /// SLO, balanced experts, analytic plan choice.
    pub fn new(model: ModelConfig, cluster: ClusterSpec) -> Self {
        Self {
            model,
            cluster,
            spec: WorkloadSpec::default(),
            requests: 0,
            seed: 42,
            slo: 0.150,
            popularity: ExpertPopularity::Ideal,
            validate_top: None,
            max_sim_seconds: None,
        }
    }
}

/// One system's simulated outcome.
#[derive(Debug, Clone)]
pub struct SystemResult {
    /// Which system ran.
    pub system: SystemKind,
    /// Human-readable deployment shape (plan or fleet description).
    pub deployment: String,
    /// Fleet GPU count the per-GPU metric divides by.
    pub gpus: usize,
    /// The engine's full report.
    pub report: ClusterReport,
    /// Fraction of decode iterations meeting the TPOT SLO
    /// ([`crate::metrics::Histogram::fraction_below`] on the TPOT
    /// distribution).
    pub tpot_slo_attainment: f64,
}

impl SystemResult {
    /// JSON rendering (one entry of the `msi compare --json` payload).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("system", self.system.name())
            .set("deployment", self.deployment.as_str())
            .set("gpus", self.gpus)
            .set("tpot_slo_attainment", self.tpot_slo_attainment)
            .set("report", self.report.to_json())
    }
}

/// Outcome of one comparison: the three systems plus the Figure-8 ratios.
#[derive(Debug, Clone)]
pub struct CompareReport {
    /// The disaggregated plan that ran (analytic or sim-validated winner).
    pub plan: DeploymentPlan,
    /// Requests actually served (after auto-sizing).
    pub requests: usize,
    /// The run's seed.
    pub seed: u64,
    /// TPOT SLO used for attainment metrics (seconds).
    pub slo: f64,
    /// MegaScale-Infer's result.
    pub disaggregated: SystemResult,
    /// The vLLM-style fleet's result.
    pub vllm: SystemResult,
    /// The TRT-LLM-style fleet's result.
    pub trtllm: SystemResult,
}

impl CompareReport {
    /// The three results in report order (disaggregated first).
    pub fn systems(&self) -> [&SystemResult; 3] {
        [&self.disaggregated, &self.vllm, &self.trtllm]
    }

    /// Per-GPU decode-throughput ratio of disaggregated over `other` (the
    /// Figure-8 headline number).
    fn ratio_over(&self, other: &SystemResult) -> f64 {
        self.disaggregated.report.per_gpu_throughput
            / other.report.per_gpu_throughput.max(f64::MIN_POSITIVE)
    }

    /// Disaggregated / vLLM per-GPU throughput.
    pub fn ratio_vs_vllm(&self) -> f64 {
        self.ratio_over(&self.vllm)
    }

    /// Disaggregated / TRT-LLM per-GPU throughput.
    pub fn ratio_vs_trtllm(&self) -> f64 {
        self.ratio_over(&self.trtllm)
    }

    /// Deterministic multi-line rendering (the `msi compare` stdout table).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "compare: {} requests | plan tp_a={} tp_e={} n_a={} n_p={} m={} B={} ({} GPUs)\n\
             {:<10} {:>26} {:>5} | {:>11} {:>9} | {:>9} {:>9} {:>9} {:>9} | {:>8}\n",
            self.requests,
            self.plan.tp_a,
            self.plan.tp_e,
            self.plan.n_a,
            self.plan.n_p,
            self.plan.m,
            self.plan.global_batch,
            self.plan.total_gpus(),
            "system",
            "deployment",
            "GPUs",
            "tok/s/GPU",
            "tok/s",
            "TTFT p50",
            "prefill50",
            "TPOT p50",
            "E2E p99",
            "SLO att",
        );
        for r in self.systems() {
            s.push_str(&format!(
                "{:<10} {:>26} {:>5} | {:>11.2} {:>9.0} | {:>8.0}ms {:>8.0}ms {:>8.1}ms {:>8.2}s | {:>7.1}%\n",
                r.system.name(),
                r.deployment,
                r.gpus,
                r.report.per_gpu_throughput,
                r.report.throughput,
                r.report.ttft.median() * 1e3,
                r.report.ttft_prefill.median() * 1e3,
                r.report.tpot.median() * 1e3,
                r.report.e2e.p99(),
                r.tpot_slo_attainment * 100.0,
            ));
        }
        s.push_str(&format!(
            "per-GPU throughput ratio: {:.2}x vs vLLM, {:.2}x vs TensorRT-LLM \
             (paper Fig. 8: 2.56x/1.28x Mixtral+DBRX avg, 7.11x/1.90x Scaled-MoE)",
            self.ratio_vs_vllm(),
            self.ratio_vs_trtllm(),
        ));
        s
    }

    /// Machine-readable report (the `msi compare --json` payload).
    /// Byte-identical across same-seed runs.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("plan", self.plan.to_json())
            .set("requests", self.requests)
            .set("seed", self.seed)
            .set("slo_s", self.slo)
            .set("ratio_vs_vllm", self.ratio_vs_vllm())
            .set("ratio_vs_trtllm", self.ratio_vs_trtllm())
            .set(
                "systems",
                Json::Arr(self.systems().iter().map(|r| r.to_json()).collect()),
            )
    }

    /// CSV rendering: one row per system, per-GPU throughput normalized to
    /// vLLM in the last column (Figure 8's bar heights).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "system,deployment,gpus,per_gpu_throughput,throughput,completed,tokens,\
             ttft_p50_s,ttft_p99_s,ttft_queue_p50_s,ttft_prefill_p50_s,\
             ttft_transfer_p50_s,ttft_decode_p50_s,tpot_p50_s,e2e_p50_s,e2e_p99_s,\
             tpot_slo_attainment,vs_vllm\n",
        );
        let vllm_pgpu = self.vllm.report.per_gpu_throughput.max(f64::MIN_POSITIVE);
        for r in self.systems() {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                r.system.name(),
                r.deployment,
                r.gpus,
                r.report.per_gpu_throughput,
                r.report.throughput,
                r.report.completed,
                r.report.tokens,
                r.report.ttft.median(),
                r.report.ttft.p99(),
                r.report.ttft_queue.median(),
                r.report.ttft_prefill.median(),
                r.report.ttft_transfer.median(),
                r.report.ttft_decode.median(),
                r.report.tpot.median(),
                r.report.e2e.median(),
                r.report.e2e.p99(),
                r.tpot_slo_attainment,
                r.report.per_gpu_throughput / vllm_pgpu,
            ));
        }
        s
    }
}

/// Run one baseline fleet over the shared workload.
fn run_baseline(
    cfg: &CompareConfig,
    kind: BaselineKind,
    target_gpus: usize,
    workload: &[crate::workload::Request],
) -> SystemResult {
    let cplan = ColocatedPlan::sized_to_match(kind, &cfg.model, &cfg.cluster, target_gpus);
    let deployment = cplan.describe();
    let gpus = cplan.total_gpus();
    let sim_cfg = ClusterSimConfig {
        seed: cfg.seed,
        tenants: cfg.spec.tenants.clone(),
        max_sim_seconds: cfg.max_sim_seconds,
        ..ClusterSimConfig::colocated(cfg.model.clone(), cfg.cluster.clone(), cplan)
    };
    let report = ClusterSim::new(sim_cfg).run(workload);
    SystemResult {
        system: match kind {
            BaselineKind::Vllm => SystemKind::Vllm,
            BaselineKind::TrtLlm => SystemKind::TrtLlm,
        },
        deployment,
        gpus,
        tpot_slo_attainment: report.tpot.fraction_below(cfg.slo),
        report,
    }
}

/// Run the full three-system comparison. See the module docs for the
/// procedure; fails only when no feasible disaggregated plan exists.
pub fn run_compare(cfg: &CompareConfig) -> Result<CompareReport> {
    let avg_seq = cfg.spec.avg_seq_len();
    let mut searcher = PlanSearcher::new(cfg.model.clone(), cfg.cluster.clone(), avg_seq);
    searcher.limits.slo = cfg.slo;
    // Size the prefill pool for the actual workload shape, so prefill is
    // neither the bottleneck nor idle ballast in the comparison.
    searcher.prompt = PromptShape::of_spec(&cfg.spec);
    let plan = match cfg.validate_top {
        Some(k) if k > 0 => validate_top_k(
            &searcher,
            &cfg.spec,
            &ValidationConfig {
                top_k: k,
                seed: cfg.seed,
                popularity: cfg.popularity,
                ..Default::default()
            },
        )
        .map(|v| v.plan),
        _ => searcher.search(),
    }
    .ok_or_else(|| anyhow!("no feasible disaggregated plan under the SLO"))?;

    // Size the baseline fleets to at least the plan's GPU count, then
    // auto-size the workload so every system reaches steady state: twice
    // the disaggregated global batch and at least each fleet's aggregate
    // scheduler cap.
    let target_gpus = plan.total_gpus();
    let requests = if cfg.requests == 0 {
        let fleet_cap = |kind: BaselineKind| {
            let p = ColocatedPlan::sized_to_match(kind, &cfg.model, &cfg.cluster, target_gpus);
            p.replicas * p.max_batch_per_group()
        };
        (2 * plan.global_batch)
            .max(fleet_cap(BaselineKind::Vllm))
            .max(fleet_cap(BaselineKind::TrtLlm))
            .max(256)
    } else {
        cfg.requests
    };
    let workload = cfg.spec.generate(requests, cfg.seed ^ WORKLOAD_SALT);

    let disagg_cfg = ClusterSimConfig {
        popularity: cfg.popularity,
        seed: cfg.seed,
        tenants: cfg.spec.tenants.clone(),
        max_sim_seconds: cfg.max_sim_seconds,
        ..ClusterSimConfig::new(cfg.model.clone(), cfg.cluster.clone(), plan.clone())
    };
    let disagg_report = ClusterSim::new(disagg_cfg).run(&workload);
    let disaggregated = SystemResult {
        system: SystemKind::Disaggregated,
        deployment: format!(
            "MSI a={}x{} e={}x{} p={}x{} m={}",
            plan.n_a, plan.tp_a, plan.n_e, plan.tp_e, plan.n_p, plan.tp_p, plan.m
        ),
        gpus: target_gpus,
        tpot_slo_attainment: disagg_report.tpot.fraction_below(cfg.slo),
        report: disagg_report,
    };

    let vllm = run_baseline(cfg, BaselineKind::Vllm, target_gpus, &workload);
    let trtllm = run_baseline(cfg, BaselineKind::TrtLlm, target_gpus, &workload);

    Ok(CompareReport {
        plan,
        requests,
        seed: cfg.seed,
        slo: cfg.slo,
        disaggregated,
        vllm,
        trtllm,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_kind_parse_roundtrip() {
        for k in [SystemKind::Disaggregated, SystemKind::Vllm, SystemKind::TrtLlm] {
            assert_eq!(SystemKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(SystemKind::parse("disagg").unwrap(), SystemKind::Disaggregated);
        assert_eq!(SystemKind::parse("trt").unwrap(), SystemKind::TrtLlm);
        assert!(SystemKind::parse("sglang").is_err());
    }

    #[test]
    fn baseline_mapping() {
        assert_eq!(SystemKind::Disaggregated.baseline(), None);
        assert_eq!(SystemKind::Vllm.baseline(), Some(BaselineKind::Vllm));
        assert_eq!(SystemKind::TrtLlm.baseline(), Some(BaselineKind::TrtLlm));
    }
}
