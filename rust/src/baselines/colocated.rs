//! Colocated (monolithic) deployments as first-class *simulated* systems.
//!
//! The analytic functions in [`crate::baselines`] evaluate a vLLM-style or
//! TensorRT-LLM-style deployment at a steady-state batch; this module makes
//! the same deployments runnable through the event-driven
//! [`crate::sim::engine::ClusterEngine`] so the paper's central comparison
//! (§7.2, Figure 8) can be reproduced on *realistic traffic* — bursty
//! arrivals, multi-tenant mixes, ramp-up and drain — on the exact same
//! [`crate::workload::ArrivalSource`] workloads the disaggregated path
//! serves.
//!
//! The architectural differences the engine models, per §2.3/§2.4:
//!
//! * attention and experts are **colocated on one pool of serving groups**:
//!   a decode layer is one serial stage (attention + all experts' GEMMs +
//!   TP collectives), so there is no ping-pong overlap (`m = 1`) and the
//!   "expert stage"/M2N link contribute zero time;
//! * the decode batch is **never aggregated across replicas** — each group
//!   runs continuous batching under its own scheduler cap
//!   ([`BaselineKind::max_batch`]), so per-expert batches stay in the
//!   low-utilization regime of Figure 1(b);
//! * unoverlapped MoE all-to-all, per-step scheduler overhead, and kernel
//!   quality differences are folded into the per-layer time through
//!   [`BaselineKind::kernel_efficiency`] (see the calibration note in
//!   `EXPERIMENTS.md`).

use crate::config::{ClusterSpec, GpuSpec, ModelConfig, DTYPE_BYTES};
use crate::perf_model::PrefillModel;

use super::{layer_time, minimal_deployment, pp_send_time, BaselineDeployment, BaselineKind};

/// A colocated deployment scaled out to `replicas` independent serving
/// groups: the simulation-mode counterpart of
/// [`super::BaselineDeployment`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColocatedPlan {
    /// Which baseline system the groups run.
    pub kind: BaselineKind,
    /// Tensor-parallel degree inside each group (GPUs per PP stage).
    pub tp: usize,
    /// Pipeline-parallel stages per group (multi-node models).
    pub pp: usize,
    /// Independent serving groups (data-parallel replicas). Batches are
    /// never aggregated across them — the capability disaggregation adds.
    pub replicas: usize,
}

impl ColocatedPlan {
    /// GPUs in one serving group (`tp · pp`).
    pub fn gpus_per_group(&self) -> usize {
        self.tp * self.pp
    }

    /// GPUs across the whole fleet.
    pub fn total_gpus(&self) -> usize {
        self.gpus_per_group() * self.replicas
    }

    /// Scheduler cap per group (vLLM `max_num_seqs` / TRT-LLM batch
    /// scheduler defaults).
    pub fn max_batch_per_group(&self) -> usize {
        self.kind.max_batch()
    }

    /// The minimal viable group for `model` (mirroring §7.2), replicated
    /// until the fleet reaches at least `target_gpus` — how `msi compare`
    /// sizes a baseline fleet to match a disaggregated plan's GPU count so
    /// per-GPU throughput is compared at comparable scale.
    pub fn sized_to_match(
        kind: BaselineKind,
        model: &ModelConfig,
        cluster: &ClusterSpec,
        target_gpus: usize,
    ) -> Self {
        let dep = minimal_deployment(kind, model, cluster);
        let per_group = (dep.tp * dep.pp).max(1);
        Self {
            kind,
            tp: dep.tp,
            pp: dep.pp,
            replicas: target_gpus.div_ceil(per_group).max(1),
        }
    }

    /// KV-token budget of one serving group: the group's aggregate GPU
    /// memory minus the **full** model parameters (every GPU slice holds
    /// attention *and* experts — the memory pressure §2.4 calls out) with
    /// 5% activation headroom.
    pub fn group_kv_tokens(&self, model: &ModelConfig, cluster: &ClusterSpec) -> u64 {
        let gpu = cluster.attention_gpu();
        let params = model.total_params() * DTYPE_BYTES;
        let budget = self.gpus_per_group() as f64 * gpu.mem_bytes() - params * 1.05;
        (budget.max(0.0) / model.kv_bytes_per_token()).floor() as u64
    }

    /// One-line human description, e.g. `vLLM tp=8 pp=1 x4`.
    pub fn describe(&self) -> String {
        format!(
            "{} tp={} pp={} x{}",
            self.kind.name(),
            self.tp,
            self.pp,
            self.replicas
        )
    }
}

/// Per-layer stage-time model of a colocated serving group at the live
/// batch composition — the colocated counterpart of
/// [`crate::perf_model::PerfModel`], rebuilt each decode iteration at the
/// batch's live average sequence length.
///
/// The whole decode layer (attention + MoE + TP collectives, at the
/// baseline's kernel efficiency) is charged to the single serial stage the
/// engine's pipeline runs in colocated mode; pipeline-parallel stage
/// rounding and inter-stage hops are amortized into the per-layer time so
/// one pass over `L` layers reproduces the analytic
/// [`super::evaluate_at_batch`] TPOT exactly.
#[derive(Debug, Clone)]
pub struct ColocatedModel {
    kind: BaselineKind,
    tp: usize,
    pp: usize,
    gpu: GpuSpec,
    model: ModelConfig,
    avg_seq: f64,
    /// `ceil(L/pp)·pp / L`: PP stage rounding spread over the `L` hops.
    stage_factor: f64,
}

impl ColocatedModel {
    /// Build the model for one serving group of `plan` at the live average
    /// sequence length `avg_seq`.
    pub fn new(
        plan: &ColocatedPlan,
        model: &ModelConfig,
        cluster: &ClusterSpec,
        avg_seq: f64,
    ) -> Self {
        let layers = model.layers.max(1) as f64;
        let pp = plan.pp.max(1) as f64;
        let stage_factor = (layers / pp).ceil() * pp / layers;
        Self {
            kind: plan.kind,
            tp: plan.tp.max(1),
            pp: plan.pp.max(1),
            gpu: cluster.attention_gpu(),
            model: model.clone(),
            avg_seq,
            stage_factor,
        }
    }

    /// Roofline model for the group's inline chunked-prefill passes. The
    /// engine builds this ONCE (it does not depend on the live batch) and
    /// passes it back into [`Self::prefill_layer_time`] each iteration —
    /// `ColocatedModel` itself is rebuilt per iteration at the live
    /// `avg_seq`, and must stay cheap to construct.
    pub fn prefill_model(
        plan: &ColocatedPlan,
        model: &ModelConfig,
        cluster: &ClusterSpec,
    ) -> PrefillModel {
        PrefillModel::new(model, &cluster.attention_gpu(), plan.tp.max(1))
    }

    /// Effective per-layer decode time of one group at batch `b`, such that
    /// `L · layer_time(b)` equals the group's full TPOT (including PP stage
    /// rounding and inter-stage activation hops).
    pub fn layer_time(&self, b: f64) -> f64 {
        let lt = layer_time(self.kind, &self.model, &self.gpu, self.tp, self.avg_seq, b);
        let hops = (self.pp as f64 - 1.0) * pp_send_time(&self.model, &self.gpu, b)
            / self.model.layers.max(1) as f64;
        lt * self.stage_factor + hops
    }

    /// Per-layer time of one inline chunked-prefill pass of `tokens` prompt
    /// tokens at mean attended context `ctx`, charged ON TOP of the decode
    /// layer time when a group mixes a prefill chunk into an iteration
    /// (vLLM-style chunked prefill interfering with decode). The roofline
    /// chunk cost (from the [`Self::prefill_model`] the caller holds) is
    /// discounted by the baseline's kernel efficiency and spread like the
    /// decode layers across PP stages.
    pub fn prefill_layer_time(&self, prefill: &PrefillModel, tokens: f64, ctx: f64) -> f64 {
        prefill.chunk_layer_time(tokens, ctx) / self.kind.kernel_efficiency() * self.stage_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::evaluate_at_batch;
    use crate::config::GpuKind;

    #[test]
    fn sized_to_match_covers_target() {
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        for target in [1, 8, 11, 52] {
            let p = ColocatedPlan::sized_to_match(BaselineKind::Vllm, &model, &cluster, target);
            assert!(p.total_gpus() >= target);
            assert!(p.total_gpus() - target < p.gpus_per_group());
        }
    }

    #[test]
    fn layer_time_reproduces_analytic_tpot() {
        // L · layer_time(b) must equal the analytic TPOT of the same
        // deployment at the same batch (the steady-state cross-check the
        // engine path anchors to).
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        for kind in [BaselineKind::Vllm, BaselineKind::TrtLlm] {
            let plan = ColocatedPlan::sized_to_match(kind, &model, &cluster, 8);
            let cm = ColocatedModel::new(&plan, &model, &cluster, 730.0);
            let b = 128;
            let analytic = evaluate_at_batch(
                &BaselineDeployment {
                    kind,
                    tp: plan.tp,
                    pp: plan.pp,
                },
                &model,
                &cluster,
                730.0,
                b,
            );
            let des = cm.layer_time(b as f64) * model.layers as f64;
            let rel = (des - analytic.tpot).abs() / analytic.tpot;
            assert!(rel < 1e-9, "{kind:?}: des {des} vs analytic {}", analytic.tpot);
        }
    }

    #[test]
    fn inline_prefill_chunk_costs_more_at_lower_kernel_efficiency() {
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let time = |kind| {
            let plan = ColocatedPlan::sized_to_match(kind, &model, &cluster, 8);
            let pm = ColocatedModel::prefill_model(&plan, &model, &cluster);
            ColocatedModel::new(&plan, &model, &cluster, 730.0)
                .prefill_layer_time(&pm, 2048.0, 1024.0)
        };
        let vllm = time(BaselineKind::Vllm);
        let trt = time(BaselineKind::TrtLlm);
        assert!(trt > 0.0 && vllm > trt, "vllm {vllm} vs trt {trt}");
    }

    #[test]
    fn group_kv_budget_positive_and_param_dominated() {
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let p = ColocatedPlan::sized_to_match(BaselineKind::Vllm, &model, &cluster, 8);
        let kv = p.group_kv_tokens(&model, &cluster);
        assert!(kv > 0, "8x80GB minus 141B params leaves KV room");
        // The whole model's parameters squeeze the budget well below the
        // attention-only budget a disaggregated node enjoys per GPU.
        let disagg_per_gpu =
            (cluster.attention_gpu().mem_bytes() - model.attn_param_bytes()).max(0.0)
                / model.kv_bytes_per_token();
        assert!((kv as f64 / p.gpus_per_group() as f64) < disagg_per_gpu);
    }
}
