//! Baseline serving systems for the end-to-end comparison (paper §7.2):
//! monolithic (non-disaggregated) deployments in the style of **vLLM**
//! (tensor parallelism for the whole model) and **TensorRT-LLM** (tensor
//! parallelism + expert parallelism for MoE layers, faster custom kernels).
//!
//! Both share the substrate of [`crate::perf_model`], so measured
//! differences come from *architecture*: in a monolithic deployment every
//! GPU holds a slice of every expert (TP) or a subset of experts (EP) and
//! the decode batch is never aggregated across replicas, so each expert
//! sees only `b·K/E` tokens — the low-utilization regime of Figure 1(b).
//!
//! Two evaluation paths exist:
//!
//! * **analytic** (this module): steady-state metrics at a chosen batch
//!   ([`evaluate_at_batch`], [`best_under_slo`]) — the closed-form Figure 8
//!   columns the benches print;
//! * **simulated** ([`colocated`], [`compare`]): the same deployments run
//!   through the event-driven [`crate::sim::engine::ClusterEngine`] on
//!   arbitrary arrival processes, which is what `msi compare` uses to
//!   reproduce the paper's comparison under realistic traffic.

mod colocated;
mod compare;

pub use colocated::{ColocatedModel, ColocatedPlan};
pub use compare::{run_compare, CompareConfig, CompareReport, SystemKind, SystemResult};

use crate::config::{ClusterSpec, GpuSpec, ModelConfig, DTYPE_BYTES};
use crate::perf_model::{AttentionModel, GpuPerf, GemmShape};

/// Which baseline system to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    /// vLLM 0.6.6-style: TP (+PP across nodes), PagedAttention, continuous
    /// batching; experts computed as TP-sharded GEMMs.
    Vllm,
    /// TensorRT-LLM 0.15-style: like vLLM plus expert parallelism for MoE
    /// layers and more aggressive kernel fusion.
    TrtLlm,
}

impl BaselineKind {
    /// Achieved efficiency vs the substrate's achievable-rate model.
    ///
    /// This folds together the real-system effects the paper's measured
    /// baselines exhibit and MegaScale-Infer engineers away: unoverlapped
    /// MoE all-to-all and TP collectives in the decode loop, per-step
    /// scheduler/sampling overhead, and grouped-GEMM inefficiency at small
    /// per-expert batches. TensorRT-LLM's custom kernels sit well above
    /// vLLM's Triton path (paper: "TensorRT-LLM achieves higher throughput
    /// than vLLM through custom kernel optimizations"); both sit below the
    /// fused, overlap-scheduled MegaScale stack. Calibrated so the Figure 8
    /// ratios land in the paper's measured bands (see DESIGN.md).
    pub fn kernel_efficiency(&self) -> f64 {
        match self {
            BaselineKind::Vllm => 0.55,
            BaselineKind::TrtLlm => 0.80,
        }
    }

    /// Maximum concurrent sequences per serving group — the shipped
    /// scheduler defaults (vLLM `max_num_seqs`, TRT-LLM batch scheduler).
    /// A monolithic group cannot aggregate beyond this; aggregating across
    /// replicas is exactly the capability disaggregation adds (§2.4).
    pub fn max_batch(&self) -> usize {
        match self {
            BaselineKind::Vllm => 256,
            BaselineKind::TrtLlm => 512,
        }
    }

    /// Whether MoE layers run with expert parallelism (full per-expert
    /// GEMMs on one GPU) instead of TP-sharded GEMMs.
    pub fn uses_expert_parallelism(&self) -> bool {
        matches!(self, BaselineKind::TrtLlm)
    }

    /// Human-readable system name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            BaselineKind::Vllm => "vLLM",
            BaselineKind::TrtLlm => "TensorRT-LLM",
        }
    }
}

/// A monolithic deployment: `tp` GPUs per stage within a node, `pp` stages
/// across nodes.
#[derive(Debug, Clone)]
pub struct BaselineDeployment {
    /// Which baseline system runs the deployment.
    pub kind: BaselineKind,
    /// Tensor-parallel degree within one node.
    pub tp: usize,
    /// Pipeline-parallel stages across nodes.
    pub pp: usize,
}

/// Analytic steady-state metrics for a baseline at a given batch size.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineMetrics {
    /// Decode time per output token (seconds).
    pub tpot: f64,
    /// Output tokens per second for the serving group.
    pub throughput: f64,
    /// Output tokens per second per GPU (the Figure-8 metric).
    pub per_gpu_throughput: f64,
    /// Output tokens per second per normalized dollar (Table 3 prices).
    pub throughput_per_dollar: f64,
    /// The batch size evaluated.
    pub batch: usize,
    /// GPUs in the serving group.
    pub gpus: usize,
    /// Normalized cost of the serving group.
    pub cost: f64,
}

/// Per-layer decode time of the monolithic deployment at batch `b`.
///
/// Attention: the same model as MegaScale's attention nodes, TP over `tp`.
/// MoE: every expert computes on `b·K/E` tokens; under TP the expert weight
/// panels are sharded (`h'/tp` columns) but **all experts' panels stream
/// every iteration**; under EP each GPU holds `E/tp` full experts. Either
/// way the per-expert batch stays small — the utilization collapse of §2.3.
fn layer_time(
    kind: BaselineKind,
    model: &ModelConfig,
    gpu: &GpuSpec,
    tp: usize,
    avg_seq: f64,
    b: f64,
) -> f64 {
    let mut perf = GpuPerf::from_spec(gpu);
    perf.mfu_cap *= kind.kernel_efficiency();
    perf.mem_eff *= kind.kernel_efficiency().max(0.85);
    let h = model.hidden as f64;
    let h2 = model.intermediate as f64;
    let e = model.experts as f64;
    let k = model.top_k as f64;

    // Attention side (shared implementation with MegaScale's model, at this
    // baseline's kernel efficiency).
    let attn = {
        let m = AttentionModel::new(model, gpu, tp, avg_seq);
        // Scale the whole attention term by kernel efficiency.
        (m.k1 * b + m.k2) / kind.kernel_efficiency()
    };

    // MoE side.
    let b_exp = b * k / e; // tokens per expert
    let moe = if kind.uses_expert_parallelism() {
        // EP: each GPU computes E/tp full experts back to back.
        let experts_per_gpu = (e / tp as f64).ceil();
        let fin = GemmShape::new(b_exp, h, h2);
        let fout = GemmShape::new(b_exp, h2, h);
        experts_per_gpu * (perf.gemm_time(&fin) + perf.gemm_time(&fout))
            // all-to-all dispatch+combine inside the TP group (NVLink).
            + 2.0 * perf.allreduce_time(b * h * DTYPE_BYTES * k / e, tp, 0.0)
    } else {
        // TP: all E experts' sharded panels stream every iteration.
        let fin = GemmShape::new(b_exp, h, h2 / tp as f64);
        let fout = GemmShape::new(b_exp, h2 / tp as f64, h);
        e * (perf.gemm_time(&fin) + perf.gemm_time(&fout))
    };

    // Two TP all-reduces per layer (attention out, FFN out).
    let ar = 2.0 * perf.allreduce_time(b * h * DTYPE_BYTES, tp, 0.0);

    attn + moe + ar
}

/// Inter-stage activation send for pipeline parallelism (per token batch).
fn pp_send_time(model: &ModelConfig, gpu: &GpuSpec, b: f64) -> f64 {
    let bytes = b * model.hidden as f64 * DTYPE_BYTES;
    bytes / (gpu.nic_gbps * 1e9 / 8.0) + 10e-6
}

/// Evaluate a baseline deployment at batch `b`.
pub fn evaluate_at_batch(
    dep: &BaselineDeployment,
    model: &ModelConfig,
    cluster: &ClusterSpec,
    avg_seq: f64,
    b: usize,
) -> BaselineMetrics {
    let gpu = cluster.attention_gpu(); // monolithic: one GPU type
    let layers_per_stage = (model.layers as f64 / dep.pp as f64).ceil();
    let lt = layer_time(dep.kind, model, &gpu, dep.tp, avg_seq, b as f64);
    // Decode has no intra-request pipelining across stages: TPOT is the sum
    // of stage times plus inter-stage hops.
    let tpot = lt * layers_per_stage * dep.pp as f64
        + (dep.pp as f64 - 1.0) * pp_send_time(model, &gpu, b as f64);
    let gpus = dep.tp * dep.pp;
    let cost = gpus as f64 * gpu.price;
    let throughput = b as f64 / tpot;
    BaselineMetrics {
        tpot,
        throughput,
        per_gpu_throughput: throughput / gpus as f64,
        throughput_per_dollar: throughput / cost,
        batch: b,
        gpus,
        cost,
    }
}

/// KV memory feasibility for the monolithic deployment: params + KV must fit
/// in the aggregate GPU memory of the serving group.
pub fn kv_fits(
    dep: &BaselineDeployment,
    model: &ModelConfig,
    cluster: &ClusterSpec,
    avg_seq: f64,
    b: usize,
) -> bool {
    let gpu = cluster.attention_gpu();
    let total_mem = (dep.tp * dep.pp) as f64 * gpu.mem_bytes();
    let params = model.total_params() * DTYPE_BYTES;
    let kv = b as f64 * avg_seq * model.kv_bytes_per_token();
    params * 1.05 + kv < total_mem
}

/// Find the best batch size under the SLO (binary search like Algorithm 1's
/// SIMULATE, applied to the baseline).
pub fn best_under_slo(
    dep: &BaselineDeployment,
    model: &ModelConfig,
    cluster: &ClusterSpec,
    avg_seq: f64,
    slo: f64,
) -> Option<BaselineMetrics> {
    let ok = |b: usize| -> Option<BaselineMetrics> {
        if b == 0 || b > dep.kind.max_batch() || !kv_fits(dep, model, cluster, avg_seq, b) {
            return None;
        }
        let m = evaluate_at_batch(dep, model, cluster, avg_seq, b);
        (m.tpot <= slo).then_some(m)
    };
    ok(1)?;
    let (mut lo, mut hi) = (1usize, 2usize);
    while ok(hi).is_some() {
        lo = hi;
        hi *= 2;
        if hi > 1 << 22 {
            break;
        }
    }
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if ok(mid).is_some() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    ok(lo)
}

/// The minimal viable deployment for a model on a GPU type, mirroring §7.2:
/// "serving Mixtral 8x22B and DBRX requires a minimum of 8 GPUs, while the
/// scaled-MoE necessitates multi-node deployment". Grows PP until the
/// parameters fit.
pub fn minimal_deployment(
    kind: BaselineKind,
    model: &ModelConfig,
    cluster: &ClusterSpec,
) -> BaselineDeployment {
    let gpu = cluster.attention_gpu();
    let tp = gpu.max_per_node;
    let params = model.total_params() * DTYPE_BYTES;
    let mut pp = 1usize;
    // Require ~20% headroom beyond parameters for KV + activations.
    while (tp * pp) as f64 * gpu.mem_bytes() < params * 1.25 {
        pp += 1;
    }
    BaselineDeployment { kind, tp, pp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(GpuKind::Ampere80G)
    }

    #[test]
    fn minimal_deployment_matches_paper() {
        // Mixtral/DBRX: single 8-GPU node; Scaled-MoE: two nodes.
        let c = cluster();
        let m = minimal_deployment(BaselineKind::Vllm, &ModelConfig::mixtral_8x22b(), &c);
        assert_eq!((m.tp, m.pp), (8, 1));
        let d = minimal_deployment(BaselineKind::Vllm, &ModelConfig::dbrx(), &c);
        assert_eq!((d.tp, d.pp), (8, 1));
        let s = minimal_deployment(BaselineKind::Vllm, &ModelConfig::scaled_moe(), &c);
        assert!(s.pp >= 2, "Scaled-MoE needs multi-node, got pp={}", s.pp);
    }

    #[test]
    fn trtllm_beats_vllm() {
        let c = cluster();
        let model = ModelConfig::mixtral_8x22b();
        let v = best_under_slo(
            &minimal_deployment(BaselineKind::Vllm, &model, &c),
            &model,
            &c,
            730.0,
            0.150,
        )
        .unwrap();
        let t = best_under_slo(
            &minimal_deployment(BaselineKind::TrtLlm, &model, &c),
            &model,
            &c,
            730.0,
            0.150,
        )
        .unwrap();
        assert!(
            t.per_gpu_throughput > v.per_gpu_throughput,
            "TRT {} vs vLLM {}",
            t.per_gpu_throughput,
            v.per_gpu_throughput
        );
    }

    #[test]
    fn slo_respected() {
        let c = cluster();
        let model = ModelConfig::dbrx();
        let dep = minimal_deployment(BaselineKind::TrtLlm, &model, &c);
        let m = best_under_slo(&dep, &model, &c, 730.0, 0.150).unwrap();
        assert!(m.tpot <= 0.150);
        // Next larger batch violates SLO, KV memory, or the scheduler cap.
        let next = evaluate_at_batch(&dep, &model, &c, 730.0, m.batch + 1);
        assert!(
            next.tpot > 0.150
                || !kv_fits(&dep, &model, &c, 730.0, m.batch + 1)
                || m.batch + 1 > dep.kind.max_batch()
        );
    }

    #[test]
    fn tpot_monotone_in_batch() {
        let c = cluster();
        let model = ModelConfig::mixtral_8x22b();
        let dep = minimal_deployment(BaselineKind::Vllm, &model, &c);
        let a = evaluate_at_batch(&dep, &model, &c, 730.0, 32);
        let b = evaluate_at_batch(&dep, &model, &c, 730.0, 256);
        assert!(b.tpot > a.tpot);
    }
}
