//! Deployment plan search — paper Algorithm 1 (§4.2) plus the heterogeneous
//! hardware enumeration of §4.3.
//!
//! Given the MoE model, workload characteristics (average sequence length),
//! available hardware, and the TPOT SLO, the search picks:
//!
//! 1. tensor-parallel sizes `tp_a`, `tp_e` for attention / expert nodes,
//! 2. the number of attention nodes `n_a` (BALANCE step, constraint 1),
//! 3. the number of micro-batches `m` for the ping-pong pipeline,
//! 4. the maximum global batch size `B` that meets the SLO (binary search
//!    inside SIMULATE),
//!
//! and maximizes **throughput per unit cost**.
//!
//! The closed-form ranking can optionally be **sim-validated**
//! ([`validate_top_k`], `msi plan --validate-top K`): the top-K candidates
//! are re-scored by short [`crate::sim::engine::ClusterEngine`] runs over a
//! shared workload and the winner is picked by simulated goodput per
//! dollar, catching queueing/admission effects Eq. 4–6 cannot see.

mod heterogeneous;
mod simulate;
mod validate;

pub use heterogeneous::{search_heterogeneous, table3_kinds, HeteroResult};
pub use simulate::{simulate_plan, simulate_plan_des, PlanMetrics};
pub use validate::{
    validate_heterogeneous, validate_top_k, CandidateScore, ValidatedPlan, ValidationConfig,
};

use crate::config::{ClusterSpec, ModelConfig};
use crate::perf_model::{prefill_node_gpus, PerfModel, PrefillModel, DEFAULT_PREFILL_CHUNK};
use crate::workload::WorkloadSpec;

/// Search-space limits (paper: `N_m = 4`, GPUs per node in {1,2,4,8}).
#[derive(Debug, Clone)]
pub struct SearchLimits {
    /// Max micro-batches per instance (`N_m`).
    pub max_micro_batches: usize,
    /// Min micro-batches considered (Algorithm 1 starts at 3; ablations use 1).
    pub min_micro_batches: usize,
    /// TPOT SLO in seconds (paper: 150 ms).
    pub slo: f64,
    /// Candidate TP degrees (subset of {1, 2, 4, 8} that divides node size).
    pub tp_choices: Vec<usize>,
    /// Upper bound on attention nodes to consider.
    pub max_attention_nodes: usize,
    /// Upper bound on prefill nodes the BALANCE-style prefill sizing may
    /// pick (degenerate tiny-model plans would otherwise demand hundreds).
    pub max_prefill_nodes: usize,
}

impl Default for SearchLimits {
    fn default() -> Self {
        Self {
            max_micro_batches: 4,
            min_micro_batches: 3,
            slo: 0.150,
            tp_choices: vec![1, 2, 4, 8],
            max_attention_nodes: 64,
            max_prefill_nodes: 64,
        }
    }
}

/// Mean prompt/output lengths the prefill-pool sizing balances against.
///
/// The decode side of a plan consumes prefilled requests at
/// `throughput / mean_output` requests/second, each carrying `mean_input`
/// prompt tokens to prefill — the prefill pool is sized so its aggregate
/// chunked-prefill rate covers that demand (the attention : prefill :
/// expert analogue of Algorithm 1's BALANCE step).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PromptShape {
    /// Mean prompt length in tokens.
    pub mean_input: f64,
    /// Mean output length in tokens.
    pub mean_output: f64,
}

impl PromptShape {
    /// Paper-ratio shape (571:159 production medians) scaled so that
    /// `mean_input + mean_output/2` matches the given average sequence
    /// length — the default when a caller only knows `avg_seq`.
    pub fn from_avg_seq(avg_seq: f64) -> Self {
        let scale = (avg_seq / (571.0 + 159.0 / 2.0)).max(1e-6);
        Self {
            mean_input: 571.0 * scale,
            mean_output: 159.0 * scale,
        }
    }

    /// Exact mean lengths of a workload spec.
    pub fn of_spec(spec: &WorkloadSpec) -> Self {
        Self {
            mean_input: spec.mean_input().max(1.0),
            mean_output: spec.mean_output().max(1.0),
        }
    }
}

/// A fully-specified deployment plan with its simulated metrics.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    /// Name of the model the plan serves.
    pub model: String,
    /// TP inside each attention node.
    pub tp_a: usize,
    /// TP inside each expert node.
    pub tp_e: usize,
    /// Number of attention (data-parallel) nodes.
    pub n_a: usize,
    /// Number of expert nodes (= number of experts `E`).
    pub n_e: usize,
    /// Prefill-pool nodes: full-model instances feeding the decode pools
    /// with chunk-prefilled prompts (0 = prefill not modeled). Sized by the
    /// search so the pool's packed chunked-prefill rate covers the decode
    /// side's request consumption under [`PlanSearcher::prompt`].
    pub n_p: usize,
    /// GPUs per prefill node (enough to hold the full model).
    pub tp_p: usize,
    /// Micro-batches in the ping-pong pipeline.
    pub m: usize,
    /// Global batch size per instance.
    pub global_batch: usize,
    /// Analytic metrics of the plan (Eq. 4-6 closed forms; decode-instance
    /// scope — prefill-pool cost is layered on via [`Self::prefill_cost`]).
    pub metrics: PlanMetrics,
}

impl DeploymentPlan {
    /// GPUs across all pools (attention + expert + prefill).
    pub fn total_gpus(&self) -> usize {
        self.tp_a * self.n_a + self.tp_e * self.n_e + self.tp_p * self.n_p
    }

    /// GPUs across the two decode pools only (the Eq. 4–6 instance).
    pub fn decode_gpus(&self) -> usize {
        self.tp_a * self.n_a + self.tp_e * self.n_e
    }

    /// Normalized Table-3 cost of the prefill pool (attention-GPU prices).
    pub fn prefill_cost(&self, cluster: &ClusterSpec) -> f64 {
        cluster.attention_gpu().price * (self.tp_p * self.n_p) as f64
    }

    /// Micro-batch size per attention node (`b_a`).
    pub fn b_a(&self) -> f64 {
        self.global_batch as f64 / (self.m * self.n_a) as f64
    }

    /// Micro-batch size per expert node (`b_e`), from
    /// `b_a·m·n_a = b_e·m·E/K = B`.
    pub fn b_e(&self, model: &ModelConfig) -> f64 {
        self.global_batch as f64 * model.top_k as f64
            / (self.m * model.experts) as f64
    }

    /// JSON rendering for the CLI and experiment logs.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("model", self.model.as_str())
            .set("tp_a", self.tp_a)
            .set("tp_e", self.tp_e)
            .set("n_a", self.n_a)
            .set("n_e", self.n_e)
            .set("n_p", self.n_p)
            .set("tp_p", self.tp_p)
            .set("m", self.m)
            .set("global_batch", self.global_batch)
            .set("total_gpus", self.total_gpus())
            .set("metrics", self.metrics.to_json())
    }
}

/// Algorithm 1 driver.
pub struct PlanSearcher {
    /// The model to deploy.
    pub model: ModelConfig,
    /// Hardware offered to the search.
    pub cluster: ClusterSpec,
    /// Search-space limits and the TPOT SLO.
    pub limits: SearchLimits,
    /// Average sequence length of the workload (`s`).
    pub avg_seq: f64,
    /// Mean prompt/output lengths driving the prefill-pool sizing. Defaults
    /// to the paper ratio scaled to `avg_seq`; set it from the actual
    /// workload ([`PromptShape::of_spec`]) when known.
    pub prompt: PromptShape,
}

impl PlanSearcher {
    /// A searcher with the default limits (paper settings).
    pub fn new(model: ModelConfig, cluster: ClusterSpec, avg_seq: f64) -> Self {
        Self {
            model,
            cluster,
            limits: SearchLimits::default(),
            avg_seq,
            prompt: PromptShape::from_avg_seq(avg_seq),
        }
    }

    /// Size the prefill pool for a decode throughput of `throughput` output
    /// tokens/s: the pool must chunk-prefill `throughput / mean_output ·
    /// mean_input` prompt tokens/s. Returns `(n_p, tp_p)`.
    pub fn size_prefill_pool(&self, throughput: f64) -> (usize, usize) {
        let tp_p = prefill_node_gpus(&self.model, &self.cluster);
        let gpu = self.cluster.attention_gpu();
        let node_rate = PrefillModel::new(&self.model, &gpu, tp_p)
            .steady_rate(DEFAULT_PREFILL_CHUNK, self.prompt.mean_input);
        let demand = throughput / self.prompt.mean_output * self.prompt.mean_input;
        let n_p = (demand / node_rate.max(1e-9)).ceil() as usize;
        (n_p.clamp(1, self.limits.max_prefill_nodes.max(1)), tp_p)
    }

    /// BALANCE (Algorithm 1 line 5): choose `n_a` so that `T_a ≈ T_e`.
    ///
    /// Paper: `n_a = (k1·E)/(k3·K)` from the affine slopes. We evaluate the
    /// integer neighbours of the analytic optimum and keep the one with the
    /// smallest imbalance at a reference batch.
    pub fn balance(&self, tp_a: usize, tp_e: usize) -> usize {
        let pm = PerfModel::new(&self.model, &self.cluster, tp_a, tp_e, self.avg_seq);
        let e = self.model.experts as f64;
        let k = self.model.top_k as f64;
        let raw = (pm.attention.k1 * e) / (pm.expert.k3 * k);
        let cand = [raw.floor().max(1.0) as usize, raw.ceil().max(1.0) as usize];
        let b_a_ref = 512.0;
        let imbalance = |n_a: usize| {
            let b_e = b_a_ref * n_a as f64 * k / e;
            (pm.t_a(b_a_ref) - pm.t_e(b_e)).abs()
        };
        let n_a = *cand
            .iter()
            .min_by(|a, b| imbalance(**a).total_cmp(&imbalance(**b)))
            .unwrap();
        n_a.min(self.limits.max_attention_nodes)
    }

    /// Feasibility (Algorithm 1 line 4): parameters must fit in GPU memory
    /// with headroom for activations and (on attention nodes) the KV cache.
    fn feasible(&self, tp_a: usize, tp_e: usize) -> bool {
        let attn_gpu = self.cluster.attention_gpu();
        let exp_gpu = self.cluster.expert_gpu();
        let p_a = self.model.attn_param_bytes();
        let p_e = self.model.expert_param_bytes();
        tp_a as f64 * attn_gpu.mem_bytes() > p_a * 1.2
            && tp_e as f64 * exp_gpu.mem_bytes() > p_e * 1.2
            && tp_a <= attn_gpu.max_per_node
            && tp_e <= exp_gpu.max_per_node
    }

    /// Run the full search; returns the best plan (max throughput/$), or
    /// `None` when no feasible plan meets the SLO.
    ///
    /// ```
    /// use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
    /// use megascale_infer::plan::PlanSearcher;
    ///
    /// let searcher = PlanSearcher::new(
    ///     ModelConfig::tiny(),
    ///     ClusterSpec::homogeneous(GpuKind::Ampere80G),
    ///     200.0, // average sequence length of the workload
    /// );
    /// let plan = searcher.search().expect("a feasible plan");
    /// assert!(plan.metrics.tpot <= searcher.limits.slo);
    /// assert!(plan.total_gpus() > 0 && plan.global_batch > 0);
    /// ```
    pub fn search(&self) -> Option<DeploymentPlan> {
        self.search_all().into_iter().max_by(|a, b| {
            a.metrics
                .throughput_per_dollar
                .total_cmp(&b.metrics.throughput_per_dollar)
        })
    }

    /// All feasible plans with their metrics (for ablation studies).
    pub fn search_all(&self) -> Vec<DeploymentPlan> {
        let mut plans = Vec::new();
        for &tp_e in &self.limits.tp_choices {
            for &tp_a in &self.limits.tp_choices {
                if !self.feasible(tp_a, tp_e) {
                    continue;
                }
                let n_a = self.balance(tp_a, tp_e);
                for m in self.limits.min_micro_batches..=self.limits.max_micro_batches {
                    if let Some(plan) = self.evaluate(tp_a, tp_e, n_a, m) {
                        plans.push(plan);
                    }
                }
            }
        }
        plans
    }

    /// Evaluate one (tp_a, tp_e, n_a, m) point: binary-search the max global
    /// batch under the SLO and return the plan with its metrics.
    pub fn evaluate(
        &self,
        tp_a: usize,
        tp_e: usize,
        n_a: usize,
        m: usize,
    ) -> Option<DeploymentPlan> {
        let pm = PerfModel::new(&self.model, &self.cluster, tp_a, tp_e, self.avg_seq);
        let (global_batch, metrics) = simulate::max_batch_under_slo(
            &pm,
            &self.model,
            &self.cluster,
            tp_a,
            tp_e,
            n_a,
            m,
            self.avg_seq,
            self.limits.slo,
        )?;
        let (n_p, tp_p) = self.size_prefill_pool(metrics.throughput);
        Some(DeploymentPlan {
            model: self.model.name.clone(),
            tp_a,
            tp_e,
            n_a,
            n_e: self.model.experts,
            n_p,
            tp_p,
            m,
            global_batch,
            metrics,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn searcher(model: ModelConfig) -> PlanSearcher {
        PlanSearcher::new(
            model,
            ClusterSpec::homogeneous(GpuKind::Ampere80G),
            730.0,
        )
    }

    #[test]
    fn finds_a_plan_for_each_paper_model() {
        for model in ModelConfig::paper_models() {
            let s = searcher(model.clone());
            let plan = s.search().unwrap_or_else(|| panic!("no plan for {}", model.name));
            assert!(plan.metrics.tpot <= 0.150 + 1e-9);
            assert!(plan.metrics.throughput > 0.0);
            assert!(plan.n_a >= 1);
            assert!(plan.global_batch > 0);
        }
    }

    #[test]
    fn balance_equalizes_compute_times() {
        let s = searcher(ModelConfig::mixtral_8x22b());
        let n_a = s.balance(4, 2);
        let pm = PerfModel::new(&s.model, &s.cluster, 4, 2, s.avg_seq);
        // Evaluate in the compute-bound operating regime the plan search
        // lands in (slope balance; the weight-load floors dominate only at
        // small batches).
        let b_a = 512.0;
        let b_e = b_a * n_a as f64 * s.model.top_k as f64 / s.model.experts as f64;
        let (ta, te) = (pm.t_a(b_a), pm.t_e(b_e));
        let ratio = ta.max(te) / ta.min(te);
        assert!(ratio < 1.5, "T_a={ta} T_e={te} imbalance {ratio}");
    }

    #[test]
    fn infeasible_tp_rejected() {
        // Mixtral attention params (~3.4 GB bf16 incl. all layers) fit on
        // one 80GB GPU, but Scaled-MoE's expert on a 48GB L40S at tp=1 needs
        // checking; construct an artificial failure: tiny GPU memory.
        let s = searcher(ModelConfig::scaled_moe());
        assert!(s.feasible(1, 1)); // 80GB fits both modules
        let plans = s.search_all();
        assert!(!plans.is_empty());
        for p in &plans {
            assert!(p.m >= 3 && p.m <= 4);
        }
    }

    #[test]
    fn prefill_pool_sized_and_bounded() {
        let s = searcher(ModelConfig::mixtral_8x22b());
        let plan = s.search().unwrap();
        assert!(plan.n_p >= 1 && plan.n_p <= s.limits.max_prefill_nodes);
        assert_eq!(plan.tp_p, 4, "141B bf16 over 80GB GPUs: 4 per prefill node");
        assert_eq!(
            plan.total_gpus(),
            plan.decode_gpus() + plan.tp_p * plan.n_p,
            "total GPUs = decode pools + prefill pool"
        );
        assert!(plan.prefill_cost(&s.cluster) > 0.0);
        // A prompt-heavier mix needs at least as many prefill nodes for the
        // same decode throughput.
        let mut heavy = searcher(ModelConfig::mixtral_8x22b());
        heavy.prompt = PromptShape {
            mean_input: 4.0 * s.prompt.mean_input,
            mean_output: s.prompt.mean_output,
        };
        let (n_heavy, _) = heavy.size_prefill_pool(plan.metrics.throughput);
        let (n_base, _) = s.size_prefill_pool(plan.metrics.throughput);
        assert!(n_heavy >= n_base, "heavy {n_heavy} vs base {n_base}");
    }

    #[test]
    fn best_plan_dominates_all_evaluated() {
        let s = searcher(ModelConfig::dbrx());
        let best = s.search().unwrap();
        for p in s.search_all() {
            assert!(
                best.metrics.throughput_per_dollar >= p.metrics.throughput_per_dollar - 1e-12
            );
        }
    }
}
