//! Sim-in-the-loop plan validation: re-score the top analytic plans by
//! *running* them through the event-driven cluster engine and pick by
//! simulated goodput per dollar (`msi plan --validate-top K`).
//!
//! Algorithm 1's SIMULATE step is a closed form (Eq. 4–6) evaluated at one
//! steady-state batch; it cannot see queueing, KV admission, ramp-up/drain,
//! multinomial gating noise, or multi-tenant SLO pressure. Validation takes
//! the top-`K` candidates by analytic throughput/$, serves the *same*
//! workload through [`ClusterSim`] for each, and picks the plan whose
//! **simulated** goodput per normalized dollar is highest — goodput being
//! simulated token throughput scaled by SLO attainment when the workload
//! declares tenant classes. Cost is the plan's Table-3 normalized price, so
//! heterogeneous pairings (cheap-compute experts, big-memory attention) are
//! compared on cost-per-token, not GPU count.
//!
//! Validation also searches the **prefill-pool dimension**: each top-K
//! candidate is re-scored at its BALANCE-sized prefill pool `n_p` and at
//! ±25% perturbations of it (the attention : prefill : expert third axis),
//! with the pool's Table-3 cost included in the goodput-per-dollar metric —
//! the knob that matters under prompt-heavy workloads
//! ([`crate::workload::WorkloadSpec::prompt_heavy`], `msi plan
//! --prompt-heavy`), where TTFT is prefill-dominated and an undersized pool
//! starves the decode fleet.
//!
//! Ties keep the analytically better-ranked (then smaller-pool) candidate,
//! and every draw is seeded, so the choice is deterministic for a given
//! (model, cluster, spec, seed).

use crate::config::{ClusterSpec, GpuKind, ModelConfig, NodeSpec};
use crate::sim::cluster::{ClusterSim, ClusterSimConfig, ExpertPopularity};
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

use super::{DeploymentPlan, PlanSearcher, SearchLimits};

/// Salt decorrelating the validation workload's generator from the engine
/// runs' gating streams (mirrors `sim::sweep` / `baselines::compare`):
/// feeding both SimRngs the identical seed would make request lengths
/// track the expert-gating draws sample for sample, biasing the scores.
const WORKLOAD_SALT: u64 = 0xa076_1d64_78bd_642f;

/// Knobs of the sim-in-the-loop validation pass.
#[derive(Debug, Clone)]
pub struct ValidationConfig {
    /// How many analytically-ranked candidates to re-score (`K`).
    pub top_k: usize,
    /// Requests in the shared validation workload (each candidate serves
    /// the identical request list).
    pub requests: usize,
    /// Seed for both the workload draw and every candidate's engine run.
    pub seed: u64,
    /// Expert popularity the candidates are validated under. `Uniform`
    /// includes multinomial gating noise; `Ideal` is the noise-free
    /// perf-model assumption (cheapest).
    pub popularity: ExpertPopularity,
}

impl Default for ValidationConfig {
    fn default() -> Self {
        Self {
            top_k: 3,
            requests: 512,
            seed: 42,
            popularity: ExpertPopularity::Uniform,
        }
    }
}

/// One candidate's analytic rank and simulated score.
#[derive(Debug, Clone)]
pub struct CandidateScore {
    /// The candidate plan (analytic metrics included).
    pub plan: DeploymentPlan,
    /// 0-based analytic rank (0 = best analytic throughput/$).
    pub analytic_rank: usize,
    /// Simulated output-token throughput over the validation workload.
    pub simulated_throughput: f64,
    /// Mean per-tenant SLO attainment (1.0 for single-tenant workloads).
    pub attainment: f64,
    /// The selection metric: `throughput · attainment / cost`.
    pub goodput_per_dollar: f64,
}

impl CandidateScore {
    /// JSON rendering (one row of the `msi plan --validate-top` report).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("plan", self.plan.to_json())
            .set("analytic_rank", self.analytic_rank)
            .set("simulated_throughput", self.simulated_throughput)
            .set("attainment", self.attainment)
            .set("goodput_per_dollar", self.goodput_per_dollar)
    }
}

/// Outcome of [`validate_top_k`]: the winning plan plus every candidate's
/// score (in analytic rank order) for reporting.
#[derive(Debug, Clone)]
pub struct ValidatedPlan {
    /// The plan with the best simulated goodput per dollar.
    pub plan: DeploymentPlan,
    /// Index of the winner within `candidates`.
    pub chosen: usize,
    /// All re-scored candidates: analytic-rank-major, prefill-pool size
    /// ascending within a rank.
    pub candidates: Vec<CandidateScore>,
}

impl ValidatedPlan {
    /// True when the simulation overturned the analytic ranking.
    pub fn overturned(&self) -> bool {
        self.chosen != 0
    }

    /// JSON rendering (the `msi plan --validate-top --json` payload).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("chosen", self.chosen)
            .set("overturned", self.overturned())
            .set("plan", self.plan.to_json())
            .set(
                "candidates",
                Json::Arr(self.candidates.iter().map(|c| c.to_json()).collect()),
            )
    }
}

/// Deterministic prefill-pool variants for one candidate: the BALANCE-sized
/// `n_p` and ±25% perturbations (deduplicated, clamped to `[0 stays 0, 1..=cap]`).
/// A plan with prefill modeling off (`n_p == 0`) gets no variants.
fn prefill_variants(n_p: usize, cap: usize) -> Vec<usize> {
    if n_p == 0 {
        return vec![0];
    }
    let cap = cap.max(1);
    let lo = ((n_p * 3) / 4).max(1);
    let hi = ((n_p * 5).div_ceil(4)).max(n_p + 1).min(cap);
    let mut v = vec![lo, n_p.min(cap), hi];
    v.sort_unstable();
    v.dedup();
    v
}

/// Rank `searcher`'s feasible plans analytically, re-score the top
/// `cfg.top_k` — each across its prefill-pool variants — by short engine
/// runs over the same `spec`-drawn workload, and return the plan with the
/// best simulated goodput per dollar.
///
/// Returns `None` when no feasible plan exists. Deterministic: the workload
/// and every gating draw derive from `cfg.seed`, candidate order is
/// total-ordered (analytic score, then shape), and ties keep the earlier
/// (analytically better) candidate.
pub fn validate_top_k(
    searcher: &PlanSearcher,
    spec: &WorkloadSpec,
    cfg: &ValidationConfig,
) -> Option<ValidatedPlan> {
    let mut plans = searcher.search_all();
    if plans.is_empty() {
        return None;
    }
    // Total order: analytic throughput/$ descending, shape as tie-break so
    // the rank (and therefore the seed-derived choice) is deterministic.
    plans.sort_by(|a, b| {
        b.metrics
            .throughput_per_dollar
            .total_cmp(&a.metrics.throughput_per_dollar)
            .then(a.tp_a.cmp(&b.tp_a))
            .then(a.tp_e.cmp(&b.tp_e))
            .then(a.n_a.cmp(&b.n_a))
            .then(a.m.cmp(&b.m))
    });
    plans.truncate(cfg.top_k.max(1));

    let requests = spec.generate(cfg.requests.max(1), cfg.seed ^ WORKLOAD_SALT);
    let mut candidates = Vec::new();
    for (rank, plan) in plans.into_iter().enumerate() {
        for n_p in prefill_variants(plan.n_p, searcher.limits.max_prefill_nodes) {
            let mut plan = plan.clone();
            plan.n_p = n_p;
            // Goodput per TOTAL dollar: the decode instance's Table-3 cost
            // plus the prefill pool's.
            let cost = (plan.metrics.cost + plan.prefill_cost(&searcher.cluster))
                .max(f64::MIN_POSITIVE);
            let sim_cfg = ClusterSimConfig {
                popularity: cfg.popularity,
                seed: cfg.seed,
                tenants: spec.tenants.clone(),
                ..ClusterSimConfig::new(
                    searcher.model.clone(),
                    searcher.cluster.clone(),
                    plan.clone(),
                )
            };
            let rep = ClusterSim::new(sim_cfg).run(&requests);
            let attainment = if rep.tenants.is_empty() {
                1.0
            } else {
                rep.tenants.iter().map(|t| t.attainment()).sum::<f64>() / rep.tenants.len() as f64
            };
            candidates.push(CandidateScore {
                goodput_per_dollar: rep.throughput * attainment / cost,
                simulated_throughput: rep.throughput,
                attainment,
                analytic_rank: rank,
                plan,
            });
        }
    }

    // First strict maximum wins: on exact ties the analytically
    // better-ranked candidate is kept.
    let mut chosen = 0;
    for (i, c) in candidates.iter().enumerate().skip(1) {
        if c.goodput_per_dollar > candidates[chosen].goodput_per_dollar {
            chosen = i;
        }
    }
    Some(ValidatedPlan {
        plan: candidates[chosen].plan.clone(),
        chosen,
        candidates,
    })
}

/// Heterogeneous pairing search with sim-in-the-loop re-ranking: run
/// [`super::search_heterogeneous`] over `kinds`, then validate the top
/// `cfg.top_k` pairings' best plans on their own clusters against the same
/// workload and return `(pairing, simulated goodput/$)` sorted by the
/// simulated score (descending, deterministic).
///
/// This is §4.3's cost-per-token argument carried through to simulation:
/// each pairing's cost uses its own Table-3 prices, so a cheap-compute
/// expert pool can win on goodput per dollar even when its raw throughput
/// is lower.
pub fn validate_heterogeneous(
    model: &ModelConfig,
    kinds: &[GpuKind],
    spec: &WorkloadSpec,
    limits: &SearchLimits,
    cfg: &ValidationConfig,
) -> Vec<(super::HeteroResult, f64)> {
    let results = super::search_heterogeneous(model, kinds, spec.avg_seq_len(), limits);
    let requests = spec.generate(cfg.requests.max(1), cfg.seed ^ WORKLOAD_SALT);
    let mut scored: Vec<(super::HeteroResult, f64)> = results
        .into_iter()
        .take(cfg.top_k.max(1))
        .map(|r| {
            let cluster = ClusterSpec {
                attention: NodeSpec {
                    gpu: r.attention_gpu,
                    gpus_per_node: 8,
                    nodes: None,
                },
                expert: NodeSpec {
                    gpu: r.expert_gpu,
                    gpus_per_node: 8,
                    nodes: None,
                },
            };
            let sim_cfg = ClusterSimConfig {
                popularity: cfg.popularity,
                seed: cfg.seed,
                tenants: spec.tenants.clone(),
                ..ClusterSimConfig::new(model.clone(), cluster.clone(), r.plan.clone())
            };
            let rep = ClusterSim::new(sim_cfg).run(&requests);
            let attainment = if rep.tenants.is_empty() {
                1.0
            } else {
                rep.tenants.iter().map(|t| t.attainment()).sum::<f64>() / rep.tenants.len() as f64
            };
            let cost = (r.plan.metrics.cost + r.plan.prefill_cost(&cluster))
                .max(f64::MIN_POSITIVE);
            let score = rep.throughput * attainment / cost;
            (r, score)
        })
        .collect();
    // Stable sort + total_cmp keeps equal scores in analytic order.
    scored.sort_by(|a, b| b.1.total_cmp(&a.1));
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn tiny_searcher() -> PlanSearcher {
        PlanSearcher::new(
            ModelConfig::tiny(),
            ClusterSpec::homogeneous(GpuKind::Ampere80G),
            200.0,
        )
    }

    fn tiny_spec() -> WorkloadSpec {
        WorkloadSpec {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.3,
            ..Default::default()
        }
    }

    #[test]
    fn validation_is_deterministic_across_runs() {
        let searcher = tiny_searcher();
        let cfg = ValidationConfig {
            top_k: 3,
            requests: 96,
            seed: 11,
            popularity: ExpertPopularity::Ideal,
        };
        let a = validate_top_k(&searcher, &tiny_spec(), &cfg).expect("plan");
        let b = validate_top_k(&searcher, &tiny_spec(), &cfg).expect("plan");
        assert_eq!(a.chosen, b.chosen);
        assert_eq!(
            (a.plan.tp_a, a.plan.tp_e, a.plan.n_a, a.plan.m, a.plan.global_batch),
            (b.plan.tp_a, b.plan.tp_e, b.plan.n_a, b.plan.m, b.plan.global_batch),
        );
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn candidates_cover_top_k_with_prefill_variants() {
        let searcher = tiny_searcher();
        let cfg = ValidationConfig {
            top_k: 2,
            requests: 64,
            seed: 3,
            popularity: ExpertPopularity::Ideal,
        };
        let v = validate_top_k(&searcher, &tiny_spec(), &cfg).expect("plan");
        assert!(!v.candidates.is_empty());
        // Rank-major order; the prefill-pool dimension ascends within a
        // rank and covers more than one pool size.
        for w in v.candidates.windows(2) {
            assert!(w[0].analytic_rank <= w[1].analytic_rank);
            if w[0].analytic_rank == w[1].analytic_rank {
                assert!(w[0].plan.n_p < w[1].plan.n_p, "variants ascend");
            }
        }
        let ranks: std::collections::BTreeSet<usize> =
            v.candidates.iter().map(|c| c.analytic_rank).collect();
        assert!(ranks.contains(&0) && ranks.len() <= 2);
        let pools: std::collections::BTreeSet<usize> = v
            .candidates
            .iter()
            .filter(|c| c.analytic_rank == 0)
            .map(|c| c.plan.n_p)
            .collect();
        assert!(pools.len() >= 2, "prefill dimension searched: {pools:?}");
        for c in &v.candidates {
            assert!(c.simulated_throughput > 0.0);
            assert!(c.goodput_per_dollar > 0.0);
            assert_eq!(c.attainment, 1.0, "single-tenant => attainment 1");
        }
        assert!(v.chosen < v.candidates.len());
    }

    #[test]
    fn prefill_variants_deterministic_and_bounded() {
        assert_eq!(prefill_variants(0, 64), vec![0]);
        assert_eq!(prefill_variants(1, 64), vec![1, 2]);
        assert_eq!(prefill_variants(8, 64), vec![6, 8, 10]);
        assert_eq!(prefill_variants(64, 64), vec![48, 64]);
        for v in prefill_variants(26, 64) {
            assert!((1..=64).contains(&v));
        }
    }

    #[test]
    fn hetero_validation_scores_sorted() {
        let scored = validate_heterogeneous(
            &ModelConfig::tiny(),
            &[GpuKind::H20, GpuKind::L40S],
            &tiny_spec(),
            &SearchLimits {
                slo: 0.200,
                ..Default::default()
            },
            &ValidationConfig {
                top_k: 2,
                requests: 48,
                seed: 5,
                popularity: ExpertPopularity::Ideal,
            },
        );
        assert!(!scored.is_empty());
        for w in scored.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }
}
