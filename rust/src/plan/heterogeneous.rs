//! Heterogeneous deployment search (paper §4.3): enumerate GPU types for
//! attention and expert pools, run Algorithm 1 for each pairing, and rank
//! by throughput per normalized dollar.

use crate::config::{gpu_catalog, ClusterSpec, GpuKind, ModelConfig, NodeSpec};

use super::{DeploymentPlan, PlanSearcher, SearchLimits};

/// Result of one hardware pairing.
#[derive(Debug, Clone)]
pub struct HeteroResult {
    /// GPU type of the attention pool.
    pub attention_gpu: GpuKind,
    /// GPU type of the expert pool.
    pub expert_gpu: GpuKind,
    /// Best plan found for the pairing.
    pub plan: DeploymentPlan,
}

/// Enumerate all (attention GPU, expert GPU) pairings from `kinds` and run
/// the plan search for each. Results are sorted by throughput/$ descending.
pub fn search_heterogeneous(
    model: &ModelConfig,
    kinds: &[GpuKind],
    avg_seq: f64,
    limits: &SearchLimits,
) -> Vec<HeteroResult> {
    let mut out = Vec::new();
    for &a in kinds {
        for &e in kinds {
            let cluster = ClusterSpec {
                attention: NodeSpec {
                    gpu: a,
                    gpus_per_node: 8,
                    nodes: None,
                },
                expert: NodeSpec {
                    gpu: e,
                    gpus_per_node: 8,
                    nodes: None,
                },
            };
            let mut searcher = PlanSearcher::new(model.clone(), cluster, avg_seq);
            searcher.limits = limits.clone();
            if let Some(plan) = searcher.search() {
                out.push(HeteroResult {
                    attention_gpu: a,
                    expert_gpu: e,
                    plan,
                });
            }
        }
    }
    out.sort_by(|x, y| {
        y.plan
            .metrics
            .throughput_per_dollar
            .total_cmp(&x.plan.metrics.throughput_per_dollar)
    });
    out
}

/// All Table 3 GPU kinds.
pub fn table3_kinds() -> Vec<GpuKind> {
    gpu_catalog()
        .into_iter()
        .map(|g| g.kind)
        .filter(|k| *k != GpuKind::Ampere80G)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h20_l40s_pairing_beats_homogeneous_h20_or_l40s() {
        // §4.3 intuition: H20 attention + L40S experts should beat both
        // homogeneous options on throughput per dollar.
        let model = ModelConfig::mixtral_8x22b();
        let results = search_heterogeneous(
            &model,
            &[GpuKind::H20, GpuKind::L40S],
            730.0,
            &SearchLimits::default(),
        );
        assert!(!results.is_empty());
        let tpd = |a: GpuKind, e: GpuKind| {
            results
                .iter()
                .find(|r| r.attention_gpu == a && r.expert_gpu == e)
                .map(|r| r.plan.metrics.throughput_per_dollar)
        };
        let hetero = tpd(GpuKind::H20, GpuKind::L40S).expect("hetero pairing feasible");
        if let Some(h20) = tpd(GpuKind::H20, GpuKind::H20) {
            assert!(hetero > h20, "hetero {hetero} vs H20 homo {h20}");
        }
        if let Some(l40s) = tpd(GpuKind::L40S, GpuKind::L40S) {
            assert!(hetero > l40s, "hetero {hetero} vs L40S homo {l40s}");
        }
    }

    #[test]
    fn results_sorted_descending() {
        let model = ModelConfig::dbrx();
        let results = search_heterogeneous(
            &model,
            &[GpuKind::H20, GpuKind::L40S, GpuKind::A800],
            730.0,
            &SearchLimits::default(),
        );
        for w in results.windows(2) {
            assert!(
                w[0].plan.metrics.throughput_per_dollar
                    >= w[1].plan.metrics.throughput_per_dollar
            );
        }
    }
}
