//! SIMULATE (Algorithm 1 line 8): evaluate a candidate plan's iteration
//! latency and throughput-per-dollar at a given global batch, and
//! binary-search the maximum batch under the SLO and the KV-memory
//! constraint (Eq. 7 and Eq. 8).

use crate::config::{ClusterSpec, ModelConfig, DTYPE_BYTES};
use crate::coordinator::{PingPongEngine, StageTimes};
use crate::perf_model::{IterationModel, PerfModel};

/// Simulated steady-state metrics of a deployment plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanMetrics {
    /// Decode-iteration latency of the global batch == time per output
    /// token, seconds (Eq. 5).
    pub tpot: f64,
    /// Tokens generated per second per instance (`B / T_total`).
    pub throughput: f64,
    /// Tokens/s per GPU — the homogeneous-deployment headline metric.
    pub per_gpu_throughput: f64,
    /// Tokens/s per normalized dollar — the heterogeneous headline metric.
    pub throughput_per_dollar: f64,
    /// Normalized cost of the instance (Table 3 prices).
    pub cost: f64,
    /// Per-micro-batch times for one layer (diagnostics).
    pub t_a: f64,
    /// Expert time per micro-batch per layer.
    pub t_e: f64,
    /// One-direction transfer time per micro-batch.
    pub t_c: f64,
    /// Whether the ping-pong pipeline fully hides communication.
    pub pipeline_full: bool,
    /// Attention / expert busy fractions.
    pub attn_busy: f64,
    /// Expert busy fraction.
    pub expert_busy: f64,
}

impl PlanMetrics {
    /// All-zero placeholder for plans whose numbers come from simulation
    /// rather than the closed forms (e.g. the facade plan a colocated
    /// baseline fleet hands the cluster engine).
    pub fn zeroed() -> Self {
        Self {
            tpot: 0.0,
            throughput: 0.0,
            per_gpu_throughput: 0.0,
            throughput_per_dollar: 0.0,
            cost: 0.0,
            t_a: 0.0,
            t_e: 0.0,
            t_c: 0.0,
            pipeline_full: false,
            attn_busy: 0.0,
            expert_busy: 0.0,
        }
    }

    /// JSON rendering for the CLI and experiment logs.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("tpot_ms", self.tpot * 1e3)
            .set("throughput", self.throughput)
            .set("per_gpu_throughput", self.per_gpu_throughput)
            .set("throughput_per_dollar", self.throughput_per_dollar)
            .set("cost", self.cost)
            .set("t_a_us", self.t_a * 1e6)
            .set("t_e_us", self.t_e * 1e6)
            .set("t_c_us", self.t_c * 1e6)
            .set("pipeline_full", self.pipeline_full)
            .set("attn_busy", self.attn_busy)
            .set("expert_busy", self.expert_busy)
    }
}

/// Shared assembly for the closed-form and DES evaluations: derives the
/// per-micro-batch sizes and stage times, obtains `(t_total, attn_busy,
/// expert_busy)` from `timing`, and fills in the cost/throughput fields so
/// pricing and batch-derivation changes stay in one place.
#[allow(clippy::too_many_arguments)]
fn assemble_metrics(
    pm: &PerfModel,
    model: &ModelConfig,
    cluster: &ClusterSpec,
    tp_a: usize,
    tp_e: usize,
    n_a: usize,
    m: usize,
    global_batch: usize,
    timing: impl FnOnce(&IterationModel) -> (f64, f64, f64),
) -> PlanMetrics {
    let b = global_batch as f64;
    let b_a = b / (m * n_a) as f64;
    let b_e = b * model.top_k as f64 / (m * model.experts) as f64;

    let it = IterationModel {
        t_a: pm.t_a(b_a),
        t_e: pm.t_e(b_e),
        t_c: pm.t_c(b_a, b_e),
        m,
        layers: model.layers,
    };
    let (t_total, attn_busy, expert_busy) = timing(&it);

    let cost_a = cluster.attention_gpu().price * (tp_a * n_a) as f64;
    let cost_e = cluster.expert_gpu().price * (tp_e * model.experts) as f64;
    let cost = cost_a + cost_e;
    let throughput = b / t_total;
    let gpus = (tp_a * n_a + tp_e * model.experts) as f64;

    PlanMetrics {
        tpot: t_total,
        throughput,
        per_gpu_throughput: throughput / gpus,
        throughput_per_dollar: throughput / cost,
        cost,
        t_a: it.t_a,
        t_e: it.t_e,
        t_c: it.t_c,
        pipeline_full: it.pipeline_full(),
        attn_busy,
        expert_busy,
    }
}

/// Evaluate a plan at a specific global batch size `b` (tokens).
#[allow(clippy::too_many_arguments)]
pub fn simulate_plan(
    pm: &PerfModel,
    model: &ModelConfig,
    cluster: &ClusterSpec,
    tp_a: usize,
    tp_e: usize,
    n_a: usize,
    m: usize,
    global_batch: usize,
) -> PlanMetrics {
    assemble_metrics(pm, model, cluster, tp_a, tp_e, n_a, m, global_batch, |it| {
        let breakdown = it.breakdown();
        (breakdown.t_total, breakdown.attn_busy, breakdown.expert_busy)
    })
}

/// Evaluate a plan point by *running* the shared event-driven pipeline core
/// instead of the Eq. 4–5 closed forms — the cross-check used by the test
/// suite and available to callers who sweep regimes where the pipeline-full
/// assumption breaks (m below constraint 3, extreme T_c).
///
/// This is a degenerate-workload wrapper over the same
/// [`crate::sim::pipeline::PipelineCore`] that drives the full trace-driven
/// [`crate::sim::engine::ClusterEngine`]: one steady-state iteration with
/// constant per-hop stage times, scheduled through the identical ping-pong
/// event machine. In the pipeline-full regime this agrees with
/// [`simulate_plan`] to within 2%; outside it, the DES is the ground truth
/// the closed form approximates.
#[allow(clippy::too_many_arguments)]
pub fn simulate_plan_des(
    pm: &PerfModel,
    model: &ModelConfig,
    cluster: &ClusterSpec,
    tp_a: usize,
    tp_e: usize,
    n_a: usize,
    m: usize,
    global_batch: usize,
) -> PlanMetrics {
    assemble_metrics(pm, model, cluster, tp_a, tp_e, n_a, m, global_batch, |it| {
        let st = StageTimes {
            t_a: it.t_a,
            t_e: it.t_e,
            t_c: it.t_c,
        };
        let stats = PingPongEngine {
            m: it.m,
            layers: it.layers,
        }
        .run(|_, _| st);
        (
            stats.total_time,
            stats.attn_utilization,
            stats.expert_utilization,
        )
    })
}

/// KV-cache memory feasibility (Eq. 8):
/// `4·m·b_a·s·h·L/g + 2·P_a < tp_a·C_a` (bytes, bf16).
pub fn kv_memory_ok(
    model: &ModelConfig,
    cluster: &ClusterSpec,
    tp_a: usize,
    m: usize,
    b_a: f64,
    avg_seq: f64,
) -> bool {
    let kv_bytes = DTYPE_BYTES
        * 2.0
        * m as f64
        * b_a
        * avg_seq
        * model.hidden as f64
        * model.layers as f64
        / model.gqa_group() as f64;
    let p_a = model.attn_param_bytes();
    kv_bytes + p_a < tp_a as f64 * cluster.attention_gpu().mem_bytes()
}

/// Binary-search the largest global batch satisfying the SLO (Eq. 7) and the
/// KV-memory limit (Eq. 8). Returns `(B, metrics)` or `None` if even the
/// smallest batch violates a constraint.
#[allow(clippy::too_many_arguments)]
pub fn max_batch_under_slo(
    pm: &PerfModel,
    model: &ModelConfig,
    cluster: &ClusterSpec,
    tp_a: usize,
    tp_e: usize,
    n_a: usize,
    m: usize,
    avg_seq: f64,
    slo: f64,
) -> Option<(usize, PlanMetrics)> {
    // B must be a multiple of m·n_a so micro-batches are integral per node.
    let unit = m * n_a;
    let ok = |mult: usize| -> Option<PlanMetrics> {
        let b = mult * unit;
        let b_a = b as f64 / unit as f64;
        if !kv_memory_ok(model, cluster, tp_a, m, b_a, avg_seq) {
            return None;
        }
        let metrics = simulate_plan(pm, model, cluster, tp_a, tp_e, n_a, m, b);
        (metrics.tpot <= slo).then_some(metrics)
    };

    ok(1)?;
    // Exponential probe then binary search on the multiplier.
    let mut lo = 1usize;
    let mut hi = 2usize;
    while ok(hi).is_some() {
        lo = hi;
        hi *= 2;
        if hi > 1 << 22 {
            break;
        }
    }
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if ok(mid).is_some() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let metrics = ok(lo)?;
    Some((lo * unit, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn setup() -> (ModelConfig, ClusterSpec, PerfModel) {
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let pm = PerfModel::new(&model, &cluster, 4, 2, 730.0);
        (model, cluster, pm)
    }

    #[test]
    fn tpot_monotone_in_batch() {
        let (model, cluster, pm) = setup();
        let m1 = simulate_plan(&pm, &model, &cluster, 4, 2, 4, 3, 1200);
        let m2 = simulate_plan(&pm, &model, &cluster, 4, 2, 4, 3, 2400);
        assert!(m2.tpot > m1.tpot);
    }

    #[test]
    fn binary_search_is_maximal() {
        let (model, cluster, pm) = setup();
        let (b, metrics) =
            max_batch_under_slo(&pm, &model, &cluster, 4, 2, 4, 3, 730.0, 0.150).unwrap();
        assert!(metrics.tpot <= 0.150);
        // One more multiplier must violate a constraint.
        let unit = 3 * 4;
        let next = b + unit;
        let m_next =
            simulate_plan(&pm, &model, &cluster, 4, 2, 4, 3, next);
        let b_a_next = next as f64 / unit as f64;
        let mem_next = kv_memory_ok(&model, &cluster, 4, 3, b_a_next, 730.0);
        assert!(
            m_next.tpot > 0.150 || !mem_next,
            "larger batch should violate SLO or memory"
        );
    }

    #[test]
    fn des_cross_check_agrees_with_closed_form() {
        // Pipeline-full regime: the DES-backed evaluation and the Eq. 5
        // closed form agree within 2% on TPOT and throughput.
        let (model, cluster, pm) = setup();
        let closed = simulate_plan(&pm, &model, &cluster, 4, 2, 4, 3, 2400);
        let des = simulate_plan_des(&pm, &model, &cluster, 4, 2, 4, 3, 2400);
        assert!(closed.pipeline_full);
        let rel = (des.tpot - closed.tpot).abs() / closed.tpot;
        assert!(rel < 0.02, "DES {} vs closed {} (rel {rel})", des.tpot, closed.tpot);
        assert!((des.cost - closed.cost).abs() < 1e-9);
        // Same stage-time inputs on both paths.
        assert_eq!((des.t_a, des.t_e, des.t_c), (closed.t_a, closed.t_e, closed.t_c));
    }

    #[test]
    fn des_shows_bubbles_below_constraint3() {
        // m=1 violates constraint 3: the DES pays the unoverlapped round
        // trips and per-token latency degrades vs m=3.
        let (model, cluster, pm) = setup();
        let m1 = simulate_plan_des(&pm, &model, &cluster, 4, 2, 4, 1, 800);
        let m3 = simulate_plan_des(&pm, &model, &cluster, 4, 2, 4, 3, 2400);
        assert!(!m1.pipeline_full);
        // Same per-micro-batch size => same stage times; throughput per
        // token should favour the full pipeline.
        assert!(
            m3.throughput > 1.5 * m1.throughput,
            "m3 {} vs m1 {}",
            m3.throughput,
            m1.throughput
        );
        assert!(m1.attn_busy < 0.7, "m=1 attention busy {}", m1.attn_busy);
    }

    #[test]
    fn kv_memory_constraint_binds_eventually() {
        let (model, cluster, _) = setup();
        assert!(kv_memory_ok(&model, &cluster, 4, 3, 8.0, 730.0));
        assert!(!kv_memory_ok(&model, &cluster, 1, 4, 100_000.0, 730.0));
    }

    #[test]
    fn throughput_per_dollar_uses_table3_prices() {
        let (model, cluster, pm) = setup();
        let m = simulate_plan(&pm, &model, &cluster, 4, 2, 4, 3, 1200);
        let expected_cost = 2.26 * (4.0 * 4.0) + 2.26 * (2.0 * 8.0);
        assert!((m.cost - expected_cost).abs() < 1e-9);
        assert!((m.throughput_per_dollar - m.throughput / m.cost).abs() < 1e-12);
    }
}
