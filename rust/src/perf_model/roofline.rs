//! GPU-utilization curves of paper Figure 1 and the closed forms of §2.3.
//!
//! * dense FFN:          `util = min(B/F · b, 1)`
//! * MoE FFN:            `util = min(topk/#experts · B/F · b, 1)`
//! * MegaScale-Infer FFN: the MoE curve with `b` replaced by the aggregated
//!   batch `b · n_a · K / E` — disaggregation restores the dense slope.
//! * decode attention:   pinned at the memory roofline regardless of batch
//!   (each request scans its own KV cache, so batching does not increase
//!   arithmetic intensity).

use crate::config::GpuSpec;

/// Dense-model FFN utilization at decode batch `b` (Fig 1a).
pub fn ffn_utilization_dense(gpu: &GpuSpec, b: f64) -> f64 {
    (b / gpu.roofline_batch()).min(1.0)
}

/// MoE FFN utilization at decode batch `b` with `top_k` of `experts`
/// selected (Fig 1b): each expert sees only `b·K/E` tokens.
pub fn ffn_utilization_moe(gpu: &GpuSpec, b: f64, top_k: usize, experts: usize) -> f64 {
    let frac = top_k as f64 / experts as f64;
    (frac * b / gpu.roofline_batch()).min(1.0)
}

/// Decode-attention utilization: the attention core is a batched GEMV over
/// per-request KV caches, arithmetic intensity ~O(1) flops/byte, so the MFU
/// ceiling is `AI · B / F` independent of the batch size. `ai` defaults to
/// 1 flop/byte for bf16 GEMV (2 flops per 2-byte element).
pub fn attention_utilization(gpu: &GpuSpec, ai: f64) -> f64 {
    (ai * gpu.mem_bw_gbps * 1e9 / (gpu.tflops * 1e12)).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn a100() -> GpuSpec {
        GpuSpec::of(GpuKind::Ampere80G)
    }

    #[test]
    fn dense_saturates_at_roofline_batch() {
        let g = a100();
        let b = g.roofline_batch();
        assert!(ffn_utilization_dense(&g, b * 0.5) < 1.0);
        assert_eq!(ffn_utilization_dense(&g, b * 2.0), 1.0);
    }

    #[test]
    fn moe_needs_e_over_k_larger_batch() {
        // §2.3: Mixtral (K=2, E=8) at b=156 gives theoretical MFU 25%.
        let g = a100();
        let u = ffn_utilization_moe(&g, g.roofline_batch(), 2, 8);
        assert!((u - 0.25).abs() < 1e-9);
    }

    #[test]
    fn aggregation_restores_dense_curve() {
        // MegaScale-Infer: n_a attention replicas aggregate to
        // b_e = b·n_a·K/E; with n_a = E/K the dense curve is recovered.
        let g = a100();
        let b = 100.0;
        let n_a = 4.0; // E/K = 8/2
        let agg = b * n_a * 2.0 / 8.0;
        assert_eq!(
            ffn_utilization_dense(&g, agg),
            ffn_utilization_dense(&g, b)
        );
    }

    #[test]
    fn attention_is_batch_independent_and_low() {
        let g = a100();
        let u = attention_utilization(&g, 1.0);
        // 2039 GB/s / 312 TFLOPS ~ 0.65%.
        assert!(u < 0.05, "attention util {u}");
    }
}
