//! Analytical performance model for disaggregated MoE serving (paper §4.2).
//!
//! The paper models per-micro-batch times as affine functions obtained by
//! profiling: `T_a = k1·b_a + k2`, `T_e = k3·b_e + k4`, and the M2N
//! communication time `T_c` from a bandwidth-utilization curve (Eq. 6).
//! Since we have no GPUs to profile, the `k_i` are *derived* from hardware
//! specifications (Table 3) and the GEMM shapes of Table 2 via the roofline
//! model — the same structure the paper fits empirically.
//!
//! All times are in seconds, per **one MoE layer** unless stated otherwise.

mod attention;
mod comm;
mod expert;
mod gemm;
mod iteration;
mod prefill;
mod roofline;

pub use attention::AttentionModel;
pub use comm::{CommModel, bandwidth_util};
pub use expert::ExpertModel;
pub use gemm::{GemmShape, GpuPerf, table2_gemms};
pub use iteration::{IterationModel, LatencyBreakdown};
pub use prefill::{prefill_node_gpus, PrefillModel, DEFAULT_PREFILL_CHUNK};
pub use roofline::{attention_utilization, ffn_utilization_dense, ffn_utilization_moe};

use crate::config::{ClusterSpec, ModelConfig};

/// Bundle of the per-module models for one deployment configuration.
///
/// This is the `SIMULATE` substrate of Algorithm 1 and also drives the
/// virtual-time coordinator backend.
#[derive(Debug, Clone)]
pub struct PerfModel {
    /// `T_a` model of the attention pool.
    pub attention: AttentionModel,
    /// `T_e` model of the expert pool.
    pub expert: ExpertModel,
    /// `T_c` model of the M2N link (Eq. 6).
    pub comm: CommModel,
    /// The model architecture the times are derived from.
    pub model: ModelConfig,
}

impl PerfModel {
    /// Build the model for a given cluster + parallelism choice.
    ///
    /// * `tp_a`, `tp_e` — tensor-parallel degree inside attention / expert
    ///   nodes.
    /// * `avg_seq` — average sequence length `s` of the workload.
    pub fn new(
        model: &ModelConfig,
        cluster: &ClusterSpec,
        tp_a: usize,
        tp_e: usize,
        avg_seq: f64,
    ) -> Self {
        let attn_gpu = cluster.attention_gpu();
        let exp_gpu = cluster.expert_gpu();
        Self {
            attention: AttentionModel::new(model, &attn_gpu, tp_a, avg_seq),
            expert: ExpertModel::new(model, &exp_gpu, tp_e),
            comm: CommModel::new(model, &attn_gpu, &exp_gpu, tp_a, tp_e),
            model: model.clone(),
        }
    }

    /// Rebuild only the attention-side model for a new average sequence
    /// length, bit-identically to `PerfModel::new(model, cluster, tp_a,
    /// tp_e, avg_seq)` — `avg_seq` feeds exclusively into
    /// [`AttentionModel`], so the expert, comm, and model-config parts are
    /// untouched. `attn_gpu` must be the cluster's
    /// [`ClusterSpec::attention_gpu`] (callers cache it to keep this call
    /// allocation-free). The cluster engine calls this once per decode
    /// iteration instead of reconstructing the bundle, which both avoids
    /// the `ModelConfig` clone and keeps [`ExpertModel`]'s memoized
    /// roofline table warm across iterations.
    pub fn set_avg_seq(
        &mut self,
        model: &ModelConfig,
        attn_gpu: &crate::config::GpuSpec,
        tp_a: usize,
        avg_seq: f64,
    ) {
        self.attention = AttentionModel::new(model, attn_gpu, tp_a, avg_seq);
    }

    /// `T_a`: attention-node time for a micro-batch of `b_a` tokens (one layer).
    pub fn t_a(&self, b_a: f64) -> f64 {
        self.attention.time(b_a)
    }

    /// `T_e`: expert-node time for a micro-batch of `b_e` tokens (one layer).
    pub fn t_e(&self, b_e: f64) -> f64 {
        self.expert.time(b_e)
    }

    /// `T_c`: one-direction M2N communication time (Eq. 6).
    pub fn t_c(&self, b_a: f64, b_e: f64) -> f64 {
        self.comm.time(b_a, b_e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, GpuKind};

    #[test]
    fn times_monotone_in_batch() {
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let pm = PerfModel::new(&model, &cluster, 4, 2, 730.0);
        assert!(pm.t_a(64.0) < pm.t_a(256.0));
        assert!(pm.t_e(64.0) < pm.t_e(256.0));
        assert!(pm.t_c(64.0, 128.0) < pm.t_c(512.0, 1024.0));
    }

    #[test]
    fn set_avg_seq_matches_fresh_construction() {
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let mut pm = PerfModel::new(&model, &cluster, 4, 2, 300.0);
        pm.set_avg_seq(&model, &cluster.attention_gpu(), 4, 730.0);
        let fresh = PerfModel::new(&model, &cluster, 4, 2, 730.0);
        for b in [1.0, 64.0, 256.0, 1024.0] {
            assert_eq!(pm.t_a(b), fresh.t_a(b), "b={b}");
            assert_eq!(pm.t_e(b), fresh.t_e(b), "b={b}");
            assert_eq!(pm.t_c(b, 2.0 * b), fresh.t_c(b, 2.0 * b), "b={b}");
        }
    }

    #[test]
    fn affine_structure() {
        // T_a must be affine in b_a in the memory-bound regime the paper
        // fits: T(2b) - T(b) == T(3b) - T(2b).
        let model = ModelConfig::dbrx();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let pm = PerfModel::new(&model, &cluster, 8, 2, 730.0);
        let d1 = pm.t_a(64.0) - pm.t_a(32.0);
        let d2 = pm.t_a(96.0) - pm.t_a(64.0);
        assert!((d1 - d2).abs() < 1e-9, "attention time not affine");
    }
}
