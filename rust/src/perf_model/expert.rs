//! Expert-node time model: `T_e = k3·b_e + k4` (paper §4.2).
//!
//! An expert node runs the two FFN GEMMs of Table 2 for the tokens routed to
//! its expert. The fixed cost `k4` is the expert's weight panels streamed
//! from HBM once per micro-batch; the marginal cost `k3` is per-token
//! compute + activation traffic. When `b_e` exceeds the GPU's roofline batch
//! the GEMMs turn compute-bound — exactly the transition MegaScale-Infer
//! engineers by aggregating tokens from many attention replicas.

use std::cell::RefCell;

use crate::config::{GpuSpec, ModelConfig, DTYPE_BYTES};

use super::gemm::{table2_gemms, GpuPerf};

/// Largest integer batch size memoized by [`ExpertModel::time`]. Decode
/// micro-batches are a few hundred tokens; the cap only bounds the lazily
/// grown table against pathological inputs.
const MEMO_CAP: usize = 1 << 16;

/// Per-layer expert (FFN) time model.
///
/// Unlike the attention side, we keep the exact roofline evaluation rather
/// than a single affine fit: the compute-bound/memory-bound transition at
/// `b_e ≈ F/B` matters for the plan search (it is *the* effect the paper
/// exploits). The affine view (`k3`, `k4`) is exposed for the balance
/// heuristic of Algorithm 1.
#[derive(Debug, Clone)]
pub struct ExpertModel {
    /// Marginal seconds per token in the compute-bound regime (`k3`).
    pub k3: f64,
    /// Fixed seconds per layer: weight-panel load (`k4`).
    pub k4: f64,
    /// TP degree this model was built for.
    pub tp: usize,
    perf: GpuPerf,
    model: ModelConfig,
    /// Lazy roofline table: `memo[b]` caches `time(b as f64)` for integer
    /// `b < MEMO_CAP` (NaN = not computed yet). Every constant the roofline
    /// depends on is fixed at construction, so entries never invalidate;
    /// interior mutability keeps the `&self` signature, and `RefCell` is
    /// `Send` — all the sharded engine needs (each engine owns its models).
    memo: RefCell<Vec<f64>>,
}

impl ExpertModel {
    /// Derive `k3`, `k4` from hardware specs and model shapes.
    pub fn new(model: &ModelConfig, gpu: &GpuSpec, tp: usize) -> Self {
        let perf = GpuPerf::from_spec(gpu);
        let h = model.hidden as f64;
        let h2 = model.intermediate as f64;
        let tpf = tp as f64;

        // Compute-bound marginal cost: SwiGLU = 3 GEMMs (w1, w3 up, w2
        // down), 2·h·h'/tp flops each per token, plus activation bytes and
        // the wire portion of the TP all-reduce on the output (the fixed
        // all-reduce latency belongs to k4).
        let mats = model.ffn_matrices() as f64;
        let flops_per_token = mats * (2.0 * h * h2 / tpf);
        let act_bytes_per_token = (h + mats * h2 / tpf) * DTYPE_BYTES;
        let ar_wire = if tp > 1 {
            2.0 * (tpf - 1.0) / tpf * h * DTYPE_BYTES / perf.intra_bw * 0.5
        } else {
            0.0
        };
        let k3 = flops_per_token / (perf.flops * perf.mfu_cap)
            + act_bytes_per_token / (perf.mem_bw * perf.mem_eff)
            + ar_wire;

        // Fixed cost: the expert's weight panels, 3·h·h'/tp elements, plus
        // the all-reduce step latency.
        let weight_bytes = mats * h * h2 / tpf * DTYPE_BYTES;
        let ar_lat = if tp > 1 { 2.0 * (tpf - 1.0) * 1.5e-6 * 0.5 } else { 0.0 };
        let k4 = perf.mem_time(weight_bytes) + mats * perf.launch_overhead + ar_lat;

        Self {
            k3,
            k4,
            tp,
            perf,
            model: model.clone(),
            memo: RefCell::new(Vec::new()),
        }
    }

    /// `T_e` for `b_e` tokens (one layer, seconds): exact roofline. The
    /// decode hot loop calls this with integer-valued batch sizes, which
    /// hit a lazily grown memo table; fractional sizes (e.g. a balanced
    /// makespan) fall through to the direct evaluation.
    pub fn time(&self, b_e: f64) -> f64 {
        if b_e >= 0.0 && b_e.fract() == 0.0 && b_e < MEMO_CAP as f64 {
            let b = b_e as usize;
            let mut memo = self.memo.borrow_mut();
            if memo.len() <= b {
                memo.resize(b + 1, f64::NAN);
            }
            if memo[b].is_nan() {
                memo[b] = self.evaluate(b_e);
            }
            return memo[b];
        }
        self.evaluate(b_e)
    }

    /// The uncached roofline evaluation behind [`ExpertModel::time`]. The
    /// up-projection GEMM occurs `ffn_matrices - 1` times (w1 and w3).
    fn evaluate(&self, b_e: f64) -> f64 {
        let (_, _, fin, fout) = table2_gemms(&self.model, 1.0, b_e, 1, self.tp);
        let ar = if self.tp > 1 {
            self.perf
                .allreduce_time(b_e * self.model.hidden as f64 * DTYPE_BYTES, self.tp, 0.5)
        } else {
            0.0
        };
        let ups = (self.model.ffn_matrices() - 1) as f64;
        ups * self.perf.gemm_time(&fin) + self.perf.gemm_time(&fout) + ar
    }

    /// Model-flops-utilization of the FFN GEMMs at batch `b_e` — the paper's
    /// `util = min(B/F·b, 1)` per-GEMM utilization, evaluated on the exact
    /// roofline.
    pub fn mfu(&self, b_e: f64) -> f64 {
        let (_, _, fin, fout) = table2_gemms(&self.model, 1.0, b_e, 1, self.tp);
        let ups = (self.model.ffn_matrices() - 1) as f64;
        let flops = ups * fin.flops() + fout.flops();
        let t = self.time(b_e);
        (flops / t / self.perf.flops).clamp(0.0, 1.0)
    }

    /// Batch size where the FFN becomes compute-bound on this GPU.
    pub fn roofline_batch(&self) -> f64 {
        self.perf.flops * self.perf.mfu_cap / (self.perf.mem_bw * self.perf.mem_eff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn mk() -> ExpertModel {
        ExpertModel::new(
            &ModelConfig::mixtral_8x22b(),
            &GpuSpec::of(GpuKind::Ampere80G),
            2,
        )
    }

    #[test]
    fn memoized_integer_batches_match_direct_evaluation() {
        let m = mk();
        for b in [0.0, 1.0, 8.0, 39.0, 156.0, 1024.0] {
            assert_eq!(m.time(b), m.evaluate(b), "first call (fills table)");
            assert_eq!(m.time(b), m.evaluate(b), "second call (table hit)");
        }
        // Fractional and beyond-cap batch sizes bypass the table entirely.
        assert_eq!(m.time(12.5), m.evaluate(12.5));
        let big = MEMO_CAP as f64 * 2.0;
        assert_eq!(m.time(big), m.evaluate(big));
    }

    #[test]
    fn memory_bound_floor() {
        // For tiny batches T_e is dominated by the weight load: doubling a
        // small batch barely changes the time.
        let m = mk();
        let t1 = m.time(1.0);
        let t8 = m.time(8.0);
        assert!((t8 - t1) / t1 < 0.05, "small batches should ride the floor");
    }

    #[test]
    fn compute_bound_linear() {
        // Past the roofline batch, time scales ~linearly with tokens.
        let m = mk();
        let b = m.roofline_batch() * 4.0;
        let r = m.time(2.0 * b) / m.time(b);
        assert!((r - 2.0).abs() < 0.15, "ratio {r}");
    }

    #[test]
    fn mfu_saturates_with_batch() {
        let m = mk();
        assert!(m.mfu(8.0) < 0.2);
        assert!(m.mfu(1024.0) > 0.6);
        assert!(m.mfu(1024.0) <= 1.0);
    }

    #[test]
    fn paper_25pct_mfu_example() {
        // §2.3: batch 156 on Mixtral => 39 tokens/expert => theoretical MFU
        // topk/#experts = 25%. Our achievable-rate model should land in the
        // same neighbourhood (theoretical 25% of peak, times the ~80%
        // achievable cap => ~20-30% band).
        let m = ExpertModel::new(
            &ModelConfig::mixtral_8x22b(),
            &GpuSpec::of(GpuKind::Ampere80G),
            1,
        );
        let mfu = m.mfu(39.0);
        assert!((0.1..0.35).contains(&mfu), "mfu {mfu}");
    }
}
