//! Roofline GEMM timing and the GEMM inventory of paper Table 2.

use crate::config::{GpuSpec, ModelConfig, DTYPE_BYTES};

/// An `m × k` by `k × n` GEMM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmShape {
    /// Rows of the output (tokens).
    pub m: f64,
    /// Contraction dimension.
    pub k: f64,
    /// Columns of the output.
    pub n: f64,
}

impl GemmShape {
    /// A GEMM of the given dimensions.
    pub fn new(m: f64, k: f64, n: f64) -> Self {
        Self { m, k, n }
    }

    /// Floating-point operations: `2·m·k·n`.
    pub fn flops(&self) -> f64 {
        2.0 * self.m * self.k * self.n
    }

    /// Bytes moved from HBM: weights `k·n` (the dominant term during
    /// decoding, §2.3) plus activations in/out `m·(k+n)`.
    pub fn bytes(&self) -> f64 {
        (self.k * self.n + self.m * (self.k + self.n)) * DTYPE_BYTES
    }

    /// Arithmetic intensity in flops/byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops() / self.bytes()
    }
}

/// Effective GPU rates used by the roofline timing.
///
/// `mfu_cap` and `mem_eff` account for achievable (rather than peak) rates:
/// well-tuned decode GEMM kernels reach ~75-85% of peak on both axes.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuPerf {
    /// Peak dense bf16 flops/s.
    pub flops: f64,
    /// Peak HBM bytes/s.
    pub mem_bw: f64,
    /// Fraction of peak compute achievable (MFU ceiling).
    pub mfu_cap: f64,
    /// Fraction of peak bandwidth achievable.
    pub mem_eff: f64,
    /// Fixed per-kernel launch overhead (seconds).
    pub launch_overhead: f64,
    /// Intra-node interconnect bytes/s (NVLink / PCIe) for TP collectives.
    pub intra_bw: f64,
}

impl GpuPerf {
    /// Achievable-rate model for a GPU spec.
    pub fn from_spec(spec: &GpuSpec) -> Self {
        Self {
            flops: spec.tflops * 1e12,
            mem_bw: spec.mem_bw_gbps * 1e9,
            mfu_cap: 0.80,
            mem_eff: 0.85,
            launch_overhead: 4e-6,
            intra_bw: spec.intra_node_gbps * 1e9,
        }
    }

    /// Roofline time for one GEMM: `max(compute, memory)` + launch.
    pub fn gemm_time(&self, g: &GemmShape) -> f64 {
        let compute = g.flops() / (self.flops * self.mfu_cap);
        let memory = g.bytes() / (self.mem_bw * self.mem_eff);
        compute.max(memory) + self.launch_overhead
    }

    /// Time to stream `bytes` from HBM (e.g. the KV cache scan).
    pub fn mem_time(&self, bytes: f64) -> f64 {
        bytes / (self.mem_bw * self.mem_eff)
    }

    /// Ring all-reduce time for `bytes` per GPU across `tp` GPUs over the
    /// intra-node interconnect: `2·(tp-1)/tp · bytes / bw` plus a small
    /// per-step latency. The paper's fused all-gather+GEMM kernels (§6)
    /// partially overlap this; `overlap` is the hidden fraction.
    pub fn allreduce_time(&self, bytes: f64, tp: usize, overlap: f64) -> f64 {
        if tp <= 1 {
            return 0.0;
        }
        let steps = 2.0 * (tp as f64 - 1.0);
        let wire = steps / tp as f64 * bytes / self.intra_bw;
        let lat = steps * 1.5e-6;
        (wire + lat) * (1.0 - overlap)
    }
}

/// The four GEMMs of paper Table 2 for given micro-batch sizes and TP.
///
/// Returns `(qkv_project, attn_output, ffn_input, ffn_output)`.
pub fn table2_gemms(
    model: &ModelConfig,
    b_a: f64,
    b_e: f64,
    tp_a: usize,
    tp_e: usize,
) -> (GemmShape, GemmShape, GemmShape, GemmShape) {
    let h = model.hidden as f64;
    let h2 = model.intermediate as f64;
    let g = model.gqa_group() as f64;
    let tpa = tp_a as f64;
    let tpe = tp_e as f64;
    (
        // QKV Project: (b_a, h) x (h, h(1 + 2/g)/tp_a)
        GemmShape::new(b_a, h, h * (1.0 + 2.0 / g) / tpa),
        // Attn Output: (b_a, h/tp_a) x (h/tp_a, h)
        GemmShape::new(b_a, h / tpa, h),
        // FFN Input: (b_e, h) x (h, h'/tp_e)
        GemmShape::new(b_e, h, h2 / tpe),
        // FFN Output: (b_e, h'/tp_e) x (h'/tp_e, h)
        GemmShape::new(b_e, h2 / tpe, h),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuKind, GpuSpec};

    #[test]
    fn flops_and_bytes() {
        let g = GemmShape::new(4.0, 8.0, 16.0);
        assert_eq!(g.flops(), 2.0 * 4.0 * 8.0 * 16.0);
        assert_eq!(g.bytes(), (8.0 * 16.0 + 4.0 * (8.0 + 16.0)) * 2.0);
    }

    #[test]
    fn roofline_crossover_near_spec_ratio() {
        // A GEMM with m >> F/B must be compute-bound; m << F/B memory-bound.
        let perf = GpuPerf::from_spec(&GpuSpec::of(GpuKind::Ampere80G));
        let big = GemmShape::new(4096.0, 8192.0, 8192.0);
        let small = GemmShape::new(4.0, 8192.0, 8192.0);
        let t_big = perf.gemm_time(&big) - perf.launch_overhead;
        let t_small = perf.gemm_time(&small) - perf.launch_overhead;
        // big: dominated by compute term
        assert!((t_big - big.flops() / (perf.flops * perf.mfu_cap)).abs() / t_big < 1e-6);
        // small: dominated by memory term
        assert!((t_small - small.bytes() / (perf.mem_bw * perf.mem_eff)).abs() / t_small < 1e-6);
    }

    #[test]
    fn table2_shapes_match_paper() {
        let m = ModelConfig::mixtral_8x22b();
        let (qkv, out, fin, fout) = table2_gemms(&m, 128.0, 256.0, 2, 4);
        // QKV: (128, 6144) x (6144, 6144*(1+2/6)/2)
        assert_eq!(qkv.m, 128.0);
        assert_eq!(qkv.k, 6144.0);
        assert!((qkv.n - 6144.0 * (1.0 + 2.0 / 6.0) / 2.0).abs() < 1e-9);
        assert_eq!(out.k, 6144.0 / 2.0);
        assert_eq!(fin.n, 16384.0 / 4.0);
        assert_eq!(fout.m, 256.0);
        assert_eq!(fout.k, 16384.0 / 4.0);
        assert_eq!(fout.n, 6144.0);
    }

    #[test]
    fn allreduce_zero_for_tp1() {
        let perf = GpuPerf::from_spec(&GpuSpec::of(GpuKind::H20));
        assert_eq!(perf.allreduce_time(1e6, 1, 0.0), 0.0);
        assert!(perf.allreduce_time(1e6, 8, 0.0) > 0.0);
        // Overlap reduces the cost.
        assert!(
            perf.allreduce_time(1e6, 8, 0.5) < perf.allreduce_time(1e6, 8, 0.0)
        );
    }
}
