//! Chunked-prefill time model for a full-model prefill instance.
//!
//! MegaScale-Infer's attention/FFN disaggregation serves the *decode* phase;
//! prefill runs on a separate pool of full-model instances (§2, following
//! DistServe/Mooncake). A prefill node holds attention *and* every expert,
//! and processes prompts in token-budgeted chunks (Sarathi-style): each
//! chunk streams the whole model's weight panels once per layer, so small
//! chunks are weight-load bound while large chunks amortize the panels and
//! turn compute-bound — the knob [`DEFAULT_PREFILL_CHUNK`] defaults into
//! the compute-bound regime.
//!
//! Per layer, a chunk of `c` tokens at mean attended context `ctx` costs:
//!
//! * the QKV/output projection GEMMs at batch `c` (roofline),
//! * the attention core, `4·c·ctx·h` flops (causal score+value matmuls),
//! * the MoE FFN: `c·K` token-copies spread over all `E` resident experts,
//!   each expert's GEMMs evaluated on the exact roofline (which charges the
//!   per-expert weight-panel floor `E` times — the chunking trade-off).
//!
//! All times are seconds; weights are sharded over the node's `tp` GPUs.

use std::cell::Cell;

use crate::config::{ClusterSpec, GpuSpec, ModelConfig, DTYPE_BYTES};

use super::gemm::{table2_gemms, GpuPerf};
use super::ExpertModel;

/// Default chunked-prefill token budget (per pass on a prefill node, and
/// per iteration per colocated serving group) — vLLM's default
/// `max_num_batched_tokens`, large enough that Table-4-scale models run
/// their prefill GEMMs compute-bound.
pub const DEFAULT_PREFILL_CHUNK: usize = 2048;

/// GPUs one prefill node needs to hold the FULL model (attention + all
/// experts) with 5% activation headroom, on the cluster's attention GPU
/// type. May exceed one node's GPU count for Scaled-MoE-class models; the
/// time model then stands in for a (perfectly balanced) multi-node TP/PP
/// prefill instance.
pub fn prefill_node_gpus(model: &ModelConfig, cluster: &ClusterSpec) -> usize {
    let gpu = cluster.attention_gpu();
    let params = model.total_params() * DTYPE_BYTES;
    ((params * 1.05 / gpu.mem_bytes()).ceil() as usize).max(1)
}

/// Roofline time model of one full-model prefill node.
#[derive(Debug, Clone)]
pub struct PrefillModel {
    perf: GpuPerf,
    expert: ExpertModel,
    model: ModelConfig,
    tp: usize,
    /// Last-call memo of `chunk_layer_time(tokens, ctx)` keyed by exact
    /// bit patterns: a packed steady-state prefill stream prices the same
    /// full-chunk pass layer after layer, so repeated evaluations collapse
    /// to one compare. The sentinel key is a NaN pattern callers never
    /// produce.
    cache: Cell<(u64, u64, f64)>,
}

impl PrefillModel {
    /// Build the model for a prefill node of `tp` GPUs of type `gpu`.
    pub fn new(model: &ModelConfig, gpu: &GpuSpec, tp: usize) -> Self {
        let tp = tp.max(1);
        Self {
            perf: GpuPerf::from_spec(gpu),
            expert: ExpertModel::new(model, gpu, tp),
            model: model.clone(),
            tp,
            cache: Cell::new((u64::MAX, u64::MAX, 0.0)),
        }
    }

    /// Time for one chunk of `tokens` prompt tokens through ONE layer, at
    /// mean attended context `ctx` (seconds). The chunk may pack segments
    /// of several prompts — callers pass the token-weighted mean context.
    pub fn chunk_layer_time(&self, tokens: f64, ctx: f64) -> f64 {
        let key = (tokens.to_bits(), ctx.to_bits());
        let (kt, kc, cached) = self.cache.get();
        if (kt, kc) == key {
            return cached;
        }
        let tokens = tokens.max(1.0);
        let (qkv, out, _, _) = table2_gemms(&self.model, tokens, 1.0, self.tp, 1);
        let attn_gemm = self.perf.gemm_time(&qkv) + self.perf.gemm_time(&out);
        // Causal attention core: ~4·c·ctx·h flops (QK^T + PV), compute-bound
        // during prefill.
        let core = 4.0 * tokens * ctx.max(1.0) * self.model.hidden as f64 / self.tp as f64
            / (self.perf.flops * self.perf.mfu_cap);
        // MoE FFN: c·K copies spread evenly over the E resident experts;
        // the exact per-expert roofline charges E weight-panel floors.
        let e = self.model.experts.max(1) as f64;
        let per_expert = tokens * self.model.top_k.max(1) as f64 / e;
        let moe = e * self.expert.time(per_expert);
        let t = attn_gemm + core + moe;
        self.cache.set((key.0, key.1, t));
        t
    }

    /// Full chunked prefill time of a single `prompt`-token request across
    /// all layers (no cross-request packing), chunked at `chunk` tokens.
    pub fn prompt_time(&self, prompt: usize, chunk: usize) -> f64 {
        let layers = self.model.layers.max(1) as f64;
        let chunk = chunk.max(1);
        let mut t = 0.0;
        let mut done = 0usize;
        let prompt = prompt.max(1);
        while done < prompt {
            let c = chunk.min(prompt - done);
            t += layers * self.chunk_layer_time(c as f64, done as f64 + c as f64 / 2.0);
            done += c;
        }
        t
    }

    /// Steady-state packed prefill rate (prompt tokens/second) of one node
    /// running full `chunk`-token passes over a stream of `mean_prompt`-token
    /// prompts (mean attended context ≈ half the prompt).
    pub fn steady_rate(&self, chunk: usize, mean_prompt: f64) -> f64 {
        let c = chunk.max(1) as f64;
        let layers = self.model.layers.max(1) as f64;
        let per_pass = layers * self.chunk_layer_time(c, (mean_prompt / 2.0).max(1.0));
        c / per_pass.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn mixtral_node() -> PrefillModel {
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let tp = prefill_node_gpus(&model, &cluster);
        PrefillModel::new(&model, &cluster.attention_gpu(), tp)
    }

    #[test]
    fn mixtral_needs_four_gpus_per_prefill_node() {
        // 141B bf16 params (282 GB) + headroom over 80 GB GPUs => 4.
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        assert_eq!(
            prefill_node_gpus(&ModelConfig::mixtral_8x22b(), &cluster),
            4
        );
        // The tiny model fits on one GPU.
        assert_eq!(prefill_node_gpus(&ModelConfig::tiny(), &cluster), 1);
    }

    #[test]
    fn prompt_time_monotone_in_length() {
        let pm = mixtral_node();
        let t1 = pm.prompt_time(256, DEFAULT_PREFILL_CHUNK);
        let t2 = pm.prompt_time(1024, DEFAULT_PREFILL_CHUNK);
        assert!(t2 > t1 * 3.0, "4x prompt should cost >3x: {t1} vs {t2}");
    }

    #[test]
    fn small_chunks_pay_weight_streaming() {
        // Chunking a prompt into many small passes re-streams every
        // expert's weight panels per pass: strictly slower than one big
        // chunk (the §2.3 utilization argument, applied to prefill).
        let pm = mixtral_node();
        let big = pm.prompt_time(2048, 2048);
        let small = pm.prompt_time(2048, 128);
        assert!(small > 1.5 * big, "chunk 128 {small} vs chunk 2048 {big}");
    }

    #[test]
    fn packed_rate_beats_single_short_prompt() {
        // A full 2048-token pass amortizes weight panels that a lone
        // 256-token prompt pays alone.
        let pm = mixtral_node();
        let packed = pm.steady_rate(DEFAULT_PREFILL_CHUNK, 256.0);
        let alone = 256.0 / pm.prompt_time(256, DEFAULT_PREFILL_CHUNK);
        assert!(packed > 1.5 * alone, "packed {packed} vs alone {alone}");
        assert!(packed.is_finite() && packed > 0.0);
    }

    #[test]
    fn quadratic_context_term_matters_for_long_prompts() {
        // At fixed chunk size, later chunks (larger attended context) cost
        // more than earlier ones, so doubling a long prompt more than
        // doubles its time.
        let pm = mixtral_node();
        let t1 = pm.prompt_time(8192, 2048);
        let t2 = pm.prompt_time(16384, 2048);
        assert!(t2 > 2.05 * t1, "{t2} vs 2x{t1}");
    }
}
