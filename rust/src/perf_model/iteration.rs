//! Ping-pong pipeline iteration latency (paper Eq. 1–5) and the feasibility
//! constraints of §4.1.

/// Inputs: per-micro-batch times for one MoE layer.
#[derive(Debug, Clone, Copy)]
pub struct IterationModel {
    /// Attention compute time per micro-batch per layer (`T_a`).
    pub t_a: f64,
    /// Expert compute time per micro-batch per layer (`T_e`).
    pub t_e: f64,
    /// One-direction communication time per micro-batch (`T_c`).
    pub t_c: f64,
    /// Number of micro-batches (`m`).
    pub m: usize,
    /// Number of MoE layers (`L`).
    pub layers: usize,
}

/// Where the time goes in a decode iteration.
#[derive(Debug, Clone, Copy)]
pub struct LatencyBreakdown {
    /// Total decode-iteration latency of the global batch (`T_total`, Eq. 5).
    pub t_total: f64,
    /// Bottleneck stage time `T_f = max(T_a, T_e)` (Eq. text).
    pub t_f: f64,
    /// Fraction of the iteration each attention node is busy.
    pub attn_busy: f64,
    /// Fraction of the iteration each expert node is busy.
    pub expert_busy: f64,
}

impl IterationModel {
    /// `T_f = max{T_a, T_e}`.
    pub fn t_f(&self) -> f64 {
        self.t_a.max(self.t_e)
    }

    /// Constraint 2: `T_c < T_f` — communication must fit under compute.
    pub fn comm_hidden(&self) -> bool {
        self.t_c < self.t_f()
    }

    /// Constraint 3: `m·T_f >= 2·(T_f + T_c)` — enough micro-batches to fill
    /// the ping-pong pipeline.
    pub fn pipeline_full(&self) -> bool {
        self.m as f64 * self.t_f() >= 2.0 * (self.t_f() + self.t_c)
    }

    /// Minimum `m` that satisfies constraint 3: `m >= 2·(1 + T_c/T_f)`.
    pub fn min_micro_batches(&self) -> usize {
        (2.0 * (1.0 + self.t_c / self.t_f())).ceil() as usize
    }

    /// Eq. 5 verbatim, valid when the pipeline is full:
    /// `T_total = (T_a + T_e + 2·T_c) + T_f·(m·L − 1)`.
    pub fn t_total_eq5(&self) -> f64 {
        (self.t_a + self.t_e + 2.0 * self.t_c)
            + self.t_f() * (self.m as f64 * self.layers as f64 - 1.0)
    }

    /// Busy fractions and total latency.
    pub fn breakdown(&self) -> LatencyBreakdown {
        let t_total = self.total();
        let m = self.m as f64;
        let l = self.layers as f64;
        LatencyBreakdown {
            t_total,
            t_f: self.t_f(),
            attn_busy: (m * l * self.t_a / t_total).clamp(0.0, 1.0),
            expert_busy: (m * l * self.t_e / t_total).clamp(0.0, 1.0),
        }
    }

    /// Total iteration latency: Eq. 5 when the pipeline is full, the
    /// bubble-extended form otherwise.
    pub fn total(&self) -> f64 {
        if self.pipeline_full() {
            self.t_total_eq5()
        } else {
            // Per layer the critical path is the unpipelined round trip of
            // each micro-batch where overlap is impossible.
            let round = self.t_a + self.t_e + 2.0 * self.t_c;
            let m = self.m as f64;
            let l = self.layers as f64;
            let tf = self.t_f();
            // m micro-batches pass through each layer; up to
            // `overlap = m·tf` of work overlaps per layer, but the layer
            // cannot finish before one full round trip.
            round.max(m * tf) * l + (round - tf).max(0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced(m: usize) -> IterationModel {
        IterationModel {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m,
            layers: 10,
        }
    }

    #[test]
    fn min_micro_batches_formula() {
        // T_c/T_f = 0.3 => m >= 2.6 => 3 (paper: "fast communication
        // (T_c < T_f/2) needs at least 3").
        assert_eq!(balanced(3).min_micro_batches(), 3);
        // Slow communication (T_c > T_f/2) needs 4.
        let slow = IterationModel {
            t_c: 0.7,
            ..balanced(3)
        };
        assert_eq!(slow.min_micro_batches(), 4);
    }

    #[test]
    fn eq5_matches_when_full() {
        let it = balanced(3);
        assert!(it.pipeline_full());
        let eq5 = it.t_total_eq5();
        assert!((it.total() - eq5).abs() < 1e-12);
        // Eq. 5 expansion: (1+1+0.6) + 1·(3·10−1) = 31.6
        assert!((eq5 - 31.6).abs() < 1e-9);
    }

    #[test]
    fn eq4_bounds_hold() {
        // Eq. 4: (T_a+T_e+2T_c) + m·T_f·(L−1) <= T_iter <= m·T_f·L applies
        // to one micro-batch's latency; T_total of the global batch sits
        // between m·T_f·L−ish values. Check Eq. 5 against the bounds scaled
        // to the global batch.
        let it = balanced(4);
        let t = it.t_total_eq5();
        let lower = it.m as f64 * it.t_f() * (it.layers as f64 - 1.0);
        let upper = (it.t_a + it.t_e + 2.0 * it.t_c)
            + it.m as f64 * it.t_f() * it.layers as f64;
        assert!(t > lower && t < upper);
    }

    #[test]
    fn m1_has_bubbles() {
        // Without ping-pong (m=1), each layer pays the full round trip.
        let it1 = balanced(1);
        assert!(!it1.pipeline_full());
        let it3 = balanced(3);
        // Per-token-normalized: t(m)/m tokens processed.
        let per_batch1 = it1.total() / 1.0;
        let per_batch3 = it3.total() / 3.0;
        assert!(
            per_batch1 > 1.8 * per_batch3,
            "m=1 {per_batch1} vs m=3 {per_batch3}: ping-pong should ~2x"
        );
    }

    #[test]
    fn busy_fraction_peaks_when_balanced() {
        let it = balanced(3);
        let b = it.breakdown();
        assert!(b.attn_busy > 0.85);
        let skew = IterationModel {
            t_e: 0.2,
            ..balanced(3)
        };
        assert!(skew.breakdown().expert_busy < 0.3);
    }
}
