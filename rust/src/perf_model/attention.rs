//! Attention-node time model: `T_a = k1·b_a + k2` (paper §4.2).
//!
//! Per MoE layer, an attention node runs (Table 2): the QKV projection, the
//! attention core (which streams the KV cache of every request — the
//! memory-intensive part), the output projection, gating (negligible), and a
//! TP all-reduce. Every term is linear in the micro-batch size `b_a` except
//! the weight-load floor, which is constant — hence the affine fit the paper
//! obtains by profiling.

use crate::config::{GpuSpec, ModelConfig, DTYPE_BYTES};

use super::gemm::{table2_gemms, GpuPerf};

/// Affine per-layer attention time model.
#[derive(Debug, Clone)]
pub struct AttentionModel {
    /// Marginal seconds per token (`k1`).
    pub k1: f64,
    /// Fixed seconds per layer (`k2`): weight loads + launches + TP latency.
    pub k2: f64,
    /// TP degree this model was built for.
    pub tp: usize,
}

impl AttentionModel {
    /// Derive `k1`, `k2` from hardware specs and model shapes.
    ///
    /// `avg_seq` is the average sequence length `s`: the KV-cache scan per
    /// token of batch is proportional to `s` (paper: "KV cache access time
    /// is nearly proportional to `b_a·s`").
    pub fn new(model: &ModelConfig, gpu: &GpuSpec, tp: usize, avg_seq: f64) -> Self {
        let perf = GpuPerf::from_spec(gpu);
        let h = model.hidden as f64;
        let g = model.gqa_group() as f64;
        let tpf = tp as f64;

        // --- marginal (per-token) cost k1 ---
        // GEMM activations: the projections add m·(k+n) bytes and 2·m·k·n
        // flops per token; in the decode regime these GEMMs are
        // memory-bound, so the marginal cost is the activation traffic plus
        // the compute time per token, whichever roofline arm dominates.
        // We evaluate the exact roofline at two batch sizes to extract the
        // slope (affine by construction).
        let t = |b: f64| {
            let (qkv, out, _, _) = table2_gemms(model, b, 1.0, tp, 1);
            perf.gemm_time(&qkv) + perf.gemm_time(&out)
        };
        let gemm_slope = (t(512.0) - t(256.0)) / 256.0;

        // KV-cache scan: each token of the batch reads its whole cache,
        // `kv_bytes_per_token · s / L` bytes per layer, sharded over TP.
        let kv_bytes_per_layer_token =
            model.kv_bytes_per_token() / model.layers as f64 * avg_seq / tpf;
        let kv_slope = perf.mem_time(kv_bytes_per_layer_token);

        // Attention-core flops (QK^T + PV): 4·s·h per token, rarely binding
        // during decode but included for completeness.
        let core_flops_slope = 4.0 * avg_seq * h / tpf / (perf.flops * perf.mfu_cap);

        // TP all-reduce on the output: b_a·h·2 bytes of wire (paper:
        // O(b_a·h·(tp-1)/tp)); the fused all-gather+GEMM kernel (§6)
        // overlaps ~50%. Only the per-byte wire cost scales with the batch;
        // the per-step latency is fixed per layer and lands in k2.
        let ar_slope = if tp > 1 {
            2.0 * (tpf - 1.0) / tpf * h * DTYPE_BYTES / perf.intra_bw * 0.5
        } else {
            0.0
        };

        // Gating GEMM (h × E) is ~E/h' the size of an FFN GEMM — noise, but
        // the fused gating kernel (§6) makes it one launch.
        let gate_slope = 2.0 * h * model.experts as f64 / (perf.flops * perf.mfu_cap);

        let k1 = gemm_slope + kv_slope + core_flops_slope + ar_slope + gate_slope;

        // --- fixed cost k2 ---
        // Weight panels streamed once per layer per micro-batch:
        // QKV h·h(1+2/g)/tp + output h·h/tp, plus kernel launches.
        let weight_bytes = (h * h * (1.0 + 2.0 / g) + h * h) / tpf * DTYPE_BYTES;
        let launches = 4.0 * perf.launch_overhead; // qkv, core, out, gating (fused)
        let ar_lat = if tp > 1 { 2.0 * (tpf - 1.0) * 1.5e-6 * 0.5 } else { 0.0 };
        let k2 = perf.mem_time(weight_bytes) + launches + ar_lat;

        Self { k1, k2, tp }
    }

    /// `T_a` for a micro-batch of `b_a` tokens (one layer, seconds).
    pub fn time(&self, b_a: f64) -> f64 {
        self.k1 * b_a + self.k2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    fn mk(tp: usize, s: f64) -> AttentionModel {
        AttentionModel::new(
            &ModelConfig::mixtral_8x22b(),
            &GpuSpec::of(GpuKind::Ampere80G),
            tp,
            s,
        )
    }

    #[test]
    fn affine() {
        let m = mk(4, 730.0);
        let d1 = m.time(100.0) - m.time(50.0);
        let d2 = m.time(150.0) - m.time(100.0);
        assert!((d1 - d2).abs() < 1e-12);
    }

    #[test]
    fn longer_sequences_cost_more() {
        assert!(mk(4, 2000.0).k1 > mk(4, 500.0).k1);
    }

    #[test]
    fn tp_shards_fixed_cost() {
        // More TP => less weight per GPU => smaller k2.
        assert!(mk(8, 730.0).k2 < mk(1, 730.0).k2);
    }

    #[test]
    fn decode_iteration_latency_plausible() {
        // One full decode step (all 56 layers) for a 128-token micro-batch
        // on tp=8 Ampere should land in the single-digit-millisecond to
        // tens-of-ms range — the regime that makes a 150 ms TPOT SLO
        // meaningful for m~3 micro-batches.
        let m = mk(8, 730.0);
        let per_layer = m.time(128.0);
        let step = per_layer * 56.0;
        assert!(step > 1e-3 && step < 0.15, "step {step}s out of range");
    }
}
