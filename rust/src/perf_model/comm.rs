//! M2N communication time model `T_c` (paper Eq. 6).
//!
//! `T_c = max( send_bytes / (W_a · Util(send_bytes)),
//!             recv_bytes / (W_e · Util(recv_bytes)) )`
//!
//! where `Util(size)` is the profiled bandwidth-utilization curve of the
//! fabric: small messages are dominated by per-message overhead and achieve
//! a small fraction of line rate; large messages approach it. We model the
//! curve with the standard half-saturation form
//! `Util(s) = s / (s + s_half)`, equivalent to the LogP-style
//! `t = overhead + s/W` cost with `s_half = overhead · W`.

use std::cell::Cell;

use crate::config::{GpuSpec, ModelConfig, DTYPE_BYTES};

/// Bandwidth utilization for a message of `bytes` on a NIC with line rate
/// `bw` bytes/s and per-message overhead `overhead` seconds.
pub fn bandwidth_util(bytes: f64, bw: f64, overhead: f64) -> f64 {
    let s_half = overhead * bw;
    bytes / (bytes + s_half)
}

/// Per-direction M2N communication model for one (attention pool, expert
/// pool) pair.
#[derive(Debug, Clone)]
pub struct CommModel {
    /// Per-GPU NIC bandwidth on attention nodes, bytes/s (`W_a`).
    pub w_a: f64,
    /// Per-GPU NIC bandwidth on expert nodes, bytes/s (`W_e`).
    pub w_e: f64,
    /// Per-message software+fabric overhead (RDMA post + propagation), s.
    pub overhead: f64,
    hidden: f64,
    top_k: f64,
    tp_a: f64,
    tp_e: f64,
    /// Last-call memo of `time(b_a, b_e)` keyed by the operands' exact bit
    /// patterns: decode iterations price the same `(b_a, hot)` pair for
    /// every layer, so the Eq. 6 evaluation collapses to one compare in
    /// the hot loop. The sentinel key is a NaN bit pattern no caller can
    /// produce (`record`-style guards keep batch sizes finite).
    cache: Cell<(u64, u64, f64)>,
}

impl CommModel {
    /// Build from the model's shapes and the two pools' NIC rates.
    pub fn new(
        model: &ModelConfig,
        attn_gpu: &GpuSpec,
        exp_gpu: &GpuSpec,
        tp_a: usize,
        tp_e: usize,
    ) -> Self {
        Self {
            w_a: attn_gpu.nic_gbps * 1e9 / 8.0,
            w_e: exp_gpu.nic_gbps * 1e9 / 8.0,
            // M2N library: RDMA write-with-immediate post + CQ poll,
            // single-digit microseconds (paper §5 / Figure 10 regime).
            overhead: 6e-6,
            hidden: model.hidden as f64,
            top_k: model.top_k as f64,
            tp_a: tp_a as f64,
            tp_e: tp_e as f64,
            cache: Cell::new((u64::MAX, u64::MAX, 0.0)),
        }
    }

    /// Bytes each attention GPU sends per micro-batch (all destinations):
    /// `b_a · h · K / tp_a · sizeof(dtype)` — each token is dispatched to
    /// K experts (paper §7.3 example).
    pub fn send_bytes(&self, b_a: f64) -> f64 {
        b_a * self.hidden * self.top_k / self.tp_a * DTYPE_BYTES
    }

    /// Bytes each expert GPU receives per micro-batch:
    /// `b_e · h / tp_e · sizeof(dtype)`.
    pub fn recv_bytes(&self, b_e: f64) -> f64 {
        b_e * self.hidden / self.tp_e * DTYPE_BYTES
    }

    /// `T_c` (Eq. 6): the slower of the send and receive sides.
    pub fn time(&self, b_a: f64, b_e: f64) -> f64 {
        let key = (b_a.to_bits(), b_e.to_bits());
        let (ka, ke, cached) = self.cache.get();
        if (ka, ke) == key {
            return cached;
        }
        let s = self.send_bytes(b_a);
        let r = self.recv_bytes(b_e);
        let t_send = s / (self.w_a * bandwidth_util(s, self.w_a, self.overhead));
        let t_recv = r / (self.w_e * bandwidth_util(r, self.w_e, self.overhead));
        let t = t_send.max(t_recv);
        self.cache.set((key.0, key.1, t));
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;

    #[test]
    fn util_curve_shape() {
        let bw = 25e9; // 200 Gbps
        let oh = 6e-6;
        assert!(bandwidth_util(1024.0, bw, oh) < 0.05);
        assert!(bandwidth_util(10e6, bw, oh) > 0.95);
        // Monotone.
        let mut prev = 0.0;
        for s in [1e2, 1e3, 1e4, 1e5, 1e6, 1e7] {
            let u = bandwidth_util(s, bw, oh);
            assert!(u > prev);
            prev = u;
        }
    }

    #[test]
    fn paper_dispatch_size_example() {
        // §7.3: Mixtral 8x22B, micro-batch 128, tp_a=2 => each attention GPU
        // sends 196,608 bytes *total across experts*
        // (128 · 2/8 · 6144 · 2 / 2 per expert GPU × 8 experts).
        let m = ModelConfig::mixtral_8x22b();
        let c = CommModel::new(
            &m,
            &GpuSpec::of(GpuKind::Ampere80G),
            &GpuSpec::of(GpuKind::Ampere80G),
            2,
            1,
        );
        // Paper's per-expert-GPU arithmetic: 128 × 2/8 × 6144 × 2 / 2.
        let total = c.send_bytes(128.0);
        let per_expert_gpu = total / m.experts as f64;
        assert!((per_expert_gpu - 196_608.0).abs() < 1e-6);
    }

    #[test]
    fn tc_balanced_when_b_e_scaled() {
        // With b_e = b_a·n_a·K/E and symmetric NICs, both directions move
        // comparable bytes.
        let m = ModelConfig::mixtral_8x22b();
        let gpu = GpuSpec::of(GpuKind::Ampere80G);
        let c = CommModel::new(&m, &gpu, &gpu, 2, 2);
        let b_a = 128.0;
        let n_a = 4.0;
        let b_e = b_a * n_a * m.top_k as f64 / m.experts as f64;
        let t = c.time(b_a, b_e);
        assert!(t > 0.0 && t < 1e-3, "t_c {t}");
    }
}
