//! Workload generation: synthetic request traces matched to the paper's
//! production dataset statistics (§7.1: median input 571 tokens, median
//! output 159 tokens), with log-normal length distributions, Poisson
//! arrivals, and optional multi-tenant traffic classes with per-class SLOs.
//!
//! Workloads reach the cluster engine through the pull-based
//! [`ArrivalSource`] trait ([`arrivals`]): either a [`TraceSource`] over an
//! explicit request list or a streaming [`RequestStream`] generator with
//! O(1) state, so simulations only ever hold in-flight requests.

mod arrivals;
mod phased;
mod trace;

pub use arrivals::{ArrivalSource, RequestStream, StridedSource, TraceSource};
pub use phased::{PhaseSpec, PhasedSource, RateCurve};
pub use trace::{Trace, TraceStats};

use anyhow::bail;

use crate::sim::SimRng;

/// One inference request. All fields are scalars, so the struct is `Copy`
/// — the request table and batchers move it by value with no heap traffic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Unique request id (generator index or trace id).
    pub id: u64,
    /// Arrival time in seconds (0 for closed-loop benchmarks).
    pub arrival: f64,
    /// Prompt length in tokens.
    pub input_len: usize,
    /// Number of tokens to decode.
    pub output_len: usize,
    /// Traffic-class index into the workload's tenant list (0 when the
    /// workload is single-tenant).
    pub tenant: usize,
}

impl Request {
    /// Sequence length after `decoded` output tokens have been produced.
    pub fn seq_len_at(&self, decoded: usize) -> usize {
        self.input_len + decoded.min(self.output_len)
    }

    /// JSON rendering for trace files (one JSONL line).
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("id", self.id)
            .set("arrival", self.arrival)
            .set("input_len", self.input_len)
            .set("output_len", self.output_len)
            .set("tenant", self.tenant)
    }

    /// Parse one trace line; `tenant` defaults to 0 for pre-multi-tenancy traces.
    pub fn from_json(v: &crate::util::json::Json) -> anyhow::Result<Self> {
        Ok(Self {
            id: v.get("id")?.as_u64()?,
            arrival: v.get("arrival")?.as_f64()?,
            input_len: v.get("input_len")?.as_usize()?,
            output_len: v.get("output_len")?.as_usize()?,
            // Absent in traces written before multi-tenancy existed.
            tenant: match v.opt("tenant") {
                Some(t) => t.as_usize()?,
                None => 0,
            },
        })
    }
}

/// A traffic class in a multi-tenant workload.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    /// Class name used in reports.
    pub name: String,
    /// Relative traffic share (normalized over the mix).
    pub weight: f64,
    /// End-to-end SLO for the class (seconds, arrival → last token).
    pub slo_e2e: f64,
}

impl TenantClass {
    /// Parse a CLI tenant mix: comma-separated `name:weight:slo_seconds`
    /// triples, e.g. `interactive:0.7:2.5,batch:0.3:60`.
    pub fn parse_list(spec: &str) -> anyhow::Result<Vec<TenantClass>> {
        let mut out = Vec::new();
        for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let fields: Vec<&str> = part.trim().split(':').collect();
            if fields.len() != 3 {
                bail!("tenant {part:?} is not name:weight:slo_seconds");
            }
            let weight: f64 = fields[1]
                .parse()
                .map_err(|_| anyhow::anyhow!("tenant weight {:?} not a number", fields[1]))?;
            let slo_e2e: f64 = fields[2]
                .parse()
                .map_err(|_| anyhow::anyhow!("tenant SLO {:?} not a number", fields[2]))?;
            // `> 0.0` (not `!(<= 0.0)`) so NaN is rejected too.
            if !(weight > 0.0 && weight.is_finite()) || !(slo_e2e > 0.0) {
                bail!("tenant {part:?}: weight and SLO must be positive");
            }
            out.push(TenantClass {
                name: fields[0].to_string(),
                weight,
                slo_e2e,
            });
        }
        if out.is_empty() {
            bail!("empty tenant spec");
        }
        Ok(out)
    }
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Median prompt length (paper: 571).
    pub median_input: f64,
    /// Median output length (paper: 159).
    pub median_output: f64,
    /// Log-normal sigma for both lengths.
    pub sigma: f64,
    /// Mean request arrival rate, requests/second (None = closed loop).
    pub arrival_rate: Option<f64>,
    /// Arrival burstiness: 0.0 keeps pure Poisson arrivals; larger values
    /// modulate each inter-arrival gap by a unit-mean log-normal with this
    /// sigma, producing the clustered bursts + lulls of production traffic
    /// while preserving the mean rate.
    pub burst_sigma: f64,
    /// Clamp lengths into [1, max_len].
    pub max_len: usize,
    /// Traffic classes: each request draws a class by weight (empty = all
    /// requests belong to tenant 0).
    pub tenants: Vec<TenantClass>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        Self {
            median_input: 571.0,
            median_output: 159.0,
            sigma: 0.7,
            arrival_rate: None,
            burst_sigma: 0.0,
            max_len: 8192,
            tenants: Vec::new(),
        }
    }
}

impl WorkloadSpec {
    /// The small fixed workload shape shared by the simulator
    /// self-throughput benchmark (`msi sweep --bench`), the CI smoke
    /// sweep, and the streaming scale tests — one definition so they
    /// cannot silently diverge.
    pub fn tiny_bench() -> Self {
        Self {
            median_input: 64.0,
            median_output: 8.0,
            sigma: 0.3,
            ..Default::default()
        }
    }

    /// A prompt-heavy preset (long-context summarization / RAG shape):
    /// prompts ~13x the output length, so TTFT — and therefore the prefill
    /// pool — dominates. This is the workload `msi plan --prompt-heavy` and
    /// `msi compare --prompt-heavy` re-rank prefill-pool sizing under.
    pub fn prompt_heavy() -> Self {
        Self {
            median_input: 2048.0,
            median_output: 160.0,
            ..Default::default()
        }
    }

    /// Expected prompt length: E[lognormal] = median · exp(σ²/2).
    pub fn mean_input(&self) -> f64 {
        self.median_input * (self.sigma * self.sigma / 2.0).exp()
    }

    /// Expected output length: E[lognormal] = median · exp(σ²/2). Divides
    /// a token throughput into a request service rate (benchmark/test
    /// calibration).
    pub fn mean_output(&self) -> f64 {
        self.median_output * (self.sigma * self.sigma / 2.0).exp()
    }

    /// Expected steady-state average sequence length during decoding: the
    /// prompt plus half the output on average.
    pub fn avg_seq_len(&self) -> f64 {
        self.mean_input() + self.mean_output() / 2.0
    }

    /// Weighted tenant draw (0 when the workload is single-tenant).
    fn draw_tenant(&self, rng: &mut SimRng) -> usize {
        if self.tenants.is_empty() {
            return 0;
        }
        let total: f64 = self.tenants.iter().map(|t| t.weight).sum();
        let mut x = rng.uniform() * total;
        for (i, t) in self.tenants.iter().enumerate() {
            if x < t.weight {
                return i;
            }
            x -= t.weight;
        }
        self.tenants.len() - 1
    }

    /// Generate `n` requests (the materialized form of [`Self::stream`]).
    ///
    /// ```
    /// use megascale_infer::workload::WorkloadSpec;
    ///
    /// let spec = WorkloadSpec {
    ///     median_input: 64.0,
    ///     median_output: 8.0,
    ///     ..Default::default()
    /// };
    /// let reqs = spec.generate(4, 42);
    /// assert_eq!(reqs.len(), 4);
    /// // No arrival rate => closed loop: everything arrives at t = 0.
    /// assert!(reqs.iter().all(|r| r.arrival == 0.0));
    /// ```
    pub fn generate(&self, n: usize, seed: u64) -> Vec<Request> {
        self.stream(n, seed).collect()
    }

    /// Streaming generator over the same request sequence as
    /// [`Self::generate`], yielding one request at a time with O(1) state.
    ///
    /// ```
    /// use megascale_infer::workload::WorkloadSpec;
    ///
    /// let spec = WorkloadSpec::tiny_bench();
    /// // The stream yields bit-identically the same requests as
    /// // `generate` — without materializing the list.
    /// let streamed: Vec<_> = spec.stream(16, 7).collect();
    /// assert_eq!(streamed, spec.generate(16, 7));
    /// ```
    pub fn stream(&self, n: usize, seed: u64) -> RequestStream {
        RequestStream::new(self.clone(), n, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn medians_match_paper() {
        let spec = WorkloadSpec::default();
        let reqs = spec.generate(20_001, 3);
        let mut ins: Vec<usize> = reqs.iter().map(|r| r.input_len).collect();
        ins.sort_unstable();
        let med_in = ins[ins.len() / 2] as f64;
        assert!(
            (med_in - 571.0).abs() / 571.0 < 0.08,
            "median input {med_in}"
        );
        let mut outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        outs.sort_unstable();
        let med_out = outs[outs.len() / 2] as f64;
        assert!(
            (med_out - 159.0).abs() / 159.0 < 0.08,
            "median output {med_out}"
        );
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let spec = WorkloadSpec {
            arrival_rate: Some(10.0),
            ..Default::default()
        };
        let reqs = spec.generate(100, 1);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let duration = reqs.last().unwrap().arrival;
        assert!((duration - 10.0).abs() < 4.0, "~100 reqs at 10/s => ~10s");
    }

    #[test]
    fn bursty_preserves_rate_and_raises_variance() {
        let n = 20_000;
        let gaps = |burst_sigma: f64| -> Vec<f64> {
            let reqs = WorkloadSpec {
                arrival_rate: Some(10.0),
                burst_sigma,
                ..Default::default()
            }
            .generate(n, 17);
            reqs.windows(2).map(|w| w[1].arrival - w[0].arrival).collect()
        };
        let stats = |v: &[f64]| {
            let mean = v.iter().sum::<f64>() / v.len() as f64;
            let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
            (mean, var.sqrt() / mean) // (mean, CV)
        };
        let (mean_p, cv_p) = stats(&gaps(0.0));
        let (mean_b, cv_b) = stats(&gaps(1.0));
        // Mean rate preserved within 5%.
        assert!((mean_p - 0.1).abs() / 0.1 < 0.05, "poisson mean {mean_p}");
        assert!((mean_b - 0.1).abs() / 0.1 < 0.10, "bursty mean {mean_b}");
        // Poisson CV ≈ 1; bursty CV well above it.
        assert!((cv_p - 1.0).abs() < 0.1, "poisson cv {cv_p}");
        assert!(cv_b > 1.3, "bursty cv {cv_b} should exceed Poisson");
    }

    #[test]
    fn closed_loop_all_at_zero() {
        let reqs = WorkloadSpec::default().generate(10, 1);
        assert!(reqs.iter().all(|r| r.arrival == 0.0));
        assert!(reqs.iter().all(|r| r.tenant == 0), "single-tenant default");
    }

    #[test]
    fn seq_len_progression() {
        let r = Request {
            id: 0,
            arrival: 0.0,
            input_len: 100,
            output_len: 10,
            tenant: 0,
        };
        assert_eq!(r.seq_len_at(0), 100);
        assert_eq!(r.seq_len_at(5), 105);
        assert_eq!(r.seq_len_at(50), 110); // capped at output_len
    }

    #[test]
    fn tenant_shares_follow_weights() {
        let spec = WorkloadSpec {
            tenants: vec![
                TenantClass {
                    name: "interactive".into(),
                    weight: 3.0,
                    slo_e2e: 2.0,
                },
                TenantClass {
                    name: "batch".into(),
                    weight: 1.0,
                    slo_e2e: 60.0,
                },
            ],
            ..Default::default()
        };
        let reqs = spec.generate(20_000, 5);
        let interactive = reqs.iter().filter(|r| r.tenant == 0).count() as f64;
        let share = interactive / reqs.len() as f64;
        assert!((share - 0.75).abs() < 0.02, "interactive share {share}");
        assert!(reqs.iter().all(|r| r.tenant < 2));
    }

    #[test]
    fn tenant_spec_parses() {
        let ts = TenantClass::parse_list("interactive:0.7:2.5,batch:0.3:60").unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "interactive");
        assert!((ts[0].weight - 0.7).abs() < 1e-12);
        assert!((ts[1].slo_e2e - 60.0).abs() < 1e-12);
        assert!(TenantClass::parse_list("").is_err());
        assert!(TenantClass::parse_list("a:b:c").is_err());
        assert!(TenantClass::parse_list("a:1").is_err());
        assert!(TenantClass::parse_list("a:-1:5").is_err());
        assert!(TenantClass::parse_list("a:NaN:5").is_err());
        assert!(TenantClass::parse_list("a:1:NaN").is_err());
    }

    #[test]
    fn tenant_survives_json_roundtrip_and_defaults_to_zero() {
        let r = Request {
            id: 7,
            arrival: 1.5,
            input_len: 10,
            output_len: 3,
            tenant: 1,
        };
        let back = Request::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // Pre-multi-tenancy trace lines still load.
        let legacy = crate::util::json::Json::parse(
            r#"{"id":1,"arrival":0,"input_len":8,"output_len":2}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&legacy).unwrap().tenant, 0);
    }
}
