//! Pull-based arrival streams for the cluster engine.
//!
//! The first generation of the engine preloaded the whole trace: every
//! `Arrive` event was scheduled upfront and an owned `Vec<Request>` lived
//! for the whole run, so memory and event-queue size were O(total
//! requests). An [`ArrivalSource`] inverts that: the engine *pulls* one
//! request at a time and only ever materializes the in-flight set, which is
//! what makes million-request (and, with a generator, effectively
//! unbounded) simulations cheap.
//!
//! Two implementations:
//!
//! * [`TraceSource`] — wraps an explicit request list (a replayed JSONL
//!   trace or a pre-generated workload), sorted into arrival order. The
//!   source itself owns the list, but the engine's state stays
//!   O(in-flight).
//! * [`RequestStream`] — generator-backed: synthesizes requests one at a
//!   time from a [`WorkloadSpec`] and a seed, producing *exactly* the same
//!   sequence as [`WorkloadSpec::generate`] (which is now implemented on
//!   top of it), with O(1) state.

use crate::sim::engine::KV_BLOCK;
use crate::sim::SimRng;

use super::{Request, WorkloadSpec};

/// A pull-based stream of requests in non-decreasing arrival order.
///
/// Contract: successive [`ArrivalSource::next_request`] calls yield
/// `arrival` values that never decrease (the engine schedules exactly one
/// future `Arrive` event at a time and cannot travel back in virtual time).
///
/// Sources must be [`Send`]: the sharded runner moves engines (and the
/// sources they own) between epoch worker threads.
pub trait ArrivalSource: Send {
    /// Pull the next request, or `None` when the stream is exhausted.
    fn next_request(&mut self) -> Option<Request>;

    /// KV-token demand of the whole stream — `Σ (input + output + one
    /// block of rounding)` over every request it will ever yield — except
    /// that implementations may stop accumulating once the running sum
    /// reaches `cap` and return that partial sum. The engine only ever
    /// uses `min(hardware budget, demand)` with `cap` = the hardware
    /// budget, so the early stop cannot change the result; it keeps the
    /// generator replay O(cap / avg-request) instead of O(stream length).
    /// Must be called before the stream is consumed; implementations may
    /// replay the stream to compute it, but must not hold it in memory.
    fn kv_demand(&self, cap: u64) -> u64;
}

/// KV-token demand of one request (prompt + output + one block of
/// partial-block rounding) — shared by all sources so a trace and a
/// generator replaying the same requests size the allocator identically.
pub(crate) fn request_kv_demand(r: &Request) -> u64 {
    (r.input_len + r.output_len) as u64 + KV_BLOCK
}

/// Trace-backed source: an explicit request list streamed in arrival order.
#[derive(Debug, Clone)]
pub struct TraceSource {
    /// Reverse-sorted by (arrival, id) so pulling is a pop from the back.
    pending: Vec<Request>,
    kv_demand: u64,
}

impl TraceSource {
    /// Build a source over `requests` (sorted into arrival order internally).
    pub fn new(mut requests: Vec<Request>) -> Self {
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        let kv_demand = requests.iter().map(request_kv_demand).sum();
        requests.reverse();
        Self {
            pending: requests,
            kv_demand,
        }
    }
}

impl ArrivalSource for TraceSource {
    fn next_request(&mut self) -> Option<Request> {
        self.pending.pop()
    }

    fn kv_demand(&self, _cap: u64) -> u64 {
        // Precomputed exactly at construction (the list is materialized
        // anyway); `min(cap, ·)` downstream gives the same result.
        self.kv_demand
    }
}

/// Generator-backed streaming source: synthesizes the `n`-request workload
/// of `WorkloadSpec::generate(n, seed)` one request at a time, holding only
/// the RNG state and the arrival clock.
#[derive(Debug, Clone)]
pub struct RequestStream {
    spec: WorkloadSpec,
    /// Construction seed, kept so `kv_demand` can replay from the start.
    seed: u64,
    total: u64,
    rng: SimRng,
    t: f64,
    next_id: u64,
}

impl RequestStream {
    /// A stream yielding exactly the workload of `spec.generate(n, seed)`.
    pub fn new(spec: WorkloadSpec, n: usize, seed: u64) -> Self {
        Self {
            spec,
            seed,
            total: n as u64,
            rng: SimRng::new(seed),
            t: 0.0,
            next_id: 0,
        }
    }

    /// Requests not yet yielded.
    pub fn remaining(&self) -> usize {
        (self.total - self.next_id) as usize
    }
}

impl Iterator for RequestStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.next_id >= self.total {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        if let Some(rate) = self.spec.arrival_rate {
            let mut gap = self.rng.exponential(1.0 / rate);
            if self.spec.burst_sigma > 0.0 {
                // Unit-mean log-normal modulation: median exp(-σ²/2) has
                // mean 1, so the arrival rate is preserved while the
                // inter-arrival CV grows.
                let s = self.spec.burst_sigma;
                gap *= self.rng.lognormal_median((-s * s / 2.0).exp(), s);
            }
            self.t += gap;
        }
        Some(Request {
            id,
            arrival: self.t,
            input_len: (self.rng.lognormal_median(self.spec.median_input, self.spec.sigma)
                as usize)
                .clamp(1, self.spec.max_len),
            output_len: (self.rng.lognormal_median(self.spec.median_output, self.spec.sigma)
                as usize)
                .clamp(1, self.spec.max_len),
            tenant: self.spec.draw_tenant(&mut self.rng),
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.remaining();
        (n, Some(n))
    }
}

impl ArrivalSource for RequestStream {
    fn next_request(&mut self) -> Option<Request> {
        self.next()
    }

    fn kv_demand(&self, cap: u64) -> u64 {
        // O(1)-memory replay from the initial seed — identical draws, so
        // the (cap-saturated) sum matches a preloaded trace exactly after
        // the engine's `min(hardware budget, demand)`.
        let mut sum = 0u64;
        for r in RequestStream::new(self.spec.clone(), self.total as usize, self.seed) {
            sum += request_kv_demand(&r);
            if sum >= cap {
                break;
            }
        }
        sum
    }
}

/// Strided view of another source: yields requests whose pull index `i`
/// satisfies `i % stride == shard`, preserving arrival order. This is how
/// the sharded engine partitions one arrival stream across independent
/// sub-clusters — each shard wraps its own copy of the underlying source,
/// so no cross-thread coordination is needed.
#[derive(Debug, Clone)]
pub struct StridedSource<S> {
    inner: S,
    shard: usize,
    stride: usize,
    pulled: u64,
}

impl<S: ArrivalSource + Clone> StridedSource<S> {
    /// The `shard`-th of `stride` interleaved sub-streams of `inner`.
    pub fn new(inner: S, shard: usize, stride: usize) -> Self {
        assert!(stride > 0 && shard < stride, "shard {shard} of {stride}");
        Self {
            inner,
            shard,
            stride,
            pulled: 0,
        }
    }
}

impl<S: ArrivalSource + Clone> ArrivalSource for StridedSource<S> {
    fn next_request(&mut self) -> Option<Request> {
        loop {
            let r = self.inner.next_request()?;
            let mine = (self.pulled % self.stride as u64) as usize == self.shard;
            self.pulled += 1;
            if mine {
                return Some(r);
            }
        }
    }

    fn kv_demand(&self, cap: u64) -> u64 {
        // Replay a fresh copy of the stream, summing only this shard's
        // requests with the same cap-saturated early stop as the inner
        // sources. Like theirs, this must run before consumption (the
        // engine calls it once at construction).
        let mut replay = self.clone();
        let mut sum = 0u64;
        while let Some(r) = replay.next_request() {
            sum += request_kv_demand(&r);
            if sum >= cap {
                break;
            }
        }
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strided_sources_partition_the_stream() {
        let spec = WorkloadSpec {
            arrival_rate: Some(30.0),
            ..Default::default()
        };
        let all: Vec<Request> = RequestStream::new(spec.clone(), 97, 5).collect();
        let stride = 3;
        let mut seen: Vec<Request> = Vec::new();
        for shard in 0..stride {
            let mut src =
                StridedSource::new(RequestStream::new(spec.clone(), 97, 5), shard, stride);
            let mut count = 0usize;
            let mut last = f64::NEG_INFINITY;
            while let Some(r) = src.next_request() {
                assert!(r.arrival >= last, "shard stream stays ordered");
                last = r.arrival;
                assert_eq!(r, all[shard + count * stride], "strided element");
                seen.push(r);
                count += 1;
            }
        }
        // Every request lands in exactly one shard.
        assert_eq!(seen.len(), all.len());
        let mut ids: Vec<u64> = seen.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), all.len());
    }

    #[test]
    fn strided_kv_demand_sums_to_whole_stream() {
        let spec = WorkloadSpec {
            arrival_rate: Some(12.0),
            ..Default::default()
        };
        let whole = RequestStream::new(spec.clone(), 60, 8).kv_demand(u64::MAX);
        let parts: u64 = (0..4)
            .map(|s| {
                StridedSource::new(RequestStream::new(spec.clone(), 60, 8), s, 4)
                    .kv_demand(u64::MAX)
            })
            .sum();
        assert_eq!(parts, whole);
        // Cap saturation still early-stops.
        let capped =
            StridedSource::new(RequestStream::new(spec.clone(), 60, 8), 0, 4).kv_demand(100);
        assert!(capped >= 100 || capped == whole);
    }

    #[test]
    fn stream_matches_generate_bit_for_bit() {
        let spec = WorkloadSpec {
            arrival_rate: Some(25.0),
            burst_sigma: 0.6,
            ..Default::default()
        };
        let streamed: Vec<Request> = RequestStream::new(spec.clone(), 200, 9).collect();
        assert_eq!(streamed, spec.generate(200, 9));
    }

    #[test]
    fn trace_source_sorts_and_streams_in_arrival_order() {
        let mut reqs = WorkloadSpec {
            arrival_rate: Some(10.0),
            ..Default::default()
        }
        .generate(50, 3);
        reqs.reverse(); // deliberately unsorted input
        let mut src = TraceSource::new(reqs);
        let mut last = f64::NEG_INFINITY;
        let mut n = 0;
        while let Some(r) = src.next_request() {
            assert!(r.arrival >= last, "non-decreasing arrivals");
            last = r.arrival;
            n += 1;
        }
        assert_eq!(n, 50);
    }

    #[test]
    fn kv_demand_agrees_between_trace_and_stream() {
        let spec = WorkloadSpec {
            arrival_rate: Some(40.0),
            ..Default::default()
        };
        let stream = RequestStream::new(spec.clone(), 120, 7);
        let trace = TraceSource::new(spec.generate(120, 7));
        let exact = trace.kv_demand(u64::MAX);
        assert_eq!(stream.kv_demand(u64::MAX), exact);
        assert!(exact > 0);
        // A cap saturates the replay but stays consistent under the
        // engine's `min(cap, demand)`.
        let capped = stream.kv_demand(exact / 2);
        assert!(capped >= exact / 2 && capped <= exact);
        assert_eq!((exact / 2).min(capped), exact / 2);
    }

    #[test]
    fn stream_remaining_counts_down() {
        let mut s = RequestStream::new(WorkloadSpec::default(), 3, 1);
        assert_eq!(s.remaining(), 3);
        s.next_request();
        assert_eq!(s.remaining(), 2);
        assert!(s.next_request().is_some());
        assert!(s.next_request().is_some());
        assert!(s.next_request().is_none());
        assert_eq!(s.remaining(), 0);
    }
}
