//! Trace container: save/load request traces as JSON lines, compute summary
//! statistics. Lets experiments be replayed bit-identically and lets users
//! substitute their own production traces for the synthetic generator.

use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::Request;

/// A replayable request trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// The requests, in file order.
    pub requests: Vec<Request>,
}

/// Summary statistics of a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceStats {
    /// Number of requests.
    pub count: usize,
    /// Median prompt length (tokens).
    pub median_input: usize,
    /// Median output length (tokens).
    pub median_output: usize,
    /// Mean prompt length (tokens).
    pub mean_input: f64,
    /// Mean output length (tokens).
    pub mean_output: f64,
    /// Steady-state average sequence length (input + output/2).
    pub avg_seq: f64,
}

impl Trace {
    /// Wrap a request list.
    pub fn new(requests: Vec<Request>) -> Self {
        Self { requests }
    }

    /// Write as JSON lines.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        for r in &self.requests {
            writeln!(f, "{}", r.to_json())?;
        }
        Ok(())
    }

    /// Load from JSON lines.
    pub fn load(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut requests = Vec::new();
        for line in std::io::BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            requests.push(Request::from_json(&Json::parse(&line)?)?);
        }
        Ok(Self { requests })
    }

    /// Summary statistics (length medians/means, steady-state average sequence).
    pub fn stats(&self) -> TraceStats {
        let n = self.requests.len();
        if n == 0 {
            return TraceStats {
                count: 0,
                median_input: 0,
                median_output: 0,
                mean_input: 0.0,
                mean_output: 0.0,
                avg_seq: 0.0,
            };
        }
        let mut ins: Vec<usize> = self.requests.iter().map(|r| r.input_len).collect();
        let mut outs: Vec<usize> = self.requests.iter().map(|r| r.output_len).collect();
        ins.sort_unstable();
        outs.sort_unstable();
        let mean_in = ins.iter().sum::<usize>() as f64 / n as f64;
        let mean_out = outs.iter().sum::<usize>() as f64 / n as f64;
        TraceStats {
            count: n,
            median_input: ins[n / 2],
            median_output: outs[n / 2],
            mean_input: mean_in,
            mean_output: mean_out,
            avg_seq: mean_in + mean_out / 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    #[test]
    fn save_load_roundtrip() {
        let trace = Trace::new(WorkloadSpec::default().generate(50, 9));
        let dir = std::env::temp_dir().join("msi_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        trace.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(trace.requests, back.requests);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stats_basic() {
        let t = Trace::new(vec![
            Request { id: 0, arrival: 0.0, input_len: 100, output_len: 10, tenant: 0 },
            Request { id: 1, arrival: 0.0, input_len: 200, output_len: 30, tenant: 0 },
            Request { id: 2, arrival: 0.0, input_len: 300, output_len: 20, tenant: 0 },
        ]);
        let s = t.stats();
        assert_eq!(s.count, 3);
        assert_eq!(s.median_input, 200);
        assert_eq!(s.median_output, 20);
        assert!((s.mean_input - 200.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stats() {
        assert_eq!(Trace::default().stats().count, 0);
    }
}
