//! Phased, non-stationary arrival streams for `msi scenario`.
//!
//! A [`PhasedSource`] concatenates [`PhaseSpec`] segments — each with its
//! own duration, rate curve, prompt/output length regime, and optional
//! tenant-mix override — into one pull-based [`ArrivalSource`]. This is
//! how scenario files express diurnal load, flash crowds, tenant-mix
//! shifts, and prompt-length regime changes as *data* instead of CLI
//! flags.
//!
//! Arrivals are a piecewise non-homogeneous Poisson process: each gap is
//! drawn from the instantaneous rate at the draw point, and a gap that
//! would cross the current phase boundary is discarded and redrawn from
//! the boundary (by memorylessness this is *exact* for constant-rate
//! phases; for ramp/sine curves the rate is frozen over each gap, a
//! standard and deterministic approximation). Everything derives from the
//! construction seed, so replaying a phased stream — including the
//! [`ArrivalSource::kv_demand`] sizing pass and sharded
//! [`super::StridedSource`] copies — reproduces the same requests bit for
//! bit.

use crate::sim::SimRng;

use super::arrivals::request_kv_demand;
use super::{ArrivalSource, Request};

/// Rates below this are treated as silence: the stream skips to the next
/// phase boundary instead of drawing astronomically long gaps.
const MIN_RATE: f64 = 1e-9;

/// Shape of the arrival-rate curve over one phase, in requests/second as
/// a function of time since the phase started.
#[derive(Debug, Clone, PartialEq)]
pub enum RateCurve {
    /// Constant `rate` for the whole phase (0 = silence).
    Constant(f64),
    /// Linear ramp from `from` at the phase start to `to` at its end.
    Ramp {
        /// Rate at the phase start.
        from: f64,
        /// Rate at the phase end.
        to: f64,
    },
    /// Diurnal-style `mean · (1 + amplitude · sin(2π·t/period))`, clamped
    /// at zero.
    Sine {
        /// Mean rate the curve oscillates around.
        mean: f64,
        /// Relative swing (0..=1 keeps the rate non-negative on its own).
        amplitude: f64,
        /// Oscillation period in seconds.
        period: f64,
    },
}

impl RateCurve {
    /// Instantaneous rate `elapsed` seconds into a phase of length
    /// `duration`, clamped to be non-negative.
    pub fn at(&self, elapsed: f64, duration: f64) -> f64 {
        let r = match *self {
            RateCurve::Constant(r) => r,
            RateCurve::Ramp { from, to } => {
                let frac = if duration > 0.0 { elapsed / duration } else { 0.0 };
                from + (to - from) * frac.clamp(0.0, 1.0)
            }
            RateCurve::Sine {
                mean,
                amplitude,
                period,
            } => {
                let phase = if period > 0.0 {
                    std::f64::consts::TAU * elapsed / period
                } else {
                    0.0
                };
                mean * (1.0 + amplitude * phase.sin())
            }
        };
        r.max(0.0)
    }
}

/// One segment of a phased workload timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSpec {
    /// Segment length in virtual seconds.
    pub duration: f64,
    /// Arrival-rate curve over the segment.
    pub rate: RateCurve,
    /// Median prompt length (tokens) of requests arriving in the segment.
    pub median_input: f64,
    /// Median output length (tokens).
    pub median_output: f64,
    /// Log-normal sigma shared by both length draws (0 = deterministic).
    pub sigma: f64,
    /// Tenant-mix override for the segment: relative weights, one per
    /// tenant class. `None` keeps the stream's base mix.
    pub mix: Option<Vec<f64>>,
}

/// Pull-based stream over a sequence of [`PhaseSpec`] segments. The
/// stream ends when the last phase does, so a scenario run without an
/// explicit horizon quiesces once the timeline is served.
#[derive(Debug, Clone)]
pub struct PhasedSource {
    phases: Vec<PhaseSpec>,
    /// Base tenant weights (empty or singleton = single-tenant).
    base_mix: Vec<f64>,
    max_len: usize,
    /// Construction seed, kept so `kv_demand` can replay from the start.
    seed: u64,
    rng: SimRng,
    t: f64,
    next_id: u64,
}

impl PhasedSource {
    /// Stream over `phases` with tenant weights `base_mix` (empty for a
    /// single-tenant workload); lengths are clamped to `[1, max_len]`.
    pub fn new(phases: Vec<PhaseSpec>, base_mix: Vec<f64>, max_len: usize, seed: u64) -> Self {
        assert!(!phases.is_empty(), "phased source needs at least one phase");
        Self {
            phases,
            base_mix,
            max_len: max_len.max(1),
            seed,
            rng: SimRng::new(seed),
            t: 0.0,
            next_id: 0,
        }
    }

    /// Total timeline length in seconds.
    pub fn total_duration(&self) -> f64 {
        self.phases.iter().map(|p| p.duration.max(0.0)).sum()
    }

    /// Index and `[start, end)` window of the phase containing `t`, or
    /// `None` past the end of the timeline.
    fn phase_at(&self, t: f64) -> Option<(usize, f64, f64)> {
        let mut start = 0.0;
        for (i, p) in self.phases.iter().enumerate() {
            let end = start + p.duration.max(0.0);
            if t < end {
                return Some((i, start, end));
            }
            start = end;
        }
        None
    }

    fn draw_tenant(&mut self, phase: usize) -> usize {
        let mix = self.phases[phase]
            .mix
            .as_deref()
            .unwrap_or(&self.base_mix);
        if mix.len() <= 1 {
            return 0;
        }
        let total: f64 = mix.iter().sum();
        let mut u = self.rng.uniform() * total;
        for (i, &w) in mix.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        mix.len() - 1
    }
}

impl ArrivalSource for PhasedSource {
    fn next_request(&mut self) -> Option<Request> {
        loop {
            let (idx, start, end) = self.phase_at(self.t)?;
            let rate = self.phases[idx].rate.at(self.t - start, end - start);
            if rate < MIN_RATE {
                // Silent stretch: jump to the phase boundary.
                self.t = end;
                continue;
            }
            let gap = self.rng.exponential(1.0 / rate);
            if self.t + gap >= end {
                // The gap crosses into the next phase; redraw there (exact
                // for constant rates by memorylessness).
                self.t = end;
                continue;
            }
            self.t += gap;
            let p = &self.phases[idx];
            let (median_input, median_output, sigma) = (p.median_input, p.median_output, p.sigma);
            let id = self.next_id;
            self.next_id += 1;
            let input_len = (self.rng.lognormal_median(median_input, sigma) as usize)
                .clamp(1, self.max_len);
            let output_len = (self.rng.lognormal_median(median_output, sigma) as usize)
                .clamp(1, self.max_len);
            let tenant = self.draw_tenant(idx);
            return Some(Request {
                id,
                arrival: self.t,
                input_len,
                output_len,
                tenant,
            });
        }
    }

    fn kv_demand(&self, cap: u64) -> u64 {
        // O(1)-memory replay from the construction seed, with the same
        // cap-saturated early stop as the other generator sources.
        let mut replay = Self::new(
            self.phases.clone(),
            self.base_mix.clone(),
            self.max_len,
            self.seed,
        );
        let mut sum = 0u64;
        while let Some(r) = replay.next_request() {
            sum += request_kv_demand(&r);
            if sum >= cap {
                break;
            }
        }
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phase(duration: f64, rate: RateCurve) -> PhaseSpec {
        PhaseSpec {
            duration,
            rate,
            median_input: 64.0,
            median_output: 16.0,
            sigma: 0.4,
            mix: None,
        }
    }

    #[test]
    fn arrivals_stay_ordered_and_inside_the_timeline() {
        let mut src = PhasedSource::new(
            vec![
                phase(5.0, RateCurve::Constant(40.0)),
                phase(2.0, RateCurve::Constant(0.0)),
                phase(
                    5.0,
                    RateCurve::Sine {
                        mean: 30.0,
                        amplitude: 0.8,
                        period: 2.5,
                    },
                ),
            ],
            Vec::new(),
            4096,
            7,
        );
        let mut last = 0.0;
        let mut n = 0u64;
        let mut silent = 0u64;
        while let Some(r) = src.next_request() {
            assert!(r.arrival >= last, "non-decreasing arrivals");
            assert!(r.arrival < 12.0, "arrival inside the timeline");
            if r.arrival >= 5.0 && r.arrival < 7.0 {
                silent += 1;
            }
            last = r.arrival;
            n += 1;
        }
        assert!(n > 100, "got {n} arrivals");
        assert_eq!(silent, 0, "zero-rate phase stays silent");
    }

    #[test]
    fn replay_is_bit_identical() {
        let mk = || {
            PhasedSource::new(
                vec![
                    phase(3.0, RateCurve::Ramp { from: 5.0, to: 80.0 }),
                    phase(3.0, RateCurve::Constant(20.0)),
                ],
                vec![3.0, 1.0],
                4096,
                11,
            )
        };
        let (mut a, mut b) = (mk(), mk());
        loop {
            let (x, y) = (a.next_request(), b.next_request());
            assert_eq!(x, y);
            if x.is_none() {
                break;
            }
        }
    }

    #[test]
    fn ramp_shifts_arrival_mass_toward_the_heavy_end() {
        let mut src = PhasedSource::new(
            vec![phase(10.0, RateCurve::Ramp { from: 2.0, to: 60.0 })],
            Vec::new(),
            4096,
            3,
        );
        let (mut early, mut late) = (0u64, 0u64);
        while let Some(r) = src.next_request() {
            if r.arrival < 5.0 {
                early += 1;
            } else {
                late += 1;
            }
        }
        assert!(late > early * 2, "ramp skews arrivals: {early} vs {late}");
    }

    #[test]
    fn mix_override_changes_the_tenant_draw() {
        let mut p0 = phase(4.0, RateCurve::Constant(50.0));
        p0.mix = Some(vec![0.0, 1.0]); // all traffic from tenant 1
        let mut src = PhasedSource::new(vec![p0], vec![1.0, 1.0], 4096, 5);
        let mut n = 0u64;
        while let Some(r) = src.next_request() {
            assert_eq!(r.tenant, 1);
            n += 1;
        }
        assert!(n > 50);
    }

    #[test]
    fn kv_demand_matches_a_full_replay_and_respects_the_cap() {
        let src = PhasedSource::new(
            vec![phase(4.0, RateCurve::Constant(25.0))],
            Vec::new(),
            4096,
            9,
        );
        let exact = src.kv_demand(u64::MAX);
        assert!(exact > 0);
        let capped = src.kv_demand(exact / 3);
        assert!(capped >= exact / 3 && capped <= exact);
        // The sizing pass must not consume the stream.
        let mut consume = src.clone();
        let mut sum = 0u64;
        while let Some(r) = consume.next_request() {
            sum += (r.input_len + r.output_len) as u64 + crate::sim::engine::KV_BLOCK;
        }
        assert_eq!(sum, exact);
    }
}
