//! Micro-benchmark harness for the `cargo bench` targets (criterion is not
//! available offline): warmup, timed repetitions, robust statistics.

use std::time::Instant;

/// Timing result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Seconds per iteration: median, p10, p90 across samples.
    pub median: f64,
    /// 10th-percentile seconds per iteration.
    pub p10: f64,
    /// 90th-percentile seconds per iteration.
    pub p90: f64,
    /// Iterations batched per timed sample.
    pub iters_per_sample: u64,
    /// Timed samples taken.
    pub samples: usize,
}

impl BenchResult {
    /// Print one aligned report line.
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} /iter   [{} .. {}]  ({} samples x {} iters)",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.p10),
            fmt_duration(self.p90),
            self.samples,
            self.iters_per_sample,
        );
    }

    /// Iterations per second at the median.
    pub fn rate(&self) -> f64 {
        if self.median > 0.0 {
            1.0 / self.median
        } else {
            f64::INFINITY
        }
    }
}

/// Format seconds human-readably (ns/us/ms/s).
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Run `f` repeatedly and report per-iteration timing. Auto-calibrates the
/// iteration count to make each sample take ~20 ms, collects 12 samples.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // Calibrate.
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.02 || iters > 1 << 24 {
            break;
        }
        iters = (iters * 4).min(1 << 25);
    }

    // Sample.
    const SAMPLES: usize = 12;
    let mut per_iter = Vec::with_capacity(SAMPLES);
    for _ in 0..SAMPLES {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    per_iter.sort_by(|a, b| a.total_cmp(b));
    BenchResult {
        name: name.to_string(),
        median: per_iter[SAMPLES / 2],
        p10: per_iter[SAMPLES / 10],
        p90: per_iter[SAMPLES * 9 / 10],
        iters_per_sample: iters,
        samples: SAMPLES,
    }
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header for a figure/table reproduction.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one row of a result table.
pub fn row(cols: &[String]) {
    println!("{}", cols.join("  "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.median > 0.0);
        assert!(r.p10 <= r.median && r.median <= r.p90 * 1.0001);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(5e-9).contains("ns"));
        assert!(fmt_duration(5e-6).contains("us"));
        assert!(fmt_duration(5e-3).contains("ms"));
        assert!(fmt_duration(5.0).ends_with("s"));
    }
}
