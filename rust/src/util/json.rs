//! Minimal JSON: a value model, a recursive-descent parser and a compact
//! writer. Covers the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); numbers are represented as `f64`.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number (all JSON numbers are `f64`).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object; keys are sorted, so rendering is deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert a key (builder style); no-op on non-objects.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), value.into());
        }
        self
    }

    // ---- accessors ----
    /// Field lookup; errors on a missing key or a non-object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing JSON key {key:?}")),
            _ => bail!("not a JSON object (looking up {key:?})"),
        }
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as a number, or an error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self}"),
        }
    }

    /// The value as a non-negative integer, or an error.
    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    /// The value as a `u64`, or an error.
    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    /// The value as a string, or an error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self}"),
        }
    }

    /// The value as a boolean, or an error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self}"),
        }
    }

    /// The value as an array slice, or an error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self}"),
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of numbers -> Vec<usize>.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(key, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("invalid escape at offset {}", self.i),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at offset {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "hi\n\"there\""}"#;
        let v = Json::parse(text).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap(), true);
        assert_eq!(v.get("e").unwrap().as_str().unwrap(), "hi\n\"there\"");
    }

    #[test]
    fn integers_print_without_dot() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string(), "42");
        let v = Json::Num(42.5);
        assert_eq!(v.to_string(), "42.5");
    }

    #[test]
    fn builder_api() {
        let v = Json::obj().set("x", 3usize).set("name", "msi");
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("x").unwrap().as_usize().unwrap(), 3);
        assert_eq!(back.get("name").unwrap().as_str().unwrap(), "msi");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""é中""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é中");
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn usize_validation() {
        assert!(Json::Num(-1.0).as_usize().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert_eq!(Json::Num(7.0).as_usize().unwrap(), 7);
    }
}
