//! Tiny command-line argument parser for the `msi` launcher:
//! `msi <subcommand> [--key value]... [--flag]...`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments: a subcommand plus `--key value` pairs and bare flags.
#[derive(Debug, Default)]
pub struct Args {
    /// First positional argument (the subcommand name).
    pub subcommand: String,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, flag_names: &[&str]) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut out = Args {
            subcommand,
            ..Default::default()
        };
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            // Support --key=value.
            if let Some((k, v)) = key.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if flag_names.contains(&key) {
                out.flags.push(key.to_string());
            } else {
                let v = it
                    .next()
                    .with_context(|| format!("--{key} expects a value"))?;
                out.options.insert(key.to_string(), v);
            }
        }
        Ok(out)
    }

    /// Whether the bare flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw option value, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Option value, or `default` when absent.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Option parsed as `usize`, or `default` when absent.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name}={v} not an integer")),
            None => Ok(default),
        }
    }

    /// Option parsed as `u64`, or `default` when absent.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name}={v} not an integer")),
            None => Ok(default),
        }
    }

    /// Option parsed as `f64`, or `default` when absent.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name}={v} not a number")),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["all", "baselines"]).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("plan --model dbrx --slo-ms 150");
        assert_eq!(a.subcommand, "plan");
        assert_eq!(a.get("model"), Some("dbrx"));
        assert_eq!(a.f64_or("slo-ms", 0.0).unwrap(), 150.0);
    }

    #[test]
    fn flags_and_equals() {
        let a = parse("plan --all --model=tiny");
        assert!(a.flag("all"));
        assert!(!a.flag("baselines"));
        assert_eq!(a.get("model"), Some("tiny"));
    }

    #[test]
    fn defaults() {
        let a = parse("simulate");
        assert_eq!(a.usize_or("requests", 512).unwrap(), 512);
        assert_eq!(a.str_or("gpu", "ampere"), "ampere");
    }

    #[test]
    fn errors() {
        assert!(Args::parse(
            ["plan".into(), "positional".into()].into_iter(),
            &[]
        )
        .is_err());
        assert!(Args::parse(["plan".into(), "--model".into()].into_iter(), &[]).is_err());
    }
}
