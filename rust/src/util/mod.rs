//! In-tree substrates for an offline build environment.
//!
//! The vendored crate set contains only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (serde/serde_json, clap, rand,
//! criterion, proptest) are unavailable. The pieces of them this project
//! needs are small and well-specified, so we implement them here:
//!
//! * [`json`] — a complete JSON value model, parser and writer (RFC 8259
//!   subset: no surrogate-pair escapes beyond BMP handling).
//! * [`cli`] — `--flag value` argument parsing for the `msi` launcher.
//! * [`bench`] — a timing harness with warmup, repetition and robust
//!   statistics for the `cargo bench` targets.
//!
//! (Random-number generation lives in [`crate::sim::SimRng`].)

pub mod bench;
pub mod cli;
pub mod json;
