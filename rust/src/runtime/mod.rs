//! PJRT runtime: loads the HLO artifacts produced by the JAX/Pallas compile
//! path (`python/compile/aot.py`) and executes them on the request path.
//!
//! Python never runs at serving time: `make artifacts` lowers the L2 model
//! (which calls the L1 Pallas kernels) to HLO **text** once, and this module
//! compiles + executes it through the `xla` crate's PJRT CPU client.
//!
//! * [`artifacts`] — the artifact manifest (executables, tensor shapes,
//!   weight blobs) written at compile time.
//! * [`tensor`] — minimal host tensor type and Literal conversions.
//! * [`engine`] — PJRT client with an executable cache.
//! * [`serving`] — the real disaggregated decode loop: attention step,
//!   gating, expert dispatch (the same [`crate::coordinator`] logic that the
//!   virtual-time simulator uses), expert FFN, combine, sampling.

// Feature-gated (`pjrt`) and excluded from the default `cargo doc` build;
// the missing-docs bar applies to the always-built surface.
#![allow(missing_docs)]

pub mod artifacts;
pub mod engine;
pub mod serving;
pub mod tensor;

pub use artifacts::{ArtifactManifest, WeightStore};
pub use engine::Engine;
pub use serving::{ServingEngine, ServingReport};
pub use tensor::HostTensor;
