//! The real disaggregated serving loop over PJRT.
//!
//! This is the executable counterpart of the virtual-time instance: the same
//! gating / dispatch / combine / continuous-batching logic from
//! [`crate::coordinator`], driving the AOT-compiled JAX+Pallas artifacts.
//! Attention executables and expert executables are separate compiled
//! modules — the disaggregation boundary of the paper — and micro-batches
//! shuttle between them in ping-pong order within each layer.
//!
//! Slot model: the engine owns `m` micro-batches of `b` slots each
//! (`b = manifest.model.micro_batch`, fixed at AOT time). Requests are
//! admitted into free slots; prefill replays the prompt through the decode
//! step (passive slots re-write their last KV entry, which is idempotent).

// BTreeMap, not HashMap: executable and weight lookup order shows up in
// logs and replay traces, and must not depend on hasher seeding.
use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::coordinator::dispatch::{build_dispatch, combine_expert_outputs};
use crate::coordinator::gating::softmax_topk;
use crate::metrics::Histogram;
use crate::workload::Request;

use super::artifacts::{ArtifactManifest, WeightStore};
use super::engine::Engine;
use super::tensor::{argmax_rows, i32_literal, HostTensor};

/// One serving slot.
#[derive(Debug, Clone, Default)]
struct Slot {
    /// Request occupying this slot, if any.
    request: Option<u64>,
    /// Tokens currently in the KV cache for this slot.
    position: usize,
    /// Last token id fed (re-fed while the slot is passive).
    last_token: usize,
    /// Output tokens still to produce.
    remaining: usize,
    /// Generated token count (for reporting).
    generated: usize,
}

/// Aggregate report of a serving run.
#[derive(Debug)]
pub struct ServingReport {
    pub completed: u64,
    pub output_tokens: u64,
    pub elapsed: f64,
    /// Output tokens per second.
    pub throughput: f64,
    /// Per-decode-iteration latency (TPOT) distribution.
    pub tpot: Histogram,
    /// Wall time spent in attention(+gating) vs expert executables.
    pub attn_time: f64,
    pub expert_time: f64,
    /// Wall time in dispatch/combine/sampling on the coordinator.
    pub coord_time: f64,
    pub decode_iterations: u64,
}

/// Take the first element of an executable's output tuple by value
/// (front-first drain; the outputs vec is consumed either way).
fn pop_first(outs: Vec<xla::Literal>) -> xla::Literal {
    VecDeque::from(outs)
        .pop_front()
        .expect("executable returned no outputs")
}

/// The PJRT-backed serving engine.
pub struct ServingEngine {
    engine: Engine,
    manifest: ArtifactManifest,
    /// Weight device-buffers uploaded once at load time (no host→device
    /// copy on the hot path — §Perf).
    wbuf: BTreeMap<String, xla::PjRtBuffer>,
    /// Stacked per-layer expert weights `[E,h,f]/[E,f,h]` for the grouped
    /// expert executable (one PJRT call per layer instead of up to E —
    /// §Perf). None when the artifacts predate the grouped kernel.
    grouped_w: Option<Vec<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)>>,
    /// KV caches: `[micro_batch][layer] -> (k, v)` device buffers, threaded
    /// through attention calls.
    kv: Vec<Vec<(xla::PjRtBuffer, xla::PjRtBuffer)>>,
    slots: Vec<Vec<Slot>>, // [micro_batch][slot]
    m: usize,
}

impl ServingEngine {
    /// Load artifacts from `dir` and compile all executables. `m` is the
    /// number of micro-batches for the ping-pong schedule.
    pub fn load(dir: &Path, m: usize) -> Result<Self> {
        ensure!(m >= 1, "need at least one micro-batch");
        let manifest = ArtifactManifest::load(dir)?;
        let weights = WeightStore::load(&manifest)?;
        let mut engine = Engine::cpu()?;
        engine.load_manifest(&manifest)?;

        // Upload all weights to device buffers once.
        let mut wbuf = BTreeMap::new();
        for e in &manifest.tensors {
            let lit = weights.get(&e.name)?.to_literal()?;
            wbuf.insert(e.name.clone(), engine.upload(&lit)?);
        }

        // Stack expert weights per layer for the grouped executable.
        let grouped_w = if manifest.executables.contains_key("experts_grouped") {
            let md = &manifest.model;
            let mut per_layer = Vec::with_capacity(md.layers);
            for l in 0..md.layers {
                let stack = |part: &str, d1: usize, d2: usize| -> Result<xla::PjRtBuffer> {
                    let mut data = Vec::with_capacity(md.experts * d1 * d2);
                    for e in 0..md.experts {
                        data.extend_from_slice(
                            &weights.get(&format!("l{l}.e{e}.{part}"))?.data,
                        );
                    }
                    let lit = HostTensor::new(vec![md.experts, d1, d2], data)?.to_literal()?;
                    engine.upload(&lit)
                };
                per_layer.push((
                    stack("w1", md.hidden, md.intermediate)?,
                    stack("w3", md.hidden, md.intermediate)?,
                    stack("w2", md.intermediate, md.hidden)?,
                ));
            }
            Some(per_layer)
        } else {
            None
        };

        let md = &manifest.model;
        let kv_shape = vec![md.micro_batch, md.max_seq, md.kv_heads, md.head_dim];
        let kv = (0..m)
            .map(|_| {
                (0..md.layers)
                    .map(|_| {
                        let k = engine.upload(&HostTensor::zeros(kv_shape.clone()).to_literal()?)?;
                        let v = engine.upload(&HostTensor::zeros(kv_shape.clone()).to_literal()?)?;
                        Ok((k, v))
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        let slots = vec![vec![Slot::default(); md.micro_batch]; m];
        Ok(Self {
            engine,
            manifest,
            wbuf,
            grouped_w,
            kv,
            slots,
            m,
        })
    }

    pub fn model(&self) -> &super::artifacts::ArtifactModel {
        &self.manifest.model
    }

    pub fn capacity(&self) -> usize {
        self.m * self.manifest.model.micro_batch
    }

    /// Disable the grouped expert fast path (falls back to one PJRT call
    /// per expert). Used by tests to prove both paths produce identical
    /// tokens.
    pub fn disable_grouped_experts(&mut self) {
        self.grouped_w = None;
    }

    fn w(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        self.wbuf
            .get(name)
            .with_context(|| format!("weight buffer {name} missing"))
    }

    /// Run one decode step for micro-batch `mb`.
    ///
    /// `ids[i]` is the token fed to slot `i`; `advance[i]` marks slots whose
    /// position moves forward (active this step). Returns the next-token
    /// argmax for every slot plus (attention, expert, coordinator) times.
    pub fn step_micro_batch(
        &mut self,
        mb: usize,
        ids: &[usize],
        advance: &[bool],
    ) -> Result<(Vec<usize>, f64, f64, f64)> {
        let md = self.manifest.model.clone();
        let b = md.micro_batch;
        ensure!(ids.len() == b && advance.len() == b, "slot arity mismatch");
        let mut t_attn = 0.0;
        let mut t_expert = 0.0;
        let mut t_coord = 0.0;

        let ids_i32: Vec<i32> = ids.iter().map(|&t| t as i32).collect();
        let positions: Vec<i32> = self.slots[mb].iter().map(|s| s.position as i32).collect();

        // Embed.
        let t0 = Instant::now();
        let ids_buf = self.engine.upload(&i32_literal(&ids_i32, &[b])?)?;
        let x = pop_first(
            self.engine
                .run_b("embed", &[&ids_buf, self.w("emb")?])
                .context("embed")?,
        );
        let mut x = self.engine.upload(&x)?;
        t_coord += t0.elapsed().as_secs_f64();

        let pos_buf = self.engine.upload(&i32_literal(&positions, &[b])?)?;
        for layer in 0..md.layers {
            // --- attention node ---
            let t0 = Instant::now();
            let mut outs = {
                let (k, v) = {
                    let p = &self.kv[mb][layer];
                    (&p.0, &p.1)
                };
                self.engine.run_b(
                    "attention",
                    &[
                        &x,
                        k,
                        v,
                        &pos_buf,
                        self.w(&format!("l{layer}.attn_norm"))?,
                        self.w(&format!("l{layer}.wq"))?,
                        self.w(&format!("l{layer}.wk"))?,
                        self.w(&format!("l{layer}.wv"))?,
                        self.w(&format!("l{layer}.wo"))?,
                    ],
                )?
            };
            let new_v = outs.pop().unwrap();
            let new_k = outs.pop().unwrap();
            let h1 = outs.pop().unwrap();
            self.kv[mb][layer] = (self.engine.upload(&new_k)?, self.engine.upload(&new_v)?);
            let h1_buf = self.engine.upload(&h1)?;
            t_attn += t0.elapsed().as_secs_f64();

            // --- gating (runs on the attention node, §6 fused kernels) ---
            let t0 = Instant::now();
            let mut outs = self.engine.run_b(
                "gating",
                &[
                    &h1_buf,
                    self.w(&format!("l{layer}.ffn_norm"))?,
                    self.w(&format!("l{layer}.wg"))?,
                ],
            )?;
            let logits = HostTensor::from_literal(&outs.pop().unwrap())?;
            let normed = HostTensor::from_literal(&outs.pop().unwrap())?;
            let gating = softmax_topk(&logits.data, md.experts, md.top_k);
            let plan = build_dispatch(&gating, md.experts);
            t_attn += t0.elapsed().as_secs_f64();

            // --- dispatch -> expert nodes (M2N) -> combine ---
            let mut expert_outputs: Vec<Vec<f32>> = vec![Vec::new(); md.experts];
            if self.grouped_w.is_some() {
                // Grouped path (§Perf): one executable call computes all
                // experts' (padded) token blocks.
                let tc = Instant::now();
                let mut xall = vec![0f32; md.experts * b * md.hidden];
                for e in 0..md.experts {
                    let (tokens, _) = plan.expert_slice(e);
                    let base = e * b * md.hidden;
                    for (row, &t) in tokens.iter().enumerate() {
                        xall[base + row * md.hidden..base + (row + 1) * md.hidden]
                            .copy_from_slice(normed.row(t as usize));
                    }
                }
                let xall = HostTensor::new(vec![md.experts, b, md.hidden], xall)?;
                t_coord += tc.elapsed().as_secs_f64();

                let te = Instant::now();
                let xall_buf = self.engine.upload(&xall.to_literal()?)?;
                let (w1, w3, w2) = &self.grouped_w.as_ref().unwrap()[layer];
                let yall =
                    pop_first(self.engine.run_b("experts_grouped", &[&xall_buf, w1, w3, w2])?);
                t_expert += te.elapsed().as_secs_f64();

                let tc = Instant::now();
                let yall = HostTensor::from_literal(&yall)?;
                for e in 0..md.experts {
                    let load = plan.expert_load(e);
                    if load == 0 {
                        continue;
                    }
                    let base = e * b * md.hidden;
                    expert_outputs[e] =
                        yall.data[base..base + load * md.hidden].to_vec();
                }
                t_coord += tc.elapsed().as_secs_f64();
            } else {
                for e in 0..md.experts {
                    let (tokens, _) = plan.expert_slice(e);
                    if tokens.is_empty() {
                        continue;
                    }
                    // Gather + pad to the compiled batch size.
                    let tc = Instant::now();
                    let mut xe = vec![0f32; b * md.hidden];
                    for (row, &t) in tokens.iter().enumerate() {
                        xe[row * md.hidden..(row + 1) * md.hidden]
                            .copy_from_slice(normed.row(t as usize));
                    }
                    let xe = HostTensor::new(vec![b, md.hidden], xe)?;
                    t_coord += tc.elapsed().as_secs_f64();

                    let te = Instant::now();
                    let xe_buf = self.engine.upload(&xe.to_literal()?)?;
                    let ye = pop_first(self.engine.run_b(
                        "expert",
                        &[
                            &xe_buf,
                            self.w(&format!("l{layer}.e{e}.w1"))?,
                            self.w(&format!("l{layer}.e{e}.w3"))?,
                            self.w(&format!("l{layer}.e{e}.w2"))?,
                        ],
                    )?);
                    t_expert += te.elapsed().as_secs_f64();

                    let tc = Instant::now();
                    let ye = HostTensor::from_literal(&ye)?;
                    expert_outputs[e] = ye.data[..tokens.len() * md.hidden].to_vec();
                    t_coord += tc.elapsed().as_secs_f64();
                }
            }

            let tc = Instant::now();
            let combined = combine_expert_outputs(&plan, &expert_outputs, b, md.hidden);
            // Residual add on the coordinator (trivially small).
            let mut h1 = HostTensor::from_literal(&h1)?;
            for (a, c) in h1.data.iter_mut().zip(&combined) {
                *a += c;
            }
            x = self.engine.upload(&h1.to_literal()?)?;
            t_coord += tc.elapsed().as_secs_f64();
        }

        // LM head + sampling.
        let t0 = Instant::now();
        let logits = pop_first(
            self.engine
                .run_b("lm_head", &[&x, self.w("final_norm")?, self.w("emb")?])?,
        );
        let next = argmax_rows(&HostTensor::from_literal(&logits)?);
        t_coord += t0.elapsed().as_secs_f64();

        // Advance slot state.
        for i in 0..b {
            if advance[i] {
                self.slots[mb][i].position += 1;
                self.slots[mb][i].last_token = ids[i];
            }
        }
        Ok((next, t_attn, t_expert, t_coord))
    }

    /// Prefill a request's prompt into `slot` of micro-batch `mb`. Returns
    /// the model's predicted continuation token.
    fn prefill(&mut self, mb: usize, slot: usize, prompt: &[usize]) -> Result<usize> {
        let b = self.manifest.model.micro_batch;
        let mut last = 0usize;
        for &tok in prompt {
            let mut ids: Vec<usize> =
                (0..b).map(|i| self.slots[mb][i].last_token).collect();
            let mut advance = vec![false; b];
            ids[slot] = tok;
            advance[slot] = true;
            let (next, _, _, _) = self.step_micro_batch(mb, &ids, &advance)?;
            last = next[slot];
        }
        Ok(last)
    }

    /// Serve a set of requests to completion (closed loop). Token ids are
    /// derived from the request id (synthetic vocabulary).
    pub fn serve(&mut self, requests: &[Request]) -> Result<ServingReport> {
        let md = self.manifest.model.clone();
        let b = md.micro_batch;
        let mut waiting: Vec<Request> = requests.to_vec();
        waiting.reverse(); // pop from the back = FIFO

        let mut completed = 0u64;
        let mut output_tokens = 0u64;
        let mut tpot = Histogram::new();
        let (mut attn_time, mut expert_time, mut coord_time) = (0.0, 0.0, 0.0);
        let mut decode_iterations = 0u64;
        let start = Instant::now();

        // Pending next-token per (mb, slot) produced by prefill/decode.
        let mut pending: Vec<Vec<Option<usize>>> = vec![vec![None; b]; self.m];

        loop {
            // Admission: fill free slots, run prefill.
            for mb in 0..self.m {
                for s in 0..b {
                    if self.slots[mb][s].request.is_none() && !waiting.is_empty() {
                        let r = waiting.pop().unwrap();
                        // Cap prompt + output to the KV capacity.
                        let output_len = r.output_len.clamp(1, md.max_seq / 2);
                        let max_prompt = md.max_seq - output_len - 1;
                        let plen = r.input_len.clamp(1, max_prompt);
                        let prompt: Vec<usize> = (0..plen)
                            .map(|i| (r.id as usize * 131 + i * 7) % md.vocab)
                            .collect();
                        self.slots[mb][s] = Slot {
                            request: Some(r.id),
                            position: 0,
                            last_token: prompt[0],
                            remaining: output_len,
                            generated: 0,
                        };
                        let first = self.prefill(mb, s, &prompt)?;
                        pending[mb][s] = Some(first);
                    }
                }
            }

            let any_active = self.slots.iter().flatten().any(|s| s.request.is_some());
            if !any_active && waiting.is_empty() {
                break;
            }

            // One decode iteration: ping-pong order over micro-batches.
            let iter_start = Instant::now();
            for mb in 0..self.m {
                let mut ids = vec![0usize; b];
                let mut advance = vec![false; b];
                let mut any = false;
                for s in 0..b {
                    if self.slots[mb][s].request.is_some() {
                        ids[s] = pending[mb][s].unwrap_or(self.slots[mb][s].last_token);
                        advance[s] = true;
                        any = true;
                    } else {
                        ids[s] = self.slots[mb][s].last_token;
                    }
                }
                if !any {
                    continue;
                }
                let (next, ta, te, tc) = self.step_micro_batch(mb, &ids, &advance)?;
                attn_time += ta;
                expert_time += te;
                coord_time += tc;

                for s in 0..b {
                    if !advance[s] {
                        continue;
                    }
                    let slot = &mut self.slots[mb][s];
                    slot.generated += 1;
                    output_tokens += 1;
                    slot.remaining -= 1;
                    pending[mb][s] = Some(next[s]);
                    let full = slot.position >= md.max_seq - 1;
                    if slot.remaining == 0 || full {
                        completed += 1;
                        *slot = Slot::default();
                        pending[mb][s] = None;
                    }
                }
            }
            decode_iterations += 1;
            tpot.record(iter_start.elapsed().as_secs_f64());
        }

        let elapsed = start.elapsed().as_secs_f64();
        Ok(ServingReport {
            completed,
            output_tokens,
            elapsed,
            throughput: if elapsed > 0.0 {
                output_tokens as f64 / elapsed
            } else {
                0.0
            },
            tpot,
            attn_time,
            expert_time,
            coord_time,
            decode_iterations,
        })
    }
}

// Exercised end-to-end by rust/tests/e2e_pjrt.rs and examples/serve_e2e.rs
// against real artifacts.
