//! PJRT engine: a CPU client plus a cache of compiled executables.
//!
//! HLO **text** is the interchange format (see `/opt/xla-example/README.md`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly).

// BTreeMap, not HashMap: `names()` feeds logs and replay traces, so the
// executable listing must be hasher-independent.
use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::artifacts::ArtifactManifest;

/// A PJRT client with named, cached executables.
pub struct Engine {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            executables: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text file under `name`.
    pub fn load_hlo(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Compile every executable listed in the manifest.
    pub fn load_manifest(&mut self, manifest: &ArtifactManifest) -> Result<()> {
        for name in manifest.executables.keys() {
            let path = manifest.hlo_path(name)?;
            self.load_hlo(name, &path)?;
        }
        Ok(())
    }

    /// Execute executable `name` with the given arguments; returns the
    /// elements of the output tuple (aot.py lowers with `return_tuple=True`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("executable {name} not loaded"))?;
        let out = exe
            .execute::<L>(args)
            .with_context(|| format!("executing {name}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} output"))?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with pre-uploaded device buffers (no host->device copy of
    /// the arguments — the §Perf fast path for weight operands).
    pub fn run_b<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        name: &str,
        args: &[B],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("executable {name} not loaded"))?;
        let out = exe
            .execute_b::<B>(args)
            .with_context(|| format!("executing {name} (buffers)"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} output"))?;
        Ok(lit.to_tuple()?)
    }

    /// Upload a host literal to a device buffer (done once per weight).
    ///
    /// Goes through the raw host-buffer path: `buffer_from_host_literal` in
    /// xla_extension 0.5.1 mis-sizes the destination for reshaped literals.
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>()?;
                Ok(self.client.buffer_from_host_buffer(&data, &dims, None)?)
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(self.client.buffer_from_host_buffer(&data, &dims, None)?)
            }
            other => anyhow::bail!("upload: unsupported element type {other:?}"),
        }
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }
}

// Engine is exercised by rust/tests/e2e_pjrt.rs against real artifacts;
// no PJRT client is constructed in unit tests (slow, global state).
