//! Minimal host tensor: row-major `f32`/`i32` data + shape, with conversions
//! to/from `xla::Literal` for PJRT execution.

use anyhow::{ensure, Result};

/// A row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        ensure!(
            n == data.len(),
            "shape {shape:?} wants {n} elements, got {}",
            data.len()
        );
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Convert to an XLA literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Self::new(dims, data)?)
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.shape[self.shape.len() - 1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let w = self.shape[self.shape.len() - 1];
        &mut self.data[i * w..(i + 1) * w]
    }
}

/// Build an i32 literal of the given shape.
pub fn i32_literal(values: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(values).reshape(&dims)?)
}

/// Argmax along the last axis of a `[rows, cols]` tensor.
pub fn argmax_rows(t: &HostTensor) -> Vec<usize> {
    let cols = *t.shape.last().unwrap();
    let rows = t.numel() / cols;
    (0..rows)
        .map(|r| {
            let row = &t.data[r * cols..(r + 1) * cols];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows() {
        let t = HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn argmax() {
        let t = HostTensor::new(vec![2, 3], vec![1., 9., 3., 7., 5., 6.]).unwrap();
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }

    // Literal round-trips are covered by the e2e_pjrt integration test,
    // which requires the PJRT client.
}
