//! Artifact manifest: the contract between the Python compile path and the
//! Rust serving path.
//!
//! `python/compile/aot.py` writes into `artifacts/`:
//!
//! * `<name>.hlo.txt` — one HLO-text module per disaggregated function
//!   (attention step, gating, expert FFN, embed, lm head);
//! * `weights.bin` — all model weights as little-endian f32, concatenated;
//! * `manifest.json` — model config, executable names, tensor table
//!   (name/shape/offset into `weights.bin`), and test vectors for the
//!   numerics integration test.

// BTreeMap, not HashMap: manifest and tensor listings reach compile order
// and diagnostics, and must not depend on hasher seeding.
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::tensor::HostTensor;

/// Model geometry of the compiled artifacts (the tiny MoE by default).
#[derive(Debug, Clone)]
pub struct ArtifactModel {
    pub layers: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub experts: usize,
    pub top_k: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    /// KV-cache capacity per slot (max sequence length).
    pub max_seq: usize,
    /// The fixed micro-batch size the executables were compiled for.
    pub micro_batch: usize,
}

impl ArtifactModel {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            layers: v.get("layers")?.as_usize()?,
            hidden: v.get("hidden")?.as_usize()?,
            intermediate: v.get("intermediate")?.as_usize()?,
            experts: v.get("experts")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            q_heads: v.get("q_heads")?.as_usize()?,
            kv_heads: v.get("kv_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            max_seq: v.get("max_seq")?.as_usize()?,
            micro_batch: v.get("micro_batch")?.as_usize()?,
        })
    }
}

/// One tensor in the weight blob.
#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Element offset (f32 units) into `weights.bin`.
    pub offset: usize,
}

/// A named array in a test vector: either inline data or a reference to a
/// tensor in the weight blob (keeps the manifest small).
#[derive(Debug, Clone)]
pub struct NamedArray {
    pub name: String,
    /// Inline payload (shape + data), or None when `weight` is set.
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
    /// Name of a tensor in `weights.bin` to use instead of inline data.
    pub weight: Option<String>,
}

impl NamedArray {
    fn from_json(v: &Json) -> Result<Self> {
        if let Some(w) = v.opt("weight") {
            return Ok(Self {
                name: v.get("name")?.as_str()?.to_string(),
                shape: Vec::new(),
                data: Vec::new(),
                weight: Some(w.as_str()?.to_string()),
            });
        }
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v.get("shape")?.as_usize_vec()?,
            data: v
                .get("data")?
                .as_f64_vec()?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
            weight: None,
        })
    }

    /// Materialize: inline data, or the referenced weight from `store`.
    pub fn to_tensor(&self, store: &WeightStore) -> Result<HostTensor> {
        match &self.weight {
            Some(w) => Ok(store.get(w)?.clone()),
            None => HostTensor::new(self.shape.clone(), self.data.clone()),
        }
    }
}

/// Reference input/output pair for the numerics integration test: executing
/// `name` on `inputs` must reproduce `outputs` (computed by JAX at AOT time).
#[derive(Debug, Clone)]
pub struct TestVector {
    pub name: String,
    pub inputs: Vec<NamedArray>,
    pub outputs: Vec<NamedArray>,
}

/// `manifest.json` root.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub model: ArtifactModel,
    /// Logical executable name -> HLO text file (relative to the dir).
    pub executables: BTreeMap<String, String>,
    pub weights_file: String,
    pub tensors: Vec<TensorEntry>,
    pub test_vectors: Vec<TestVector>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let model = ArtifactModel::from_json(v.get("model")?)?;

        let mut executables = BTreeMap::new();
        if let Json::Obj(m) = v.get("executables")? {
            for (k, f) in m {
                executables.insert(k.clone(), f.as_str()?.to_string());
            }
        } else {
            bail!("executables must be an object");
        }

        let tensors = v
            .get("tensors")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(TensorEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    shape: e.get("shape")?.as_usize_vec()?,
                    offset: e.get("offset")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let test_vectors = match v.opt("test_vectors") {
            Some(tv) => tv
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(TestVector {
                        name: e.get("name")?.as_str()?.to_string(),
                        inputs: e
                            .get("inputs")?
                            .as_arr()?
                            .iter()
                            .map(NamedArray::from_json)
                            .collect::<Result<Vec<_>>>()?,
                        outputs: e
                            .get("outputs")?
                            .as_arr()?
                            .iter()
                            .map(NamedArray::from_json)
                            .collect::<Result<Vec<_>>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };

        Ok(Self {
            model,
            executables,
            weights_file: v.get("weights_file")?.as_str()?.to_string(),
            tensors,
            test_vectors,
            dir: dir.to_path_buf(),
        })
    }

    /// Absolute path of an executable's HLO text.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        match self.executables.get(name) {
            Some(f) => Ok(self.dir.join(f)),
            None => bail!("no executable named {name} in manifest"),
        }
    }
}

/// All weights, loaded into host memory and indexed by name.
#[derive(Debug)]
pub struct WeightStore {
    tensors: BTreeMap<String, HostTensor>,
}

impl WeightStore {
    pub fn load(manifest: &ArtifactManifest) -> Result<Self> {
        let path = manifest.dir.join(&manifest.weights_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "weight blob not f32-aligned");
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut tensors = BTreeMap::new();
        for e in &manifest.tensors {
            let n: usize = e.shape.iter().product();
            anyhow::ensure!(
                e.offset + n <= floats.len(),
                "tensor {} out of bounds ({} + {} > {})",
                e.name,
                e.offset,
                n,
                floats.len()
            );
            tensors.insert(
                e.name.clone(),
                HostTensor::new(e.shape.clone(), floats[e.offset..e.offset + n].to_vec())?,
            );
        }
        Ok(Self { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("weight {name} missing from store"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
        "model": {"layers": 2, "hidden": 8, "intermediate": 16, "experts": 4,
                  "top_k": 2, "q_heads": 2, "kv_heads": 1, "head_dim": 4,
                  "vocab": 32, "max_seq": 16, "micro_batch": 2},
        "executables": {"attention": "attention.hlo.txt"},
        "weights_file": "weights.bin",
        "tensors": [{"name": "l0.wq", "shape": [8, 8], "offset": 0}],
        "test_vectors": [
            {"name": "expert",
             "inputs":  [{"name": "x", "shape": [1, 2], "data": [1.0, 2.0]}],
             "outputs": [{"name": "y", "shape": [1, 2], "data": [3.0, 4.0]}]}
        ]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = ArtifactManifest::parse(MANIFEST, Path::new("/tmp")).unwrap();
        assert_eq!(m.model.hidden, 8);
        assert_eq!(m.executables["attention"], "attention.hlo.txt");
        assert_eq!(m.tensors[0].shape, vec![8, 8]);
        assert_eq!(m.test_vectors.len(), 1);
        assert_eq!(m.test_vectors[0].outputs[0].data, vec![3.0, 4.0]);
        assert_eq!(
            m.hlo_path("attention").unwrap(),
            PathBuf::from("/tmp/attention.hlo.txt")
        );
        assert!(m.hlo_path("nope").is_err());
    }

    #[test]
    fn weight_store_from_blob() {
        let dir = std::env::temp_dir().join("msi_ws_test");
        std::fs::create_dir_all(&dir).unwrap();
        let floats: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("weights.bin"), &bytes).unwrap();
        let m = ArtifactManifest::parse(MANIFEST, &dir).unwrap();
        let ws = WeightStore::load(&m).unwrap();
        assert_eq!(ws.len(), 1);
        assert_eq!(ws.get("l0.wq").unwrap().data[..3], [0.0, 1.0, 2.0]);
        assert!(ws.get("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn weight_store_rejects_out_of_bounds() {
        let dir = std::env::temp_dir().join("msi_ws_oob");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("weights.bin"), [0u8; 8]).unwrap(); // 2 floats
        let m = ArtifactManifest::parse(MANIFEST, &dir).unwrap(); // wants 64
        assert!(WeightStore::load(&m).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
