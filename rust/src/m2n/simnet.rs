//! Message-level discrete-event simulation of M-to-N token dispatch.
//!
//! One *round* = every sender transmits one message to every receiver (the
//! MoE dispatch pattern: each attention GPU scatters its tokens' activations
//! to all expert GPUs it selected). The per-round latency of a sender is the
//! time from round start until its last message is confirmed delivered —
//! matching how the paper's microbenchmarks report One-to-N / M2N latency.
//!
//! Modeled costs per message (see [`super::LibraryProfile`]):
//!
//! ```text
//! sender:   group setup (per batch of <=group_batch ops)
//!           + post_overhead  (serialized on the sender CPU/NIC)
//!           + copy_per_byte·size (GPU->CPU proxy copy, NCCL only)
//!           + sender NIC serialization at line rate
//! network:  propagation (fixed 2us)
//! receiver: NIC serialization with incast penalty when k senders converge
//!           + recv_overhead + sync_overhead
//! both:     lognormal jitter, Pareto stalls with probability stall_prob
//! ```

use crate::metrics::Histogram;
use crate::sim::SimRng;

use super::profiles::LibraryProfile;

/// Scenario description for one microbenchmark run.
#[derive(Debug, Clone)]
pub struct M2nScenario {
    /// Cost profile of the stack under test.
    pub profile: LibraryProfile,
    /// Number of senders (M).
    pub senders: usize,
    /// Number of receivers (N).
    pub receivers: usize,
    /// Bytes per (sender, receiver) message.
    pub msg_bytes: usize,
    /// Rounds to simulate (statistics accumulate per sender per round).
    pub rounds: usize,
    /// Model bidirectional load (ping-pong pipeline in flight both ways):
    /// adds the ACK-delay term for stacks without high-priority ACKs.
    pub bidirectional: bool,
    /// Seed for the jitter/stall draws.
    pub seed: u64,
}

/// Results of a run.
#[derive(Debug, Clone)]
pub struct M2nStats {
    /// Per-sender per-round dispatch latency (seconds).
    pub latency: Histogram,
    /// Goodput per sender GPU, bytes/s (total bytes sent / busy time).
    pub throughput: f64,
}

const PROPAGATION: f64 = 2e-6;

/// Run the microbenchmark and return latency/throughput statistics.
pub fn simulate_m2n(sc: &M2nScenario) -> M2nStats {
    let p = &sc.profile;
    let mut rng = SimRng::new(sc.seed);
    let mut latency = Histogram::new();

    // busy-until per receiver NIC (seconds).
    let mut recv_busy = vec![0.0f64; sc.receivers];
    // busy-until per sender NIC.
    let mut send_busy = vec![0.0f64; sc.senders];

    let mut clock = 0.0f64; // round start
    let mut total_busy = 0.0f64;
    let wire = p.wire_time(sc.msg_bytes);
    // Effective per-receiver incast slowdown this round: with M senders
    // converging on each receiver, serialization plus penalty.
    let incast_factor = 1.0 + p.incast_penalty * (sc.senders.saturating_sub(1)) as f64;

    // GPU-sync interference grows with fan-in for stacks that synchronize
    // the device (absent in RDMA-direct stacks).
    let sync_pressure = if p.sync_overhead > 0.0 {
        1.0 + 0.5 * ((sc.receivers as f64 / 8.0) - 1.0).max(0.0)
    } else {
        1.0
    };

    struct Msg {
        sender: usize,
        rx: usize,
        head_arrive: f64,
    }

    let mut msgs: Vec<Msg> = Vec::with_capacity(sc.senders * sc.receivers);
    for _ in 0..sc.rounds {
        // --- sender side: compute each message's arrival at its receiver ---
        msgs.clear();
        for s in 0..sc.senders {
            let mut t = clock;
            let mut ops_in_batch = 0usize;
            for r in 0..sc.receivers {
                // Group setup applies at the start of every batch of ops
                // (NCCL processes p2p groups in batches of <= 8).
                if ops_in_batch == 0 && p.group_setup > 0.0 {
                    t += p.group_setup;
                }
                ops_in_batch += 1;
                if ops_in_batch >= p.group_batch {
                    ops_in_batch = 0;
                }

                // Post (CPU) + proxy copy (GPU->CPU staging on the sender).
                t += p.post_overhead + p.copy_per_byte * sc.msg_bytes as f64;

                // Sender NIC serialization.
                let nic_start = t.max(send_busy[s]);
                send_busy[s] = nic_start + wire;

                // Cut-through: the head of the message reaches the receiver
                // after propagation; the receiver NIC's serialization window
                // overlaps the sender's.
                msgs.push(Msg {
                    sender: s,
                    rx: (s + r) % sc.receivers,
                    head_arrive: nic_start + PROPAGATION,
                });
            }
        }

        // --- receiver side: FIFO service in arrival order ---
        msgs.sort_by(|a, b| a.head_arrive.total_cmp(&b.head_arrive));
        let mut last_delivery = vec![clock; sc.senders];
        for m in &msgs {
            let jit = if p.jitter_sigma > 0.0 {
                rng.lognormal_median(1.0, p.jitter_sigma * sync_pressure)
            } else {
                1.0
            };
            let rx_start = m.head_arrive.max(recv_busy[m.rx]);
            // Proxy stacks copy CPU->GPU on the receive side as well.
            let service =
                (wire * incast_factor + p.copy_per_byte * sc.msg_bytes as f64) * jit;
            let rx_done = rx_start + service;
            recv_busy[m.rx] = rx_done;

            // Receiver-side completion: CQ poll / proxy delivery + sync.
            let mut done = rx_done + p.recv_overhead + p.sync_overhead;

            // ACK handling under bidirectional load.
            if sc.bidirectional {
                done += p.ack_delay * sc.senders as f64;
            }

            // Heavy-tailed stall? A GPU-sync/OS stall halts the proxy
            // progress thread with the rest of the group queued behind it,
            // so its impact scales with the outstanding-op pressure — this
            // is the "instability exacerbates at higher percentiles when
            // scaling to 32 receivers" effect of Figure 5(b).
            if p.stall_prob > 0.0 && rng.chance(p.stall_prob) {
                done += rng.pareto(p.stall_scale * sync_pressure, p.stall_alpha);
            }

            last_delivery[m.sender] = last_delivery[m.sender].max(done);
        }

        let mut round_end = clock;
        for &d in &last_delivery {
            latency.record(d - clock);
            round_end = round_end.max(d);
        }
        total_busy += round_end - clock;
        clock = round_end;
    }

    let bytes_per_sender = (sc.msg_bytes * sc.receivers * sc.rounds) as f64;
    M2nStats {
        latency,
        throughput: if total_busy > 0.0 {
            bytes_per_sender / total_busy
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::m2n::LibraryKind;

    #[test]
    fn latency_grows_with_receivers() {
        let mk = |n| {
            simulate_m2n(&M2nScenario {
                profile: LibraryProfile::of(LibraryKind::MegaScale),
                senders: 1,
                receivers: n,
                msg_bytes: 128 * 1024,
                rounds: 100,
                bidirectional: false,
                seed: 1,
            })
            .latency
            .median()
        };
        assert!(mk(8) < mk(16));
        assert!(mk(16) < mk(32));
    }

    #[test]
    fn latency_grows_with_size() {
        let mk = |b| {
            simulate_m2n(&M2nScenario {
                profile: LibraryProfile::of(LibraryKind::Nccl),
                senders: 8,
                receivers: 8,
                msg_bytes: b,
                rounds: 100,
                bidirectional: false,
                seed: 1,
            })
            .latency
            .median()
        };
        assert!(mk(16 * 1024) < mk(512 * 1024));
    }

    #[test]
    fn bidirectional_hurts_nccl_more() {
        let run = |kind, bidir| {
            simulate_m2n(&M2nScenario {
                profile: LibraryProfile::of(kind),
                senders: 8,
                receivers: 8,
                msg_bytes: 256 * 1024,
                rounds: 200,
                bidirectional: bidir,
                seed: 5,
            })
            .latency
            .median()
        };
        let nccl_penalty = run(LibraryKind::Nccl, true) / run(LibraryKind::Nccl, false);
        let ours_penalty =
            run(LibraryKind::MegaScale, true) / run(LibraryKind::MegaScale, false);
        assert!(nccl_penalty > ours_penalty, "{nccl_penalty} vs {ours_penalty}");
    }

    #[test]
    fn sender_nic_serializes() {
        // One sender to 32 receivers of 1MB each cannot be faster than
        // 32 MB at line rate.
        let s = simulate_m2n(&M2nScenario {
            profile: LibraryProfile::of(LibraryKind::Perftest),
            senders: 1,
            receivers: 32,
            msg_bytes: 1024 * 1024,
            rounds: 20,
            bidirectional: false,
            seed: 2,
        });
        let floor = 31.0 * 1024.0 * 1024.0 / 25e9; // 31 msgs serialized + last overlaps
        assert!(s.latency.median() >= floor);
    }
}
