//! Cost profiles of the three communication stacks compared in the paper.
//!
//! Constants are chosen from the paper's qualitative attribution (§5) and
//! public RDMA/NCCL microbenchmark lore, scaled so that the headline
//! comparisons of §7.3 (median/P99/throughput at 256 KB, 8→8 on 200 Gbps
//! NICs) reproduce in *shape*. They are inputs to the message-level
//! simulator in [`super::simnet`].

/// Which communication stack to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LibraryKind {
    /// The paper's RDMA write-with-immediate library.
    MegaScale,
    /// NCCL peer-to-peer send/recv groups.
    Nccl,
    /// `perftest` (ib_write_bw-style): CPU-driven RDMA, the latency floor.
    Perftest,
}

/// Per-operation cost constants for one stack.
#[derive(Debug, Clone, PartialEq)]
pub struct LibraryProfile {
    /// Which stack this profile models.
    pub kind: LibraryKind,
    /// NIC line rate per GPU, bytes/s (200 Gbps default, §7.3 testbed).
    pub nic_bw: f64,
    /// CPU/NIC work to post one message (doorbell, WQE build), seconds.
    pub post_overhead: f64,
    /// Fixed cost to set up one batch/group of sends (kernel launch for
    /// NCCL's group, nothing for RDMA-direct stacks), seconds.
    pub group_setup: f64,
    /// Max operations per group batch (NCCL processes p2p groups in batches
    /// of at most 8; others unlimited => usize::MAX).
    pub group_batch: usize,
    /// Extra per-byte cost of intermediate GPU→CPU proxy copies (NCCL
    /// networking copies through the CPU proxy), seconds per byte.
    pub copy_per_byte: f64,
    /// Fixed receiver-side completion cost (CQ poll + GDRCopy flush for
    /// MegaScale; proxy delivery + stream wait for NCCL), seconds.
    pub recv_overhead: f64,
    /// GPU synchronization cost per operation (stream sync/event waits NCCL
    /// needs; eliminated in MegaScale), seconds.
    pub sync_overhead: f64,
    /// Probability that one message hits a slow-path stall (OS noise,
    /// GPU-sync interference). Drawn per message.
    pub stall_prob: f64,
    /// Pareto scale (minimum) of a stall, seconds.
    pub stall_scale: f64,
    /// Pareto shape of a stall; smaller = heavier tail.
    pub stall_alpha: f64,
    /// Log-normal sigma of benign per-message jitter.
    pub jitter_sigma: f64,
    /// Incast penalty: effective receiver bandwidth fraction when k senders
    /// converge is `1/(1 + incast_penalty·(k−1))` beyond fair sharing.
    /// Congestion-control fine-tuning (§5) lowers it.
    pub incast_penalty: f64,
    /// Extra delay for ACK processing under bidirectional load; the
    /// high-priority-ACK fix (§5) removes it.
    pub ack_delay: f64,
}

impl LibraryProfile {
    /// The calibrated cost profile of one stack.
    pub fn of(kind: LibraryKind) -> Self {
        match kind {
            LibraryKind::MegaScale => Self {
                kind,
                nic_bw: 25e9,
                post_overhead: 1.2e-6,
                group_setup: 0.0,
                group_batch: usize::MAX,
                copy_per_byte: 0.0,
                recv_overhead: 1.5e-6, // CQ poll + GDRCopy flush + flag update
                sync_overhead: 0.0,
                stall_prob: 0.0005,
                stall_scale: 4e-6,
                stall_alpha: 2.5, // light tail
                jitter_sigma: 0.04,
                incast_penalty: 0.02, // congestion control fine-tuned
                ack_delay: 0.0,       // high-priority ACK queues
            },
            LibraryKind::Nccl => Self {
                kind,
                nic_bw: 25e9,
                post_overhead: 2.5e-6,
                group_setup: 14e-6, // group launch + checks + proxy wakeup
                group_batch: 8,     // p2p groups processed <=8 ops at a time
                // proxy copy path ~ 20 GB/s effective => 5e-11 s/B extra
                copy_per_byte: 5e-11,
                recv_overhead: 4e-6,
                sync_overhead: 7e-6, // stream sync / event wait per op
                stall_prob: 0.004,
                stall_scale: 60e-6,
                stall_alpha: 1.15, // heavy tail: GPU sync + device mem access
                jitter_sigma: 0.10,
                incast_penalty: 0.35,
                ack_delay: 3e-6,
            },
            LibraryKind::Perftest => Self {
                kind,
                nic_bw: 25e9,
                post_overhead: 1.0e-6,
                group_setup: 0.0,
                group_batch: usize::MAX,
                copy_per_byte: 0.0,
                recv_overhead: 1.0e-6,
                sync_overhead: 0.0,
                stall_prob: 0.0003,
                stall_scale: 3e-6,
                stall_alpha: 2.5,
                jitter_sigma: 0.03,
                incast_penalty: 0.05,
                ack_delay: 0.0,
            },
        }
    }

    /// Serial wire time of one message.
    pub fn wire_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.nic_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn megascale_removes_the_overheads() {
        let ours = LibraryProfile::of(LibraryKind::MegaScale);
        let nccl = LibraryProfile::of(LibraryKind::Nccl);
        assert_eq!(ours.copy_per_byte, 0.0);
        assert_eq!(ours.sync_overhead, 0.0);
        assert_eq!(ours.group_setup, 0.0);
        assert!(nccl.copy_per_byte > 0.0);
        assert!(nccl.sync_overhead > 0.0);
        assert_eq!(nccl.group_batch, 8);
        assert!(ours.stall_alpha > nccl.stall_alpha, "NCCL tail heavier");
    }

    #[test]
    fn wire_time_256kb() {
        let p = LibraryProfile::of(LibraryKind::MegaScale);
        let t = p.wire_time(256 * 1024);
        assert!((t - 256.0 * 1024.0 / 25e9).abs() < 1e-12);
    }
}
