//! M2N communication: the paper's custom RDMA library, the NCCL baseline,
//! and the perftest lower bound, reproduced on a message-level
//! discrete-event network simulator (paper §5, Figures 5/10/11).
//!
//! The paper attributes NCCL's deficit on the M2N token-dispatch pattern to
//! enumerable overhead terms: GPU→CPU proxy copies, peer-to-peer group
//! operations batched ≤8 at a time, general group setup, and
//! GPU-synchronization/device-memory-access instability that inflates tail
//! latency. The MegaScale library removes each term (RDMA write-with-
//! immediate from pre-registered buffers, CQ polling, GDRCopy flush on the
//! receiver) and adds traffic-oriented fixes (high-priority ACKs, congestion
//! control tuning). We model every term explicitly; see
//! [`profiles::LibraryProfile`] for the constants.

mod profiles;
mod simnet;
mod transfer;

pub use profiles::{LibraryKind, LibraryProfile};
pub use simnet::{simulate_m2n, M2nScenario, M2nStats};
pub use transfer::TransferModel;

#[cfg(test)]
mod tests {
    use super::*;

    fn scen(kind: LibraryKind, m: usize, n: usize, size: usize) -> M2nStats {
        simulate_m2n(&M2nScenario {
            profile: LibraryProfile::of(kind),
            senders: m,
            receivers: n,
            msg_bytes: size,
            rounds: 400,
            bidirectional: false,
            seed: 42,
        })
    }

    #[test]
    fn megascale_beats_nccl_median_256kb() {
        // §7.3 headline @256KB: 68.2% median latency reduction, 4.2x
        // throughput. Accept the shape: >=50% reduction and >=3x throughput.
        let ours = scen(LibraryKind::MegaScale, 8, 8, 256 * 1024);
        let nccl = scen(LibraryKind::Nccl, 8, 8, 256 * 1024);
        let red = 1.0 - ours.latency.median() / nccl.latency.median();
        assert!(red > 0.5, "median reduction {red}");
        let speedup = ours.throughput / nccl.throughput;
        assert!(speedup > 3.0, "throughput speedup {speedup}");
    }

    #[test]
    fn nccl_tail_blows_up_at_scale() {
        // Figure 5b / 11: NCCL P99/median ratio grows with N; MegaScale
        // stays stable.
        let nccl_small = scen(LibraryKind::Nccl, 1, 8, 128 * 1024);
        let nccl_large = scen(LibraryKind::Nccl, 1, 32, 128 * 1024);
        let r_small = nccl_small.latency.p99() / nccl_small.latency.median();
        let r_large = nccl_large.latency.p99() / nccl_large.latency.median();
        assert!(
            r_large > r_small,
            "NCCL tail ratio should grow: {r_small} -> {r_large}"
        );
        let ours = scen(LibraryKind::MegaScale, 1, 32, 128 * 1024);
        let r_ours = ours.latency.p99() / ours.latency.median();
        assert!(r_ours < 1.5, "MegaScale tail ratio {r_ours}");
    }

    #[test]
    fn perftest_is_lower_bound() {
        for n in [8usize, 16, 32] {
            let pt = scen(LibraryKind::Perftest, 1, n, 128 * 1024);
            let nccl = scen(LibraryKind::Nccl, 1, n, 128 * 1024);
            assert!(
                pt.latency.median() < nccl.latency.median(),
                "perftest must beat NCCL at N={n}"
            );
        }
    }

    #[test]
    fn throughput_approaches_line_rate_for_large_messages() {
        // 200 Gbps NIC = 25 GB/s; at 1 MB messages MegaScale should achieve
        // most of it.
        let ours = scen(LibraryKind::MegaScale, 8, 8, 1024 * 1024);
        assert!(
            ours.throughput > 0.7 * 25e9,
            "per-GPU throughput {} should near line rate",
            ours.throughput
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = scen(LibraryKind::Nccl, 4, 8, 64 * 1024);
        let b = scen(LibraryKind::Nccl, 4, 8, 64 * 1024);
        assert_eq!(a.latency.median(), b.latency.median());
        assert_eq!(a.throughput, b.throughput);
    }
}
