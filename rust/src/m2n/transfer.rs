//! Calibrated per-transfer latency model: the bridge from the message-level
//! simnet microbenchmark ([`super::simulate_m2n`]) to the cluster
//! simulator's per-micro-batch M2N hops.
//!
//! Running the full message-level DES inside every pipeline hop of an
//! end-to-end serving simulation would dominate its cost; instead we probe
//! the simnet once per (library, M, N) configuration at two message sizes
//! and fit the affine `latency(bytes) = base + per_byte · bytes` the LogP
//! family predicts (and the simnet exhibits away from its stall tail).
//! Calibration is fully deterministic given the seed, so cluster runs stay
//! bit-replayable.

use super::profiles::LibraryProfile;
use super::simnet::{simulate_m2n, M2nScenario};

/// Affine per-dispatch latency model for an M-to-N token transfer.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferModel {
    /// Senders (M) the model was calibrated for.
    pub senders: usize,
    /// Receivers (N) the model was calibrated for.
    pub receivers: usize,
    /// Fixed per-dispatch latency (seconds): setup, posts, propagation.
    pub base: f64,
    /// Marginal seconds per byte of per-(sender, receiver) message size.
    pub per_byte: f64,
}

impl TransferModel {
    /// Probe the simnet at two message sizes and fit the affine model.
    pub fn calibrate(
        profile: &LibraryProfile,
        senders: usize,
        receivers: usize,
        seed: u64,
    ) -> Self {
        assert!(senders >= 1 && receivers >= 1);
        let probe = |msg_bytes: usize| {
            simulate_m2n(&M2nScenario {
                profile: profile.clone(),
                senders,
                receivers,
                msg_bytes,
                rounds: 64,
                bidirectional: true,
                seed,
            })
            .latency
            .median()
        };
        let (s0, s1) = (32 * 1024usize, 512 * 1024usize);
        let (t0, t1) = (probe(s0), probe(s1));
        let per_byte = ((t1 - t0) / (s1 - s0) as f64).max(0.0);
        let base = (t0 - per_byte * s0 as f64).max(0.0);
        Self {
            senders,
            receivers,
            base,
            per_byte,
        }
    }

    /// Latency of one dispatch in which every (sender, receiver) pair
    /// carries `pair_bytes` bytes.
    pub fn latency(&self, pair_bytes: f64) -> f64 {
        self.base + self.per_byte * pair_bytes.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::m2n::LibraryKind;

    #[test]
    fn calibration_is_deterministic() {
        let p = LibraryProfile::of(LibraryKind::MegaScale);
        let a = TransferModel::calibrate(&p, 8, 8, 7);
        let b = TransferModel::calibrate(&p, 8, 8, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn latency_monotone_in_bytes() {
        let p = LibraryProfile::of(LibraryKind::MegaScale);
        let t = TransferModel::calibrate(&p, 8, 8, 1);
        assert!(t.base >= 0.0 && t.per_byte >= 0.0);
        assert!(t.latency(64.0 * 1024.0) <= t.latency(1024.0 * 1024.0));
        assert!(t.latency(0.0) >= 0.0);
    }

    #[test]
    fn nccl_costs_more_than_megascale() {
        let ours = TransferModel::calibrate(&LibraryProfile::of(LibraryKind::MegaScale), 8, 8, 3);
        let nccl = TransferModel::calibrate(&LibraryProfile::of(LibraryKind::Nccl), 8, 8, 3);
        let sz = 256.0 * 1024.0;
        assert!(
            nccl.latency(sz) > ours.latency(sz),
            "NCCL {} vs MegaScale {}",
            nccl.latency(sz),
            ours.latency(sz)
        );
    }

    #[test]
    fn fit_tracks_simnet_between_probe_points() {
        // The affine fit should land within a factor-ish band of a direct
        // simnet run at an intermediate size.
        let p = LibraryProfile::of(LibraryKind::MegaScale);
        let t = TransferModel::calibrate(&p, 4, 8, 5);
        let direct = simulate_m2n(&M2nScenario {
            profile: p.clone(),
            senders: 4,
            receivers: 8,
            msg_bytes: 128 * 1024,
            rounds: 64,
            bidirectional: true,
            seed: 5,
        })
        .latency
        .median();
        let fit = t.latency(128.0 * 1024.0);
        let rel = (fit - direct).abs() / direct;
        assert!(rel < 0.35, "fit {fit} vs direct {direct} (rel {rel})");
    }
}
