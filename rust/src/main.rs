//! `msi` — the MegaScale-Infer command-line launcher.
//!
//! ```text
//! msi plan      --model mixtral --attention-gpu ampere [--expert-gpu l40s]
//!               [--hetero h20:l40s] [--slo-ms 150] [--avg-seq 730] [--all]
//!               [--validate-top K] [--validate-requests 512]
//!               [--prompt-heavy] [--seed 42]
//! msi compare   --model mixtral [--attention-gpu ampere] [--expert-gpu l40s]
//!               [--hetero h20:l40s] [--requests 0=auto] [--rate 0]
//!               [--burst 0.0] [--skew 0] [--tenants name:w:slo,...]
//!               [--slo-ms 150] [--validate-top K] [--prompt-heavy]
//!               [--seed 42] [--json report.json] [--csv report.csv]
//! msi simulate  --model mixtral --gpu ampere [--requests 512] [--baselines]
//! msi replay    [--trace t.jsonl | --requests 1000] --model mixtral
//!               --attention-gpu ampere [--expert-gpu l40s]
//!               [--hetero h20:l40s] [--rate 0] [--burst 0.0] [--skew 0]
//!               [--popularity-drift <s>] [--rebalance <s>] [--balance]
//!               [--tenants name:weight:slo_s,...] [--simnet]
//!               [--micro-batches m] [--prefill N] [--prefill-chunk 2048]
//!               [--max-seconds <s>] [--shards K|auto] [--shard-workers N]
//!               [--no-fuse] [--no-macro] [--seed 42] [--json report.json]
//! msi serve     --artifacts artifacts [--micro-batches 2] [--requests 16]
//!               (requires the `pjrt` feature)
//! msi sweep     [--model tiny] [--gpu ampere] [--requests 2000]
//!               [--rates 0,200,400] [--skews 0,1.2] [--micro-batches 1,2,3]
//!               [--prompt-lens 0,571,2048]
//!               [--tenant-mixes "none;interactive:0.7:2.5,batch:0.3:60"]
//!               [--systems megascale,vllm,trtllm] [--workers N] [--seed 42]
//!               [--json sweep.json] [--csv sweep.csv] [--smoke]
//! msi sweep     --bench [--bench-requests 1000000] [--seed 42]
//!               [--bench-out BENCH_sim.json]
//!               [--bench-compare BENCH_sim.json] [--bench-threshold 0.15]
//! msi m2n       --library megascale|nccl|perftest [--senders 8]
//!               [--receivers 8] [--size-kib 256] [--rounds 1000]
//! msi hardware
//! msi trace     --out trace.jsonl [--requests 1000] [--seed 42]
//! msi lint      [--path rust/src] [--json lint.json] [--waivers]
//! msi scenario  run <file.msc> [--no-fuse] [--no-macro] [--shards K|auto]
//!               [--shard-workers N] [--json report.json]
//! msi scenario  check <file.msc>
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use megascale_infer::baselines::{
    best_under_slo, minimal_deployment, run_compare, BaselineKind, CompareConfig, SystemKind,
};
use megascale_infer::config::{gpu_catalog, ClusterSpec, GpuKind, ModelConfig, NodeSpec};
use megascale_infer::coordinator::{RoutePolicy, RuntimeInstance};
use megascale_infer::m2n::{simulate_m2n, LibraryKind, LibraryProfile, M2nScenario};
use megascale_infer::perf_model::DEFAULT_PREFILL_CHUNK;
use megascale_infer::plan::{validate_top_k, PlanSearcher, PromptShape, ValidationConfig};
#[cfg(feature = "pjrt")]
use megascale_infer::runtime::ServingEngine;
use megascale_infer::sim::cluster::{
    ClusterSim, ClusterSimConfig, EngineMode, ExpertPopularity, Transport,
};
use megascale_infer::sim::shard::effective_shards;
use megascale_infer::sim::sweep::{
    run_sim_bench, run_sweep, sweep_to_csv, sweep_to_json, SweepGrid,
};
use megascale_infer::sim::{run_sharded, ShardPlan};
use megascale_infer::util::cli::Args;
use megascale_infer::workload::{
    ArrivalSource, StridedSource, TenantClass, Trace, TraceSource, WorkloadSpec,
};

const USAGE: &str =
    "usage: msi <plan|compare|simulate|replay|sweep|serve|m2n|hardware|trace|lint|scenario> [--options]
run `msi help` or see README.md for details";

fn parse_model(name: &str) -> Result<ModelConfig> {
    Ok(match name.to_lowercase().as_str() {
        "mixtral" | "mixtral-8x22b" => ModelConfig::mixtral_8x22b(),
        "dbrx" => ModelConfig::dbrx(),
        "scaled-moe" | "scaled_moe" | "scaled" => ModelConfig::scaled_moe(),
        "tiny" => ModelConfig::tiny(),
        other => bail!("unknown model {other}"),
    })
}

fn parse_gpu(name: &str) -> Result<GpuKind> {
    Ok(match name.to_lowercase().as_str() {
        "ampere" | "a100" => GpuKind::Ampere80G,
        "h20" => GpuKind::H20,
        "l40s" => GpuKind::L40S,
        "a800" => GpuKind::A800,
        "h800" => GpuKind::H800,
        "l20" => GpuKind::L20,
        other => bail!("unknown gpu {other}"),
    })
}

/// Cluster shape from the shared GPU flags: `--hetero attn:expert` is
/// shorthand for `--attention-gpu`/`--expert-gpu` (which defaults to the
/// attention kind). Used identically by `plan`, `compare` and `replay`.
fn parse_cluster(args: &Args) -> Result<ClusterSpec> {
    let (a, e) = match args.get("hetero") {
        Some(pair) => {
            let (a, e) = pair
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("--hetero expects <attn-gpu>:<expert-gpu>"))?;
            (parse_gpu(a)?, parse_gpu(e)?)
        }
        None => {
            let a = parse_gpu(&args.str_or("attention-gpu", "ampere"))?;
            let e = match args.get("expert-gpu") {
                Some(g) => parse_gpu(g)?,
                None => a,
            };
            (a, e)
        }
    };
    Ok(ClusterSpec {
        attention: NodeSpec {
            gpu: a,
            gpus_per_node: 8,
            nodes: None,
        },
        expert: NodeSpec {
            gpu: e,
            gpus_per_node: 8,
            nodes: None,
        },
    })
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    // `msi scenario` takes positional operands (`run <file.msc>`), which the
    // shared flag parser rejects; route it before `Args::parse` sees them.
    if raw.first().map(String::as_str) == Some("scenario") {
        return cmd_scenario(&raw[1..]);
    }
    let args = Args::parse(
        raw,
        &[
            "all",
            "baselines",
            "balance",
            "simnet",
            "smoke",
            "bench",
            "prompt-heavy",
            "no-fuse",
            "no-macro",
            "waivers",
        ],
    )?;
    match args.subcommand.as_str() {
        "plan" => cmd_plan(&args),
        "compare" => cmd_compare(&args),
        "simulate" => cmd_simulate(&args),
        "replay" => cmd_replay(&args),
        "sweep" => cmd_sweep(&args),
        #[cfg(feature = "pjrt")]
        "serve" => cmd_serve(&args),
        #[cfg(not(feature = "pjrt"))]
        "serve" => bail!(
            "`msi serve` needs the real-compute path: rebuild with \
             `--features pjrt` (see DESIGN.md § PJRT runtime)"
        ),
        "m2n" => cmd_m2n(&args),
        "hardware" => cmd_hardware(),
        "trace" => cmd_trace(&args),
        "lint" => cmd_lint(&args),
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other}\n{USAGE}"),
    }
}

fn cmd_plan(args: &Args) -> Result<()> {
    let model = parse_model(&args.str_or("model", "mixtral"))?;
    let cluster = parse_cluster(args)?;
    // --prompt-heavy: rank (and, with --validate-top, sim-re-rank) under
    // the long-context preset — the regime where prefill-pool sizing is
    // the decisive third dimension.
    let prompt_heavy = args.flag("prompt-heavy");
    let default_avg_seq = if prompt_heavy {
        WorkloadSpec::prompt_heavy().avg_seq_len()
    } else {
        730.0
    };
    let mut searcher = PlanSearcher::new(model, cluster, args.f64_or("avg-seq", default_avg_seq)?);
    searcher.limits.slo = args.f64_or("slo-ms", 150.0)? / 1000.0;
    if prompt_heavy {
        searcher.prompt = PromptShape::of_spec(&WorkloadSpec::prompt_heavy());
    }
    if args.flag("all") {
        for p in searcher.search_all() {
            println!("{}", p.to_json());
        }
        return Ok(());
    }
    // Sim-in-the-loop validation: re-score the top-K analytic candidates
    // through short engine runs and pick by simulated goodput per dollar
    // (K = 1 sim-checks the analytic winner and reports its numbers).
    let k = args.usize_or("validate-top", 0)?;
    if k > 0 {
        let vcfg = ValidationConfig {
            top_k: k,
            requests: args.usize_or("validate-requests", 512)?,
            seed: args.u64_or("seed", 42)?,
            ..Default::default()
        };
        // Match the validation workload's sequence-length regime to the
        // --avg-seq the analytic search ranked under, keeping the paper's
        // input:output shape (or the prompt-heavy preset verbatim).
        let spec = if prompt_heavy {
            WorkloadSpec::prompt_heavy()
        } else {
            let base = WorkloadSpec::default();
            let scale = searcher.avg_seq / base.avg_seq_len();
            WorkloadSpec {
                median_input: base.median_input * scale,
                median_output: base.median_output * scale,
                ..base
            }
        };
        let v = validate_top_k(&searcher, &spec, &vcfg)
            .ok_or_else(|| anyhow::anyhow!("no feasible plan"))?;
        for c in &v.candidates {
            println!(
                "candidate #{}: tp_a={} tp_e={} n_a={} n_p={} m={} B={} | \
                 analytic {:.1} tok/s/$ | \
                 simulated {:.1} tok/s, goodput {:.1} tok/s/$",
                c.analytic_rank,
                c.plan.tp_a,
                c.plan.tp_e,
                c.plan.n_a,
                c.plan.n_p,
                c.plan.m,
                c.plan.global_batch,
                c.plan.metrics.throughput_per_dollar,
                c.simulated_throughput,
                c.goodput_per_dollar,
            );
        }
        if v.overturned() {
            println!(
                "simulation overturned the analytic ranking: candidate #{} wins",
                v.chosen
            );
        }
        println!("{}", v.plan.to_json());
        return Ok(());
    }
    match searcher.search() {
        Some(p) => println!("{}", p.to_json()),
        None => bail!("no feasible plan"),
    }
    Ok(())
}

/// Run the simulated Figure-8 comparison: the best disaggregated plan vs
/// vLLM-style and TRT-LLM-style colocated fleets (sized to match its GPU
/// count) on one identical workload through the same cluster engine.
fn cmd_compare(args: &Args) -> Result<()> {
    let model = parse_model(&args.str_or("model", "mixtral"))?;
    let cluster = parse_cluster(args)?;
    let rate = args.f64_or("rate", 0.0)?;
    let tenants = match args.get("tenants") {
        Some(spec) => TenantClass::parse_list(spec)?,
        None => Vec::new(),
    };
    let skew = args.f64_or("skew", 0.0)?;
    let k = args.usize_or("validate-top", 0)?;
    let base_spec = if args.flag("prompt-heavy") {
        WorkloadSpec::prompt_heavy()
    } else {
        WorkloadSpec::default()
    };
    let cfg = CompareConfig {
        spec: WorkloadSpec {
            arrival_rate: (rate > 0.0).then_some(rate),
            burst_sigma: args.f64_or("burst", 0.0)?,
            tenants,
            ..base_spec
        },
        requests: args.usize_or("requests", 0)?,
        seed: args.u64_or("seed", 42)?,
        slo: args.f64_or("slo-ms", 150.0)? / 1000.0,
        popularity: if skew > 0.0 {
            ExpertPopularity::Zipf(skew)
        } else {
            ExpertPopularity::Ideal
        },
        validate_top: (k > 0).then_some(k),
        ..CompareConfig::new(model, cluster)
    };
    let report = run_compare(&cfg)?;
    println!("{}", report.summary());
    if let Some(path) = args.get("json") {
        std::fs::write(path, format!("{}\n", report.to_json()))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote JSON report to {path}");
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.to_csv()).with_context(|| format!("writing {path}"))?;
        println!("wrote CSV report to {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = parse_model(&args.str_or("model", "mixtral"))?;
    let cluster = ClusterSpec::homogeneous(parse_gpu(&args.str_or("gpu", "ampere"))?);
    let requests = args.usize_or("requests", 512)?;
    let seed = args.u64_or("seed", 42)?;
    let spec = WorkloadSpec::default();
    let searcher = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len());
    let plan = searcher
        .search()
        .ok_or_else(|| anyhow::anyhow!("no feasible plan"))?;
    let reqs = spec.generate(requests, seed);
    let inst = RuntimeInstance::new(model.clone(), cluster.clone(), plan.clone());
    let rep = inst.simulate(&reqs);
    println!(
        "MegaScale-Infer  plan: tp_a={} tp_e={} n_a={} m={} B={}",
        plan.tp_a, plan.tp_e, plan.n_a, plan.m, plan.global_batch
    );
    println!(
        "  throughput {:.1} tok/s | per-GPU {:.2} tok/s/GPU | TPOT p50 {:.1} ms p99 {:.1} ms",
        rep.throughput,
        rep.per_gpu_throughput,
        rep.tpot.median() * 1e3,
        rep.tpot.p99() * 1e3
    );
    if args.flag("baselines") {
        for kind in [BaselineKind::Vllm, BaselineKind::TrtLlm] {
            let dep = minimal_deployment(kind, &model, &cluster);
            if let Some(m) = best_under_slo(&dep, &model, &cluster, spec.avg_seq_len(), 0.150) {
                println!(
                    "{:>14}  tp={} pp={} B={} | per-GPU {:.2} tok/s/GPU | TPOT {:.1} ms",
                    kind.name(),
                    dep.tp,
                    dep.pp,
                    m.batch,
                    m.per_gpu_throughput,
                    m.tpot * 1e3
                );
            }
        }
    }
    Ok(())
}

/// Replay a trace (or a synthetic workload) through the event-driven
/// cluster engine: router → attention pool → gating/dispatch → M2N →
/// expert pool → ping-pong pipeline, on one virtual clock. Scenario knobs
/// cover heterogeneous pools (`--hetero`), multi-tenant traffic classes
/// with per-class SLOs (`--tenants`), and time-varying expert popularity
/// with periodic online re-balancing (`--popularity-drift`/`--rebalance`).
fn cmd_replay(args: &Args) -> Result<()> {
    let model = parse_model(&args.str_or("model", "mixtral"))?;
    let cluster = parse_cluster(args)?;
    let seed = args.u64_or("seed", 42)?;
    let rate = args.f64_or("rate", 0.0)?;
    let tenants = match args.get("tenants") {
        Some(spec) => TenantClass::parse_list(spec)?,
        None => Vec::new(),
    };
    let spec = WorkloadSpec {
        arrival_rate: (rate > 0.0).then_some(rate),
        burst_sigma: args.f64_or("burst", 0.0)?,
        tenants: tenants.clone(),
        ..Default::default()
    };
    let requests = match args.get("trace") {
        Some(path) => Trace::load(&PathBuf::from(path))?.requests,
        None => spec.generate(args.usize_or("requests", 1000)?, seed),
    };

    // Size the plan for the workload actually being replayed, not the
    // synthetic defaults.
    let avg_seq = {
        let s = Trace::new(requests.clone()).stats();
        if s.count == 0 {
            spec.avg_seq_len()
        } else {
            s.avg_seq
        }
    };
    let searcher = PlanSearcher::new(model.clone(), cluster.clone(), avg_seq);
    let mut plan = searcher
        .search()
        .ok_or_else(|| anyhow::anyhow!("no feasible plan"))?;
    if let Some(m) = args.get("micro-batches") {
        plan.m = m.parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--micro-batches={m} not an integer"))?
            .max(1);
    }

    let skew = args.f64_or("skew", 0.0)?;
    let drift = args.f64_or("popularity-drift", 0.0)?;
    if drift > 0.0 && skew <= 0.0 {
        bail!("--popularity-drift needs a skewed popularity: add --skew <alpha>");
    }
    let popularity = if skew <= 0.0 {
        ExpertPopularity::Uniform
    } else if drift > 0.0 {
        ExpertPopularity::ZipfDrifting {
            alpha: skew,
            period: drift,
        }
    } else if args.flag("balance") {
        ExpertPopularity::ZipfBalanced(skew)
    } else {
        ExpertPopularity::Zipf(skew)
    };
    // Periodic online re-balancing: explicit interval, or a quarter of the
    // drift period when `--balance` rides along with drifting popularity.
    let rebalance_period = match args.get("rebalance") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--rebalance={v} not a number"))?,
        ),
        None => (drift > 0.0 && args.flag("balance")).then_some(drift / 4.0),
    };
    let transport = if args.flag("simnet") {
        Transport::Simnet(LibraryKind::MegaScale)
    } else {
        Transport::Analytic
    };

    // Prefill-pool override: `--prefill N` resizes the pool the plan
    // search picked; `--prefill 0` disables prefill modeling entirely.
    let prefill_nodes = args.usize_or("prefill", plan.n_p)?;
    let prefill_chunk = args.usize_or("prefill-chunk", DEFAULT_PREFILL_CHUNK)?;
    println!(
        "replay: {} requests | plan tp_a={} tp_e={} n_a={} m={} B={} | \
         prefill {} nodes x{} GPUs (chunk {})",
        requests.len(),
        plan.tp_a,
        plan.tp_e,
        plan.n_a,
        plan.m,
        plan.global_batch,
        prefill_nodes,
        plan.tp_p,
        prefill_chunk,
    );
    let max_sim_seconds = match args.get("max-seconds") {
        Some(v) => {
            let h: f64 = v
                .parse()
                .map_err(|_| anyhow::anyhow!("--max-seconds={v} not a number"))?;
            if h.is_nan() || h <= 0.0 {
                bail!("--max-seconds must be positive (got {v})");
            }
            Some(h)
        }
        None => None,
    };
    let cfg = ClusterSimConfig {
        model,
        cluster,
        plan,
        route: RoutePolicy::LeastLoaded,
        popularity,
        transport,
        seed,
        tenants,
        rebalance_period,
        max_sim_seconds,
        prefill_nodes,
        prefill_chunk,
        mode: EngineMode::Disaggregated,
        fuse: !args.flag("no-fuse"),
        macro_step: !args.flag("no-macro"),
        injections: Vec::new(),
    };
    let plan_json = cfg.plan.to_json();
    // --shards K: run as K independent sub-clusters stepped in parallel
    // (deterministic: byte-identical reports for any --shard-workers).
    // `--shards auto` sizes K to the host's available parallelism; the
    // pool-width clamp below still applies.
    let shards = match args.get("shards") {
        None => 1,
        Some("auto") => std::thread::available_parallelism().map_or(1, |n| n.get()),
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--shards={v} is not an integer or `auto`"))?,
    };
    let report = if shards > 1 {
        let eff = effective_shards(&cfg, shards);
        if eff != shards {
            println!("note: --shards {shards} clamped to {eff} (pool widths bound the shard count)");
        }
        let mut splan = ShardPlan::new(eff);
        if let Some(w) = args.get("shard-workers") {
            let w: usize = w
                .parse()
                .map_err(|_| anyhow::anyhow!("--shard-workers={w} not an integer"))?;
            splan = splan.with_workers(w);
        }
        println!(
            "sharded run: {} sub-clusters on {} worker threads",
            eff, splan.workers
        );
        let reqs = requests.clone();
        run_sharded(&cfg, splan, move |shard, stride| -> Box<dyn ArrivalSource> {
            Box::new(StridedSource::new(TraceSource::new(reqs.clone()), shard, stride))
        })
    } else {
        if args.get("shard-workers").is_some() {
            bail!("--shard-workers only applies with --shards > 1");
        }
        ClusterSim::new(cfg).run(&requests)
    };
    println!("{}", report.summary());
    if let Some(path) = args.get("json") {
        let payload = megascale_infer::util::json::Json::obj()
            .set("plan", plan_json)
            .set("report", report.to_json());
        std::fs::write(path, format!("{payload}\n"))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote JSON report to {path}");
    }
    Ok(())
}

/// `msi scenario run|check <file.msc>`: compile a declarative scenario
/// (workload phases plus fault/elasticity injections) and run it through
/// the cluster engine. `check` stops after compilation.
fn cmd_scenario(rest: &[String]) -> Result<()> {
    const SCENARIO_USAGE: &str = "usage: msi scenario <run|check> <file.msc> \
[--no-fuse] [--no-macro] [--shards K|auto] [--shard-workers N] [--json report.json]";
    let verb = rest.first().map(String::as_str).unwrap_or("");
    let check_only = match verb {
        "run" => false,
        "check" => true,
        "" | "help" | "--help" | "-h" => {
            println!("{SCENARIO_USAGE}");
            return Ok(());
        }
        other => bail!("unknown scenario verb `{other}`\n{SCENARIO_USAGE}"),
    };
    let Some(file) = rest.get(1).filter(|f| !f.starts_with("--")) else {
        bail!("`msi scenario {verb}` expects a .msc file\n{SCENARIO_USAGE}");
    };
    let args = Args::parse(
        std::iter::once("scenario".to_string()).chain(rest[2..].iter().cloned()),
        &["no-fuse", "no-macro"],
    )?;
    let compiled = megascale_infer::sim::scenario::load(file)?;
    let mut cfg = compiled.cfg.clone();
    cfg.fuse = !args.flag("no-fuse");
    cfg.macro_step = !args.flag("no-macro");
    println!(
        "scenario `{}`: {} phase(s), {} injection(s) | plan tp_a={} tp_e={} \
         n_a={} m={} B={} | prefill {} nodes",
        compiled.name,
        compiled.phases.len(),
        cfg.injections.len(),
        cfg.plan.tp_a,
        cfg.plan.tp_e,
        cfg.plan.n_a,
        cfg.plan.m,
        cfg.plan.global_batch,
        cfg.prefill_nodes,
    );
    if check_only {
        println!("scenario OK");
        return Ok(());
    }
    let plan_json = cfg.plan.to_json();
    let shards = match args.get("shards") {
        None => 1,
        Some("auto") => std::thread::available_parallelism().map_or(1, |n| n.get()),
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--shards={v} is not an integer or `auto`"))?,
    };
    let report = if shards > 1 {
        let eff = effective_shards(&cfg, shards);
        if eff != shards {
            println!(
                "note: --shards {shards} clamped to {eff} \
                 (pool widths bound the shard count)"
            );
        }
        let mut splan = ShardPlan::new(eff);
        if let Some(w) = args.get("shard-workers") {
            let w: usize = w
                .parse()
                .map_err(|_| anyhow::anyhow!("--shard-workers={w} not an integer"))?;
            splan = splan.with_workers(w);
        }
        println!(
            "sharded run: {} sub-clusters on {} worker threads",
            eff, splan.workers
        );
        let base = compiled.source();
        run_sharded(&cfg, splan, move |shard, stride| -> Box<dyn ArrivalSource> {
            Box::new(StridedSource::new(base.clone(), shard, stride))
        })
    } else {
        if args.get("shard-workers").is_some() {
            bail!("--shard-workers only applies with --shards > 1");
        }
        ClusterSim::new(cfg).run_streaming(Box::new(compiled.source()))
    };
    println!("{}", report.summary());
    if let Some(path) = args.get("json") {
        let payload = megascale_infer::util::json::Json::obj()
            .set("plan", plan_json)
            .set("report", report.to_json());
        std::fs::write(path, format!("{payload}\n"))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote JSON report to {path}");
    }
    Ok(())
}

fn parse_f64_list(spec: &str, flag: &str) -> Result<Vec<f64>> {
    spec.split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{flag}: {s:?} is not a number"))
        })
        .collect()
}

fn parse_usize_list(spec: &str, flag: &str) -> Result<Vec<usize>> {
    spec.split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--{flag}: {s:?} is not an integer"))
        })
        .collect()
}

/// Run a scenario grid (arrival rate × popularity skew × micro-batches ×
/// tenant mix) across worker threads with deterministic per-cell seeds, or
/// (with `--bench`) the simulator self-throughput benchmark. Reports are
/// byte-identical across runs with the same seed.
fn cmd_sweep(args: &Args) -> Result<()> {
    if args.flag("bench") {
        // Grid flags don't apply to the benchmark — error out instead of
        // silently ignoring them (e.g. `--requests` would otherwise run
        // the 1M default while the user expected `--bench-requests`).
        if args.flag("smoke") {
            bail!("--smoke is a grid-sweep option and has no effect with --bench");
        }
        for grid_only in [
            "json",
            "csv",
            "rates",
            "skews",
            "micro-batches",
            "prompt-lens",
            "tenant-mixes",
            "systems",
            "requests",
            "workers",
            "model",
            "gpu",
        ] {
            if args.get(grid_only).is_some() {
                bail!(
                    "--{grid_only} is a grid-sweep option; with --bench use \
                     --bench-requests / --bench-out"
                );
            }
        }
        let n = args.usize_or("bench-requests", 1_000_000)?;
        let seed = args.u64_or("seed", 42)?;
        let out = args.str_or("bench-out", "BENCH_sim.json");
        // Read the committed baseline BEFORE running (and possibly
        // overwriting the same path via --bench-out) so the gate always
        // compares against the committed numbers.
        let gate = match args.get("bench-compare") {
            Some(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading committed bench baseline {path}"))?;
                let baseline = megascale_infer::util::json::Json::parse(&text)?;
                let committed = baseline.get("tokens_per_wall_second")?.as_f64()?;
                // Tolerate baselines committed before the scenario-library
                // leg existed (and 0.0 = "directory absent when measured").
                let committed_library = baseline
                    .opt("scenario_library_wall_seconds")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0);
                Some((path.to_string(), committed, committed_library))
            }
            None => None,
        };
        let threshold = args.f64_or("bench-threshold", 0.15)?;
        if !(0.0..1.0).contains(&threshold) {
            bail!("--bench-threshold must be in [0, 1) (got {threshold})");
        }
        let scenario_dir = std::path::Path::new("scenarios").is_dir().then_some("scenarios");
        let payload = run_sim_bench(n, seed, scenario_dir);
        std::fs::write(&out, format!("{payload}\n"))
            .with_context(|| format!("writing {out}"))?;
        println!("{payload}");
        println!("wrote benchmark report to {out}");
        if let Some((path, committed, committed_library)) = gate {
            let fresh = payload.get("tokens_per_wall_second")?.as_f64()?;
            let floor = committed * (1.0 - threshold);
            if fresh < floor {
                bail!(
                    "simulator throughput regression: {fresh:.0} tok/wall-s is more than \
                     {:.0}% below the committed baseline {committed:.0} tok/wall-s \
                     (floor {floor:.0}) from {path}",
                    threshold * 100.0
                );
            }
            println!(
                "bench gate OK: {fresh:.0} tok/wall-s vs committed {committed:.0} \
                 (floor {floor:.0}, -{:.0}%)",
                threshold * 100.0
            );
            // Second gate: wall time over the committed scenario library.
            // Skipped (with a note) when either side is 0 — the library
            // wasn't measured there, so there is nothing to compare.
            let fresh_library = payload.get("scenario_library_wall_seconds")?.as_f64()?;
            if committed_library > 0.0 && fresh_library > 0.0 {
                let ceiling = committed_library * (1.0 + threshold);
                if fresh_library > ceiling {
                    bail!(
                        "scenario-library regression: {fresh_library:.3} s is more than \
                         {:.0}% above the committed baseline {committed_library:.3} s \
                         (ceiling {ceiling:.3} s) from {path}",
                        threshold * 100.0
                    );
                }
                println!(
                    "scenario-library gate OK: {fresh_library:.3} s vs committed \
                     {committed_library:.3} s (ceiling {ceiling:.3} s, +{:.0}%)",
                    threshold * 100.0
                );
            } else {
                println!(
                    "scenario-library gate skipped (committed {committed_library:.3} s, \
                     fresh {fresh_library:.3} s)"
                );
            }
        }
        return Ok(());
    }

    // Mirror of the --bench guard: bench-only flags are meaningless for a
    // grid sweep and almost certainly signal a forgotten --bench.
    for bench_only in ["bench-requests", "bench-out", "bench-compare", "bench-threshold"] {
        if args.get(bench_only).is_some() {
            bail!("--{bench_only} only applies with --bench");
        }
    }

    // --smoke: a tiny fixed grid for CI — small model, few requests.
    let smoke = args.flag("smoke");
    let model = parse_model(&args.str_or("model", if smoke { "tiny" } else { "mixtral" }))?;
    let cluster = ClusterSpec::homogeneous(parse_gpu(&args.str_or("gpu", "ampere"))?);
    let requests = args.usize_or("requests", if smoke { 192 } else { 2000 })?;
    let base_seed = args.u64_or("seed", 42)?;
    let rates = parse_f64_list(
        &args.str_or("rates", if smoke { "0,400" } else { "0" }),
        "rates",
    )?;
    let skews = parse_f64_list(
        &args.str_or("skews", if smoke { "0,1.2" } else { "0" }),
        "skews",
    )?;
    let micro_batches = parse_usize_list(
        &args.str_or("micro-batches", if smoke { "1,2" } else { "1,2,3" }),
        "micro-batches",
    )?;
    // Prompt-length axis (median input tokens; 0 = the base spec's median).
    let prompt_lens = parse_f64_list(&args.str_or("prompt-lens", "0"), "prompt-lens")?;
    // Tenant-mix axis: semicolon-separated mixes, each a `--tenants`-style
    // list; `none` (or an empty entry) is the single-tenant mix.
    let tenant_mixes: Vec<Vec<TenantClass>> = args
        .str_or("tenant-mixes", "none")
        .split(';')
        .map(|mix| {
            let mix = mix.trim();
            if mix.is_empty() || mix.eq_ignore_ascii_case("none") {
                Ok(Vec::new())
            } else {
                TenantClass::parse_list(mix)
            }
        })
        .collect::<Result<_>>()?;
    let workers = args.usize_or(
        "workers",
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    )?;
    // Serving-system axis: the disaggregated plan and/or colocated
    // baseline fleets sized to match its GPU count (the compare pairing).
    let systems: Vec<SystemKind> = args
        .str_or("systems", if smoke { "megascale,vllm" } else { "megascale" })
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(SystemKind::parse)
        .collect::<Result<_>>()?;
    if systems.is_empty() {
        bail!("--systems needs at least one of megascale,vllm,trtllm");
    }

    let spec = if smoke {
        WorkloadSpec::tiny_bench()
    } else {
        WorkloadSpec::default()
    };
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len())
        .search()
        .ok_or_else(|| anyhow::anyhow!("no feasible plan"))?;
    let grid = SweepGrid {
        model,
        cluster,
        plan,
        spec,
        requests,
        base_seed,
        rates,
        skews,
        micro_batches,
        prompt_lens,
        tenant_mixes,
        systems,
    };
    let cells = run_sweep(&grid, workers.max(1));
    println!(
        "sweep: {} cells ({} requests each) on {} workers",
        cells.len(),
        grid.requests,
        workers.max(1)
    );
    println!(
        "{:>8} {:>6} {:>3} {:>7} {:>4} {:>10} | {:>10} {:>10} | {:>9} {:>9} | {:>5} {:>5}",
        "rate",
        "skew",
        "m",
        "prompt",
        "mix",
        "system",
        "tok/s",
        "tok/s/GPU",
        "p50 E2E",
        "p99 E2E",
        "rej",
        "unsrv"
    );
    for c in &cells {
        println!(
            "{:>8.1} {:>6.2} {:>3} {:>7.0} {:>4} {:>10} | {:>10.1} {:>10.3} | {:>8.3}s {:>8.3}s | {:>5} {:>5}",
            c.rate,
            c.skew,
            c.m,
            c.prompt_len,
            c.tenant_mix,
            c.system,
            c.throughput,
            c.per_gpu_throughput,
            c.e2e_p50,
            c.e2e_p99,
            c.rejected,
            c.unserved_queued
        );
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, format!("{}\n", sweep_to_json(&grid, &cells)))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote JSON report to {path}");
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, sweep_to_csv(&cells))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote CSV report to {path}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let m = args.usize_or("micro-batches", 2)?;
    let n = args.usize_or("requests", 16)?;
    let seed = args.u64_or("seed", 42)?;
    let mut engine = ServingEngine::load(&artifacts, m)?;
    let spec = WorkloadSpec {
        median_input: 12.0,
        median_output: 16.0,
        sigma: 0.4,
        max_len: engine.model().max_seq,
        ..Default::default()
    };
    let reqs = spec.generate(n, seed);
    let rep = engine.serve(&reqs)?;
    println!(
        "served {} requests, {} tokens in {:.2}s  ({:.1} tok/s)",
        rep.completed, rep.output_tokens, rep.elapsed, rep.throughput
    );
    println!(
        "TPOT p50 {:.1} ms p99 {:.1} ms | attention {:.2}s expert {:.2}s coordinator {:.2}s",
        rep.tpot.median() * 1e3,
        rep.tpot.p99() * 1e3,
        rep.attn_time,
        rep.expert_time,
        rep.coord_time
    );
    Ok(())
}

fn cmd_m2n(args: &Args) -> Result<()> {
    let kind = match args.str_or("library", "megascale").to_lowercase().as_str() {
        "megascale" | "ours" => LibraryKind::MegaScale,
        "nccl" => LibraryKind::Nccl,
        "perftest" => LibraryKind::Perftest,
        other => bail!("unknown library {other}"),
    };
    let senders = args.usize_or("senders", 8)?;
    let receivers = args.usize_or("receivers", 8)?;
    let size_kib = args.usize_or("size-kib", 256)?;
    let stats = simulate_m2n(&M2nScenario {
        profile: LibraryProfile::of(kind),
        senders,
        receivers,
        msg_bytes: size_kib * 1024,
        rounds: args.usize_or("rounds", 1000)?,
        bidirectional: false,
        seed: args.u64_or("seed", 42)?,
    });
    println!(
        "{:?} M={senders} N={receivers} size={size_kib}KiB: \
         median {:.1} us  p99 {:.1} us  throughput {:.2} GB/s",
        kind,
        stats.latency.median() * 1e6,
        stats.latency.p99() * 1e6,
        stats.throughput / 1e9
    );
    Ok(())
}

fn cmd_hardware() -> Result<()> {
    println!(
        "{:<12} {:>6} {:>6} {:>9} {:>9} | {:>7} {:>9} {:>9}",
        "GPU", "price", "GB", "GB/s", "TFLOPS", "GB/$", "GB/s/$", "TFLOPS/$"
    );
    for g in gpu_catalog() {
        println!(
            "{:<12} {:>6.2} {:>6.0} {:>9.1} {:>9.1} | {:>7.1} {:>9.1} {:>9.1}",
            g.name,
            g.price,
            g.mem_gb,
            g.mem_bw_gbps,
            g.tflops,
            g.gb_per_cost(),
            g.bw_per_cost(),
            g.tflops_per_cost()
        );
    }
    Ok(())
}

/// Run the determinism & event-kernel invariant linter (`tools/msi-lint`)
/// over the tree. Exits nonzero on unwaived findings; `--json FILE` writes
/// the machine-readable report and `--waivers` prints the exception
/// inventory with its recorded reasons.
fn cmd_lint(args: &Args) -> Result<()> {
    let path = PathBuf::from(args.str_or("path", "rust/src"));
    let report = msi_lint::lint_paths(&[path.clone()])
        .with_context(|| format!("linting {}", path.display()))?;
    for f in report.active() {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
    }
    if args.flag("waivers") {
        for f in report.waived() {
            println!(
                "waived {}:{}: [{}] -- {}",
                f.file,
                f.line,
                f.rule,
                f.waiver.as_deref().unwrap_or("")
            );
        }
    }
    let active = report.active().count();
    println!(
        "msi-lint: {} files, {} active, {} waived",
        report.files,
        active,
        report.waived().count()
    );
    if let Some(p) = args.get("json") {
        std::fs::write(p, report.to_json()).with_context(|| format!("writing {p}"))?;
        println!("wrote lint report to {p}");
    }
    if active > 0 {
        bail!("msi lint: {active} unwaived finding(s)");
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let out = PathBuf::from(
        args.get("out")
            .ok_or_else(|| anyhow::anyhow!("--out is required"))?,
    );
    let trace = Trace::new(
        WorkloadSpec::default().generate(args.usize_or("requests", 1000)?, args.u64_or("seed", 42)?),
    );
    trace.save(&out)?;
    let s = trace.stats();
    println!(
        "wrote {} requests to {} (median in/out {}/{})",
        s.count,
        out.display(),
        s.median_input,
        s.median_output
    );
    Ok(())
}
