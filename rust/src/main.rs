//! `msi` — the MegaScale-Infer command-line launcher.
//!
//! ```text
//! msi plan      --model mixtral --attention-gpu ampere [--expert-gpu l40s]
//!               [--slo-ms 150] [--avg-seq 730] [--all]
//! msi simulate  --model mixtral --gpu ampere [--requests 512] [--baselines]
//! msi replay    [--trace t.jsonl | --requests 1000] --model mixtral
//!               --attention-gpu ampere [--expert-gpu l40s]
//!               [--hetero h20:l40s] [--rate 0] [--burst 0.0] [--skew 0]
//!               [--popularity-drift <s>] [--rebalance <s>] [--balance]
//!               [--tenants name:weight:slo_s,...] [--simnet]
//!               [--micro-batches m] [--seed 42] [--json report.json]
//! msi serve     --artifacts artifacts [--micro-batches 2] [--requests 16]
//!               (requires the `pjrt` feature)
//! msi m2n       --library megascale|nccl|perftest [--senders 8]
//!               [--receivers 8] [--size-kib 256] [--rounds 1000]
//! msi hardware
//! msi trace     --out trace.jsonl [--requests 1000] [--seed 42]
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use megascale_infer::baselines::{best_under_slo, minimal_deployment, BaselineKind};
use megascale_infer::config::{gpu_catalog, ClusterSpec, GpuKind, ModelConfig, NodeSpec};
use megascale_infer::coordinator::{RoutePolicy, RuntimeInstance};
use megascale_infer::m2n::{simulate_m2n, LibraryKind, LibraryProfile, M2nScenario};
use megascale_infer::plan::PlanSearcher;
#[cfg(feature = "pjrt")]
use megascale_infer::runtime::ServingEngine;
use megascale_infer::sim::cluster::{ClusterSim, ClusterSimConfig, ExpertPopularity, Transport};
use megascale_infer::util::cli::Args;
use megascale_infer::workload::{TenantClass, Trace, WorkloadSpec};

const USAGE: &str = "usage: msi <plan|simulate|replay|serve|m2n|hardware|trace> [--options]
run `msi help` or see README.md for details";

fn parse_model(name: &str) -> Result<ModelConfig> {
    Ok(match name.to_lowercase().as_str() {
        "mixtral" | "mixtral-8x22b" => ModelConfig::mixtral_8x22b(),
        "dbrx" => ModelConfig::dbrx(),
        "scaled-moe" | "scaled_moe" | "scaled" => ModelConfig::scaled_moe(),
        "tiny" => ModelConfig::tiny(),
        other => bail!("unknown model {other}"),
    })
}

fn parse_gpu(name: &str) -> Result<GpuKind> {
    Ok(match name.to_lowercase().as_str() {
        "ampere" | "a100" => GpuKind::Ampere80G,
        "h20" => GpuKind::H20,
        "l40s" => GpuKind::L40S,
        "a800" => GpuKind::A800,
        "h800" => GpuKind::H800,
        "l20" => GpuKind::L20,
        other => bail!("unknown gpu {other}"),
    })
}

fn main() -> Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &["all", "baselines", "balance", "simnet"],
    )?;
    match args.subcommand.as_str() {
        "plan" => cmd_plan(&args),
        "simulate" => cmd_simulate(&args),
        "replay" => cmd_replay(&args),
        #[cfg(feature = "pjrt")]
        "serve" => cmd_serve(&args),
        #[cfg(not(feature = "pjrt"))]
        "serve" => bail!(
            "`msi serve` needs the real-compute path: rebuild with \
             `--features pjrt` (see DESIGN.md § PJRT runtime)"
        ),
        "m2n" => cmd_m2n(&args),
        "hardware" => cmd_hardware(),
        "trace" => cmd_trace(&args),
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other}\n{USAGE}"),
    }
}

fn cmd_plan(args: &Args) -> Result<()> {
    let model = parse_model(&args.str_or("model", "mixtral"))?;
    let a = parse_gpu(&args.str_or("attention-gpu", "ampere"))?;
    let e = match args.get("expert-gpu") {
        Some(g) => parse_gpu(g)?,
        None => a,
    };
    let cluster = ClusterSpec {
        attention: NodeSpec {
            gpu: a,
            gpus_per_node: 8,
            nodes: None,
        },
        expert: NodeSpec {
            gpu: e,
            gpus_per_node: 8,
            nodes: None,
        },
    };
    let mut searcher = PlanSearcher::new(model, cluster, args.f64_or("avg-seq", 730.0)?);
    searcher.limits.slo = args.f64_or("slo-ms", 150.0)? / 1000.0;
    if args.flag("all") {
        for p in searcher.search_all() {
            println!("{}", p.to_json());
        }
    } else {
        match searcher.search() {
            Some(p) => println!("{}", p.to_json()),
            None => bail!("no feasible plan"),
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = parse_model(&args.str_or("model", "mixtral"))?;
    let cluster = ClusterSpec::homogeneous(parse_gpu(&args.str_or("gpu", "ampere"))?);
    let requests = args.usize_or("requests", 512)?;
    let seed = args.u64_or("seed", 42)?;
    let spec = WorkloadSpec::default();
    let searcher = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len());
    let plan = searcher
        .search()
        .ok_or_else(|| anyhow::anyhow!("no feasible plan"))?;
    let reqs = spec.generate(requests, seed);
    let inst = RuntimeInstance::new(model.clone(), cluster.clone(), plan.clone());
    let rep = inst.simulate(&reqs);
    println!(
        "MegaScale-Infer  plan: tp_a={} tp_e={} n_a={} m={} B={}",
        plan.tp_a, plan.tp_e, plan.n_a, plan.m, plan.global_batch
    );
    println!(
        "  throughput {:.1} tok/s | per-GPU {:.2} tok/s/GPU | TPOT p50 {:.1} ms p99 {:.1} ms",
        rep.throughput,
        rep.per_gpu_throughput,
        rep.tpot.median() * 1e3,
        rep.tpot.p99() * 1e3
    );
    if args.flag("baselines") {
        for kind in [BaselineKind::Vllm, BaselineKind::TrtLlm] {
            let dep = minimal_deployment(kind, &model, &cluster);
            if let Some(m) = best_under_slo(&dep, &model, &cluster, spec.avg_seq_len(), 0.150) {
                println!(
                    "{:>14}  tp={} pp={} B={} | per-GPU {:.2} tok/s/GPU | TPOT {:.1} ms",
                    kind.name(),
                    dep.tp,
                    dep.pp,
                    m.batch,
                    m.per_gpu_throughput,
                    m.tpot * 1e3
                );
            }
        }
    }
    Ok(())
}

/// Replay a trace (or a synthetic workload) through the event-driven
/// cluster engine: router → attention pool → gating/dispatch → M2N →
/// expert pool → ping-pong pipeline, on one virtual clock. Scenario knobs
/// cover heterogeneous pools (`--hetero`), multi-tenant traffic classes
/// with per-class SLOs (`--tenants`), and time-varying expert popularity
/// with periodic online re-balancing (`--popularity-drift`/`--rebalance`).
fn cmd_replay(args: &Args) -> Result<()> {
    let model = parse_model(&args.str_or("model", "mixtral"))?;
    // `--hetero attn:expert` is shorthand for the per-pool GPU flags.
    let (a, e) = match args.get("hetero") {
        Some(pair) => {
            let (a, e) = pair
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("--hetero expects <attn-gpu>:<expert-gpu>"))?;
            (parse_gpu(a)?, parse_gpu(e)?)
        }
        None => {
            let a = parse_gpu(&args.str_or("attention-gpu", "ampere"))?;
            let e = match args.get("expert-gpu") {
                Some(g) => parse_gpu(g)?,
                None => a,
            };
            (a, e)
        }
    };
    let cluster = ClusterSpec {
        attention: NodeSpec {
            gpu: a,
            gpus_per_node: 8,
            nodes: None,
        },
        expert: NodeSpec {
            gpu: e,
            gpus_per_node: 8,
            nodes: None,
        },
    };
    let seed = args.u64_or("seed", 42)?;
    let rate = args.f64_or("rate", 0.0)?;
    let tenants = match args.get("tenants") {
        Some(spec) => TenantClass::parse_list(spec)?,
        None => Vec::new(),
    };
    let spec = WorkloadSpec {
        arrival_rate: (rate > 0.0).then_some(rate),
        burst_sigma: args.f64_or("burst", 0.0)?,
        tenants: tenants.clone(),
        ..Default::default()
    };
    let requests = match args.get("trace") {
        Some(path) => Trace::load(&PathBuf::from(path))?.requests,
        None => spec.generate(args.usize_or("requests", 1000)?, seed),
    };

    // Size the plan for the workload actually being replayed, not the
    // synthetic defaults.
    let avg_seq = {
        let s = Trace::new(requests.clone()).stats();
        if s.count == 0 {
            spec.avg_seq_len()
        } else {
            s.avg_seq
        }
    };
    let searcher = PlanSearcher::new(model.clone(), cluster.clone(), avg_seq);
    let mut plan = searcher
        .search()
        .ok_or_else(|| anyhow::anyhow!("no feasible plan"))?;
    if let Some(m) = args.get("micro-batches") {
        plan.m = m.parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--micro-batches={m} not an integer"))?
            .max(1);
    }

    let skew = args.f64_or("skew", 0.0)?;
    let drift = args.f64_or("popularity-drift", 0.0)?;
    if drift > 0.0 && skew <= 0.0 {
        bail!("--popularity-drift needs a skewed popularity: add --skew <alpha>");
    }
    let popularity = if skew <= 0.0 {
        ExpertPopularity::Uniform
    } else if drift > 0.0 {
        ExpertPopularity::ZipfDrifting {
            alpha: skew,
            period: drift,
        }
    } else if args.flag("balance") {
        ExpertPopularity::ZipfBalanced(skew)
    } else {
        ExpertPopularity::Zipf(skew)
    };
    // Periodic online re-balancing: explicit interval, or a quarter of the
    // drift period when `--balance` rides along with drifting popularity.
    let rebalance_period = match args.get("rebalance") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--rebalance={v} not a number"))?,
        ),
        None => (drift > 0.0 && args.flag("balance")).then_some(drift / 4.0),
    };
    let transport = if args.flag("simnet") {
        Transport::Simnet(LibraryKind::MegaScale)
    } else {
        Transport::Analytic
    };

    println!(
        "replay: {} requests | plan tp_a={} tp_e={} n_a={} m={} B={}",
        requests.len(),
        plan.tp_a,
        plan.tp_e,
        plan.n_a,
        plan.m,
        plan.global_batch
    );
    let cfg = ClusterSimConfig {
        model,
        cluster,
        plan,
        route: RoutePolicy::LeastLoaded,
        popularity,
        transport,
        seed,
        tenants,
        rebalance_period,
    };
    let plan_json = cfg.plan.to_json();
    let report = ClusterSim::new(cfg).run(&requests);
    println!("{}", report.summary());
    if let Some(path) = args.get("json") {
        let payload = megascale_infer::util::json::Json::obj()
            .set("plan", plan_json)
            .set("report", report.to_json());
        std::fs::write(path, format!("{payload}\n"))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote JSON report to {path}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let m = args.usize_or("micro-batches", 2)?;
    let n = args.usize_or("requests", 16)?;
    let seed = args.u64_or("seed", 42)?;
    let mut engine = ServingEngine::load(&artifacts, m)?;
    let spec = WorkloadSpec {
        median_input: 12.0,
        median_output: 16.0,
        sigma: 0.4,
        max_len: engine.model().max_seq,
        ..Default::default()
    };
    let reqs = spec.generate(n, seed);
    let rep = engine.serve(&reqs)?;
    println!(
        "served {} requests, {} tokens in {:.2}s  ({:.1} tok/s)",
        rep.completed, rep.output_tokens, rep.elapsed, rep.throughput
    );
    println!(
        "TPOT p50 {:.1} ms p99 {:.1} ms | attention {:.2}s expert {:.2}s coordinator {:.2}s",
        rep.tpot.median() * 1e3,
        rep.tpot.p99() * 1e3,
        rep.attn_time,
        rep.expert_time,
        rep.coord_time
    );
    Ok(())
}

fn cmd_m2n(args: &Args) -> Result<()> {
    let kind = match args.str_or("library", "megascale").to_lowercase().as_str() {
        "megascale" | "ours" => LibraryKind::MegaScale,
        "nccl" => LibraryKind::Nccl,
        "perftest" => LibraryKind::Perftest,
        other => bail!("unknown library {other}"),
    };
    let senders = args.usize_or("senders", 8)?;
    let receivers = args.usize_or("receivers", 8)?;
    let size_kib = args.usize_or("size-kib", 256)?;
    let stats = simulate_m2n(&M2nScenario {
        profile: LibraryProfile::of(kind),
        senders,
        receivers,
        msg_bytes: size_kib * 1024,
        rounds: args.usize_or("rounds", 1000)?,
        bidirectional: false,
        seed: args.u64_or("seed", 42)?,
    });
    println!(
        "{:?} M={senders} N={receivers} size={size_kib}KiB: \
         median {:.1} us  p99 {:.1} us  throughput {:.2} GB/s",
        kind,
        stats.latency.median() * 1e6,
        stats.latency.p99() * 1e6,
        stats.throughput / 1e9
    );
    Ok(())
}

fn cmd_hardware() -> Result<()> {
    println!(
        "{:<12} {:>6} {:>6} {:>9} {:>9} | {:>7} {:>9} {:>9}",
        "GPU", "price", "GB", "GB/s", "TFLOPS", "GB/$", "GB/s/$", "TFLOPS/$"
    );
    for g in gpu_catalog() {
        println!(
            "{:<12} {:>6.2} {:>6.0} {:>9.1} {:>9.1} | {:>7.1} {:>9.1} {:>9.1}",
            g.name,
            g.price,
            g.mem_gb,
            g.mem_bw_gbps,
            g.tflops,
            g.gb_per_cost(),
            g.bw_per_cost(),
            g.tflops_per_cost()
        );
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let out = PathBuf::from(
        args.get("out")
            .ok_or_else(|| anyhow::anyhow!("--out is required"))?,
    );
    let trace = Trace::new(
        WorkloadSpec::default().generate(args.usize_or("requests", 1000)?, args.u64_or("seed", 42)?),
    );
    trace.save(&out)?;
    let s = trace.stats();
    println!(
        "wrote {} requests to {} (median in/out {}/{})",
        s.count,
        out.display(),
        s.median_input,
        s.median_output
    );
    Ok(())
}
