//! Gating: softmax over router logits and top-k expert selection with
//! normalized weights (paper §2.2). This is the CPU-side mirror of the fused
//! Pallas gating kernel (L1); the PJRT serving path obtains logits from the
//! compiled gating executable and this module turns them into a dispatch
//! decision. The virtual-time path uses it directly on synthetic logits.
//!
//! Hot path (§Perf): selection is an O(E·k) partial scan on raw logits (no
//! sort, no allocation per row), and — because the top-k weights are
//! re-normalized over the selected experts — the full-softmax denominator
//! cancels, so `exp()` runs only on the k selected logits instead of all E.

/// Gating decision for a batch of tokens, flat row-major `[batch, k]`.
#[derive(Debug, Clone, PartialEq)]
pub struct GatingOutput {
    /// Experts selected per token.
    pub k: usize,
    /// `[batch * k]` selected expert ids, by descending router weight.
    pub experts: Vec<u16>,
    /// `[batch * k]` normalized weights (sum to 1 over each row).
    pub weights: Vec<f32>,
}

impl GatingOutput {
    /// Number of token rows in the decision.
    pub fn batch(&self) -> usize {
        if self.k == 0 {
            0
        } else {
            self.experts.len() / self.k
        }
    }

    /// Selected expert ids of token `t`.
    pub fn experts_of(&self, t: usize) -> &[u16] {
        &self.experts[t * self.k..(t + 1) * self.k]
    }

    /// Normalized weights of token `t`.
    pub fn weights_of(&self, t: usize) -> &[f32] {
        &self.weights[t * self.k..(t + 1) * self.k]
    }

    /// Number of tokens routed to each expert (the load vector `a_i` used by
    /// the load balancer).
    pub fn expert_loads(&self, num_experts: usize) -> Vec<usize> {
        let mut loads = vec![0usize; num_experts];
        for &e in &self.experts {
            loads[e as usize] += 1;
        }
        loads
    }
}

/// Compute top-k selection + renormalized softmax weights over per-token
/// router logits.
///
/// `logits` is row-major `[batch, num_experts]`. Ties break toward the lower
/// expert id (deterministic). Weights are the softmax probabilities of the
/// selected experts renormalized to sum to 1, matching Mixtral/DBRX routers.
pub fn softmax_topk(logits: &[f32], num_experts: usize, k: usize) -> GatingOutput {
    assert!(k >= 1 && k <= num_experts && num_experts <= u16::MAX as usize);
    assert_eq!(logits.len() % num_experts, 0);
    let batch = logits.len() / num_experts;
    let mut experts = vec![0u16; batch * k];
    let mut weights = vec![0f32; batch * k];

    // Per-row scratch: the current top-k (logit, id), kept sorted descending
    // by (logit, -id). Small k => insertion into a fixed array beats a sort.
    let mut top: Vec<(f32, u16)> = vec![(0.0, 0); k];

    for b in 0..batch {
        let row = &logits[b * num_experts..(b + 1) * num_experts];

        // Partial selection scan.
        let mut filled = 0usize;
        for (e, &l) in row.iter().enumerate() {
            let cand = (l, e as u16);
            if filled < k {
                // Insert into the sorted prefix.
                let mut i = filled;
                while i > 0 && better(cand, top[i - 1]) {
                    top[i] = top[i - 1];
                    i -= 1;
                }
                top[i] = cand;
                filled += 1;
            } else if better(cand, top[k - 1]) {
                let mut i = k - 1;
                while i > 0 && better(cand, top[i - 1]) {
                    top[i] = top[i - 1];
                    i -= 1;
                }
                top[i] = cand;
            }
        }

        // Renormalized softmax over the selected logits only: the full
        // denominator cancels, so exp() is needed just k times.
        let mx = top[0].0;
        let out_e = &mut experts[b * k..(b + 1) * k];
        let out_w = &mut weights[b * k..(b + 1) * k];
        let mut denom = 0f32;
        for i in 0..k {
            let w = (top[i].0 - mx).exp();
            out_e[i] = top[i].1;
            out_w[i] = w;
            denom += w;
        }
        let inv = 1.0 / denom;
        for w in out_w.iter_mut() {
            *w *= inv;
        }
    }
    GatingOutput {
        k,
        experts,
        weights,
    }
}

/// Ordering for selection: higher logit wins; ties go to the lower id.
#[inline]
fn better(a: (f32, u16), b: (f32, u16)) -> bool {
    a.0 > b.0 || (a.0 == b.0 && a.1 < b.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_top_experts() {
        // one token, 4 experts, logits favour 2 then 0.
        let logits = vec![1.0, -1.0, 3.0, 0.0];
        let g = softmax_topk(&logits, 4, 2);
        assert_eq!(g.experts_of(0), &[2, 0]);
        assert!(g.weights_of(0)[0] > g.weights_of(0)[1]);
    }

    #[test]
    fn weights_normalized() {
        let logits = vec![0.3, 0.1, -0.5, 2.0, 0.0, 0.0, 1.0, 1.0];
        let g = softmax_topk(&logits, 4, 3);
        for t in 0..2 {
            let s: f32 = g.weights_of(t).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn matches_full_softmax_renormalized() {
        // Cross-check against the straightforward full-softmax formula.
        let logits: Vec<f32> = (0..6 * 16)
            .map(|i| ((i * 2654435761u64 as usize) % 97) as f32 * 0.07)
            .collect();
        let g = softmax_topk(&logits, 16, 4);
        for t in 0..6 {
            let row = &logits[t * 16..(t + 1) * 16];
            let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&x| (x - mx).exp()).collect();
            let mut idx: Vec<usize> = (0..16).collect();
            idx.sort_by(|&a, &b| exps[b].total_cmp(&exps[a]).then(a.cmp(&b)));
            let denom: f32 = idx[..4].iter().map(|&e| exps[e]).sum();
            for (i, &e) in idx[..4].iter().enumerate() {
                assert_eq!(g.experts_of(t)[i] as usize, e, "token {t} slot {i}");
                let want = exps[e] / denom;
                let got = g.weights_of(t)[i];
                assert!((got - want).abs() < 1e-6, "token {t}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn uniform_logits_tie_break_low_id() {
        let logits = vec![0.0; 8];
        let g = softmax_topk(&logits, 8, 2);
        assert_eq!(g.experts_of(0), &[0, 1]);
        assert!((g.weights_of(0)[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn expert_loads_count_topk_fanout() {
        let logits = vec![
            1.0, 0.0, 0.0, 0.0, // token 0 -> experts {0, 1..}
            1.0, 0.9, 0.0, 0.0, // token 1 -> experts {0, 1}
        ];
        let g = softmax_topk(&logits, 4, 2);
        let loads = g.expert_loads(4);
        assert_eq!(loads.iter().sum::<usize>(), 4); // 2 tokens * k=2
        assert_eq!(loads[0], 2);
    }

    #[test]
    fn k_equals_num_experts() {
        let logits = vec![0.5, 1.5, -0.5];
        let g = softmax_topk(&logits, 3, 3);
        assert_eq!(g.experts_of(0).len(), 3);
        let s: f32 = g.weights_of(0).iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        // Descending weight order.
        assert_eq!(g.experts_of(0)[0], 1);
    }
}
