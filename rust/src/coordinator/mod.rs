//! The MegaScale-Infer runtime instance (paper §3, Figure 3): disaggregated
//! attention and expert node pools, ping-pong pipeline scheduling, token
//! dispatch/aggregation, KV-cache management, continuous batching, and
//! expert load balancing.
//!
//! The scheduling/routing/batching logic here is backend-agnostic:
//!
//! * the **virtual-time** driver ([`pingpong`], [`instance`]) advances a
//!   discrete-event clock using the analytical [`crate::perf_model`] — this
//!   regenerates every end-to-end figure of the paper at cluster scale;
//! * the **real** driver (`crate::runtime::ServingEngine`, behind the
//!   `pjrt` feature) executes the
//!   AOT-compiled JAX/Pallas artifacts through PJRT using the *same*
//!   dispatch, gating, KV-cache and batching code.

pub mod batch;
pub mod dispatch;
pub mod gating;
pub mod instance;
pub mod kv_cache;
pub mod load_balance;
pub mod pingpong;
pub mod router;
pub mod scheduler;

pub use batch::{ActiveRequest, DecodeBatch};
pub use dispatch::{build_dispatch, combine_expert_outputs, gather_expert_input, DispatchPlan};
pub use gating::{softmax_topk, GatingOutput};
pub use instance::{ExpertTraffic, InstanceReport, RuntimeInstance};
pub use kv_cache::{BlockAllocator, KvCacheConfig};
pub use load_balance::{balance_experts, ExpertPlacement};
pub use pingpong::{PingPongEngine, PingPongSim, PipelineStats, StageTimes};
pub use router::{RoutePolicy, Router};
pub use scheduler::{ContinuousBatcher, SchedulerConfig};
