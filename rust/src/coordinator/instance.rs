//! A full MegaScale-Infer runtime instance on virtual time: continuous
//! batching + ping-pong pipeline + the analytical perf model, simulating the
//! decode phase of a workload end to end (the engine behind Figures 8, 9,
//! 12, 13).

use crate::config::{ClusterSpec, ModelConfig};
use crate::metrics::Histogram;
use crate::perf_model::PerfModel;
use crate::plan::DeploymentPlan;
use crate::sim::SimRng;
use crate::workload::Request;

use super::kv_cache::{BlockAllocator, KvCacheConfig};
use super::load_balance::balance_experts;
use super::pingpong::PingPongSim;
use super::scheduler::{ContinuousBatcher, SchedulerConfig};

/// Expert-popularity model for the instance simulation (paper §6 "Load
/// balance": real traffic concentrates on hot experts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExpertTraffic {
    /// Tokens spread evenly over experts (the perf-model assumption).
    Uniform,
    /// Zipf-like skew with the given exponent (larger = more concentrated)
    /// and static one-expert-per-node placement: the expert stage runs at
    /// the pace of the hottest node.
    Skewed(f64),
    /// Same skew, but the §6 greedy redundancy balancer re-places experts
    /// every iteration from the observed loads.
    SkewedBalanced(f64),
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct InstanceReport {
    /// Output tokens generated.
    pub tokens: u64,
    /// Requests completed.
    pub completed: u64,
    /// Virtual time elapsed (seconds).
    pub elapsed: f64,
    /// Output tokens per second (instance).
    pub throughput: f64,
    /// Output tokens per second per GPU.
    pub per_gpu_throughput: f64,
    /// Output tokens per second per normalized dollar.
    pub throughput_per_dollar: f64,
    /// Time-per-output-token distribution (per decode iteration).
    pub tpot: Histogram,
    /// Mean attention / expert stage utilization over the run.
    pub attn_utilization: f64,
    /// Mean expert stage utilization over the run.
    pub expert_utilization: f64,
}

/// Virtual-time serving instance.
pub struct RuntimeInstance {
    /// The model being served.
    pub model: ModelConfig,
    /// Hardware the instance runs on.
    pub cluster: ClusterSpec,
    /// Deployment shape (TP degrees, pool sizes, micro-batches).
    pub plan: DeploymentPlan,
    /// Expert-popularity model (default Uniform).
    pub traffic: ExpertTraffic,
    /// Seed for the skewed-traffic draws.
    pub seed: u64,
}

impl RuntimeInstance {
    /// An instance with uniform expert traffic and a fixed default seed.
    pub fn new(model: ModelConfig, cluster: ClusterSpec, plan: DeploymentPlan) -> Self {
        Self {
            model,
            cluster,
            plan,
            traffic: ExpertTraffic::Uniform,
            seed: 0,
        }
    }

    /// Builder: set the expert-popularity model.
    pub fn with_traffic(mut self, traffic: ExpertTraffic, seed: u64) -> Self {
        self.traffic = traffic;
        self.seed = seed;
        self
    }

    /// Effective per-expert-node micro-batch size for this iteration: the
    /// *hottest* node's share under the traffic model (the expert stage
    /// finishes when its slowest node does).
    fn effective_b_e(&self, rng: &mut SimRng, tokens: f64, m: usize) -> f64 {
        let e = self.model.experts;
        let k = self.model.top_k as f64;
        let dispatched = tokens * k;
        match self.traffic {
            ExpertTraffic::Uniform => dispatched / (m * e) as f64,
            ExpertTraffic::Skewed(alpha) | ExpertTraffic::SkewedBalanced(alpha) => {
                // Zipf-like popularity, re-drawn per iteration with jitter:
                // p_i ∝ (i+1)^-alpha over a random expert permutation.
                let mut weights: Vec<f64> =
                    (0..e).map(|i| ((i + 1) as f64).powf(-alpha)).collect();
                // Random rotation so the hot expert moves over time.
                let rot = rng.below(e);
                weights.rotate_left(rot);
                let sum: f64 = weights.iter().sum();
                let loads: Vec<f64> =
                    weights.iter().map(|w| dispatched * w / sum).collect();
                let per_node_max = match self.traffic {
                    ExpertTraffic::SkewedBalanced(_) => {
                        // §6 greedy redundancy placement over E nodes; the
                        // cold floor is one micro-batch worth of weight
                        // loads, expressed in tokens-equivalent.
                        let cold = dispatched / (e as f64) * 0.1;
                        balance_experts(&loads, e, cold).makespan
                    }
                    _ => loads.iter().copied().fold(0.0, f64::max),
                };
                per_node_max / m as f64
            }
        }
    }

    /// KV allocator sized per attention node from the Eq. 8 budget.
    fn kv_allocator(&self) -> BlockAllocator {
        let gpu = self.cluster.attention_gpu();
        let budget = self.plan.tp_a as f64 * gpu.mem_bytes() - self.model.attn_param_bytes();
        // Per attention node; tokens cached on the node serving them.
        BlockAllocator::new(KvCacheConfig::from_budget(
            budget.max(0.0) * self.plan.n_a as f64,
            self.model.kv_bytes_per_token(),
            16,
        ))
    }

    /// Simulate decoding `requests` to completion (closed loop if arrivals
    /// are all 0, open loop otherwise). Returns aggregate metrics.
    pub fn simulate(&self, requests: &[Request]) -> InstanceReport {
        let mut batcher = ContinuousBatcher::new(SchedulerConfig {
            max_batch: self.plan.global_batch,
        });
        let mut kv = self.kv_allocator();
        let mut sorted: Vec<Request> = requests.to_vec();
        sorted.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for r in sorted {
            batcher.submit(r);
        }

        let mut rng = SimRng::new(self.seed);
        let mut now = 0.0f64;
        let mut tokens = 0u64;
        let mut completed = 0u64;
        let mut tpot = Histogram::new();
        let mut attn_util_sum = 0.0;
        let mut expert_util_sum = 0.0;
        let mut iters = 0u64;

        while batcher.has_work() {
            batcher.admit(&mut kv, now);
            if batcher.batch.is_empty() {
                // Idle: jump to the next arrival.
                now = batcher
                    .waiting
                    .front()
                    .map(|r| r.arrival)
                    .unwrap_or(now)
                    .max(now + 1e-9);
                continue;
            }

            let b = batcher.batch.len() as f64;
            let avg_seq = batcher.batch.avg_seq_len();
            let pm = PerfModel::new(
                &self.model,
                &self.cluster,
                self.plan.tp_a,
                self.plan.tp_e,
                avg_seq,
            );
            let m = self.plan.m;
            let b_a = b / (m * self.plan.n_a) as f64;
            let b_e = self.effective_b_e(&mut rng, b, m);
            let stats = PingPongSim {
                t_a: pm.t_a(b_a),
                t_e: pm.t_e(b_e),
                t_c: pm.t_c(b_a, b_e),
                m,
                layers: self.model.layers,
            }
            .run();

            now += stats.total_time;
            tpot.record(stats.total_time);
            attn_util_sum += stats.attn_utilization;
            expert_util_sum += stats.expert_utilization;
            iters += 1;
            tokens += batcher.batch.len() as u64;
            completed += batcher.complete_iteration(&mut kv).len() as u64;
        }

        // This virtual-time instance simulates the DECODE pools only, so
        // its per-GPU metric divides by the decode instance (the prefill
        // pool lives in the cluster engine's report, not here).
        let gpus = self.plan.decode_gpus() as f64;
        let cost = self.cluster.attention_gpu().price * (self.plan.tp_a * self.plan.n_a) as f64
            + self.cluster.expert_gpu().price * (self.plan.tp_e * self.plan.n_e) as f64;
        let throughput = if now > 0.0 { tokens as f64 / now } else { 0.0 };
        InstanceReport {
            tokens,
            completed,
            elapsed: now,
            throughput,
            per_gpu_throughput: throughput / gpus,
            throughput_per_dollar: throughput / cost,
            tpot,
            attn_utilization: if iters > 0 {
                attn_util_sum / iters as f64
            } else {
                0.0
            },
            expert_utilization: if iters > 0 {
                expert_util_sum / iters as f64
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuKind;
    use crate::plan::PlanSearcher;
    use crate::workload::WorkloadSpec;

    fn setup() -> (ModelConfig, ClusterSpec, DeploymentPlan) {
        let model = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
            .search()
            .expect("plan");
        (model, cluster, plan)
    }

    #[test]
    fn completes_all_requests() {
        let (model, cluster, plan) = setup();
        let inst = RuntimeInstance::new(model, cluster, plan);
        let reqs = WorkloadSpec {
            median_output: 20.0,
            ..Default::default()
        }
        .generate(64, 11);
        let rep = inst.simulate(&reqs);
        assert_eq!(rep.completed, 64);
        let expected_tokens: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        assert_eq!(rep.tokens, expected_tokens);
        assert!(rep.throughput > 0.0);
    }

    #[test]
    fn sim_tpot_close_to_plan_prediction() {
        // At the planned batch size the virtual-time TPOT should be within
        // ~25% of the analytical SIMULATE value (batch composition varies).
        let (model, cluster, plan) = setup();
        let predicted = plan.metrics.tpot;
        let inst = RuntimeInstance::new(model, cluster, plan.clone());
        // Saturate the batch with long-output requests.
        let reqs = WorkloadSpec {
            median_output: 50.0,
            sigma: 0.05,
            ..Default::default()
        }
        .generate(plan.global_batch, 5);
        let rep = inst.simulate(&reqs);
        let measured = rep.tpot.median();
        let rel = (measured - predicted).abs() / predicted;
        assert!(
            rel < 0.25,
            "sim TPOT {measured} vs plan {predicted} (rel {rel})"
        );
    }

    #[test]
    fn skew_hurts_and_balancing_recovers() {
        // Paper §6: hot experts bottleneck the expert stage; greedy
        // redundancy placement recovers most of the loss.
        // Saturate the planned batch so the experts run compute-bound —
        // at small batches the weight-load floor hides imbalance entirely
        // (itself a correct prediction of the model).
        let (model, cluster, plan) = setup();
        let reqs = WorkloadSpec {
            median_output: 25.0,
            sigma: 0.1,
            ..Default::default()
        }
        .generate(plan.global_batch, 3);
        let run = |traffic| {
            RuntimeInstance::new(model.clone(), cluster.clone(), plan.clone())
                .with_traffic(traffic, 9)
                .simulate(&reqs)
                .throughput
        };
        let uniform = run(ExpertTraffic::Uniform);
        let skewed = run(ExpertTraffic::Skewed(1.0));
        let balanced = run(ExpertTraffic::SkewedBalanced(1.0));
        assert!(
            skewed < uniform * 0.8,
            "skew should hurt: {skewed} vs {uniform}"
        );
        assert!(
            balanced > skewed * 1.2,
            "balancing should recover: {balanced} vs {skewed}"
        );
        assert!(balanced <= uniform * 1.05, "cannot beat uniform");
    }

    #[test]
    fn utilization_high_at_planned_point() {
        let (model, cluster, plan) = setup();
        let inst = RuntimeInstance::new(model, cluster, plan.clone());
        let reqs = WorkloadSpec {
            median_output: 30.0,
            sigma: 0.05,
            ..Default::default()
        }
        .generate(plan.global_batch, 7);
        let rep = inst.simulate(&reqs);
        // The searched plan balances T_a ≈ T_e; both stages should be busy.
        assert!(rep.attn_utilization > 0.5, "{}", rep.attn_utilization);
        assert!(rep.expert_utilization > 0.35, "{}", rep.expert_utilization);
    }
}
