//! Paged KV-cache management for attention nodes (vLLM-style block
//! allocator). Attention nodes own all KV state in the disaggregated
//! architecture (§3); the allocator tracks block budgets so the scheduler
//! can enforce the Eq. 8 memory constraint online.

use std::collections::BTreeMap;

/// Allocator configuration.
#[derive(Debug, Clone)]
pub struct KvCacheConfig {
    /// Tokens per block (vLLM default 16).
    pub block_size: usize,
    /// Total blocks available on this attention node.
    pub num_blocks: usize,
}

impl KvCacheConfig {
    /// Size the allocator from hardware: GPU memory left after parameters,
    /// divided by per-token KV bytes.
    pub fn from_budget(bytes_budget: f64, kv_bytes_per_token: f64, block_size: usize) -> Self {
        let tokens = (bytes_budget / kv_bytes_per_token).max(0.0) as usize;
        Self {
            block_size,
            num_blocks: tokens / block_size,
        }
    }
}

/// Block-granular KV cache allocator.
///
/// Invariants (exercised by proptests in `rust/tests/proptests.rs`):
/// free + allocated == total; no block is owned twice; freeing a request
/// returns exactly the blocks it held.
#[derive(Debug)]
pub struct BlockAllocator {
    config: KvCacheConfig,
    free: Vec<u32>,
    // BTreeMap, not HashMap: request iteration order feeds scheduler
    // decisions and reports, and must not depend on hasher seeding.
    owned: BTreeMap<u64, Vec<u32>>,
    /// Tokens stored per request (to size partial blocks).
    tokens: BTreeMap<u64, usize>,
}

impl BlockAllocator {
    /// An allocator with `config.num_blocks` free blocks.
    pub fn new(config: KvCacheConfig) -> Self {
        let free = (0..config.num_blocks as u32).rev().collect();
        Self {
            config,
            free,
            owned: BTreeMap::new(),
            tokens: BTreeMap::new(),
        }
    }

    /// Blocks currently free.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently allocated.
    pub fn allocated_blocks(&self) -> usize {
        self.config.num_blocks - self.free.len()
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.config.block_size)
    }

    /// Can a request with `tokens` of context be admitted?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Admit a request with an initial context of `tokens`. Returns false
    /// (and allocates nothing) if blocks are insufficient or the id exists.
    pub fn admit(&mut self, request_id: u64, tokens: usize) -> bool {
        if self.owned.contains_key(&request_id) || !self.can_admit(tokens) {
            return false;
        }
        let need = self.blocks_for(tokens);
        let blocks: Vec<u32> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.owned.insert(request_id, blocks);
        self.tokens.insert(request_id, tokens);
        true
    }

    /// Append one decoded token; may allocate a new block. Returns false if
    /// out of memory (caller must preempt).
    pub fn append_token(&mut self, request_id: u64) -> bool {
        let Some(tokens) = self.tokens.get_mut(&request_id) else {
            return false;
        };
        *tokens += 1;
        let need = tokens.div_ceil(self.config.block_size);
        let blocks = self.owned.get_mut(&request_id).unwrap();
        if need > blocks.len() {
            match self.free.pop() {
                Some(b) => blocks.push(b),
                None => {
                    // Roll back the token count so state stays consistent.
                    *self.tokens.get_mut(&request_id).unwrap() -= 1;
                    return false;
                }
            }
        }
        true
    }

    /// Extra blocks a request holding `tokens` of context needs to grow by
    /// `k` more tokens. Used by the macro-step span precheck: summing this
    /// over a decode batch against [`BlockAllocator::free_blocks`] proves
    /// `k` iterations of appends cannot hit out-of-memory.
    pub fn extra_blocks_for(&self, tokens: usize, k: usize) -> usize {
        self.blocks_for(tokens + k) - self.blocks_for(tokens)
    }

    /// Append `k` decoded tokens at once, topping the request's block list
    /// up to the new requirement. Equivalent to `k` successful
    /// [`BlockAllocator::append_token`] calls; returns false (allocating
    /// and appending nothing) if the request is unknown or the free list
    /// cannot cover the growth — callers precheck with
    /// [`BlockAllocator::extra_blocks_for`] so this cannot fail mid-span.
    // msi-lint: hot
    pub fn bulk_append(&mut self, request_id: u64, k: usize) -> bool {
        let Some(tokens) = self.tokens.get_mut(&request_id) else {
            return false;
        };
        let need = (*tokens + k).div_ceil(self.config.block_size);
        let blocks = self.owned.get_mut(&request_id).unwrap();
        if need > blocks.len() && need - blocks.len() > self.free.len() {
            return false;
        }
        *tokens += k;
        while blocks.len() < need {
            blocks.push(self.free.pop().unwrap());
        }
        true
    }

    /// Release all blocks of a finished/preempted request.
    pub fn release(&mut self, request_id: u64) -> usize {
        let blocks = self.owned.remove(&request_id).unwrap_or_default();
        self.tokens.remove(&request_id);
        let n = blocks.len();
        self.free.extend(blocks);
        n
    }

    /// Tokens currently cached for a request.
    pub fn tokens_of(&self, request_id: u64) -> Option<usize> {
        self.tokens.get(&request_id).copied()
    }

    /// Requests currently holding blocks.
    pub fn num_requests(&self) -> usize {
        self.owned.len()
    }

    /// Ids of requests currently holding blocks, in ascending id order —
    /// the deterministic iteration order any report-affecting caller
    /// (preemption sweeps, leak accounting) must use.
    pub fn request_ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.owned.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc(blocks: usize) -> BlockAllocator {
        BlockAllocator::new(KvCacheConfig {
            block_size: 16,
            num_blocks: blocks,
        })
    }

    #[test]
    fn admit_and_release_conserves_blocks() {
        let mut a = alloc(10);
        assert!(a.admit(1, 33)); // 3 blocks
        assert_eq!(a.free_blocks(), 7);
        assert!(a.admit(2, 16)); // 1 block
        assert_eq!(a.free_blocks(), 6);
        assert_eq!(a.release(1), 3);
        assert_eq!(a.free_blocks(), 9);
        assert_eq!(a.release(2), 1);
        assert_eq!(a.free_blocks(), 10);
    }

    #[test]
    fn append_allocates_at_block_boundary() {
        let mut a = alloc(4);
        assert!(a.admit(7, 16)); // exactly 1 block full
        assert_eq!(a.allocated_blocks(), 1);
        assert!(a.append_token(7)); // 17th token -> new block
        assert_eq!(a.allocated_blocks(), 2);
        for _ in 0..15 {
            assert!(a.append_token(7)); // fill block 2
        }
        assert_eq!(a.allocated_blocks(), 2);
        assert!(a.append_token(7));
        assert_eq!(a.allocated_blocks(), 3);
    }

    #[test]
    fn oom_on_append_rolls_back() {
        let mut a = alloc(1);
        assert!(a.admit(1, 16));
        assert!(!a.append_token(1), "no block available");
        assert_eq!(a.tokens_of(1), Some(16), "token count rolled back");
    }

    #[test]
    fn rejects_duplicate_and_oversized() {
        let mut a = alloc(2);
        assert!(a.admit(1, 16));
        assert!(!a.admit(1, 16), "duplicate id");
        assert!(!a.admit(2, 33), "needs 3 blocks, 1 free");
        assert!(a.admit(3, 10));
    }

    #[test]
    fn request_iteration_order_is_sorted_and_insertion_independent() {
        // Determinism regression for the nondeterministic-iteration lint
        // fix: iteration order is ascending id, regardless of the order
        // (or history) of admissions.
        let mut a = alloc(64);
        for id in [9u64, 2, 7, 1, 8, 3] {
            assert!(a.admit(id, 16));
        }
        assert_eq!(a.request_ids().collect::<Vec<_>>(), vec![1, 2, 3, 7, 8, 9]);
        a.release(7);
        let mut b = alloc(64);
        for id in [1u64, 2, 3, 8, 9] {
            assert!(b.admit(id, 16));
        }
        assert_eq!(
            a.request_ids().collect::<Vec<_>>(),
            b.request_ids().collect::<Vec<_>>(),
            "same live set, same order, different histories"
        );
    }

    #[test]
    fn bulk_append_matches_repeated_append() {
        let mut a = alloc(16);
        let mut b = alloc(16);
        assert!(a.admit(1, 13));
        assert!(b.admit(1, 13));
        for _ in 0..37 {
            assert!(a.append_token(1));
        }
        assert_eq!(b.extra_blocks_for(13, 37), 3);
        assert!(b.bulk_append(1, 37));
        assert_eq!(a.tokens_of(1), b.tokens_of(1));
        assert_eq!(a.allocated_blocks(), b.allocated_blocks());
        assert_eq!(a.free_blocks(), b.free_blocks());
    }

    #[test]
    fn bulk_append_refuses_oversized_growth() {
        let mut a = alloc(2);
        assert!(a.admit(1, 16));
        assert!(!a.bulk_append(1, 17), "needs 2 extra blocks, 1 free");
        assert_eq!(a.tokens_of(1), Some(16), "nothing appended");
        assert_eq!(a.allocated_blocks(), 1, "nothing allocated");
        assert!(a.bulk_append(1, 16));
        assert_eq!(a.allocated_blocks(), 2);
    }

    #[test]
    fn from_budget_sizing() {
        // 10 GB budget, 100 KB/token, 16-token blocks -> 100k tokens -> 6250 blocks.
        let c = KvCacheConfig::from_budget(10e9, 100e3, 16);
        assert_eq!(c.num_blocks, 6250);
    }
}
