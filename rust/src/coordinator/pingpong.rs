//! Ping-pong pipeline parallelism — thin scheduling-policy layers over the
//! shared event core (paper §4.1, Figure 4).
//!
//! The actual event machine lives in [`crate::sim::pipeline`]: ONE
//! implementation of the micro-batch shuttle, also embedded by the
//! trace-driven [`crate::sim::engine::ClusterEngine`] on its global event
//! queue. This module keeps the two historical entry points as thin layers
//! over that core:
//!
//! * [`PingPongSim`] — constant stage times, the closed-form ablation
//!   driver (Figures 12/13);
//! * [`PingPongEngine`] — a *stepwise* policy taking a per-(micro-batch,
//!   layer) [`StageTimes`] provider, so callers like
//!   [`crate::plan::simulate_plan_des`] can drive the pipeline with times
//!   that vary per hop.
//!
//! The simulation reproduces Eq. 5 exactly when the pipeline is full and
//! exhibits the idle bubbles of `m < 2·(1 + T_c/T_f)` otherwise — this is
//! the engine behind Figures 12 and 13.

use crate::sim::pipeline::{PipeEvent, PipelineCore};
use crate::sim::EventQueue;

pub use crate::sim::pipeline::{PipelineStats, StageTimes};

/// Stepwise ping-pong pipeline engine over `m` micro-batches and `layers`
/// MoE layers. Stage times come from a caller-supplied provider, consulted
/// exactly once per (micro-batch, layer) and memoized, so stateful
/// providers (RNG-backed gating draws) stay deterministic.
#[derive(Debug, Clone)]
pub struct PingPongEngine {
    /// Micro-batches in flight.
    pub m: usize,
    /// MoE layers per decode iteration.
    pub layers: usize,
}

impl PingPongEngine {
    /// Run the pipeline standalone; `times(mb, layer)` supplies the stage
    /// times of each hop. Returns stage utilizations + makespan.
    pub fn run<F: FnMut(usize, usize) -> StageTimes>(&self, mut times: F) -> PipelineStats {
        let mut core = PipelineCore::new(self.m, self.layers);
        let mut q: EventQueue<PipeEvent> = EventQueue::new();
        let mut out: Vec<(f64, PipeEvent)> = Vec::new();
        core.start(0.0, &mut out);
        for (at, e) in out.drain(..) {
            // msi-lint: allow(raw-schedule) -- standalone queue built at t=0; stage times are nonnegative so no insert is ever past
            q.schedule_at(at, e);
        }
        while let Some((now, ev)) = q.pop() {
            let stats = core.on_event(now, ev, &mut |_, mb, layer| times(mb, layer), &mut out);
            for (at, e) in out.drain(..) {
                // msi-lint: allow(raw-schedule) -- same standalone queue; handler outputs are now + nonnegative durations
                q.schedule_at(at, e);
            }
            if let Some(stats) = stats {
                return stats;
            }
        }
        unreachable!("pipeline event queue drained before all micro-batches completed");
    }
}

/// One decode iteration through `layers` MoE layers with `m` micro-batches
/// and constant stage times (the paper's analytical setting).
#[derive(Debug, Clone)]
pub struct PingPongSim {
    /// Attention compute time per micro-batch per layer.
    pub t_a: f64,
    /// Expert compute time per micro-batch per layer.
    pub t_e: f64,
    /// One-direction communication time per micro-batch.
    pub t_c: f64,
    /// Micro-batches in flight.
    pub m: usize,
    /// MoE layers per decode iteration.
    pub layers: usize,
}

impl PingPongSim {
    /// Run the simulation and return stage utilizations + makespan.
    pub fn run(&self) -> PipelineStats {
        let st = StageTimes {
            t_a: self.t_a,
            t_e: self.t_e,
            t_c: self.t_c,
        };
        PingPongEngine {
            m: self.m,
            layers: self.layers,
        }
        .run(|_, _| st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf_model::IterationModel;

    #[test]
    fn matches_eq5_when_pipeline_full() {
        // Balanced, fast comm, m=3 (constraint 3 satisfied).
        let sim = PingPongSim {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m: 3,
            layers: 8,
        };
        let stats = sim.run();
        let eq5 = IterationModel {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m: 3,
            layers: 8,
        }
        .t_total_eq5();
        let rel = (stats.total_time - eq5).abs() / eq5;
        assert!(rel < 0.02, "DES {} vs Eq.5 {} (rel {rel})", stats.total_time, eq5);
    }

    #[test]
    fn m1_leaves_stages_idle() {
        let sim = PingPongSim {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m: 1,
            layers: 8,
        };
        let stats = sim.run();
        // With a single micro-batch each stage is busy at most
        // T_f/(T_a+T_e+2T_c) ≈ 38% of the time.
        assert!(stats.attn_utilization < 0.45, "{}", stats.attn_utilization);
        assert!(stats.expert_utilization < 0.45);
    }

    #[test]
    fn m3_keeps_stages_nearly_saturated() {
        let sim = PingPongSim {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m: 3,
            layers: 16,
        };
        let stats = sim.run();
        assert!(stats.attn_utilization > 0.9, "{}", stats.attn_utilization);
        assert!(stats.expert_utilization > 0.9, "{}", stats.expert_utilization);
    }

    #[test]
    fn throughput_gain_m1_to_m2_is_about_2x() {
        // Paper Figure 12: m=1 -> m=2 improves throughput ~1.9x.
        let run = |m| {
            let s = PingPongSim {
                t_a: 1.0,
                t_e: 1.0,
                t_c: 0.2,
                m,
                layers: 16,
            }
            .run();
            m as f64 / s.total_time // tokens/unit-time ∝ m / makespan
        };
        let gain = run(2) / run(1);
        assert!((1.6..2.2).contains(&gain), "gain {gain}");
    }

    #[test]
    fn imbalance_caps_utilization_of_faster_stage() {
        // Expert stage 4x faster than attention: its utilization is bounded
        // by roughly t_e/t_a.
        let stats = PingPongSim {
            t_a: 1.0,
            t_e: 0.25,
            t_c: 0.1,
            m: 3,
            layers: 16,
        }
        .run();
        assert!(stats.expert_utilization < 0.35);
        assert!(stats.attn_utilization > 0.9);
    }

    #[test]
    fn zero_comm_degenerates_to_alternation() {
        let stats = PingPongSim {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.0,
            m: 2,
            layers: 4,
        }
        .run();
        // m=2, T_c=0 satisfies constraint 3 with equality: full overlap,
        // makespan = Eq.5 = 2 + 1*(2*4-1) = 9... Eq.5: (1+1+0) + (8-1) = 9.
        assert!((stats.total_time - 9.0).abs() < 1e-9, "{}", stats.total_time);
    }

    #[test]
    fn engine_with_constant_provider_matches_sim() {
        let sim = PingPongSim {
            t_a: 0.9,
            t_e: 1.1,
            t_c: 0.25,
            m: 3,
            layers: 12,
        };
        let a = sim.run();
        let b = PingPongEngine { m: 3, layers: 12 }.run(|_, _| StageTimes {
            t_a: 0.9,
            t_e: 1.1,
            t_c: 0.25,
        });
        assert_eq!(a, b);
    }

    #[test]
    fn engine_provider_called_once_per_hop() {
        use std::cell::Cell;
        let calls = Cell::new(0usize);
        let (m, layers) = (3usize, 5usize);
        PingPongEngine { m, layers }.run(|_, _| {
            calls.set(calls.get() + 1);
            StageTimes {
                t_a: 1.0,
                t_e: 1.0,
                t_c: 0.1,
            }
        });
        assert_eq!(calls.get(), m * layers, "memoization consults each hop once");
    }

    #[test]
    fn engine_varying_times_accumulate() {
        // One micro-batch, no comm: makespan is the sum of all per-layer
        // stage times.
        let stats = PingPongEngine { m: 1, layers: 4 }.run(|_, layer| StageTimes {
            t_a: 1.0 + layer as f64,
            t_e: 0.5,
            t_c: 0.0,
        });
        let expect: f64 = (0..4).map(|l| 1.0 + l as f64 + 0.5).sum();
        assert!((stats.total_time - expect).abs() < 1e-9, "{}", stats.total_time);
    }
}
