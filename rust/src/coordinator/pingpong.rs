//! Ping-pong pipeline parallelism — discrete-event simulation (paper §4.1,
//! Figure 4).
//!
//! `m` micro-batches shuttle between the attention stage and the expert
//! stage for `L` layers. Each stage processes one micro-batch at a time
//! (the node's GPUs are a single serially-reused resource); transfers take
//! `T_c` each way and overlap with compute. The simulation reproduces
//! Eq. 5 exactly when the pipeline is full and exhibits the idle bubbles of
//! `m < 2·(1 + T_c/T_f)` otherwise — this is the engine behind Figures 12
//! and 13.
//!
//! Two entry points share one event loop:
//!
//! * [`PingPongSim`] — constant stage times, the closed-form ablation
//!   driver (Figures 12/13);
//! * [`PingPongEngine`] — a *stepwise* engine taking a per-(micro-batch,
//!   layer) [`StageTimes`] provider, so callers like
//!   [`crate::sim::cluster`] can drive the pipeline with times that vary
//!   with the actual routed expert loads and transfer sizes of each hop.

use std::collections::VecDeque;

use crate::sim::EventQueue;

/// Per-stage/per-run statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineStats {
    /// Completion time of the last micro-batch (seconds).
    pub total_time: f64,
    /// Attention-stage busy time / total time.
    pub attn_utilization: f64,
    /// Expert-stage busy time / total time.
    pub expert_utilization: f64,
    /// Per-micro-batch completion times.
    pub mb_done: Vec<f64>,
}

/// Stage times for one (micro-batch, layer) traversal.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageTimes {
    /// Attention compute time for this micro-batch at this layer.
    pub t_a: f64,
    /// Expert compute time for this micro-batch at this layer.
    pub t_e: f64,
    /// One-direction communication time (applies to both the dispatch to
    /// the expert pool and the combine back to the attention pool).
    pub t_c: f64,
}

/// Stepwise ping-pong pipeline engine over `m` micro-batches and `layers`
/// MoE layers. Stage times come from a caller-supplied provider, consulted
/// exactly once per (micro-batch, layer) and memoized, so stateful
/// providers (RNG-backed gating draws) stay deterministic.
#[derive(Debug, Clone)]
pub struct PingPongEngine {
    pub m: usize,
    pub layers: usize,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Micro-batch ready to start attention of layer `layer`.
    AttnReady { mb: usize, layer: usize },
    /// Attention of (mb, layer) finished computing.
    AttnDone { mb: usize, layer: usize },
    /// Micro-batch arrived at the expert stage for `layer`.
    ExpertReady { mb: usize, layer: usize },
    /// Expert compute finished.
    ExpertDone { mb: usize, layer: usize },
    /// Aggregated tokens arrived back at attention nodes after `layer`.
    BackAtAttn { mb: usize, layer: usize },
}

impl PingPongEngine {
    /// Run the pipeline; `times(mb, layer)` supplies the stage times of
    /// each hop. Returns stage utilizations + makespan.
    pub fn run<F: FnMut(usize, usize) -> StageTimes>(&self, mut times: F) -> PipelineStats {
        assert!(self.m >= 1 && self.layers >= 1);
        let mut q: EventQueue<Ev> = EventQueue::new();

        // Memoized per-(mb, layer) stage times: the provider is consulted
        // once, in deterministic event order.
        let mut cache: Vec<Option<StageTimes>> = vec![None; self.m * self.layers];
        let layers = self.layers;
        let mut t = move |mb: usize, layer: usize| -> StageTimes {
            let idx = mb * layers + layer;
            if cache[idx].is_none() {
                cache[idx] = Some(times(mb, layer));
            }
            cache[idx].unwrap()
        };

        // Stage state: busy-until + FIFO of ready micro-batches.
        let mut attn_free_at = 0.0f64;
        let mut expert_free_at = 0.0f64;
        let mut attn_queue: VecDeque<(usize, usize)> = VecDeque::new();
        let mut expert_queue: VecDeque<(usize, usize)> = VecDeque::new();
        let mut attn_busy = 0.0f64;
        let mut expert_busy = 0.0f64;
        let mut mb_done = vec![0.0f64; self.m];

        for mb in 0..self.m {
            q.schedule_at(0.0, Ev::AttnReady { mb, layer: 0 });
        }

        // Start the next queued item on a stage iff the stage is actually
        // idle at `now` (guards against double-booking when a completion and
        // a ready event share a timestamp).
        macro_rules! try_start {
            ($now:expr, $q:expr, $queue:ident, $free_at:ident, $busy:ident,
             $stage:ident, $done:ident) => {
                if $free_at <= $now {
                    if let Some((mb, layer)) = $queue.pop_front() {
                        let dur = t(mb, layer).$stage;
                        $free_at = $now + dur;
                        $busy += dur;
                        $q.schedule_at($free_at, Ev::$done { mb, layer });
                    }
                }
            };
        }

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::AttnReady { mb, layer } => {
                    attn_queue.push_back((mb, layer));
                    try_start!(now, q, attn_queue, attn_free_at, attn_busy, t_a, AttnDone);
                }
                Ev::AttnDone { mb, layer } => {
                    // Dispatch tokens to experts (M2N), arrive after t_c.
                    q.schedule_at(now + t(mb, layer).t_c, Ev::ExpertReady { mb, layer });
                    try_start!(now, q, attn_queue, attn_free_at, attn_busy, t_a, AttnDone);
                }
                Ev::ExpertReady { mb, layer } => {
                    expert_queue.push_back((mb, layer));
                    try_start!(
                        now, q, expert_queue, expert_free_at, expert_busy, t_e, ExpertDone
                    );
                }
                Ev::ExpertDone { mb, layer } => {
                    q.schedule_at(now + t(mb, layer).t_c, Ev::BackAtAttn { mb, layer });
                    try_start!(
                        now, q, expert_queue, expert_free_at, expert_busy, t_e, ExpertDone
                    );
                }
                Ev::BackAtAttn { mb, layer } => {
                    if layer + 1 < self.layers {
                        q.schedule_at(now, Ev::AttnReady { mb, layer: layer + 1 });
                    } else {
                        mb_done[mb] = now;
                    }
                }
            }
        }

        let total_time = mb_done.iter().copied().fold(0.0, f64::max);
        PipelineStats {
            total_time,
            attn_utilization: attn_busy / total_time,
            expert_utilization: expert_busy / total_time,
            mb_done,
        }
    }
}

/// One decode iteration through `layers` MoE layers with `m` micro-batches
/// and constant stage times (the paper's analytical setting).
#[derive(Debug, Clone)]
pub struct PingPongSim {
    /// Attention compute time per micro-batch per layer.
    pub t_a: f64,
    /// Expert compute time per micro-batch per layer.
    pub t_e: f64,
    /// One-direction communication time per micro-batch.
    pub t_c: f64,
    pub m: usize,
    pub layers: usize,
}

impl PingPongSim {
    /// Run the simulation and return stage utilizations + makespan.
    pub fn run(&self) -> PipelineStats {
        let st = StageTimes {
            t_a: self.t_a,
            t_e: self.t_e,
            t_c: self.t_c,
        };
        PingPongEngine {
            m: self.m,
            layers: self.layers,
        }
        .run(|_, _| st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf_model::IterationModel;

    #[test]
    fn matches_eq5_when_pipeline_full() {
        // Balanced, fast comm, m=3 (constraint 3 satisfied).
        let sim = PingPongSim {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m: 3,
            layers: 8,
        };
        let stats = sim.run();
        let eq5 = IterationModel {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m: 3,
            layers: 8,
        }
        .t_total_eq5();
        let rel = (stats.total_time - eq5).abs() / eq5;
        assert!(rel < 0.02, "DES {} vs Eq.5 {} (rel {rel})", stats.total_time, eq5);
    }

    #[test]
    fn m1_leaves_stages_idle() {
        let sim = PingPongSim {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m: 1,
            layers: 8,
        };
        let stats = sim.run();
        // With a single micro-batch each stage is busy at most
        // T_f/(T_a+T_e+2T_c) ≈ 38% of the time.
        assert!(stats.attn_utilization < 0.45, "{}", stats.attn_utilization);
        assert!(stats.expert_utilization < 0.45);
    }

    #[test]
    fn m3_keeps_stages_nearly_saturated() {
        let sim = PingPongSim {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.3,
            m: 3,
            layers: 16,
        };
        let stats = sim.run();
        assert!(stats.attn_utilization > 0.9, "{}", stats.attn_utilization);
        assert!(stats.expert_utilization > 0.9, "{}", stats.expert_utilization);
    }

    #[test]
    fn throughput_gain_m1_to_m2_is_about_2x() {
        // Paper Figure 12: m=1 -> m=2 improves throughput ~1.9x.
        let run = |m| {
            let s = PingPongSim {
                t_a: 1.0,
                t_e: 1.0,
                t_c: 0.2,
                m,
                layers: 16,
            }
            .run();
            m as f64 / s.total_time // tokens/unit-time ∝ m / makespan
        };
        let gain = run(2) / run(1);
        assert!((1.6..2.2).contains(&gain), "gain {gain}");
    }

    #[test]
    fn imbalance_caps_utilization_of_faster_stage() {
        // Expert stage 4x faster than attention: its utilization is bounded
        // by roughly t_e/t_a.
        let stats = PingPongSim {
            t_a: 1.0,
            t_e: 0.25,
            t_c: 0.1,
            m: 3,
            layers: 16,
        }
        .run();
        assert!(stats.expert_utilization < 0.35);
        assert!(stats.attn_utilization > 0.9);
    }

    #[test]
    fn zero_comm_degenerates_to_alternation() {
        let stats = PingPongSim {
            t_a: 1.0,
            t_e: 1.0,
            t_c: 0.0,
            m: 2,
            layers: 4,
        }
        .run();
        // m=2, T_c=0 satisfies constraint 3 with equality: full overlap,
        // makespan = Eq.5 = 2 + 1*(2*4-1) = 9... Eq.5: (1+1+0) + (8-1) = 9.
        assert!((stats.total_time - 9.0).abs() < 1e-9, "{}", stats.total_time);
    }

    #[test]
    fn engine_with_constant_provider_matches_sim() {
        let sim = PingPongSim {
            t_a: 0.9,
            t_e: 1.1,
            t_c: 0.25,
            m: 3,
            layers: 12,
        };
        let a = sim.run();
        let b = PingPongEngine { m: 3, layers: 12 }.run(|_, _| StageTimes {
            t_a: 0.9,
            t_e: 1.1,
            t_c: 0.25,
        });
        assert_eq!(a, b);
    }

    #[test]
    fn engine_provider_called_once_per_hop() {
        use std::cell::Cell;
        let calls = Cell::new(0usize);
        let (m, layers) = (3usize, 5usize);
        PingPongEngine { m, layers }.run(|_, _| {
            calls.set(calls.get() + 1);
            StageTimes {
                t_a: 1.0,
                t_e: 1.0,
                t_c: 0.1,
            }
        });
        assert_eq!(calls.get(), m * layers, "memoization consults each hop once");
    }

    #[test]
    fn engine_varying_times_accumulate() {
        // One micro-batch, no comm: makespan is the sum of all per-layer
        // stage times.
        let stats = PingPongEngine { m: 1, layers: 4 }.run(|_, layer| StageTimes {
            t_a: 1.0 + layer as f64,
            t_e: 0.5,
            t_c: 0.0,
        });
        let expect: f64 = (0..4).map(|l| 1.0 + l as f64 + 0.5).sum();
        assert!((stats.total_time - expect).abs() < 1e-9, "{}", stats.total_time);
    }
}
