//! Batch bookkeeping: the set of requests currently decoding on an
//! instance, and its partitioning into micro-batches.

use crate::workload::Request;

/// A request admitted to the decode batch.
#[derive(Debug, Clone, PartialEq)]
pub struct ActiveRequest {
    /// Request id (the cluster engine threads table slots through this).
    pub id: u64,
    /// Current sequence length (prompt + decoded so far).
    pub seq_len: usize,
    /// Output tokens still to produce.
    pub remaining: usize,
    /// Virtual/wall time at admission (for latency accounting).
    pub admitted_at: f64,
    /// Tokens decoded so far.
    pub decoded: usize,
}

impl ActiveRequest {
    /// Admit a request at time `now` (prompt KV already materialized, §3).
    pub fn from_request(r: &Request, now: f64) -> Self {
        Self {
            id: r.id,
            seq_len: r.input_len,
            remaining: r.output_len,
            admitted_at: now,
            decoded: 0,
        }
    }

    /// Advance one decode step; returns true if the request just finished.
    pub fn step(&mut self) -> bool {
        debug_assert!(self.remaining > 0);
        self.seq_len += 1;
        self.decoded += 1;
        self.remaining -= 1;
        self.remaining == 0
    }
}

/// The decoding batch of one instance. During decoding each request
/// contributes exactly one token per iteration, so `len()` is both the
/// request count and the token batch size `B`.
#[derive(Debug, Clone, Default)]
pub struct DecodeBatch {
    /// The live requests, in admission order.
    pub requests: Vec<ActiveRequest>,
}

impl DecodeBatch {
    /// Requests currently decoding (== token batch size `B`).
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Whether the batch is empty.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Mean sequence length over the batch (`s` in the perf model).
    pub fn avg_seq_len(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().map(|r| r.seq_len as f64).sum::<f64>()
            / self.requests.len() as f64
    }

    /// Split into `m` micro-batches of near-equal size (sizes differ by at
    /// most 1). Returns the token count of each micro-batch.
    pub fn micro_batch_sizes(&self, m: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(m);
        self.micro_batch_sizes_into(m, &mut out);
        out
    }

    /// [`DecodeBatch::micro_batch_sizes`] into a caller-recycled buffer
    /// (cleared first) — the cluster engine calls this every iteration and
    /// must not allocate in steady state.
    pub fn micro_batch_sizes_into(&self, m: usize, out: &mut Vec<usize>) {
        debug_assert!(m >= 1);
        let n = self.requests.len();
        let base = n / m;
        let extra = n % m;
        out.clear();
        out.extend((0..m).map(|i| base + usize::from(i < extra)));
    }

    /// Run one decode iteration over every request: returns ids of requests
    /// that finished and removes them from the batch.
    pub fn step_all(&mut self) -> Vec<u64> {
        let mut done = Vec::new();
        self.requests.retain_mut(|r| {
            if r.step() {
                done.push(r.id);
                false
            } else {
                true
            }
        });
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, input: usize, output: usize) -> ActiveRequest {
        ActiveRequest {
            id,
            seq_len: input,
            remaining: output,
            admitted_at: 0.0,
            decoded: 0,
        }
    }

    #[test]
    fn micro_batch_sizes_balanced() {
        let mut b = DecodeBatch::default();
        for i in 0..10 {
            b.requests.push(req(i, 100, 5));
        }
        assert_eq!(b.micro_batch_sizes(3), vec![4, 3, 3]);
        assert_eq!(b.micro_batch_sizes(3).iter().sum::<usize>(), 10);
        assert_eq!(b.micro_batch_sizes(1), vec![10]);
    }

    #[test]
    fn step_retires_finished() {
        let mut b = DecodeBatch::default();
        b.requests.push(req(0, 100, 1));
        b.requests.push(req(1, 100, 2));
        let done = b.step_all();
        assert_eq!(done, vec![0]);
        assert_eq!(b.len(), 1);
        assert_eq!(b.requests[0].seq_len, 101);
        let done = b.step_all();
        assert_eq!(done, vec![1]);
        assert!(b.is_empty());
    }

    #[test]
    fn avg_seq_len() {
        let mut b = DecodeBatch::default();
        b.requests.push(req(0, 100, 5));
        b.requests.push(req(1, 300, 5));
        assert_eq!(b.avg_seq_len(), 200.0);
    }
}
