//! Token dispatch and aggregation — the data-plane hot path between
//! attention and expert nodes (the computation the M2N library transports,
//! and the scatter/gather the paper's fused kernels accelerate, §6).
//!
//! `build_dispatch` turns a gating decision into per-expert routing tables;
//! `combine_expert_outputs` computes the weighted sum of expert outputs back
//! into token order. Both are allocation-lean: the routing tables are flat
//! index vectors sized in one pass (optimized in the §Perf pass — see
//! EXPERIMENTS.md).

use super::gating::GatingOutput;

/// Routing tables for one micro-batch.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchPlan {
    /// Flat token indices grouped by expert: tokens for expert `e` are
    /// `token_idx[offsets[e] .. offsets[e+1]]`.
    pub token_idx: Vec<u32>,
    /// The gating weight aligned with `token_idx`.
    pub gate_weight: Vec<f32>,
    /// Per-expert offsets into `token_idx`; length `num_experts + 1`.
    pub offsets: Vec<u32>,
}

impl DispatchPlan {
    /// Number of experts the plan covers.
    pub fn num_experts(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Token rows (and weights) destined for expert `e`.
    pub fn expert_slice(&self, e: usize) -> (&[u32], &[f32]) {
        let lo = self.offsets[e] as usize;
        let hi = self.offsets[e + 1] as usize;
        (&self.token_idx[lo..hi], &self.gate_weight[lo..hi])
    }

    /// Tokens routed to expert `e`.
    pub fn expert_load(&self, e: usize) -> usize {
        (self.offsets[e + 1] - self.offsets[e]) as usize
    }

    /// Total dispatched token-copies (== batch · top_k).
    pub fn total_dispatched(&self) -> usize {
        self.token_idx.len()
    }
}

/// Build the per-expert routing tables from a gating decision.
///
/// Counting-sort layout: one pass to histogram expert loads, one pass to
/// scatter indices — O(batch·k), no per-expert Vec allocations.
pub fn build_dispatch(gating: &GatingOutput, num_experts: usize) -> DispatchPlan {
    let total = gating.experts.len();
    let k = gating.k;

    // Pass 1: histogram.
    let mut counts = vec![0u32; num_experts + 1];
    for &e in &gating.experts {
        counts[e as usize + 1] += 1;
    }
    // Prefix sum -> offsets.
    for e in 0..num_experts {
        counts[e + 1] += counts[e];
    }
    let offsets = counts;

    // Pass 2: scatter (flat [batch*k] layout, token = index / k).
    let mut cursor: Vec<u32> = offsets[..num_experts].to_vec();
    let mut token_idx = vec![0u32; total];
    let mut gate_weight = vec![0f32; total];
    for (i, (&e, &w)) in gating.experts.iter().zip(&gating.weights).enumerate() {
        let slot = cursor[e as usize] as usize;
        token_idx[slot] = (i / k) as u32;
        gate_weight[slot] = w;
        cursor[e as usize] += 1;
    }

    DispatchPlan {
        token_idx,
        gate_weight,
        offsets,
    }
}

/// Aggregate expert outputs back into token order:
/// `out[t] = Σ_e w_{t,e} · expert_out_e[row of t]`.
///
/// `expert_outputs[e]` is row-major `[expert_load(e), hidden]` in the same
/// order as `expert_slice(e)`. Returns row-major `[batch, hidden]`.
pub fn combine_expert_outputs(
    plan: &DispatchPlan,
    expert_outputs: &[Vec<f32>],
    batch: usize,
    hidden: usize,
) -> Vec<f32> {
    assert_eq!(expert_outputs.len(), plan.num_experts());
    let mut out = vec![0f32; batch * hidden];
    for e in 0..plan.num_experts() {
        let (tokens, weights) = plan.expert_slice(e);
        let eo = &expert_outputs[e];
        assert_eq!(eo.len(), tokens.len() * hidden, "expert {e} output shape");
        for (row, (&t, &w)) in tokens.iter().zip(weights).enumerate() {
            let src = &eo[row * hidden..(row + 1) * hidden];
            let dst = &mut out[t as usize * hidden..(t as usize + 1) * hidden];
            for (d, s) in dst.iter_mut().zip(src) {
                *d += w * s;
            }
        }
    }
    out
}

/// Gather the input rows for one expert: `x` is `[batch, hidden]` row-major,
/// returns `[expert_load(e), hidden]`.
pub fn gather_expert_input(
    plan: &DispatchPlan,
    e: usize,
    x: &[f32],
    hidden: usize,
) -> Vec<f32> {
    let (tokens, _) = plan.expert_slice(e);
    let mut out = Vec::with_capacity(tokens.len() * hidden);
    for &t in tokens {
        out.extend_from_slice(&x[t as usize * hidden..(t as usize + 1) * hidden]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::gating::softmax_topk;

    fn gating_fixture() -> GatingOutput {
        // 3 tokens, 4 experts, top-2.
        GatingOutput {
            k: 2,
            experts: vec![0, 2, 2, 1, 0, 1],
            weights: vec![0.7, 0.3, 0.6, 0.4, 0.5, 0.5],
        }
    }

    #[test]
    fn conservation_every_token_copy_routed() {
        let g = gating_fixture();
        let plan = build_dispatch(&g, 4);
        assert_eq!(plan.total_dispatched(), 6);
        let by_expert: usize = (0..4).map(|e| plan.expert_load(e)).sum();
        assert_eq!(by_expert, 6);
        assert_eq!(plan.expert_load(0), 2);
        assert_eq!(plan.expert_load(1), 2);
        assert_eq!(plan.expert_load(2), 2);
        assert_eq!(plan.expert_load(3), 0);
    }

    #[test]
    fn combine_identity_expert_recovers_weighted_sum() {
        // Expert output == its input rows; weights sum to 1, so combining
        // over identity experts reproduces the input exactly.
        let g = gating_fixture();
        let plan = build_dispatch(&g, 4);
        let hidden = 2;
        let x: Vec<f32> = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0]; // 3 tokens
        let outs: Vec<Vec<f32>> = (0..4)
            .map(|e| gather_expert_input(&plan, e, &x, hidden))
            .collect();
        let combined = combine_expert_outputs(&plan, &outs, 3, hidden);
        for (a, b) in combined.iter().zip(&x) {
            assert!((a - b).abs() < 1e-6, "{combined:?} vs {x:?}");
        }
    }

    #[test]
    fn combine_scales_by_gate_weight() {
        let g = GatingOutput {
            k: 1,
            experts: vec![0],
            weights: vec![1.0],
        };
        let plan = build_dispatch(&g, 2);
        let outs = vec![vec![4.0, 8.0], vec![]];
        let combined = combine_expert_outputs(&plan, &outs, 1, 2);
        assert_eq!(combined, vec![4.0, 8.0]);
    }

    #[test]
    fn works_with_real_gating() {
        let logits: Vec<f32> = (0..32 * 8).map(|i| ((i * 37) % 11) as f32 * 0.1).collect();
        let g = softmax_topk(&logits, 8, 2);
        let plan = build_dispatch(&g, 8);
        assert_eq!(plan.total_dispatched(), 64);
        // Offsets monotone.
        for w in plan.offsets.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn empty_batch() {
        let g = GatingOutput {
            k: 2,
            experts: vec![],
            weights: vec![],
        };
        let plan = build_dispatch(&g, 4);
        assert_eq!(plan.total_dispatched(), 0);
        let combined = combine_expert_outputs(&plan, &vec![vec![]; 4], 0, 8);
        assert!(combined.is_empty());
    }
}
