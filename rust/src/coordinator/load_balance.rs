//! Expert load balancing with on-device redundancy (paper §6 "Load
//! balance").
//!
//! Problem: distribute `M` experts across `N` expert nodes, allowing an
//! expert to be *replicated* (fractionally split) across nodes, to minimize
//! the makespan
//!
//! `max_{j=1..N} C_j`, `C_j = Σ_i x_{i,j} · max(a_i, K)`,
//!
//! where `x_{i,j}` is the fraction of expert `i` served by node `j`
//! (`Σ_j x_{i,j} = 1`), `a_i` the measured cost of expert `i`'s active
//! tokens over the last traffic window, and `K` the floor cost of a cold
//! expert. The paper solves it with a greedy approximation; we implement the
//! classic fractional greedy: process experts in descending cost, pour each
//! into the least-loaded node, splitting across nodes whenever a node
//! reaches the optimum water level `W = max(Σ costs / N, max_i cost_i / r)`.

/// Placement result.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertPlacement {
    /// `x[i]` = list of `(node, fraction)` for expert `i`.
    pub assignments: Vec<Vec<(usize, f64)>>,
    /// Final per-node cost `C_j`.
    pub node_cost: Vec<f64>,
    /// The makespan `max_j C_j`.
    pub makespan: f64,
}

impl ExpertPlacement {
    /// Number of replicas (nodes serving a fraction) of expert `i`.
    pub fn replicas(&self, i: usize) -> usize {
        self.assignments[i].len()
    }

    /// Apply the placement to a NEW per-expert cost vector: each node's
    /// load is the fraction-weighted sum of the experts it serves. This is
    /// how the periodic online re-balancer scores a stale placement against
    /// traffic that has drifted since it was computed.
    pub fn node_loads(&self, costs: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.node_cost.len()];
        self.node_loads_into(costs, &mut out);
        out
    }

    /// Allocation-free form of [`Self::node_loads`]: accumulate into a
    /// caller-owned buffer already sized and zeroed to the node count. The
    /// decode hot loop recycles its buffer across hops, so the steady
    /// state never touches the allocator.
    pub fn node_loads_into(&self, costs: &[f64], out: &mut [f64]) {
        debug_assert_eq!(costs.len(), self.assignments.len());
        debug_assert_eq!(out.len(), self.node_cost.len());
        for (i, asg) in self.assignments.iter().enumerate() {
            for &(node, frac) in asg {
                out[node] += costs[i] * frac;
            }
        }
    }
}

/// Greedy fractional balancing of `costs.len()` experts over `nodes` nodes.
///
/// `cold_cost` is `K`: even an expert with no traffic costs this much
/// (weight loads per micro-batch), so `max(a_i, K)` is balanced.
pub fn balance_experts(costs: &[f64], nodes: usize, cold_cost: f64) -> ExpertPlacement {
    assert!(nodes >= 1);
    let eff: Vec<f64> = costs.iter().map(|&a| a.max(cold_cost)).collect();
    let total: f64 = eff.iter().sum();
    // Water level: perfect split, but a node never needs more than the
    // total; fractional splitting makes total/N achievable exactly.
    let level = total / nodes as f64;

    // Descending-cost order for stability of the greedy.
    let mut order: Vec<usize> = (0..eff.len()).collect();
    order.sort_by(|&a, &b| eff[b].total_cmp(&eff[a]).then(a.cmp(&b)));

    let mut node_cost = vec![0.0f64; nodes];
    let mut assignments = vec![Vec::new(); eff.len()];
    let mut j = 0usize; // current node being filled

    for &i in &order {
        let mut remaining = eff[i];
        while remaining > 1e-12 {
            let cap = (level - node_cost[j]).max(0.0);
            if cap <= 1e-12 {
                j = (j + 1).min(nodes - 1);
                if node_cost[j] >= level - 1e-12 && j == nodes - 1 {
                    // All nodes at level (rounding): dump the remainder on
                    // the least-loaded node.
                    let (jmin, _) = node_cost
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.total_cmp(b.1))
                        .unwrap();
                    node_cost[jmin] += remaining;
                    assignments[i].push((jmin, remaining / eff[i]));
                    remaining = 0.0;
                }
                continue;
            }
            let take = remaining.min(cap);
            node_cost[j] += take;
            assignments[i].push((j, take / eff[i]));
            remaining -= take;
        }
    }

    let makespan = node_cost.iter().copied().fold(0.0, f64::max);
    ExpertPlacement {
        assignments,
        node_cost,
        makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_load_perfectly_balanced() {
        let p = balance_experts(&[10.0; 8], 8, 1.0);
        for c in &p.node_cost {
            assert!((c - 10.0).abs() < 1e-9);
        }
        assert!((p.makespan - 10.0).abs() < 1e-9);
    }

    #[test]
    fn hot_expert_gets_replicated() {
        // One expert carries 50% of traffic over 4 nodes: it must be split.
        let p = balance_experts(&[40.0, 5.0, 5.0, 5.0, 5.0, 5.0, 5.0, 10.0], 4, 1.0);
        assert!(p.replicas(0) >= 2, "hot expert replicated: {:?}", p.assignments[0]);
        // Makespan equals the fractional optimum total/N = 80/4 = 20.
        assert!((p.makespan - 20.0).abs() < 1e-9, "makespan {}", p.makespan);
    }

    #[test]
    fn fractions_sum_to_one() {
        let costs = [3.0, 17.0, 0.0, 8.5, 1.2, 9.9];
        let p = balance_experts(&costs, 3, 2.0);
        for (i, asg) in p.assignments.iter().enumerate() {
            let s: f64 = asg.iter().map(|(_, f)| f).sum();
            assert!((s - 1.0).abs() < 1e-9, "expert {i} fractions {s}");
        }
    }

    #[test]
    fn cold_floor_applies() {
        // All experts idle: each still costs K.
        let p = balance_experts(&[0.0; 4], 2, 5.0);
        assert!((p.makespan - 10.0).abs() < 1e-9);
    }

    #[test]
    fn node_loads_reapplies_fractions() {
        let costs = [40.0, 5.0, 5.0, 5.0, 5.0, 5.0, 5.0, 10.0];
        let p = balance_experts(&costs, 4, 1.0);
        // Same traffic: per-node loads match the placement's own costs.
        let same = p.node_loads(&costs);
        for (a, b) in same.iter().zip(&p.node_cost) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        // Drifted traffic: loads redistribute but conserve the total.
        let drifted = [5.0, 40.0, 5.0, 5.0, 5.0, 5.0, 5.0, 10.0];
        let loads = p.node_loads(&drifted);
        let total: f64 = loads.iter().sum();
        assert!((total - drifted.iter().sum::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn makespan_beats_unbalanced_by_large_factor() {
        // Skewed traffic: without balancing, one node would carry 64; the
        // greedy brings it to ~ total/N.
        let costs = [64.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let p = balance_experts(&costs, 8, 1.0);
        let unbalanced = 64.0; // expert-per-node static placement
        assert!(p.makespan < unbalanced / 5.0, "makespan {}", p.makespan);
    }
}
