//! Continuous (iteration-level) batching for the decode phase — Orca-style
//! admission at every iteration boundary, bounded by the plan's maximum
//! global batch size and the KV-cache block budget.
//!
//! MegaScale-Infer decouples prefill into a separate cluster (§3, following
//! DistServe/Mooncake); requests arrive here with their prompt KV already
//! materialized, so admission = allocating KV blocks + joining the decode
//! batch.

use std::collections::VecDeque;

use crate::workload::Request;

use super::batch::{ActiveRequest, DecodeBatch};
use super::kv_cache::BlockAllocator;

/// Scheduler parameters.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Maximum decode batch size `B` (from the deployment plan).
    pub max_batch: usize,
}

/// Iteration-level scheduler state.
#[derive(Debug)]
pub struct ContinuousBatcher {
    /// Scheduler parameters.
    pub config: SchedulerConfig,
    /// Requests waiting for admission (arrived, not yet decoding).
    pub waiting: VecDeque<Request>,
    /// The live decode batch.
    pub batch: DecodeBatch,
}

/// What happened during one admission step.
#[derive(Debug, Default, PartialEq)]
pub struct AdmissionReport {
    /// Requests admitted this step.
    pub admitted: usize,
    /// Admissions blocked on KV memory this step.
    pub rejected_kv: usize,
}

impl ContinuousBatcher {
    /// An empty batcher with the given parameters.
    pub fn new(config: SchedulerConfig) -> Self {
        Self {
            config,
            waiting: VecDeque::new(),
            batch: DecodeBatch::default(),
        }
    }

    /// Enqueue arrivals.
    pub fn submit(&mut self, r: Request) {
        self.waiting.push_back(r);
    }

    /// Admission at an iteration boundary: move requests from the waiting
    /// queue into the decode batch while capacity and KV blocks last.
    pub fn admit(&mut self, kv: &mut BlockAllocator, now: f64) -> AdmissionReport {
        let mut report = AdmissionReport::default();
        while self.batch.len() < self.config.max_batch {
            let Some(front) = self.waiting.front() else { break };
            if front.arrival > now {
                break; // not yet arrived (open-loop traces are time-sorted)
            }
            if !kv.admit(front.id, front.input_len) {
                report.rejected_kv += 1;
                break; // blocked on memory; retry next iteration
            }
            let r = self.waiting.pop_front().unwrap();
            self.batch
                .requests
                .push(ActiveRequest::from_request(&r, now));
            report.admitted += 1;
        }
        report
    }

    /// Run one decode iteration's bookkeeping: extend every request's KV by
    /// one token, retire finished requests, release their blocks. Returns
    /// the finished request ids.
    pub fn complete_iteration(&mut self, kv: &mut BlockAllocator) -> Vec<u64> {
        for r in &self.batch.requests {
            // Eq. 8 guarantees block headroom for planned batches; if the
            // allocator still runs dry (e.g. user-configured budget), the
            // request keeps decoding — the real system would preempt; the
            // distinction doesn't affect iteration timing.
            let _ = kv.append_token(r.id);
        }
        let done = self.batch.step_all();
        for id in &done {
            kv.release(*id);
        }
        done
    }

    /// Whether any request is decoding or waiting.
    pub fn has_work(&self) -> bool {
        !self.batch.is_empty() || !self.waiting.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::KvCacheConfig;

    fn kv(blocks: usize) -> BlockAllocator {
        BlockAllocator::new(KvCacheConfig {
            block_size: 16,
            num_blocks: blocks,
        })
    }

    fn req(id: u64, input: usize, output: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            input_len: input,
            output_len: output,
            tenant: 0,
        }
    }

    #[test]
    fn admits_up_to_max_batch() {
        let mut s = ContinuousBatcher::new(SchedulerConfig { max_batch: 2 });
        let mut kv = kv(1000);
        for i in 0..5 {
            s.submit(req(i, 32, 4));
        }
        let rep = s.admit(&mut kv, 0.0);
        assert_eq!(rep.admitted, 2);
        assert_eq!(s.batch.len(), 2);
        assert_eq!(s.waiting.len(), 3);
    }

    #[test]
    fn kv_exhaustion_blocks_admission() {
        let mut s = ContinuousBatcher::new(SchedulerConfig { max_batch: 10 });
        let mut kv = kv(3); // 48 tokens of blocks
        s.submit(req(0, 32, 4)); // 2 blocks
        s.submit(req(1, 32, 4)); // would need 2, only 1 left
        let rep = s.admit(&mut kv, 0.0);
        assert_eq!(rep.admitted, 1);
        assert_eq!(rep.rejected_kv, 1);
    }

    #[test]
    fn continuous_refill_after_completion() {
        let mut s = ContinuousBatcher::new(SchedulerConfig { max_batch: 1 });
        let mut kv = kv(1000);
        s.submit(req(0, 16, 1));
        s.submit(req(1, 16, 1));
        s.admit(&mut kv, 0.0);
        assert_eq!(s.batch.len(), 1);
        let done = s.complete_iteration(&mut kv);
        assert_eq!(done, vec![0]);
        s.admit(&mut kv, 1.0);
        assert_eq!(s.batch.len(), 1);
        assert_eq!(s.batch.requests[0].id, 1);
        let done = s.complete_iteration(&mut kv);
        assert_eq!(done, vec![1]);
        assert!(!s.has_work());
        assert_eq!(kv.allocated_blocks(), 0, "all blocks returned");
    }

    #[test]
    fn respects_arrival_times() {
        let mut s = ContinuousBatcher::new(SchedulerConfig { max_batch: 8 });
        let mut kv = kv(1000);
        s.submit(Request {
            id: 0,
            arrival: 5.0,
            input_len: 16,
            output_len: 1,
            tenant: 0,
        });
        assert_eq!(s.admit(&mut kv, 0.0).admitted, 0);
        assert_eq!(s.admit(&mut kv, 5.0).admitted, 1);
    }
}
