//! Multi-instance request router.
//!
//! MegaScale-Infer serves a model as a fleet of runtime instances (one per
//! model replica, §3); production traffic is spread across them. This
//! router implements the standard policies of LLM serving fleets
//! (vllm-project/router, Llumnix): least-outstanding-tokens routing with
//! KV-capacity awareness, plus plain round-robin for comparison.
//!
//! The router is deliberately state-light: it tracks per-instance
//! outstanding work from its own dispatch decisions and completion
//! callbacks, exactly like a front-end proxy that never inspects
//! instance internals.

use crate::workload::Request;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through instances regardless of load.
    RoundRobin,
    /// Route to the instance with the least outstanding decode tokens,
    /// skipping instances whose KV headroom cannot admit the request.
    LeastLoaded,
}

/// Router-side view of one instance.
#[derive(Debug, Clone)]
pub struct InstanceState {
    /// Outstanding decode tokens (sum of remaining output lengths).
    pub outstanding_tokens: u64,
    /// Outstanding requests.
    pub outstanding_requests: u64,
    /// KV-token headroom (capacity minus committed prompt+output tokens).
    pub kv_headroom: u64,
    /// Instance marked failed: excluded from placement until it recovers.
    /// Completion accounting still applies, so a node that rejoins does so
    /// with a consistent view of whatever it kept serving.
    pub down: bool,
}

/// The fleet router.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    instances: Vec<InstanceState>,
    rr_next: usize,
}

impl Router {
    /// `kv_capacity[i]` is instance `i`'s KV budget in tokens.
    pub fn new(policy: RoutePolicy, kv_capacity: &[u64]) -> Self {
        Self {
            policy,
            instances: kv_capacity
                .iter()
                .map(|&c| InstanceState {
                    outstanding_tokens: 0,
                    outstanding_requests: 0,
                    kv_headroom: c,
                    down: false,
                })
                .collect(),
            rr_next: 0,
        }
    }

    /// Per-instance routing state (diagnostics/tests).
    pub fn instances(&self) -> &[InstanceState] {
        &self.instances
    }

    /// Tokens a request will commit in the KV cache (prompt + output).
    fn kv_cost(r: &Request) -> u64 {
        (r.input_len + r.output_len) as u64
    }

    /// Pick an instance for `r`; returns `None` when no instance has KV
    /// headroom (caller should queue and retry on completion).
    pub fn route(&mut self, r: &Request) -> Option<usize> {
        let need = Self::kv_cost(r);
        let n = self.instances.len();
        let pick = match self.policy {
            RoutePolicy::RoundRobin => (0..n)
                .map(|i| (self.rr_next + i) % n)
                .find(|&i| !self.instances[i].down && self.instances[i].kv_headroom >= need),
            RoutePolicy::LeastLoaded => (0..n)
                .filter(|&i| !self.instances[i].down && self.instances[i].kv_headroom >= need)
                .min_by_key(|&i| (self.instances[i].outstanding_tokens, i)),
        }?;
        if self.policy == RoutePolicy::RoundRobin {
            self.rr_next = (pick + 1) % n;
        }
        let s = &mut self.instances[pick];
        s.outstanding_tokens += r.output_len as u64;
        s.outstanding_requests += 1;
        s.kv_headroom -= need;
        Some(pick)
    }

    /// Mark an instance failed (`down = true`) or recovered (`false`).
    /// A down instance is skipped by [`Router::route`]; its outstanding
    /// accounting is untouched — the caller decides what happens to the
    /// work it held (the cluster engine requeues it via `complete`).
    pub fn set_down(&mut self, instance: usize, down: bool) {
        self.instances[instance].down = down;
    }

    /// Completion callback: release the request's accounting.
    pub fn complete(&mut self, instance: usize, r: &Request) {
        let s = &mut self.instances[instance];
        s.outstanding_tokens = s.outstanding_tokens.saturating_sub(r.output_len as u64);
        s.outstanding_requests = s.outstanding_requests.saturating_sub(1);
        s.kv_headroom += Self::kv_cost(r);
    }

    /// Imbalance metric: max/mean outstanding tokens (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let toks: Vec<u64> = self.instances.iter().map(|s| s.outstanding_tokens).collect();
        let max = *toks.iter().max().unwrap_or(&0) as f64;
        let mean = toks.iter().sum::<u64>() as f64 / toks.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimRng;
    use crate::workload::WorkloadSpec;

    fn req(id: u64, input: usize, output: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            input_len: input,
            output_len: output,
            tenant: 0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, &[10_000; 3]);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 10, 5)).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_heavy_tail() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, &[1_000_000; 4]);
        let mut rng = SimRng::new(3);
        let reqs = WorkloadSpec::default().generate(400, 7);
        for q in &reqs {
            r.route(q).unwrap();
            // Randomly complete some work to create churn.
            if rng.chance(0.3) {
                let i = rng.below(4);
                // Synthetic completion of a small request.
                r.complete(i, &req(0, 0, 0));
            }
        }
        assert!(
            r.imbalance() < 1.2,
            "least-loaded imbalance {}",
            r.imbalance()
        );
    }

    #[test]
    fn kv_headroom_gates_admission() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, &[100, 25]);
        // 30-token request only fits instance 0.
        assert_eq!(r.route(&req(0, 20, 10)), Some(0));
        assert_eq!(r.route(&req(1, 20, 10)), Some(0));
        assert_eq!(r.route(&req(2, 20, 10)), Some(0));
        // Instance 0 now has 10 headroom; instance 1 has 25 — too small.
        assert_eq!(r.route(&req(3, 20, 10)), None, "fleet full");
        // A tiny request still fits instance 1.
        assert_eq!(r.route(&req(4, 10, 10)), Some(1));
    }

    #[test]
    fn completion_restores_capacity() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, &[30]);
        let q = req(0, 20, 10);
        assert_eq!(r.route(&q), Some(0));
        assert_eq!(r.route(&req(1, 20, 10)), None);
        r.complete(0, &q);
        assert_eq!(r.route(&req(2, 20, 10)), Some(0));
        assert_eq!(r.instances()[0].outstanding_requests, 1);
    }

    #[test]
    fn round_robin_skips_full_instances() {
        let mut r = Router::new(RoutePolicy::RoundRobin, &[25, 10_000, 25]);
        assert_eq!(r.route(&req(0, 50, 10)).unwrap(), 1);
        assert_eq!(r.route(&req(1, 50, 10)).unwrap(), 1);
    }
}
