//! Integration tests for the coordinator: virtual-time instance end-to-end,
//! dispatch/combine over real gating, load balancer under skewed traffic,
//! scheduler + KV allocator interplay.

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::{
    balance_experts, build_dispatch, combine_expert_outputs, softmax_topk, BlockAllocator,
    ContinuousBatcher, KvCacheConfig, RuntimeInstance, SchedulerConfig,
};
use megascale_infer::plan::PlanSearcher;
use megascale_infer::sim::SimRng;
use megascale_infer::workload::WorkloadSpec;

#[test]
fn instance_serves_open_loop_workload() {
    let model = ModelConfig::dbrx();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let spec = WorkloadSpec {
        arrival_rate: Some(50.0),
        median_output: 25.0,
        ..Default::default()
    };
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len())
        .search()
        .unwrap();
    let reqs = spec.generate(200, 17);
    let rep = RuntimeInstance::new(model, cluster, plan).simulate(&reqs);
    assert_eq!(rep.completed, 200);
    assert!(rep.tpot.median() <= 0.150 * 1.2, "{}", rep.tpot.median());
    assert!(rep.throughput > 0.0);
}

#[test]
fn dispatch_combine_identity_under_random_gating() {
    // With identity experts and weights summing to 1, dispatch->combine is
    // the identity over any gating decision.
    let mut rng = SimRng::new(5);
    for _ in 0..20 {
        let batch = 1 + rng.below(64);
        let experts = 2 + rng.below(30);
        let k = 1 + rng.below(experts.min(4));
        let hidden = 4;
        let logits: Vec<f32> = (0..batch * experts)
            .map(|_| rng.uniform() as f32)
            .collect();
        let g = softmax_topk(&logits, experts, k);
        let plan = build_dispatch(&g, experts);
        assert_eq!(plan.total_dispatched(), batch * k);

        let x: Vec<f32> = (0..batch * hidden).map(|i| i as f32).collect();
        let outs: Vec<Vec<f32>> = (0..experts)
            .map(|e| {
                let (tokens, _) = plan.expert_slice(e);
                let mut o = Vec::with_capacity(tokens.len() * hidden);
                for &t in tokens {
                    o.extend_from_slice(&x[t as usize * hidden..(t as usize + 1) * hidden]);
                }
                o
            })
            .collect();
        let combined = combine_expert_outputs(&plan, &outs, batch, hidden);
        for (a, b) in combined.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4 * b.abs().max(1.0));
        }
    }
}

#[test]
fn load_balancer_handles_production_skew() {
    // Zipf-ish expert popularity, as in real MoE traffic.
    let mut rng = SimRng::new(11);
    let experts = 32;
    let mut costs = vec![0.0f64; experts];
    for _ in 0..100_000 {
        // Zipf via inverse-power of uniform.
        let z = (rng.uniform().powf(2.0) * experts as f64) as usize;
        costs[z.min(experts - 1)] += 1.0;
    }
    let nodes = 8;
    let placement = balance_experts(&costs, nodes, 50.0);
    let total: f64 = costs.iter().map(|c| c.max(50.0)).sum();
    let ideal = total / nodes as f64;
    assert!(
        placement.makespan <= ideal * 1.01,
        "makespan {} vs ideal {}",
        placement.makespan,
        ideal
    );
    // Hot experts replicated, cold not split gratuitously.
    let hottest = costs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    assert!(placement.replicas(hottest) >= 1);
    let replicas_total: usize = (0..experts).map(|i| placement.replicas(i)).sum();
    assert!(replicas_total < experts + nodes, "bounded splitting");
}

#[test]
fn scheduler_respects_kv_budget_under_churn() {
    let mut batcher = ContinuousBatcher::new(SchedulerConfig { max_batch: 64 });
    let mut kv = BlockAllocator::new(KvCacheConfig {
        block_size: 16,
        num_blocks: 256, // 4096 tokens
    });
    let reqs = WorkloadSpec {
        median_input: 300.0,
        median_output: 10.0,
        sigma: 0.4,
        arrival_rate: None,
        burst_sigma: 0.0,
        max_len: 1024,
    }
    .generate(60, 3);
    for r in reqs {
        batcher.submit(r);
    }
    let mut now = 0.0;
    let mut completed = 0usize;
    let mut max_alloc = 0usize;
    while batcher.has_work() {
        batcher.admit(&mut kv, now);
        assert!(!batcher.batch.is_empty(), "deadlock: nothing admitted");
        completed += batcher.complete_iteration(&mut kv).len();
        max_alloc = max_alloc.max(kv.allocated_blocks());
        now += 0.05;
    }
    assert_eq!(completed, 60);
    assert_eq!(kv.allocated_blocks(), 0, "all KV returned");
    assert!(max_alloc <= 256);
}

#[test]
fn report_metrics_are_consistent() {
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
        .search()
        .unwrap();
    // The runtime instance simulates the decode pools only; its per-GPU
    // metric divides by the decode instance, not the prefill pool.
    let gpus = plan.decode_gpus() as f64;
    let reqs = WorkloadSpec {
        median_output: 15.0,
        ..Default::default()
    }
    .generate(128, 1);
    let rep = RuntimeInstance::new(model, cluster, plan).simulate(&reqs);
    assert!((rep.per_gpu_throughput - rep.throughput / gpus).abs() < 1e-9);
    assert!(rep.elapsed > 0.0);
    assert_eq!(
        rep.tokens,
        reqs_tokens(&reqs),
        "every requested token decoded"
    );
}

fn reqs_tokens(reqs: &[megascale_infer::workload::Request]) -> u64 {
    reqs.iter().map(|r| r.output_len as u64).sum()
}
