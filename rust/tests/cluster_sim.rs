//! End-to-end tests for the event-driven cluster engine (`sim::engine`
//! behind the `sim::cluster` facade): golden agreement with the paper's
//! closed forms in the pipeline-full regime, the utilization gap below
//! constraint 3, bit-exact determinism under a fixed seed, and the
//! scenario-diversity knobs (multi-tenant SLOs, drifting popularity with
//! periodic online re-balancing).

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::m2n::LibraryKind;
use megascale_infer::perf_model::{IterationModel, PerfModel};
use megascale_infer::plan::{simulate_plan, DeploymentPlan};
use megascale_infer::sim::cluster::{
    ClusterSim, ClusterSimConfig, ExpertPopularity, Transport,
};
use megascale_infer::workload::{Request, TenantClass, WorkloadSpec};

/// A hand-specified Mixtral deployment point (same region the seed's plan
/// tests exercise) with an exactly divisible batch: `b_a = B/(m·n_a)` and
/// `b_e = B·K/(m·E)` are integral, so the Ideal-popularity run feeds the
/// pipeline the very same stage times the closed forms use.
fn fixed_plan(m: usize, global_batch: usize) -> (ModelConfig, ClusterSpec, DeploymentPlan) {
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let (tp_a, tp_e, n_a) = (4, 2, 4);
    // Constant-composition workload below: prompt 512, short outputs.
    let avg_seq = 514.0;
    let pm = PerfModel::new(&model, &cluster, tp_a, tp_e, avg_seq);
    let metrics = simulate_plan(&pm, &model, &cluster, tp_a, tp_e, n_a, m, global_batch);
    let plan = DeploymentPlan {
        model: model.name.clone(),
        tp_a,
        tp_e,
        n_a,
        n_e: model.experts,
        // Decode-stage anchor plans opt out of prefill modeling: these
        // tests pin the decode pipeline against the Eq. 4–6 closed forms.
        n_p: 0,
        tp_p: 0,
        m,
        global_batch,
        metrics,
    };
    (model, cluster, plan)
}

/// `n` identical closed-loop requests: constant batch composition while
/// decoding, so every iteration runs at the same operating point.
fn constant_requests(n: usize, input: usize, output: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|id| Request {
            id,
            arrival: 0.0,
            input_len: input,
            output_len: output,
            tenant: 0,
        })
        .collect()
}

fn run_fixed(
    m: usize,
    global_batch: usize,
    popularity: ExpertPopularity,
    seed: u64,
) -> (DeploymentPlan, ModelConfig, megascale_infer::sim::ClusterReport) {
    let (model, cluster, plan) = fixed_plan(m, global_batch);
    let reqs = constant_requests(global_batch, 512, 4);
    let rep = ClusterSim::new(ClusterSimConfig {
        popularity,
        seed,
        ..ClusterSimConfig::new(model.clone(), cluster, plan.clone())
    })
    .run(&reqs);
    (plan, model, rep)
}

/// Acceptance: with `m ≥ 2·(1 + T_c/T_f)` the end-to-end simulator's
/// decode-iteration latency matches Eq. 5 within 2%, using the stage times
/// the simulator itself derived from the live batch.
#[test]
fn pipeline_full_matches_eq5_within_2pct() {
    let (plan, model, rep) = run_fixed(3, 1200, ExpertPopularity::Ideal, 42);
    assert_eq!(rep.completed, 1200);

    let it = IterationModel {
        t_a: rep.mean_t_a,
        t_e: rep.mean_t_e,
        t_c: rep.mean_t_c,
        m: plan.m,
        layers: model.layers,
    };
    assert!(
        it.pipeline_full(),
        "test premise: constraint 3 holds (m={} needs >= {})",
        plan.m,
        it.min_micro_batches()
    );
    let eq5 = it.t_total_eq5();
    let measured = rep.tpot.median();
    let rel = (measured - eq5).abs() / eq5;
    assert!(
        rel < 0.02,
        "simulated TPOT {measured} vs Eq.5 {eq5} (rel {rel})"
    );

    // Throughput follows: B tokens per iteration.
    let predicted_tput = plan.global_batch as f64 / eq5;
    let rel_tput = (rep.throughput - predicted_tput).abs() / predicted_tput;
    assert!(
        rel_tput < 0.02,
        "throughput {} vs Eq.5 prediction {predicted_tput} (rel {rel_tput})",
        rep.throughput
    );
}

/// Acceptance: below constraint 3 (m = 1) the pipeline cannot hide the
/// round trips — both pools idle while the closed form's assumptions break.
#[test]
fn utilization_gap_when_pipeline_not_full() {
    // Same per-micro-batch operating point: b_a and b_e identical across
    // the two runs (B scales with m).
    let (_, _, full) = run_fixed(3, 1200, ExpertPopularity::Ideal, 42);
    let (_, _, single) = run_fixed(1, 400, ExpertPopularity::Ideal, 42);

    // At this operating point (b_e = 100, tp_e = 2) the expert stage is
    // weight-load dominated and is the bottleneck pool: with the pipeline
    // full it saturates; with m = 1 it idles during attention + transfers.
    assert!(
        full.expert_utilization > 0.85,
        "full pipeline expert utilization {}",
        full.expert_utilization
    );
    assert!(
        single.expert_utilization < 0.75,
        "m=1 expert utilization {}",
        single.expert_utilization
    );
    assert!(
        single.expert_utilization < full.expert_utilization - 0.15,
        "expected a utilization gap: m=1 {} vs m=3 {}",
        single.expert_utilization,
        full.expert_utilization
    );
    // Per-token latency degrades without the overlap (both runs decode the
    // same per-micro-batch sizes; normalize by tokens per iteration).
    let per_token_single = single.tpot.median() / 400.0;
    let per_token_full = full.tpot.median() / 1200.0;
    assert!(
        per_token_single > 1.3 * per_token_full,
        "m=1 {per_token_single} vs m=3 {per_token_full} per-token latency"
    );
}

/// Determinism: identical config + seed ⇒ bit-identical metrics, through
/// the full router → batcher → gating → M2N → ping-pong composition,
/// including the simnet-calibrated transport and skewed gating draws.
#[test]
fn same_seed_is_bit_identical() {
    let run = || {
        let (model, cluster, plan) = fixed_plan(3, 240);
        let reqs = WorkloadSpec {
            median_input: 256.0,
            median_output: 8.0,
            sigma: 0.4,
            arrival_rate: Some(2000.0),
            burst_sigma: 0.8,
            ..Default::default()
        }
        .generate(300, 77);
        ClusterSim::new(ClusterSimConfig {
            popularity: ExpertPopularity::Zipf(1.0),
            transport: Transport::Simnet(LibraryKind::MegaScale),
            seed: 1234,
            ..ClusterSimConfig::new(model, cluster, plan)
        })
        .run(&reqs)
    };
    let a = run();
    let b = run();

    assert_eq!(a.completed, b.completed);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits(), "virtual time");
    assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
    assert_eq!(a.mean_t_a.to_bits(), b.mean_t_a.to_bits());
    assert_eq!(a.mean_t_e.to_bits(), b.mean_t_e.to_bits());
    assert_eq!(a.mean_t_c.to_bits(), b.mean_t_c.to_bits());
    assert_eq!(
        a.attn_utilization.to_bits(),
        b.attn_utilization.to_bits()
    );
    for p in [1.0, 50.0, 90.0, 99.0] {
        assert_eq!(a.ttft.percentile(p).to_bits(), b.ttft.percentile(p).to_bits());
        assert_eq!(a.tpot.percentile(p).to_bits(), b.tpot.percentile(p).to_bits());
        assert_eq!(a.e2e.percentile(p).to_bits(), b.e2e.percentile(p).to_bits());
    }
    assert_eq!(a.per_node_tokens, b.per_node_tokens);
    assert_eq!(a.dispatched_copies, b.dispatched_copies);
    assert_eq!(a.summary(), b.summary(), "rendered summaries identical");
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}

/// Different seeds must actually change stochastic outcomes (guards against
/// the RNG being plumbed to a constant).
#[test]
fn different_seed_changes_skewed_runs() {
    let run = |seed| {
        let (model, cluster, plan) = fixed_plan(3, 240);
        let reqs = constant_requests(240, 256, 6);
        ClusterSim::new(ClusterSimConfig {
            popularity: ExpertPopularity::Zipf(1.0),
            seed,
            ..ClusterSimConfig::new(model, cluster, plan)
        })
        .run(&reqs)
    };
    let a = run(1);
    let b = run(2);
    assert_ne!(
        a.mean_t_e.to_bits(),
        b.mean_t_e.to_bits(),
        "skewed expert loads should differ across seeds"
    );
}

/// Micro-batch sweep: throughput improves m=1 → m=2 by ~2x and m=3 adds a
/// smaller gain (Figure 12 shape) at a fixed per-micro-batch size.
#[test]
fn micro_batch_sweep_reproduces_figure12_shape() {
    let tput = |m: usize| {
        let (_, _, rep) = run_fixed(m, 400 * m, ExpertPopularity::Ideal, 3);
        rep.throughput
    };
    let t1 = tput(1);
    let t2 = tput(2);
    let t3 = tput(3);
    let g12 = t2 / t1;
    let g23 = t3 / t2;
    assert!((1.4..2.3).contains(&g12), "m1->m2 gain {g12}");
    // At this point m=2 already nearly saturates the bottleneck stage, so
    // the m=3 gain is marginal-to-modest (Figure 12's flattening tail).
    assert!((0.95..1.6).contains(&g23), "m2->m3 gain {g23}");
}

/// Token-copy conservation through the event graph: every copy the link
/// dispatches is processed by the expert pool and combined back, and the
/// totals equal tokens × layers × top_k.
#[test]
fn token_copies_conserved_end_to_end() {
    for pop in [
        ExpertPopularity::Ideal,
        ExpertPopularity::Zipf(1.0),
        ExpertPopularity::ZipfBalanced(1.0),
    ] {
        let (plan, model, rep) = run_fixed(3, 240, pop, 5);
        assert_eq!(rep.completed, plan.global_batch as u64);
        let expect = rep.tokens * model.layers as u64 * model.top_k as u64;
        assert_eq!(rep.dispatched_copies, expect, "{pop:?}");
        assert_eq!(rep.processed_copies, expect, "{pop:?}");
        assert_eq!(rep.combined_copies, expect, "{pop:?}");
    }
}

/// Multi-tenant traffic classes: per-class completions partition the total,
/// per-class SLO attainment is reported, and a lax SLO attains ~100%.
#[test]
fn tenant_classes_report_slo_attainment() {
    let (model, cluster, plan) = fixed_plan(3, 240);
    let tenants = vec![
        TenantClass {
            name: "interactive".into(),
            weight: 0.7,
            slo_e2e: 1e-6, // impossible: every request misses
        },
        TenantClass {
            name: "batch".into(),
            weight: 0.3,
            slo_e2e: 1e9, // trivially met
        },
    ];
    let reqs = WorkloadSpec {
        median_input: 256.0,
        median_output: 8.0,
        sigma: 0.3,
        tenants: tenants.clone(),
        ..Default::default()
    }
    .generate(240, 21);
    let rep = ClusterSim::new(ClusterSimConfig {
        seed: 21,
        tenants,
        ..ClusterSimConfig::new(model, cluster, plan)
    })
    .run(&reqs);
    assert_eq!(rep.completed, 240);
    assert_eq!(rep.tenants.len(), 2);
    let total: u64 = rep.tenants.iter().map(|t| t.completed).sum();
    assert_eq!(total, rep.completed, "classes partition completions");
    for t in &rep.tenants {
        assert!(t.completed > 0, "both classes saw traffic");
        assert_eq!(t.e2e.count(), t.completed);
    }
    assert_eq!(rep.tenants[0].attainment(), 0.0, "impossible SLO");
    assert_eq!(rep.tenants[1].attainment(), 1.0, "lax SLO");
    assert!(rep.summary().contains("tenant"), "summary lists classes");
}

/// Drifting popularity: with static placement the hot expert moves away
/// from wherever it was, so throughput stays depressed; periodic §6 online
/// re-balancing tracks the drift and recovers most of the loss.
#[test]
fn popularity_drift_hurts_and_periodic_rebalance_recovers() {
    // Needs a compute-bound expert stage (same reasoning as the §6 skew
    // test): use the searched Mixtral plan with a saturated batch.
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let plan = megascale_infer::plan::PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
        .search()
        .expect("mixtral plan");
    let n = plan.global_batch.min(8192);
    let reqs = WorkloadSpec {
        median_output: 12.0,
        sigma: 0.1,
        ..Default::default()
    }
    .generate(n, 7);
    let run = |pop, rebalance: Option<f64>| {
        ClusterSim::new(ClusterSimConfig {
            popularity: pop,
            seed: 9,
            rebalance_period: rebalance,
            // Decode-stage anchor: the identical prefill phase would
            // compress the drift-vs-rebalance throughput gaps.
            prefill_nodes: 0,
            ..ClusterSimConfig::new(model.clone(), cluster.clone(), plan.clone())
        })
        .run(&reqs)
    };
    let uniform = run(ExpertPopularity::Uniform, None);
    let drift = ExpertPopularity::ZipfDrifting {
        alpha: 1.2,
        period: 0.5,
    };
    let static_placement = run(drift, None);
    let rebalanced = run(drift, Some(0.125));
    assert_eq!(rebalanced.completed, n as u64);
    assert!(rebalanced.rebalances > 0, "re-balancing actually ran");
    assert_eq!(static_placement.rebalances, 0);
    assert!(
        static_placement.throughput < uniform.throughput * 0.9,
        "drifting skew should hurt: {} vs {}",
        static_placement.throughput,
        uniform.throughput
    );
    assert!(
        rebalanced.throughput > static_placement.throughput * 1.05,
        "online re-balancing should recover: {} vs {}",
        rebalanced.throughput,
        static_placement.throughput
    );
}

/// Satellite regression for the prefill state machine: the four TTFT
/// components (`queue + prefill + transfer + first-decode`) sum to the
/// reported TTFT, one sample per request each, and a prompt-heavy golden
/// workload shows prefill-DOMINATED TTFT — guarding against silently
/// reverting to the old queue-wait-only TTFT. Also pins the handoff
/// conservation counters: every completed request's prompt was prefilled
/// exactly once and shipped to a decode node exactly once.
#[test]
fn ttft_decomposition_sums_and_prefill_dominates_prompt_heavy() {
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    // Fixed-length prompt-heavy workload, open loop well below saturation
    // (prefill-pool utilization ~20%) so the queue component stays small
    // and prefill compute dominates.
    let spec = WorkloadSpec {
        median_input: 2048.0,
        median_output: 8.0,
        sigma: 0.0,
        arrival_rate: Some(5.0),
        ..Default::default()
    };
    let plan = megascale_infer::plan::PlanSearcher::new(
        model.clone(),
        cluster.clone(),
        spec.avg_seq_len(),
    )
    .search()
    .expect("mixtral plan");
    assert!(plan.n_p >= 1 && plan.tp_p >= 1, "search sizes a prefill pool");
    let reqs = spec.generate(24, 11);
    let rep = ClusterSim::new(ClusterSimConfig {
        seed: 11,
        ..ClusterSimConfig::new(model, cluster, plan)
    })
    .run(&reqs);
    assert_eq!(rep.completed, 24);

    // One sample per request in every component.
    assert_eq!(rep.ttft.count(), 24);
    for h in [
        &rep.ttft_queue,
        &rep.ttft_prefill,
        &rep.ttft_transfer,
        &rep.ttft_decode,
    ] {
        assert_eq!(h.count(), rep.ttft.count());
    }
    // The component sums telescope to the TTFT sum (exact up to fp).
    let sum = rep.ttft_queue.mean()
        + rep.ttft_prefill.mean()
        + rep.ttft_transfer.mean()
        + rep.ttft_decode.mean();
    let want = rep.ttft.mean();
    assert!(
        (sum - want).abs() <= 1e-6 * want.max(1e-9),
        "components {sum} vs TTFT {want}"
    );
    // Prompt-heavy golden: prefill is the majority of TTFT, and every
    // component that should be live is live.
    assert!(
        rep.ttft_prefill.mean() > 0.5 * want,
        "prefill {} should dominate TTFT {want}",
        rep.ttft_prefill.mean()
    );
    assert!(rep.ttft_transfer.mean() > 0.0, "KV shipping takes wire time");
    assert!(rep.ttft_decode.mean() > 0.0);

    // Handoff conservation: prompts prefilled once, shipped once; no KV
    // blocks leaked at quiescence.
    let prompt_tokens: u64 = reqs.iter().map(|r| r.input_len as u64).sum();
    assert_eq!(rep.prefilled_tokens, prompt_tokens);
    assert_eq!(rep.kv_transferred_tokens, prompt_tokens);
    assert_eq!(rep.kv_blocks_in_use_at_end, 0);
    assert_eq!(rep.unserved_queued, 0);
}

/// The heterogeneous H20 (attention) + L40S (expert) pairing of §4.3 runs
/// end to end through the engine with per-pool GpuSpecs and reports
/// per-node clocks for both pools.
#[test]
fn heterogeneous_pairing_reports_per_node_clocks() {
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::heterogeneous_h20_l40s();
    let plan = megascale_infer::plan::PlanSearcher::new(model.clone(), cluster.clone(), 514.0)
        .search()
        .expect("hetero plan");
    let n = plan.global_batch.min(512);
    let reqs = constant_requests(n, 512, 6);
    let rep = ClusterSim::new(ClusterSimConfig {
        seed: 3,
        ..ClusterSimConfig::new(model, cluster, plan.clone())
    })
    .run(&reqs);
    assert_eq!(rep.completed, n as u64);
    assert_eq!(rep.per_node_attn_busy.len(), plan.n_a.max(1));
    assert_eq!(rep.per_node_expert_busy.len(), plan.n_e.max(1));
    assert!(rep.per_node_attn_busy.iter().all(|&b| (0.0..=1.0).contains(&b)));
    assert!(rep.per_node_attn_busy.iter().any(|&b| b > 0.0));
    assert!(rep.per_node_expert_busy.iter().any(|&b| b > 0.0));
}
