//! Scenario-language test suite: the fixture corpus pins the parser's
//! golden diagnostics, hand-rolled property tests pin the AST
//! pretty-print round-trip and run determinism, conservation observers
//! pin the token/KV accounting under every injected fault type, and the
//! committed `scenarios/` library is exercised fused vs stepwise.

use std::fs;
use std::path::{Path, PathBuf};

use megascale_infer::sim::scenario::{
    compile, load, parse, ActionAst, InjectAst, PhaseAst, RateAst, ScenarioAst, TenantAst,
    DEFAULT_INPUT, DEFAULT_OUTPUT, DEFAULT_SIGMA,
};
use megascale_infer::sim::{run_sharded, ShardPlan, SimRng};
use megascale_infer::workload::{ArrivalSource, StridedSource};

fn fixture_dir(sub: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/scenario")
        .join(sub)
}

/// All files with extension `ext` in `dir`, sorted by name so failures
/// replay in a stable order.
fn files_with_ext(dir: &Path, ext: &str) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("reading {}: {e}", dir.display()))
        .map(|e| e.expect("directory entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == ext))
        .collect();
    files.sort();
    files
}

fn read(path: &Path) -> String {
    fs::read_to_string(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

// ---------------------------------------------------------------- corpus

/// Every positive fixture parses, and its canonical pretty-print parses
/// back to an identical AST.
#[test]
fn ok_corpus_parses_and_round_trips() {
    let files = files_with_ext(&fixture_dir("ok"), "msc");
    assert!(!files.is_empty(), "empty positive corpus");
    for path in files {
        let src = read(&path);
        let ast = parse(&src)
            .unwrap_or_else(|e| panic!("{} failed to parse: {e}", path.display()));
        let printed = ast.pretty();
        let reparsed = parse(&printed).unwrap_or_else(|e| {
            panic!("{}: pretty-print failed to re-parse: {e}", path.display())
        });
        assert_eq!(ast, reparsed, "{}: pretty-print round-trip", path.display());
    }
}

/// Every negative fixture fails with exactly the `line:col: message`
/// pinned in its sibling `.err` golden file.
#[test]
fn err_corpus_fails_with_golden_messages() {
    let files = files_with_ext(&fixture_dir("err"), "msc");
    assert!(!files.is_empty(), "empty negative corpus");
    for path in files {
        let src = read(&path);
        let golden_path = path.with_extension("err");
        let golden = read(&golden_path);
        let err = parse(&src).map(|_| ()).expect_err(&format!(
            "{} unexpectedly parsed (golden: {})",
            path.display(),
            golden.trim()
        ));
        assert_eq!(
            err.to_string(),
            golden.trim(),
            "{}: diagnostic drifted from its golden",
            path.display()
        );
    }
}

/// Corpus meta-test: both directories are populated and every golden is
/// paired with a fixture (and vice versa) — an orphaned file is a
/// corpus-maintenance bug, not a silent skip.
#[test]
fn corpus_is_paired_and_nonempty() {
    let ok = files_with_ext(&fixture_dir("ok"), "msc");
    assert!(ok.len() >= 5, "positive corpus too small: {}", ok.len());
    let err_dir = fixture_dir("err");
    let mscs = files_with_ext(&err_dir, "msc");
    let goldens = files_with_ext(&err_dir, "err");
    assert!(mscs.len() >= 10, "negative corpus too small: {}", mscs.len());
    assert_eq!(
        mscs.len(),
        goldens.len(),
        "every negative fixture needs exactly one .err golden"
    );
    for m in &mscs {
        assert!(
            m.with_extension("err").exists(),
            "{} has no golden .err",
            m.display()
        );
    }
}

// ------------------------------------------------------------- proptests

fn cases(n: usize) -> impl Iterator<Item = (u64, SimRng)> {
    (0..n as u64).map(|seed| (seed, SimRng::new(seed.wrapping_mul(0x9e37_79b9))))
}

/// A quarter-resolution draw in `[lo, hi)`: keeps generated sources
/// readable; `{:?}` round-trips any `f64` regardless.
fn qnum(rng: &mut SimRng, lo: f64, hi: f64) -> f64 {
    let steps = (((hi - lo) * 4.0) as usize).max(1);
    lo + rng.below(steps) as f64 / 4.0
}

fn gen_rate(rng: &mut SimRng) -> RateAst {
    match rng.below(3) {
        0 => RateAst::Constant(qnum(rng, 0.0, 100.0)),
        1 => RateAst::Ramp(qnum(rng, 0.0, 50.0), qnum(rng, 0.0, 50.0)),
        _ => RateAst::Sine {
            mean: qnum(rng, 1.0, 40.0),
            amplitude: qnum(rng, 0.0, 1.0),
            period: qnum(rng, 1.0, 20.0),
        },
    }
}

fn gen_action(rng: &mut SimRng) -> ActionAst {
    match rng.below(7) {
        0 => ActionAst::FailAttention(rng.below(4)),
        1 => ActionAst::RecoverAttention(rng.below(4)),
        2 => ActionAst::StraggleAttention {
            node: rng.below(4),
            factor: qnum(rng, 0.25, 4.0),
        },
        3 => ActionAst::DegradeNic {
            factor: qnum(rng, 0.25, 4.0),
        },
        4 => ActionAst::RestoreNic,
        5 => ActionAst::ShrinkExperts(1 + rng.below(3)),
        _ => ActionAst::GrowExperts(1 + rng.below(3)),
    }
}

fn gen_scenario(case: u64, rng: &mut SimRng) -> ScenarioAst {
    let mut tenants = Vec::new();
    for i in 0..rng.below(3) {
        tenants.push(TenantAst {
            name: format!("t{i}"),
            weight: qnum(rng, 0.25, 4.0),
            slo: qnum(rng, 0.5, 60.0),
        });
    }
    let mut phases = Vec::new();
    for i in 0..1 + rng.below(3) {
        let mix = if !tenants.is_empty() && rng.below(2) == 1 {
            let mut w = Vec::new();
            for _ in 0..tenants.len() {
                w.push(qnum(rng, 0.0, 4.0));
            }
            Some(w)
        } else {
            None
        };
        phases.push(PhaseAst {
            name: format!("p{i}"),
            duration: qnum(rng, 0.25, 10.0),
            rate: gen_rate(rng),
            input: if rng.below(2) == 0 {
                DEFAULT_INPUT
            } else {
                qnum(rng, 1.0, 512.0)
            },
            output: if rng.below(2) == 0 {
                DEFAULT_OUTPUT
            } else {
                qnum(rng, 1.0, 128.0)
            },
            sigma: if rng.below(2) == 0 {
                DEFAULT_SIGMA
            } else {
                qnum(rng, 0.0, 1.5)
            },
            mix,
        });
    }
    let mut injects = Vec::new();
    let mut t = 0.0;
    for _ in 0..rng.below(6) {
        t += qnum(rng, 0.0, 3.0);
        let action = gen_action(rng);
        injects.push(InjectAst { at: t, action });
    }
    ScenarioAst {
        name: format!("gen-{case}"),
        seed: rng.below(100_000) as u64,
        model: ["tiny", "mixtral", "dbrx", "scaled-moe"][rng.below(4)].to_string(),
        attn_gpu: ["ampere", "h20", "l40s"][rng.below(3)].to_string(),
        expert_gpu: if rng.below(2) == 0 {
            None
        } else {
            Some("l40s".to_string())
        },
        horizon: if rng.below(2) == 0 {
            None
        } else {
            Some(qnum(rng, 1.0, 60.0))
        },
        micro_batches: if rng.below(2) == 0 {
            None
        } else {
            Some(1 + rng.below(4))
        },
        prefill: if rng.below(2) == 0 {
            None
        } else {
            Some(rng.below(8))
        },
        skew: if rng.below(2) == 0 {
            None
        } else {
            Some(qnum(rng, 0.0, 2.0))
        },
        rebalance: if rng.below(2) == 0 {
            None
        } else {
            Some(qnum(rng, 0.5, 8.0))
        },
        tenants,
        phases,
        injects,
    }
}

/// AST → pretty-print → parse is the identity, for every AST the
/// generator can produce (the satellite property pinning the canonical
/// form against grammar drift).
#[test]
fn prop_ast_pretty_print_round_trips() {
    for (case, mut rng) in cases(300) {
        let ast = gen_scenario(case, &mut rng);
        let printed = ast.pretty();
        let reparsed = parse(&printed)
            .unwrap_or_else(|e| panic!("case {case}: pretty output failed to parse: {e}\n{printed}"));
        assert_eq!(ast, reparsed, "case {case}: round-trip drift\n{printed}");
    }
}

// --------------------------------------------------------- determinism

/// A small fault-bearing scenario used by the determinism properties;
/// `{seed}` is substituted per case.
fn fault_scenario_src(seed: u64) -> String {
    format!(
        r#"scenario "det" {{
  seed {seed}
  model tiny
  gpu ampere
  workload {{
    phase "steady" {{ duration 4 rate constant 30 input 96 output 24 sigma 0.3 }}
  }}
  inject {{
    at 0.7 fail attention 1
    at 1.3 degrade nic factor 2.0
    at 2.1 recover attention 1
    at 2.9 restore nic
  }}
}}"#
    )
}

fn report_json(rep: &megascale_infer::sim::ClusterReport) -> String {
    rep.to_json().to_string()
}

/// Same scenario + same seed → byte-identical report JSON across runs,
/// and across fused vs stepwise stepping.
#[test]
fn prop_same_seed_same_bytes() {
    for seed in [0u64, 7, 23] {
        let ast = parse(&fault_scenario_src(seed)).expect("parse");
        let compiled = compile(&ast).expect("compile");
        let a = report_json(&compiled.run());
        let b = report_json(&compiled.run());
        assert_eq!(a, b, "seed {seed}: repeat run diverged");
        let mut stepwise = compiled.clone();
        stepwise.cfg.fuse = false;
        let c = report_json(&stepwise.run());
        assert_eq!(a, c, "seed {seed}: fused vs stepwise diverged");
    }
}

/// Fault scenarios pin global node indices, so any `--shards` request
/// collapses to one shard — and the report stays byte-identical to the
/// direct run for every requested shard/worker combination.
#[test]
fn fault_scenarios_identical_across_shard_requests() {
    let ast = parse(&fault_scenario_src(5)).expect("parse");
    let compiled = compile(&ast).expect("compile");
    let direct = report_json(&compiled.run());
    for (shards, workers) in [(2, 1), (4, 2)] {
        let base = compiled.source();
        let rep = run_sharded(
            &compiled.cfg,
            ShardPlan::new(shards).with_workers(workers),
            move |shard, stride| -> Box<dyn ArrivalSource> {
                Box::new(StridedSource::new(base.clone(), shard, stride))
            },
        );
        assert_eq!(
            direct,
            report_json(&rep),
            "shards {shards} workers {workers} diverged from the direct run"
        );
    }
}

/// An injection-free phased scenario shards normally; the merged report
/// must not depend on how many worker threads step the shards.
#[test]
fn phased_scenarios_identical_across_worker_counts() {
    let src = r#"scenario "phased" {
  seed 13
  model tiny
  gpu ampere
  workload {
    phase "calm"  { duration 3 rate constant 20 input 96 output 24 sigma 0.3 }
    phase "spike" { duration 1 rate ramp 40 -> 120 input 48 output 16 sigma 0.3 }
  }
}"#;
    let compiled = compile(&parse(src).expect("parse")).expect("compile");
    let mut reports = Vec::new();
    for workers in [1usize, 4] {
        let base = compiled.source();
        let rep = run_sharded(
            &compiled.cfg,
            ShardPlan::new(2).with_workers(workers),
            move |shard, stride| -> Box<dyn ArrivalSource> {
                Box::new(StridedSource::new(base.clone(), shard, stride))
            },
        );
        reports.push(report_json(&rep));
    }
    assert_eq!(reports[0], reports[1], "worker count changed the report");
}

// -------------------------------------------------------- conservation

/// Injection schedules covering every fault type, alone and combined
/// (the last one fires everything at odd mid-iteration instants).
const FAULT_SCHEDULES: &[&str] = &[
    "",
    "at 1.0 fail attention 1",
    "at 0.8 fail attention 0 at 1.6 fail attention 1 \
     at 2.4 recover attention 0 at 3.2 recover attention 1",
    "at 1.0 straggle attention 0 factor 4.0 at 3.0 straggle attention 0 factor 1.0",
    "at 0.5 degrade nic factor 3.0 at 2.5 restore nic",
    "at 1.0 shrink experts 3 at 3.0 grow experts 3",
    "at 0.137 fail attention 0 at 0.81 degrade nic factor 2.0 \
     at 1.44 shrink experts 2 at 2.2 recover attention 0 \
     at 2.9 restore nic at 3.6 grow experts 2",
];

/// Token / KV-block conservation at quiescence under every fault type:
/// lost in-flight decode tokens and re-prefilled prompts are accounted
/// exactly, no KV slot leaks, and every generated request completes.
#[test]
fn conservation_holds_under_every_fault_type() {
    for (i, sched) in FAULT_SCHEDULES.iter().enumerate() {
        for seed in [3u64, 17] {
            let inject_block = if sched.is_empty() {
                String::new()
            } else {
                format!("inject {{ {sched} }}")
            };
            let src = format!(
                r#"scenario "conserve-{i}" {{
  seed {seed}
  model tiny
  gpu ampere
  workload {{
    phase "steady" {{ duration 5 rate constant 30 input 96 output 24 sigma 0.3 }}
  }}
  {inject_block}
}}"#
            );
            let compiled = compile(&parse(&src).expect("parse")).expect("compile");
            let tag = format!("schedule {i} seed {seed}");

            // Replay the arrival stream independently to get the ground
            // truth the report must reconcile against.
            let mut source = compiled.source();
            let (mut n, mut input_sum, mut output_sum) = (0u64, 0u64, 0u64);
            while let Some(r) = source.next_request() {
                n += 1;
                input_sum += r.input_len as u64;
                output_sum += r.output_len as u64;
            }
            assert!(n > 0, "{tag}: generator produced no requests");

            let rep = compiled.run();
            assert_eq!(rep.rejected, 0, "{tag}: nothing is infeasibly large");
            assert_eq!(rep.unserved_queued, 0, "{tag}: quiescence serves everyone");
            assert_eq!(rep.completed, n, "{tag}: every request completes");
            assert_eq!(rep.e2e.count(), n, "{tag}: one E2E sample per request");
            assert_eq!(
                rep.kv_blocks_in_use_at_end, 0,
                "{tag}: KV slots leaked across failures"
            );
            assert_eq!(
                rep.tokens,
                output_sum + rep.lost_decode_tokens,
                "{tag}: decode tokens = final outputs + discarded in-flight work"
            );
            if compiled.cfg.prefill_nodes > 0 {
                assert_eq!(
                    rep.prefilled_tokens,
                    input_sum + rep.re_prefilled_tokens,
                    "{tag}: prefilled tokens = prompts + re-prefills"
                );
            }
            assert!(
                rep.ttft.count() >= rep.completed,
                "{tag}: a completed request lost its TTFT sample"
            );
            assert!(
                rep.ttft.count() - rep.completed <= rep.requeued_requests,
                "{tag}: more duplicate TTFT samples than requeues"
            );
            assert_eq!(
                rep.dispatched_copies, rep.processed_copies,
                "{tag}: dispatched expert copies all processed"
            );
            assert_eq!(
                rep.dispatched_copies, rep.combined_copies,
                "{tag}: processed expert copies all combined"
            );
            assert_eq!(
                rep.injections_applied,
                compiled.cfg.injections.len() as u64,
                "{tag}: every scheduled injection fired"
            );
            if sched.is_empty() {
                assert_eq!(rep.requeued_requests, 0, "{tag}: requeues without faults");
                assert_eq!(rep.lost_kv_blocks, 0, "{tag}: losses without faults");
                assert_eq!(rep.lost_decode_tokens, 0, "{tag}: losses without faults");
            }
        }
    }
}

// --------------------------------------------------- committed library

fn scenario_library() -> Vec<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios");
    files_with_ext(&dir, "msc")
}

/// The committed scenario library loads, and every scenario's report is
/// byte-identical between the fused fast path and the stepwise
/// reference — including `midfault-regression.msc`, whose injections
/// all land mid-iteration.
#[test]
fn committed_scenarios_fused_equals_stepwise() {
    let lib = scenario_library();
    assert!(lib.len() >= 6, "scenario library too small: {}", lib.len());
    let names: Vec<String> = lib
        .iter()
        .map(|p| p.file_stem().expect("stem").to_string_lossy().into_owned())
        .collect();
    for required in ["node-failure", "flash-crowd", "midfault-regression"] {
        assert!(
            names.iter().any(|n| n == required),
            "scenario library is missing {required}.msc"
        );
    }
    for path in lib {
        let compiled = load(path.to_str().expect("utf-8 path"))
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(compiled.cfg.fuse, "scenarios default to the fused path");
        let fused = report_json(&compiled.run());
        let mut stepwise = compiled.clone();
        stepwise.cfg.fuse = false;
        assert_eq!(
            fused,
            report_json(&stepwise.run()),
            "{}: fused vs stepwise drift",
            path.display()
        );
    }
}

/// The node-failure scenario actually exercises the fault machinery —
/// a regression here means injections silently stopped doing anything.
#[test]
fn node_failure_scenario_loses_and_recovers_work() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios");
    let compiled = load(dir.join("node-failure.msc").to_str().expect("utf-8 path"))
        .expect("load node-failure.msc");
    let rep = compiled.run();
    assert_eq!(rep.node_failures, 1);
    assert_eq!(rep.node_recoveries, 1);
    assert_eq!(rep.injections_applied, 2);
    assert!(
        rep.requeued_requests > 0,
        "failing a loaded node must displace in-flight requests"
    );
    assert_eq!(rep.unserved_queued, 0);
    assert_eq!(rep.kv_blocks_in_use_at_end, 0);
}
