//! Integration tests for the pull-based arrival engine: bit-exact
//! equivalence between trace-backed and generator-backed sources, memory
//! bounded by the in-flight request count, and the rejected-vs-unserved
//! accounting split.

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::plan::PlanSearcher;
use megascale_infer::sim::cluster::{ClusterSim, ClusterSimConfig, ExpertPopularity};
use megascale_infer::workload::{Request, RequestStream, TenantClass, WorkloadSpec};

fn tiny_cfg(seed: u64, tenants: Vec<TenantClass>) -> ClusterSimConfig {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
        .search()
        .expect("tiny plan");
    ClusterSimConfig {
        seed,
        tenants,
        ..ClusterSimConfig::new(model, cluster, plan)
    }
}

/// Acceptance: a streaming generator-backed source and a preloaded trace of
/// the same requests produce byte-identical `ClusterReport` JSON for the
/// same seed — through bursty open-loop arrivals, skewed popularity, and
/// multi-tenant SLO accounting.
#[test]
fn streaming_source_matches_preloaded_trace_bit_exact() {
    let tenants = vec![
        TenantClass {
            name: "interactive".into(),
            weight: 0.7,
            slo_e2e: 2.0,
        },
        TenantClass {
            name: "batch".into(),
            weight: 0.3,
            slo_e2e: 60.0,
        },
    ];
    let spec = WorkloadSpec {
        arrival_rate: Some(300.0),
        burst_sigma: 0.5,
        tenants: tenants.clone(),
        ..WorkloadSpec::tiny_bench()
    };
    let (n, seed) = (400usize, 17u64);
    let mut cfg = tiny_cfg(seed, tenants);
    cfg.popularity = ExpertPopularity::Zipf(1.0);

    let preloaded = ClusterSim::new(cfg.clone()).run(&spec.generate(n, seed));
    let streamed = ClusterSim::new(cfg)
        .run_streaming(Box::new(RequestStream::new(spec, n, seed)));

    assert_eq!(preloaded.completed, n as u64);
    assert_eq!(
        preloaded.to_json().to_string(),
        streamed.to_json().to_string(),
        "identical JSON reports"
    );
    assert_eq!(preloaded.summary(), streamed.summary());
    assert_eq!(preloaded.elapsed.to_bits(), streamed.elapsed.to_bits());
}

/// Closed-loop equivalence too (every arrival at t=0 exercises the
/// same-timestamp arrival-burst absorption path).
#[test]
fn streaming_matches_preloaded_closed_loop() {
    let spec = WorkloadSpec::tiny_bench();
    let (n, seed) = (96usize, 5u64);
    let preloaded = ClusterSim::new(tiny_cfg(seed, Vec::new())).run(&spec.generate(n, seed));
    let streamed = ClusterSim::new(tiny_cfg(seed, Vec::new()))
        .run_streaming(Box::new(RequestStream::new(spec, n, seed)));
    assert_eq!(preloaded.completed, n as u64);
    assert_eq!(
        preloaded.to_json().to_string(),
        streamed.to_json().to_string()
    );
}

/// Acceptance: a long generator-backed run at a sub-saturation arrival rate
/// keeps the in-flight request table and event queue far below the trace
/// length — the engine never materializes the stream.
#[test]
fn streaming_memory_bounded_by_in_flight() {
    let spec = WorkloadSpec::tiny_bench();
    // Calibrate a service rate from a short closed-loop run, then stream an
    // open-loop workload at half that rate so queues stay stable.
    let cal = ClusterSim::new(tiny_cfg(3, Vec::new())).run(&spec.generate(512, 3));
    assert!(cal.throughput > 0.0);
    let rate = 0.5 * cal.throughput / spec.mean_output();

    let n = 50_000usize;
    let open = WorkloadSpec {
        arrival_rate: Some(rate),
        ..spec
    };
    let rep = ClusterSim::new(tiny_cfg(11, Vec::new()))
        .run_streaming(Box::new(RequestStream::new(open, n, 11)));
    assert_eq!(rep.completed, n as u64, "everything served");
    assert_eq!(rep.rejected, 0);
    assert_eq!(rep.unserved_queued, 0);
    assert!(
        rep.peak_in_flight < (n / 4) as u64,
        "in-flight high-water mark {} should be far below the {} requests streamed",
        rep.peak_in_flight,
        n
    );
    assert!(
        rep.peak_queue_events < (n / 4) as u64,
        "event queue stayed O(in-flight): peak {}",
        rep.peak_queue_events
    );
}

/// The rejected/unserved split: a request whose KV footprint exceeds every
/// node's whole budget is rejected at the front door (it could never be
/// placed), and — unlike the old accounting that let it clog the
/// strictly-FIFO overflow queue forever — the feasible requests behind it
/// are still served and no longer mislabeled as rejected.
#[test]
fn infeasible_request_rejected_feasible_queue_served() {
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
        .search()
        .expect("mixtral plan");
    let cfg = ClusterSimConfig {
        seed: 1,
        ..ClusterSimConfig::new(model, cluster, plan)
    };
    // Request 0: a prompt far beyond any attention node's total KV budget.
    let mut reqs = vec![Request {
        id: 0,
        arrival: 0.0,
        input_len: 50_000_000,
        output_len: 4,
        tenant: 0,
    }];
    // Requests 1..=8: ordinary, feasible, but queued behind the head.
    for id in 1..=8u64 {
        reqs.push(Request {
            id,
            arrival: 0.0,
            input_len: 512,
            output_len: 4,
            tenant: 0,
        });
    }
    let rep = ClusterSim::new(cfg).run(&reqs);
    assert_eq!(rep.rejected, 1, "only the infeasible request is rejected");
    assert_eq!(
        rep.completed, 8,
        "feasible requests behind the rejected head are served"
    );
    assert_eq!(rep.unserved_queued, 0);
    assert_eq!(rep.tokens, 32, "8 requests x 4 output tokens");
    // The rejected request's slot was recycled WITHOUT touching prefill or
    // KV state: only the 8 feasible prompts were prefilled and shipped,
    // and no blocks are left allocated.
    assert_eq!(rep.prefilled_tokens, 8 * 512);
    assert_eq!(rep.kv_transferred_tokens, 8 * 512);
    assert_eq!(rep.kv_blocks_in_use_at_end, 0);
}

/// A `max_sim_seconds` horizon cuts the run short and surfaces feasible
/// work still queued as `unserved_queued`; without a horizon the engine
/// runs to quiescence and the field is 0 (every admitted request is
/// eventually served).
#[test]
fn horizon_reports_unserved_queued() {
    let spec = WorkloadSpec::tiny_bench();
    let reqs = spec.generate(300, 5);
    let mut cfg = tiny_cfg(5, Vec::new());
    // Tiny decode batch: only a handful of the t=0 burst enter the first
    // iteration, the rest sit in node waiting queues...
    cfg.plan.global_batch = 8;
    // ...and the horizon lands before that first iteration finishes.
    cfg.max_sim_seconds = Some(1e-9);
    let rep = ClusterSim::new(cfg.clone()).run(&reqs);
    assert_eq!(rep.completed, 0);
    assert_eq!(rep.rejected, 0, "everything is feasible");
    assert_eq!(
        rep.completed + rep.rejected + rep.unserved_queued,
        300,
        "every arrival is accounted for (queued, waiting, or mid-decode)"
    );
    // Same scenario without the horizon: runs to quiescence, serves all.
    cfg.max_sim_seconds = None;
    let full = ClusterSim::new(cfg).run(&reqs);
    assert_eq!(full.completed, 300);
    assert_eq!(full.unserved_queued, 0);
}

/// Manual scale check (run with `cargo test -- --ignored`): one million
/// generator-backed requests complete with memory bounded by in-flight
/// requests. This is the acceptance scenario behind `msi sweep --bench`.
#[test]
#[ignore = "million-request scale check; run explicitly with --ignored"]
fn million_request_stream_completes() {
    let spec = WorkloadSpec::tiny_bench();
    let cal = ClusterSim::new(tiny_cfg(3, Vec::new())).run(&spec.generate(4096, 3));
    let rate = 0.85 * cal.throughput / spec.mean_output();
    let n = 1_000_000usize;
    let open = WorkloadSpec {
        arrival_rate: Some(rate),
        ..spec
    };
    let rep = ClusterSim::new(tiny_cfg(42, Vec::new()))
        .run_streaming(Box::new(RequestStream::new(open, n, 42)));
    assert_eq!(rep.completed, n as u64);
    assert!(
        rep.peak_in_flight < (n / 20) as u64,
        "peak in-flight {}",
        rep.peak_in_flight
    );
}
