//! Integration tests for ping-pong pipeline parallelism: the DES against
//! the paper's closed forms (Eq. 1-6, golden values pinned by hand) and the
//! Figure 12 ablation shape.

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::PingPongSim;
use megascale_infer::perf_model::{bandwidth_util, CommModel, IterationModel, PerfModel};

/// DES and Eq. 5 agree within 2% across a parameter sweep whenever the
/// pipeline-full condition (constraint 3) holds.
#[test]
fn des_matches_eq5_across_sweep() {
    for &(t_a, t_e, t_c) in &[
        (1.0, 1.0, 0.3),
        (1.0, 0.9, 0.2),
        (0.8, 1.0, 0.45),
        (2.0, 2.0, 0.1),
        (1.0, 1.0, 0.49),
    ] {
        for m in 3..=4 {
            for layers in [4usize, 16, 56] {
                let it = IterationModel {
                    t_a,
                    t_e,
                    t_c,
                    m,
                    layers,
                };
                if !it.pipeline_full() {
                    continue;
                }
                let sim = PingPongSim {
                    t_a,
                    t_e,
                    t_c,
                    m,
                    layers,
                }
                .run();
                let eq5 = it.t_total_eq5();
                let rel = (sim.total_time - eq5).abs() / eq5;
                assert!(
                    rel < 0.02,
                    "DES {} vs Eq5 {} at (t_a={t_a},t_e={t_e},t_c={t_c},m={m},L={layers})",
                    sim.total_time,
                    eq5
                );
            }
        }
    }
}

/// Figure 12 shape on real model timings: m=1 -> m=2 gives ~1.9x; m=2 -> 3
/// gives a further 1.05-1.45x; m=4 is marginal.
#[test]
fn figure12_shape_on_real_models() {
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    for model in ModelConfig::paper_models() {
        let pm = PerfModel::new(&model, &cluster, 8, 1, 730.0);
        // Balanced operating point, constant micro-batch size (the paper's
        // ablation keeps micro-batch size fixed and varies m).
        let b_a = 256.0;
        let n_a = 8.0;
        let b_e = b_a * n_a * model.top_k as f64 / model.experts as f64;
        let (t_a, t_e, t_c) = (pm.t_a(b_a), pm.t_e(b_e), pm.t_c(b_a, b_e));

        let tput = |m: usize| {
            let s = PingPongSim {
                t_a,
                t_e,
                t_c,
                m,
                layers: model.layers,
            }
            .run();
            // Tokens per unit time ∝ m·b / makespan.
            m as f64 / s.total_time
        };

        let g12 = tput(2) / tput(1);
        assert!(
            (1.5..2.2).contains(&g12),
            "{}: m1->m2 gain {g12:.2}",
            model.name
        );
        let g23 = tput(3) / tput(2);
        assert!(
            (1.0..1.5).contains(&g23),
            "{}: m2->m3 gain {g23:.2}",
            model.name
        );
        let g34 = tput(4) / tput(3);
        assert!(
            (0.95..1.15).contains(&g34),
            "{}: m3->m4 gain {g34:.2} should be marginal",
            model.name
        );
    }
}

/// Larger models benefit more from m=3 (paper: 1.10x, 1.28x, 1.38x for
/// Mixtral, DBRX, Scaled-MoE) because communication is relatively larger.
#[test]
fn m3_gain_ordering_follows_comm_share() {
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let gain = |model: &ModelConfig| {
        let pm = PerfModel::new(model, &cluster, 8, 1, 730.0);
        let b_a = 256.0;
        let b_e = b_a * 8.0 * model.top_k as f64 / model.experts as f64;
        let run = |m: usize| {
            let s = PingPongSim {
                t_a: pm.t_a(b_a),
                t_e: pm.t_e(b_e),
                t_c: pm.t_c(b_a, b_e),
                m,
                layers: model.layers,
            }
            .run();
            m as f64 / s.total_time
        };
        run(3) / run(2)
    };
    let mixtral = gain(&ModelConfig::mixtral_8x22b());
    let scaled = gain(&ModelConfig::scaled_moe());
    assert!(
        scaled >= mixtral * 0.98,
        "Scaled-MoE m3 gain {scaled:.3} should be >= Mixtral {mixtral:.3}"
    );
}

/// Golden values: Eq. 5 pinned to hand-computed literals, with the DES
/// landing within 2% (and exactly, for the zero-comm alternation case).
#[test]
fn golden_eq5_values() {
    // (t_a, t_e, t_c, m, L, hand-computed Eq.5 = (t_a+t_e+2t_c) + T_f(mL-1))
    let cases = [
        (1.0, 1.0, 0.3, 3usize, 8usize, 25.6),
        (2.0, 1.0, 0.4, 3, 10, 61.8),
        (0.5, 1.0, 0.2, 4, 16, 64.9),
        (1.0, 1.0, 0.0, 2, 4, 9.0),
    ];
    for &(t_a, t_e, t_c, m, layers, golden) in &cases {
        let it = IterationModel {
            t_a,
            t_e,
            t_c,
            m,
            layers,
        };
        assert!(it.pipeline_full(), "premise at {t_a},{t_e},{t_c},m={m}");
        let eq5 = it.t_total_eq5();
        assert!(
            (eq5 - golden).abs() < 1e-9,
            "Eq.5 formula drifted: {eq5} vs golden {golden}"
        );
        let des = PingPongSim {
            t_a,
            t_e,
            t_c,
            m,
            layers,
        }
        .run()
        .total_time;
        let rel = (des - golden).abs() / golden;
        assert!(rel < 0.02, "DES {des} vs golden {golden} (rel {rel})");
    }
    // Zero-comm balanced alternation is exact.
    let exact = PingPongSim {
        t_a: 1.0,
        t_e: 1.0,
        t_c: 0.0,
        m: 2,
        layers: 4,
    }
    .run()
    .total_time;
    assert!((exact - 9.0).abs() < 1e-12, "{exact}");
}

/// Golden Eq. 4: the DES respects the per-iteration bounds
/// `m·T_f·(L−1) < T_total < (T_a+T_e+2T_c) + m·T_f·L` in the full regime.
#[test]
fn golden_eq4_bounds_des() {
    for &(t_a, t_e, t_c, m, layers) in &[
        (1.0, 1.0, 0.3, 3usize, 8usize),
        (1.5, 1.0, 0.5, 4, 12),
        (1.0, 2.0, 0.9, 3, 24),
    ] {
        let it = IterationModel {
            t_a,
            t_e,
            t_c,
            m,
            layers,
        };
        if !it.pipeline_full() {
            continue;
        }
        let des = PingPongSim {
            t_a,
            t_e,
            t_c,
            m,
            layers,
        }
        .run()
        .total_time;
        let lower = m as f64 * it.t_f() * (layers as f64 - 1.0);
        let upper = (t_a + t_e + 2.0 * t_c) + m as f64 * it.t_f() * layers as f64;
        assert!(
            des > lower && des < upper,
            "DES {des} outside Eq.4 bounds ({lower}, {upper})"
        );
    }
}

/// Golden Eq. 6: the half-saturation utilization curve makes
/// `T = s / (W·Util(s))` algebraically equal to the LogP cost `s/W + o`,
/// and the Mixtral §7.3 dispatch example lands on the hand value.
#[test]
fn golden_eq6_comm_model() {
    let (bw, oh) = (25e9, 6e-6);
    for s in [1e3, 64e3, 256e3, 1e6, 16e6] {
        let t = s / (bw * bandwidth_util(s, bw, oh));
        let logp = s / bw + oh;
        assert!(
            (t - logp).abs() < 1e-12 * logp.max(1.0),
            "Util identity broken at {s}: {t} vs {logp}"
        );
    }

    // Mixtral 8x22B, b_a = 128, tp_a = 2, tp_e = 1 on 200 Gbps NICs:
    // send = recv = 128·6144·K/tp_a·2 = 1,572,864 bytes
    // T_c = 1,572,864/25e9 + 6e-6 = 68.91456 µs.
    let model = ModelConfig::mixtral_8x22b();
    let gpu = megascale_infer::config::GpuSpec::of(GpuKind::Ampere80G);
    let c = CommModel::new(&model, &gpu, &gpu, 2, 1);
    assert!((c.send_bytes(128.0) - 1_572_864.0).abs() < 1e-6);
    let t_c = c.time(128.0, 128.0);
    assert!(
        (t_c - 68.91456e-6).abs() < 1e-10,
        "Eq.6 golden drifted: {t_c}"
    );
}

/// Utilization collapses when one stage dominates (Figure 13 mechanics).
#[test]
fn dp_scan_moves_bottleneck() {
    let model = ModelConfig::dbrx();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let pm = PerfModel::new(&model, &cluster, 8, 4, 730.0);
    let b_a = 512.0;
    let util = |n_a: f64| {
        let b_e = b_a * n_a * model.top_k as f64 / model.experts as f64;
        PingPongSim {
            t_a: pm.t_a(b_a),
            t_e: pm.t_e(b_e),
            t_c: pm.t_c(b_a, b_e),
            m: 3,
            layers: model.layers,
        }
        .run()
    };
    // Few replicas: experts starve.
    let low = util(1.0);
    assert!(low.expert_utilization < 0.6, "{}", low.expert_utilization);
    // Many replicas: attention starves (experts become the bottleneck).
    let high = util(32.0);
    assert!(high.attn_utilization < 0.6, "{}", high.attn_utilization);
}
