//! Integration tests for ping-pong pipeline parallelism: the DES against
//! the paper's closed forms (Eq. 1-5) and the Figure 12 ablation shape.

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::PingPongSim;
use megascale_infer::perf_model::{IterationModel, PerfModel};

/// DES and Eq. 5 agree within 2% across a parameter sweep whenever the
/// pipeline-full condition (constraint 3) holds.
#[test]
fn des_matches_eq5_across_sweep() {
    for &(t_a, t_e, t_c) in &[
        (1.0, 1.0, 0.3),
        (1.0, 0.9, 0.2),
        (0.8, 1.0, 0.45),
        (2.0, 2.0, 0.1),
        (1.0, 1.0, 0.49),
    ] {
        for m in 3..=4 {
            for layers in [4usize, 16, 56] {
                let it = IterationModel {
                    t_a,
                    t_e,
                    t_c,
                    m,
                    layers,
                };
                if !it.pipeline_full() {
                    continue;
                }
                let sim = PingPongSim {
                    t_a,
                    t_e,
                    t_c,
                    m,
                    layers,
                }
                .run();
                let eq5 = it.t_total_eq5();
                let rel = (sim.total_time - eq5).abs() / eq5;
                assert!(
                    rel < 0.02,
                    "DES {} vs Eq5 {} at (t_a={t_a},t_e={t_e},t_c={t_c},m={m},L={layers})",
                    sim.total_time,
                    eq5
                );
            }
        }
    }
}

/// Figure 12 shape on real model timings: m=1 -> m=2 gives ~1.9x; m=2 -> 3
/// gives a further 1.05-1.45x; m=4 is marginal.
#[test]
fn figure12_shape_on_real_models() {
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    for model in ModelConfig::paper_models() {
        let pm = PerfModel::new(&model, &cluster, 8, 1, 730.0);
        // Balanced operating point, constant micro-batch size (the paper's
        // ablation keeps micro-batch size fixed and varies m).
        let b_a = 256.0;
        let n_a = 8.0;
        let b_e = b_a * n_a * model.top_k as f64 / model.experts as f64;
        let (t_a, t_e, t_c) = (pm.t_a(b_a), pm.t_e(b_e), pm.t_c(b_a, b_e));

        let tput = |m: usize| {
            let s = PingPongSim {
                t_a,
                t_e,
                t_c,
                m,
                layers: model.layers,
            }
            .run();
            // Tokens per unit time ∝ m·b / makespan.
            m as f64 / s.total_time
        };

        let g12 = tput(2) / tput(1);
        assert!(
            (1.5..2.2).contains(&g12),
            "{}: m1->m2 gain {g12:.2}",
            model.name
        );
        let g23 = tput(3) / tput(2);
        assert!(
            (1.0..1.5).contains(&g23),
            "{}: m2->m3 gain {g23:.2}",
            model.name
        );
        let g34 = tput(4) / tput(3);
        assert!(
            (0.95..1.15).contains(&g34),
            "{}: m3->m4 gain {g34:.2} should be marginal",
            model.name
        );
    }
}

/// Larger models benefit more from m=3 (paper: 1.10x, 1.28x, 1.38x for
/// Mixtral, DBRX, Scaled-MoE) because communication is relatively larger.
#[test]
fn m3_gain_ordering_follows_comm_share() {
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let gain = |model: &ModelConfig| {
        let pm = PerfModel::new(model, &cluster, 8, 1, 730.0);
        let b_a = 256.0;
        let b_e = b_a * 8.0 * model.top_k as f64 / model.experts as f64;
        let run = |m: usize| {
            let s = PingPongSim {
                t_a: pm.t_a(b_a),
                t_e: pm.t_e(b_e),
                t_c: pm.t_c(b_a, b_e),
                m,
                layers: model.layers,
            }
            .run();
            m as f64 / s.total_time
        };
        run(3) / run(2)
    };
    let mixtral = gain(&ModelConfig::mixtral_8x22b());
    let scaled = gain(&ModelConfig::scaled_moe());
    assert!(
        scaled >= mixtral * 0.98,
        "Scaled-MoE m3 gain {scaled:.3} should be >= Mixtral {mixtral:.3}"
    );
}

/// Utilization collapses when one stage dominates (Figure 13 mechanics).
#[test]
fn dp_scan_moves_bottleneck() {
    let model = ModelConfig::dbrx();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let pm = PerfModel::new(&model, &cluster, 8, 4, 730.0);
    let b_a = 512.0;
    let util = |n_a: f64| {
        let b_e = b_a * n_a * model.top_k as f64 / model.experts as f64;
        PingPongSim {
            t_a: pm.t_a(b_a),
            t_e: pm.t_e(b_e),
            t_c: pm.t_c(b_a, b_e),
            m: 3,
            layers: model.layers,
        }
        .run()
    };
    // Few replicas: experts starve.
    let low = util(1.0);
    assert!(low.expert_utilization < 0.6, "{}", low.expert_utilization);
    // Many replicas: attention starves (experts become the bottleneck).
    let high = util(32.0);
    assert!(high.attn_utilization < 0.6, "{}", high.attn_utilization);
}
