//! Integration tests for the M2N communication study (paper §5, §7.3):
//! the headline comparisons of Figures 5, 10 and 11 in shape.

use megascale_infer::m2n::{simulate_m2n, LibraryKind, LibraryProfile, M2nScenario, M2nStats};

fn run(kind: LibraryKind, m: usize, n: usize, kib: usize, rounds: usize) -> M2nStats {
    simulate_m2n(&M2nScenario {
        profile: LibraryProfile::of(kind),
        senders: m,
        receivers: n,
        msg_bytes: kib * 1024,
        rounds,
        bidirectional: false,
        seed: 1234,
    })
}

/// Figure 10 @256KB (paper headline): >=50% median latency reduction,
/// >=80% P99 reduction, >=3x throughput vs NCCL.
#[test]
fn fig10_headline_256kb() {
    let ours = run(LibraryKind::MegaScale, 8, 8, 256, 600);
    let nccl = run(LibraryKind::Nccl, 8, 8, 256, 600);

    let med_red = 1.0 - ours.latency.median() / nccl.latency.median();
    assert!(med_red > 0.5, "median reduction {med_red:.2} (paper 68.2%)");

    let p99_red = 1.0 - ours.latency.p99() / nccl.latency.p99();
    assert!(p99_red > 0.6, "p99 reduction {p99_red:.2} (paper 92.9%)");

    let speedup = ours.throughput / nccl.throughput;
    assert!(
        (3.0..8.0).contains(&speedup),
        "throughput speedup {speedup:.2} (paper 4.2x)"
    );
}

/// Figure 10 across sizes: MegaScale wins median latency and throughput at
/// every size; the small-message regime shows the largest reductions
/// (paper: up to 80.8% median reduction).
#[test]
fn fig10_all_sizes() {
    let mut best_small_reduction = 0.0f64;
    for kib in [8usize, 32, 128, 256, 512, 1024] {
        let ours = run(LibraryKind::MegaScale, 8, 8, kib, 300);
        let nccl = run(LibraryKind::Nccl, 8, 8, kib, 300);
        assert!(
            ours.latency.median() < nccl.latency.median(),
            "median at {kib}KiB"
        );
        assert!(ours.throughput > nccl.throughput, "throughput at {kib}KiB");
        if kib <= 32 {
            best_small_reduction = best_small_reduction
                .max(1.0 - ours.latency.median() / nccl.latency.median());
        }
    }
    assert!(
        best_small_reduction > 0.6,
        "small-message reduction {best_small_reduction:.2}"
    );
}

/// Figure 11: scaling M=N with 256KB messages — MegaScale wins throughput
/// 3-8x and cuts tail latency everywhere.
#[test]
fn fig11_mn_scaling() {
    for mn in [8usize, 16, 32] {
        let ours = run(LibraryKind::MegaScale, mn, mn, 256, 200);
        let nccl = run(LibraryKind::Nccl, mn, mn, 256, 200);
        let tput = ours.throughput / nccl.throughput;
        assert!(
            tput > 2.5,
            "M=N={mn}: throughput ratio {tput:.2} (paper 3.3-5.8x)"
        );
        let tail_red = 1.0 - ours.latency.p99() / nccl.latency.p99();
        assert!(
            tail_red > 0.5,
            "M=N={mn}: tail reduction {tail_red:.2} (paper 54.7-96.9%)"
        );
    }
}

/// Figure 5: one-to-N — NCCL above the perftest floor at every N, with a
/// growing tail ratio; perftest stays tight.
#[test]
fn fig5_one_to_n() {
    let mut last_gap = 0.0;
    for n in [8usize, 16, 32] {
        let nccl = run(LibraryKind::Nccl, 1, n, 128, 800);
        let pt = run(LibraryKind::Perftest, 1, n, 128, 800);
        let gap = nccl.latency.median() / pt.latency.median();
        assert!(gap > 1.3, "N={n}: NCCL/perftest median gap {gap:.2}");
        last_gap = gap;
        // perftest tail stays tight (paper: "only a slight increase").
        let pt_tail = pt.latency.p99() / pt.latency.median();
        assert!(pt_tail < 1.3, "N={n}: perftest tail ratio {pt_tail:.2}");
    }
    assert!(last_gap > 1.3);
}

/// Bidirectional ping-pong traffic: the high-priority-ACK design keeps
/// MegaScale flat while NCCL degrades (the §5 traffic-oriented fix).
#[test]
fn bidirectional_ack_priority() {
    let bi = |kind| {
        let uni = simulate_m2n(&M2nScenario {
            profile: LibraryProfile::of(kind),
            senders: 8,
            receivers: 8,
            msg_bytes: 256 * 1024,
            rounds: 300,
            bidirectional: false,
            seed: 7,
        });
        let bid = simulate_m2n(&M2nScenario {
            profile: LibraryProfile::of(kind),
            senders: 8,
            receivers: 8,
            msg_bytes: 256 * 1024,
            rounds: 300,
            bidirectional: true,
            seed: 7,
        });
        bid.latency.median() / uni.latency.median()
    };
    let ours = bi(LibraryKind::MegaScale);
    let nccl = bi(LibraryKind::Nccl);
    assert!(ours < 1.05, "MegaScale bidirectional penalty {ours:.3}");
    assert!(nccl > ours, "NCCL penalty {nccl:.3} should exceed ours {ours:.3}");
}
