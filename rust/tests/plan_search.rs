//! Integration tests for Algorithm 1: plan feasibility, SLO adherence,
//! paper-shaped outcomes across all three evaluation models, and the
//! heterogeneous §4.3 result.

use megascale_infer::baselines::{best_under_slo, minimal_deployment, BaselineKind};
use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::perf_model::IterationModel;
use megascale_infer::plan::{search_heterogeneous, PlanSearcher, SearchLimits};

fn ampere() -> ClusterSpec {
    ClusterSpec::homogeneous(GpuKind::Ampere80G)
}

#[test]
fn plans_satisfy_all_paper_constraints() {
    for model in ModelConfig::paper_models() {
        let searcher = PlanSearcher::new(model.clone(), ampere(), 730.0);
        for plan in searcher.search_all() {
            let m = &plan.metrics;
            // Constraint 7 (SLO).
            assert!(m.tpot <= 0.150 + 1e-9, "{}: SLO violated", model.name);
            // Constraint 2 via the iteration model.
            let it = IterationModel {
                t_a: m.t_a,
                t_e: m.t_e,
                t_c: m.t_c,
                m: plan.m,
                layers: model.layers,
            };
            assert!(it.comm_hidden(), "{}: T_c >= T_f", model.name);
            // Paper search space: m in {3, 4}, tp in {1,2,4,8}.
            assert!(plan.m >= 3 && plan.m <= 4);
            assert!([1, 2, 4, 8].contains(&plan.tp_a));
            assert!([1, 2, 4, 8].contains(&plan.tp_e));
        }
        // The *optimal* plan must fill the pipeline (constraint 3) or be at
        // the micro-batch ceiling N_m.
        let best = searcher.search().unwrap();
        let it = IterationModel {
            t_a: best.metrics.t_a,
            t_e: best.metrics.t_e,
            t_c: best.metrics.t_c,
            m: best.m,
            layers: model.layers,
        };
        assert!(
            it.pipeline_full() || best.m == 4,
            "{}: optimal plan m={} leaves bubbles (needs {})",
            model.name,
            best.m,
            it.min_micro_batches()
        );
    }
}

#[test]
fn megascale_beats_baselines_per_gpu_throughput() {
    // Figure 8 shape: MSI > TRT-LLM > vLLM on per-GPU decoding throughput,
    // for every model.
    for model in ModelConfig::paper_models() {
        let searcher = PlanSearcher::new(model.clone(), ampere(), 730.0);
        let plan = searcher.search().expect("plan");
        let msi = plan.metrics.per_gpu_throughput;

        let vllm = best_under_slo(
            &minimal_deployment(BaselineKind::Vllm, &model, &ampere()),
            &model,
            &ampere(),
            730.0,
            0.150,
        )
        .expect("vllm point")
        .per_gpu_throughput;
        let trt = best_under_slo(
            &minimal_deployment(BaselineKind::TrtLlm, &model, &ampere()),
            &model,
            &ampere(),
            730.0,
            0.150,
        )
        .expect("trt point")
        .per_gpu_throughput;

        assert!(
            msi > trt && trt > vllm,
            "{}: expected MSI({msi:.2}) > TRT({trt:.2}) > vLLM({vllm:.2})",
            model.name
        );
        let vs_vllm = msi / vllm;
        let vs_trt = msi / trt;
        // Paper: 2.56x/1.28x (Mixtral+DBRX avg) up to 7.11x/1.90x
        // (Scaled-MoE). Accept the band [1.1, 12].
        assert!(
            (1.1..12.0).contains(&vs_vllm),
            "{}: vs vLLM {vs_vllm:.2}",
            model.name
        );
        assert!(
            (1.05..4.0).contains(&vs_trt),
            "{}: vs TRT {vs_trt:.2}",
            model.name
        );
    }
}

#[test]
fn scaled_moe_gains_most() {
    // Paper: the advantage grows with sparsity/scale (Scaled-MoE 1.90x vs
    // TRT-LLM, Mixtral 1.28x).
    let gain = |model: &ModelConfig| {
        let plan = PlanSearcher::new(model.clone(), ampere(), 730.0)
            .search()
            .unwrap();
        let trt = best_under_slo(
            &minimal_deployment(BaselineKind::TrtLlm, model, &ampere()),
            model,
            &ampere(),
            730.0,
            0.150,
        )
        .unwrap();
        plan.metrics.per_gpu_throughput / trt.per_gpu_throughput
    };
    let mixtral = gain(&ModelConfig::mixtral_8x22b());
    let scaled = gain(&ModelConfig::scaled_moe());
    assert!(
        scaled > mixtral,
        "Scaled-MoE gain {scaled:.2} should exceed Mixtral gain {mixtral:.2}"
    );
}

#[test]
fn heterogeneous_h20_attention_l40s_experts_wins() {
    // §4.3/Figure 9: the best pairing assigns H20 to attention and L40S to
    // experts.
    let model = ModelConfig::mixtral_8x22b();
    let results = search_heterogeneous(
        &model,
        &[GpuKind::H20, GpuKind::L40S],
        730.0,
        &SearchLimits::default(),
    );
    let best = &results[0];
    assert_eq!(best.attention_gpu, GpuKind::H20, "best attention GPU");
    assert_eq!(best.expert_gpu, GpuKind::L40S, "best expert GPU");
}

#[test]
fn larger_slo_allows_larger_batches() {
    // Short sequences so the KV-memory constraint (Eq. 8) does not bind
    // before the SLO does.
    let model = ModelConfig::dbrx();
    let mut s = PlanSearcher::new(model, ampere(), 200.0);
    s.limits.slo = 0.050;
    let tight = s.search().unwrap().global_batch;
    s.limits.slo = 0.300;
    let loose = s.search().unwrap().global_batch;
    assert!(loose > tight, "loose {loose} vs tight {tight}");
}

#[test]
fn balance_tracks_expert_count() {
    // More experts (lower K/E) => more attention replicas needed to feed
    // each expert to saturation.
    let s_mix = PlanSearcher::new(ModelConfig::mixtral_8x22b(), ampere(), 730.0);
    let s_scaled = PlanSearcher::new(ModelConfig::scaled_moe(), ampere(), 730.0);
    // K/E: Mixtral 1/4, Scaled 1/8 — Scaled needs proportionally more DP.
    assert!(s_scaled.balance(8, 1) >= s_mix.balance(8, 1));
}
