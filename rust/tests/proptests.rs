//! Property-based tests over coordinator invariants. The proptest crate is
//! unavailable offline, so this is a hand-rolled harness: seeded random
//! case generation (1000+ cases per property), with the failing seed
//! printed on assert so cases replay deterministically.

use megascale_infer::baselines::{BaselineKind, ColocatedPlan};
use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::{
    balance_experts, build_dispatch, combine_expert_outputs, gather_expert_input, softmax_topk,
    BlockAllocator, KvCacheConfig,
};
use megascale_infer::metrics::Histogram;
use megascale_infer::perf_model::IterationModel;
use megascale_infer::plan::PlanSearcher;
use megascale_infer::sim::cluster::{
    draw_gating, popularity_weights, ClusterSim, ClusterSimConfig, ExpertPopularity,
};
use megascale_infer::sim::{EventQueue, SimRng};
use megascale_infer::workload::WorkloadSpec;

fn cases(n: usize) -> impl Iterator<Item = (u64, SimRng)> {
    (0..n as u64).map(|seed| (seed, SimRng::new(seed.wrapping_mul(0x9e3779b9))))
}

/// Dispatch conservation: every (token, expert) pair appears exactly once;
/// per-expert loads sum to batch*k; weights stay aligned.
#[test]
fn prop_dispatch_conserves_tokens() {
    for (seed, mut rng) in cases(500) {
        let batch = 1 + rng.below(200);
        let experts = 2 + rng.below(62);
        let k = 1 + rng.below(experts.min(8));
        let logits: Vec<f32> = (0..batch * experts)
            .map(|_| (rng.uniform() * 10.0 - 5.0) as f32)
            .collect();
        let g = softmax_topk(&logits, experts, k);
        let plan = build_dispatch(&g, experts);

        assert_eq!(plan.total_dispatched(), batch * k, "seed {seed}");
        let mut seen = vec![0u8; batch * experts];
        for e in 0..experts {
            let (tokens, weights) = plan.expert_slice(e);
            assert_eq!(tokens.len(), weights.len(), "seed {seed}");
            for &t in tokens {
                let idx = t as usize * experts + e;
                assert_eq!(seen[idx], 0, "seed {seed}: duplicate routing");
                seen[idx] = 1;
            }
        }
        let routed: usize = seen.iter().map(|&x| x as usize).sum();
        assert_eq!(routed, batch * k, "seed {seed}");

        // Weights per token sum to ~1 across its k experts.
        let mut per_token = vec![0f32; batch];
        for e in 0..experts {
            let (tokens, weights) = plan.expert_slice(e);
            for (&t, &w) in tokens.iter().zip(weights) {
                assert!(w >= 0.0, "seed {seed}");
                per_token[t as usize] += w;
            }
        }
        for (t, s) in per_token.iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-4, "seed {seed} token {t}: {s}");
        }
    }
}

/// Cluster-simulator gating: for arbitrary (tokens, experts, top-k, skew),
/// the popularity-biased draw conserves token-copies end to end across the
/// M2N boundary — every dispatched copy lands on exactly one expert, the
/// per-expert loads sum to `tokens·k`, and the identity-expert combine
/// reconstructs each token with weight exactly 1.
#[test]
fn prop_cluster_gating_conserves_tokens_across_m2n() {
    for (seed, mut rng) in cases(200) {
        let tokens = 1 + rng.below(300);
        let experts = 2 + rng.below(62);
        let k = 1 + rng.below(experts.min(8));
        let alpha = rng.uniform() * 2.0;
        let mut perm_rng = SimRng::new(seed.wrapping_add(1));
        let weights = popularity_weights(experts, alpha, &mut perm_rng);
        let s: f64 = weights.iter().sum();
        assert!((s - 1.0).abs() < 1e-9, "seed {seed}: popularity normalized");

        let g = draw_gating(&mut rng, tokens, &weights, k);
        let plan = build_dispatch(&g, experts);

        // Conservation of dispatched copies.
        assert_eq!(plan.total_dispatched(), tokens * k, "seed {seed}");
        let loads = g.expert_loads(experts);
        assert_eq!(loads.iter().sum::<usize>(), tokens * k, "seed {seed}");
        for e in 0..experts {
            assert_eq!(plan.expert_load(e), loads[e], "seed {seed} expert {e}");
        }

        // Simulated M2N round trip with identity experts: gather each
        // expert's rows, send them back, combine — recovers every token.
        let hidden = 4;
        let x: Vec<f32> = (0..tokens * hidden).map(|i| i as f32).collect();
        let outs: Vec<Vec<f32>> = (0..experts)
            .map(|e| gather_expert_input(&plan, e, &x, hidden))
            .collect();
        let combined = combine_expert_outputs(&plan, &outs, tokens, hidden);
        for (i, (a, b)) in combined.iter().zip(&x).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 * b.abs().max(1.0),
                "seed {seed} elem {i}: {a} vs {b}"
            );
        }
    }
}

/// Top-k selection: ids are valid and distinct; weights are descending when
/// logits are distinct; softmax invariance under shift.
#[test]
fn prop_topk_valid_and_shift_invariant() {
    for (seed, mut rng) in cases(500) {
        let experts = 2 + rng.below(30);
        let k = 1 + rng.below(experts);
        let logits: Vec<f32> = (0..experts).map(|_| (rng.uniform() * 8.0) as f32).collect();
        let g = softmax_topk(&logits, experts, k);
        let ids = g.experts_of(0).to_vec();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), k, "seed {seed}: distinct ids");
        assert!(ids.iter().all(|&e| (e as usize) < experts), "seed {seed}");

        // Shift invariance.
        let shifted: Vec<f32> = logits.iter().map(|x| x + 3.7).collect();
        let g2 = softmax_topk(&shifted, experts, k);
        assert_eq!(g.experts, g2.experts, "seed {seed}");
        for (a, b) in g.weights.iter().zip(&g2.weights) {
            assert!((a - b).abs() < 1e-5, "seed {seed}");
        }
    }
}

/// KV allocator: blocks are conserved under arbitrary admit/append/release
/// interleavings; no block is ever double-owned.
#[test]
fn prop_kv_allocator_conservation() {
    for (seed, mut rng) in cases(300) {
        let blocks = 8 + rng.below(120);
        let mut alloc = BlockAllocator::new(KvCacheConfig {
            block_size: 1 + rng.below(32),
            num_blocks: blocks,
        });
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..400 {
            match rng.below(3) {
                0 => {
                    let tokens = 1 + rng.below(64);
                    if alloc.admit(next_id, tokens) {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 => {
                    if !live.is_empty() {
                        let id = live[rng.below(live.len())];
                        let _ = alloc.append_token(id);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let id = live.swap_remove(rng.below(live.len()));
                        alloc.release(id);
                    }
                }
            }
            assert_eq!(
                alloc.free_blocks() + alloc.allocated_blocks(),
                blocks,
                "seed {seed}: conservation"
            );
        }
        for id in live {
            alloc.release(id);
        }
        assert_eq!(alloc.free_blocks(), blocks, "seed {seed}: full return");
        assert_eq!(alloc.num_requests(), 0, "seed {seed}");
    }
}

/// Load balancer: fractions sum to 1, makespan never exceeds the
/// single-node total, and is within 1% of the fractional optimum.
#[test]
fn prop_balance_fractional_optimum() {
    for (seed, mut rng) in cases(400) {
        let experts = 1 + rng.below(64);
        let nodes = 1 + rng.below(16);
        let cold = rng.uniform() * 5.0;
        let costs: Vec<f64> = (0..experts)
            .map(|_| (rng.uniform() * 100.0).powf(1.5))
            .collect();
        let p = balance_experts(&costs, nodes, cold);
        let total: f64 = costs.iter().map(|c| c.max(cold)).sum();
        let opt = total / nodes as f64;
        assert!(
            p.makespan <= opt * 1.01 + 1e-9,
            "seed {seed}: makespan {} vs opt {opt}",
            p.makespan
        );
        for (i, asg) in p.assignments.iter().enumerate() {
            let s: f64 = asg.iter().map(|(_, f)| f).sum();
            if costs[i].max(cold) > 0.0 {
                assert!((s - 1.0).abs() < 1e-6, "seed {seed} expert {i}: {s}");
            }
            for &(node, frac) in asg {
                assert!(node < nodes && frac > 0.0, "seed {seed}");
            }
        }
    }
}

/// Event queue: pops are globally time-ordered with FIFO tie-breaking, for
/// arbitrary interleaved schedules.
#[test]
fn prop_event_queue_ordering() {
    for (seed, mut rng) in cases(200) {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut id = 0u64;
        let mut last = (0.0f64, 0u64);
        let mut pending = 0usize;
        for _ in 0..500 {
            if pending == 0 || rng.chance(0.6) {
                let delay = rng.exponential(1.0);
                q.schedule_in(delay, id);
                id += 1;
                pending += 1;
            } else {
                let (t, _) = q.pop().unwrap();
                pending -= 1;
                assert!(t >= last.0, "seed {seed}: time went backwards");
                last = (t, 0);
            }
        }
        while let Some((t, _)) = q.pop() {
            assert!(t >= last.0, "seed {seed}");
            last.0 = t;
        }
    }
}

/// Event queue: under arbitrary interleavings of absolute and relative
/// scheduling — including bursts of identical timestamps — pops never go
/// back in time and events sharing a timestamp come out in insertion order.
#[test]
fn prop_event_queue_fifo_at_equal_timestamps() {
    for (seed, mut rng) in cases(200) {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut id = 0u64;
        // Quantize times to a handful of values to force many ties.
        let mut last_time = f64::NEG_INFINITY;
        let mut last_id_at: std::collections::HashMap<u64, u64> =
            std::collections::HashMap::new();
        for _ in 0..300 {
            let n_push = 1 + rng.below(4);
            for _ in 0..n_push {
                let slot = rng.below(5) as f64;
                let at = q.now() + slot * 0.25;
                if rng.chance(0.5) {
                    q.schedule_at(at, id);
                } else {
                    q.schedule_in(at - q.now(), id);
                }
                id += 1;
            }
            let n_pop = rng.below(n_push + 1);
            for _ in 0..n_pop {
                let Some((t, e)) = q.pop() else { break };
                assert!(t >= last_time, "seed {seed}: time regressed");
                let key = t.to_bits();
                if let Some(&prev) = last_id_at.get(&key) {
                    if t == last_time {
                        assert!(
                            e > prev,
                            "seed {seed}: FIFO violated at t={t}: {prev} before {e}"
                        );
                    }
                }
                last_id_at.insert(key, e);
                last_time = t;
            }
        }
        let mut prev = last_time;
        while let Some((t, _)) = q.pop() {
            assert!(t >= prev, "seed {seed}");
            prev = t;
        }
    }
}

/// Eq. 5 is an upper-bound-tight description: the DES never beats it and
/// never exceeds it by more than one stage time when the pipeline is full.
#[test]
fn prop_eq5_bounds_des() {
    use megascale_infer::coordinator::PingPongSim;
    for (seed, mut rng) in cases(150) {
        let t_a = 0.1 + rng.uniform() * 2.0;
        let t_e = 0.1 + rng.uniform() * 2.0;
        let tf = t_a.max(t_e);
        let t_c = rng.uniform() * 0.49 * tf; // constraint 2 regime
        let m = 3 + rng.below(2);
        let layers = 2 + rng.below(30);
        let it = IterationModel {
            t_a,
            t_e,
            t_c,
            m,
            layers,
        };
        if !it.pipeline_full() {
            continue;
        }
        let sim = PingPongSim {
            t_a,
            t_e,
            t_c,
            m,
            layers,
        }
        .run();
        let eq5 = it.t_total_eq5();
        assert!(
            sim.total_time >= eq5 * 0.999 - 1e-9,
            "seed {seed}: DES {} beat Eq5 {eq5}",
            sim.total_time
        );
        assert!(
            sim.total_time <= eq5 + 2.0 * tf + 2.0 * t_c + 1e-9,
            "seed {seed}: DES {} far above Eq5 {eq5}",
            sim.total_time
        );
    }
}

/// End-to-end token conservation across the event-driven engine's
/// components, for arbitrary event interleavings: random workloads (closed
/// and open loop, bursty, skewed/drifting popularity, varying micro-batch
/// counts) produce arbitrary interleavings of Arrive/Place/IterBegin/Pipe/
/// Rebalance events, and in every one of them
///
/// * every generated output token is decoded exactly once
///   (`tokens == Σ output_len` when all requests complete),
/// * every token crosses the M2N link as exactly `top_k` copies per layer
///   (`dispatched == tokens·L·K`), and
/// * every dispatched copy is processed by the expert pool and combined
///   back (`dispatched == processed == combined`).
#[test]
fn prop_engine_conserves_tokens_across_components() {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
        .search()
        .expect("tiny plan");
    let layers = model.layers as u64;
    let top_k = model.top_k as u64;
    for (seed, mut rng) in cases(60) {
        let n = 1 + rng.below(48);
        let open_loop = rng.chance(0.5);
        let spec = WorkloadSpec {
            median_input: 32.0 + rng.uniform() * 64.0,
            median_output: 2.0 + rng.uniform() * 10.0,
            sigma: 0.2 + rng.uniform() * 0.4,
            arrival_rate: open_loop.then(|| 20.0 + rng.uniform() * 200.0),
            burst_sigma: if open_loop { rng.uniform() } else { 0.0 },
            ..Default::default()
        };
        let reqs = spec.generate(n, seed.wrapping_add(100));
        let popularity = match rng.below(4) {
            0 => ExpertPopularity::Uniform,
            1 => ExpertPopularity::Zipf(0.5 + rng.uniform()),
            2 => ExpertPopularity::ZipfBalanced(0.5 + rng.uniform()),
            _ => ExpertPopularity::ZipfDrifting {
                alpha: 0.5 + rng.uniform(),
                period: 0.01 + rng.uniform() * 0.1,
            },
        };
        let mut plan = plan.clone();
        plan.m = 1 + rng.below(4);
        let rep = ClusterSim::new(ClusterSimConfig {
            popularity,
            seed: seed.wrapping_mul(31),
            rebalance_period: rng.chance(0.5).then(|| 0.005 + rng.uniform() * 0.05),
            ..ClusterSimConfig::new(model.clone(), cluster.clone(), plan)
        })
        .run(&reqs);

        assert_eq!(rep.completed, n as u64, "seed {seed}: all requests complete");
        let want: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        assert_eq!(rep.tokens, want, "seed {seed}: every output token decoded once");
        assert_eq!(
            rep.dispatched_copies,
            rep.tokens * layers * top_k,
            "seed {seed}: top_k copies per token per layer cross the link"
        );
        assert_eq!(
            rep.dispatched_copies, rep.processed_copies,
            "seed {seed}: every dispatched copy reaches an expert"
        );
        assert_eq!(
            rep.dispatched_copies, rep.combined_copies,
            "seed {seed}: every dispatched copy is combined back"
        );
        let per_node: u64 = rep.per_node_tokens.iter().sum();
        assert_eq!(per_node, rep.tokens, "seed {seed}: per-node tokens partition");
    }
}

/// KV-block conservation across the prefill→decode handoff and request
/// slot recycling, under arbitrary event interleavings: random workloads
/// (closed and open loop, both engine modes, random chunk budgets and pool
/// sizes, with occasional front-door rejections) must neither leak nor
/// double-free KV blocks or table slots — whether requests are rejected,
/// cut off by a `max_sim_seconds` horizon, or complete normally.
#[test]
fn prop_prefill_handoff_conserves_kv_blocks_and_slots() {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let base_plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
        .search()
        .expect("tiny plan");
    for (seed, mut rng) in cases(40) {
        let n = 4 + rng.below(40);
        let open = rng.chance(0.5);
        let spec = WorkloadSpec {
            median_input: 16.0 + rng.uniform() * 128.0,
            median_output: 2.0 + rng.uniform() * 8.0,
            sigma: 0.3,
            arrival_rate: open.then(|| 50.0 + rng.uniform() * 400.0),
            ..Default::default()
        };
        let reqs = spec.generate(n, seed.wrapping_add(7));
        let colocated = rng.chance(0.3);
        let chunk = [64usize, 512, 2048][rng.below(3)];
        let mut cfg = if colocated {
            let cplan = ColocatedPlan::sized_to_match(BaselineKind::Vllm, &model, &cluster, 8);
            ClusterSimConfig::colocated(model.clone(), cluster.clone(), cplan)
        } else {
            let mut plan = base_plan.clone();
            plan.m = 1 + rng.below(3);
            ClusterSimConfig::new(model.clone(), cluster.clone(), plan)
        };
        cfg.seed = seed.wrapping_mul(17).wrapping_add(3);
        cfg.prefill_chunk = chunk;
        if !colocated {
            cfg.prefill_nodes = 1 + rng.below(3);
        }

        // Quiescent run: everything completes; no leaked blocks, prompts
        // prefilled (and, disaggregated, shipped) exactly once. The
        // front-door rejection leg of the slot-recycling story is pinned
        // by `streaming::infeasible_request_rejected_feasible_queue_served`.
        let rep = ClusterSim::new(cfg.clone()).run(&reqs);
        assert_eq!(rep.completed as usize, reqs.len(), "seed {seed}");
        assert_eq!(rep.rejected, 0, "seed {seed}");
        assert_eq!(rep.unserved_queued, 0, "seed {seed}");
        assert_eq!(
            rep.kv_blocks_in_use_at_end, 0,
            "seed {seed}: leaked KV blocks at quiescence"
        );
        let prompt: u64 = reqs.iter().map(|r| r.input_len as u64).sum();
        assert_eq!(
            rep.prefilled_tokens, prompt,
            "seed {seed}: every admitted prompt prefilled exactly once"
        );
        if colocated {
            assert_eq!(rep.kv_transferred_tokens, 0, "seed {seed}: inline KV");
        } else {
            assert_eq!(
                rep.kv_transferred_tokens, prompt,
                "seed {seed}: every prompt shipped exactly once"
            );
        }
        assert!(rep.peak_in_flight <= reqs.len() as u64, "seed {seed}");

        // Horizon-cut run (closed loop so every request arrives): the
        // workload partitions exactly into completed/unserved at ANY
        // cutoff, and a fully-drained cutoff holds no blocks.
        let mut closed = reqs.clone();
        for r in &mut closed {
            r.arrival = 0.0;
        }
        let mut hcfg = cfg.clone();
        hcfg.max_sim_seconds = Some(1e-9 + rng.uniform() * rep.elapsed);
        let hrep = ClusterSim::new(hcfg).run(&closed);
        assert_eq!(
            hrep.completed + hrep.rejected + hrep.unserved_queued,
            reqs.len() as u64,
            "seed {seed}: horizon partition"
        );
        assert!(hrep.prefilled_tokens <= prompt, "seed {seed}");
        if hrep.unserved_queued == 0 {
            assert_eq!(
                hrep.kv_blocks_in_use_at_end, 0,
                "seed {seed}: drained horizon run holds no blocks"
            );
        }
    }
}

/// Generator-backed streaming arrivals are non-decreasing in time, start at
/// or after t=0, and are bit-identical to the preloaded trace
/// `WorkloadSpec::generate` builds from the same (spec, n, seed) — the
/// contract the pull-based engine relies on.
#[test]
fn prop_stream_arrivals_monotone_and_match_generate() {
    use megascale_infer::workload::RequestStream;
    for (seed, mut rng) in cases(300) {
        let n = rng.below(400);
        let open = rng.chance(0.7);
        let spec = WorkloadSpec {
            median_input: 8.0 + rng.uniform() * 600.0,
            median_output: 2.0 + rng.uniform() * 200.0,
            sigma: 0.1 + rng.uniform(),
            arrival_rate: open.then(|| 0.5 + rng.uniform() * 500.0),
            burst_sigma: if open { rng.uniform() * 1.5 } else { 0.0 },
            ..Default::default()
        };
        let streamed: Vec<_> = RequestStream::new(spec.clone(), n, seed).collect();
        assert_eq!(streamed.len(), n, "seed {seed}");
        for w in streamed.windows(2) {
            assert!(
                w[1].arrival >= w[0].arrival,
                "seed {seed}: arrivals must be non-decreasing"
            );
        }
        assert!(
            streamed.iter().all(|r| r.arrival >= 0.0),
            "seed {seed}: arrivals start at or after t=0"
        );
        assert_eq!(
            streamed,
            spec.generate(n, seed),
            "seed {seed}: stream and preloaded trace identical"
        );
    }
}

/// Histogram percentiles agree with exact order statistics within the
/// documented 3% relative error, for log-uniform samples.
#[test]
fn prop_histogram_accuracy() {
    for (seed, mut rng) in cases(50) {
        let n = 5000 + rng.below(20_000);
        let mut h = Histogram::new();
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            let v = 10f64.powf(rng.uniform() * 6.0 - 6.0); // 1e-6 .. 1
            h.record(v);
            vals.push(v);
        }
        vals.sort_by(|a, b| a.total_cmp(b));
        for p in [50.0, 90.0, 99.0] {
            let exact = vals[((p / 100.0) * (n as f64 - 1.0)).round() as usize];
            let est = h.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.05, "seed {seed} p{p}: est {est} exact {exact}");
        }
    }
}

/// The fused-iteration fast path is BYTE-identical to the stepwise
/// reference: for arbitrary workloads (open and closed loop), expert
/// popularity skews, micro-batch counts, rebalance cadences, prefill chunk
/// budgets, engine modes, and horizon cuts, running the same trace with
/// `fuse: true` and `fuse: false` must serialize to the exact same JSON
/// report — same token counts, same RNG-driven expert loads, same latency
/// percentiles, same peak queue depth. This is the contract that lets the
/// fast path replace ~3·m·L pipe events per iteration with one `IterEnd`.
#[test]
fn prop_fused_matches_stepwise_byte_identical() {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let base_plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
        .search()
        .expect("tiny plan");
    for (seed, mut rng) in cases(40) {
        let n = 2 + rng.below(40);
        let open = rng.chance(0.5);
        let spec = WorkloadSpec {
            median_input: 16.0 + rng.uniform() * 96.0,
            median_output: 2.0 + rng.uniform() * 10.0,
            sigma: 0.3,
            arrival_rate: open.then(|| 30.0 + rng.uniform() * 300.0),
            burst_sigma: if open { rng.uniform() } else { 0.0 },
            ..Default::default()
        };
        let reqs = spec.generate(n, seed.wrapping_add(13));
        let colocated = rng.chance(0.25);
        let mut cfg = if colocated {
            let cplan = ColocatedPlan::sized_to_match(BaselineKind::Vllm, &model, &cluster, 8);
            ClusterSimConfig::colocated(model.clone(), cluster.clone(), cplan)
        } else {
            let mut plan = base_plan.clone();
            plan.m = 1 + rng.below(4);
            ClusterSimConfig::new(model.clone(), cluster.clone(), plan)
        };
        cfg.seed = seed.wrapping_mul(29).wrapping_add(5);
        cfg.popularity = match rng.below(4) {
            0 => ExpertPopularity::Uniform,
            1 => ExpertPopularity::Zipf(0.5 + rng.uniform()),
            2 => ExpertPopularity::ZipfBalanced(0.5 + rng.uniform()),
            _ => ExpertPopularity::ZipfDrifting {
                alpha: 0.5 + rng.uniform(),
                period: 0.01 + rng.uniform() * 0.1,
            },
        };
        cfg.rebalance_period = rng.chance(0.4).then(|| 0.005 + rng.uniform() * 0.05);
        cfg.prefill_chunk = [0usize, 64, 1024][rng.below(3)];
        if rng.chance(0.3) {
            cfg.max_sim_seconds = Some(1e-4 + rng.uniform() * 0.05);
        }
        assert!(cfg.fuse, "seed {seed}: fast path is the default");

        let fused = ClusterSim::new(cfg.clone()).run(&reqs);
        cfg.fuse = false;
        let stepwise = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(
            fused.to_json().to_string(),
            stepwise.to_json().to_string(),
            "seed {seed}: fused and stepwise reports must be byte-identical"
        );
    }
}

/// Macro-stepping (collapsing externally-quiet decode iterations into one
/// bulk advance) is BYTE-identical to per-iteration stepping: for
/// arbitrary workloads, expert popularity skews (including drifting),
/// rebalance cadences, fault/elasticity schedules, and horizon cuts that
/// bisect a span, running the same trace with `macro_step: true` and
/// `macro_step: false` must serialize to the exact same JSON report. A
/// third run with the fused fast path ALSO disabled pins that the whole
/// fast-path stack (macro over fused over stepwise) collapses to one
/// answer. This is the contract that lets a quiet span cost O(1) boundary
/// scans instead of O(k).
#[test]
fn prop_macro_step_matches_stepwise_byte_identical() {
    use megascale_infer::sim::{FaultInjection, FaultKind};
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let base_plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
        .search()
        .expect("tiny plan");
    for (seed, mut rng) in cases(40) {
        let n = 2 + rng.below(40);
        let open = rng.chance(0.4);
        let spec = WorkloadSpec {
            median_input: 16.0 + rng.uniform() * 96.0,
            // Long enough decodes that closed-loop cases form real spans
            // (the span length is min remaining output across the batch).
            median_output: 4.0 + rng.uniform() * 28.0,
            sigma: 0.3,
            arrival_rate: open.then(|| 30.0 + rng.uniform() * 300.0),
            burst_sigma: if open { rng.uniform() } else { 0.0 },
            ..Default::default()
        };
        let reqs = spec.generate(n, seed.wrapping_add(17));
        let colocated = rng.chance(0.2);
        let mut cfg = if colocated {
            let cplan = ColocatedPlan::sized_to_match(BaselineKind::Vllm, &model, &cluster, 8);
            ClusterSimConfig::colocated(model.clone(), cluster.clone(), cplan)
        } else {
            let mut plan = base_plan.clone();
            plan.m = 1 + rng.below(4);
            ClusterSimConfig::new(model.clone(), cluster.clone(), plan)
        };
        cfg.seed = seed.wrapping_mul(37).wrapping_add(7);
        cfg.popularity = match rng.below(4) {
            0 => ExpertPopularity::Uniform,
            1 => ExpertPopularity::Zipf(0.5 + rng.uniform()),
            2 => ExpertPopularity::ZipfBalanced(0.5 + rng.uniform()),
            _ => ExpertPopularity::ZipfDrifting {
                alpha: 0.5 + rng.uniform(),
                period: 0.01 + rng.uniform() * 0.1,
            },
        };
        cfg.rebalance_period = rng.chance(0.4).then(|| 0.005 + rng.uniform() * 0.05);
        cfg.prefill_chunk = [0usize, 64, 1024][rng.below(3)];
        // Fault/elasticity schedules: injections are external events, so a
        // span must never step across one. Failures always get a matching
        // recovery so closed-loop runs still quiesce.
        let n_a = cfg.plan.n_a.max(1);
        let mut injections = Vec::new();
        if n_a >= 2 && rng.chance(0.5) {
            let node = rng.below(n_a);
            let at = rng.uniform() * 0.02;
            injections.push(FaultInjection {
                at,
                kind: FaultKind::FailAttention { node },
                counted: true,
            });
            injections.push(FaultInjection {
                at: at + 0.005 + rng.uniform() * 0.05,
                kind: FaultKind::RecoverAttention { node },
                counted: true,
            });
        }
        if rng.chance(0.4) {
            injections.push(FaultInjection {
                at: rng.uniform() * 0.05,
                kind: FaultKind::StraggleAttention {
                    node: rng.below(n_a),
                    factor: 1.0 + rng.uniform() * 3.0,
                },
                counted: true,
            });
        }
        if rng.chance(0.4) {
            injections.push(FaultInjection {
                at: rng.uniform() * 0.05,
                kind: FaultKind::DegradeNic {
                    factor: 1.0 + rng.uniform() * 2.0,
                },
                counted: true,
            });
        }
        if !colocated && rng.chance(0.4) {
            // Shrink or grow, staying within the model's expert count —
            // the bound `msi scenario` compilation enforces.
            let target = (1 + rng.below(cfg.plan.n_e.max(1) * 2)).min(model.experts.max(1));
            injections.push(FaultInjection {
                at: rng.uniform() * 0.05,
                kind: FaultKind::ResizeExperts { n_e: target },
                counted: true,
            });
        }
        cfg.injections = injections;
        if rng.chance(0.3) {
            // Horizon cut landing mid-run — typically bisecting a span.
            cfg.max_sim_seconds = Some(1e-4 + rng.uniform() * 0.05);
        }
        assert!(cfg.macro_step, "seed {seed}: macro-stepping is the default");
        assert!(cfg.fuse, "seed {seed}: fused fast path is the default");

        let macro_run = ClusterSim::new(cfg.clone()).run(&reqs);
        cfg.macro_step = false;
        let stepped = ClusterSim::new(cfg.clone()).run(&reqs);
        assert_eq!(
            macro_run.to_json().to_string(),
            stepped.to_json().to_string(),
            "seed {seed}: macro and per-iteration reports must be byte-identical"
        );
        cfg.fuse = false;
        let unfused = ClusterSim::new(cfg).run(&reqs);
        assert_eq!(
            macro_run.to_json().to_string(),
            unfused.to_json().to_string(),
            "seed {seed}: macro report must match the unfused stepwise reference"
        );
    }
}

/// Reference event queue for the equivalence property below: the seed's
/// original `BinaryHeap` implementation, kept verbatim in spirit —
/// earliest time first, insertion order among equal timestamps.
struct RefQueue<E> {
    heap: std::collections::BinaryHeap<RefItem<E>>,
    now: f64,
    seq: u64,
}

struct RefItem<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for RefItem<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}
impl<E> Eq for RefItem<E> {}
impl<E> PartialOrd for RefItem<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for RefItem<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Inverted: BinaryHeap is a max-heap, we want the min.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<E> RefQueue<E> {
    fn new() -> Self {
        Self {
            heap: std::collections::BinaryHeap::new(),
            now: 0.0,
            seq: 0,
        }
    }
    fn schedule_at(&mut self, at: f64, event: E) {
        let time = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(RefItem { time, seq, event });
    }
    fn pop(&mut self) -> Option<(f64, E)> {
        let it = self.heap.pop()?;
        self.now = it.time;
        Some((it.time, it.event))
    }
}

/// The indexed calendar queue is pop-for-pop identical — (time, payload)
/// pairs, which pins the (time, seq) order — to a plain binary-heap
/// reference under arbitrary interleavings of schedules and pops,
/// including same-timestamp bursts, clustered times that force ties, and
/// far-future outliers that force bucket rehashing.
#[test]
fn prop_indexed_queue_matches_binary_heap_reference() {
    for (seed, mut rng) in cases(300) {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut r: RefQueue<u64> = RefQueue::new();
        let mut id = 0u64;
        let mut recent: Vec<f64> = Vec::new();
        for _ in 0..400 {
            if q.is_empty() || rng.chance(0.55) {
                let at = if !recent.is_empty() && rng.chance(0.3) {
                    // Reuse an exact earlier timestamp (if still valid) to
                    // force a tie resolved purely by insertion order.
                    recent[rng.below(recent.len())].max(q.now())
                } else if rng.chance(0.05) {
                    // Far-future outlier: lands outside the current bucket
                    // span and exercises the direct-search fallback.
                    q.now() + 1e6 * (1.0 + rng.uniform())
                } else {
                    q.now() + rng.exponential(0.5)
                };
                q.schedule_at(at, id);
                r.schedule_at(at, id);
                if recent.len() < 32 {
                    recent.push(at);
                }
                id += 1;
            } else {
                let got = q.pop();
                let want = r.pop();
                match (got, want) {
                    (Some((tg, eg)), Some((tw, ew))) => {
                        assert!(
                            tg.to_bits() == tw.to_bits() && eg == ew,
                            "seed {seed}: indexed ({tg}, {eg}) != reference ({tw}, {ew})"
                        );
                    }
                    (g, w) => panic!("seed {seed}: {g:?} vs {w:?}"),
                }
                recent.retain(|&t| t >= q.now());
            }
        }
        // Drain both completely.
        loop {
            match (q.pop(), r.pop()) {
                (None, None) => break,
                (Some((tg, eg)), Some((tw, ew))) => {
                    assert!(
                        tg.to_bits() == tw.to_bits() && eg == ew,
                        "seed {seed} drain: indexed ({tg}, {eg}) != reference ({tw}, {ew})"
                    );
                }
                (g, w) => panic!("seed {seed} drain: {g:?} vs {w:?}"),
            }
        }
        assert_eq!(q.len(), 0);
    }
}
