//! Steady-state allocation budget for the cluster engine's decode loop.
//!
//! The fused fast path plus buffer recycling (pipeline core, stage
//! context, micro-batch splits, prefill scratch, stats) is supposed to
//! make the per-iteration decode loop allocation-free. This test pins
//! that property with a counting `#[global_allocator]`: two identical
//! closed-loop runs that differ ONLY in output length (256 vs 1024
//! tokens, i.e. ~768 extra decode iterations) must allocate nearly the
//! same number of times — the difference per extra iteration must be
//! far below one.
//!
//! The test lives in its own integration-test binary because a global
//! allocator is process-wide: it must not skew allocation-sensitive
//! timing in other test binaries.
//!
//! The budget is NOT zero: a longer run legitimately allocates a little —
//! per-request KV block lists (`Vec<u32>`) double a couple more times as
//! sequences grow, latency histograms grow their exact-value arrays
//! until the 4096-sample cap, and the calendar queue re-sizes its bucket
//! array every 16384 pops. All of those are amortized-O(1) and bounded;
//! what the budget catches is any change that allocates once (or more)
//! per iteration — a fresh `Vec` in the stage-time closure, a cloned
//! `PipelineStats`, a rebuilt roofline model — which would add ≥768
//! allocations here and trip the bound immediately.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::plan::PlanSearcher;
use megascale_infer::sim::cluster::{ClusterSim, ClusterSimConfig, ExpertPopularity};
use megascale_infer::workload::Request;

/// A pass-through allocator that counts `alloc`/`realloc` calls.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: delegates verbatim to `System`; the counter has no effect on
// the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Closed-loop scenario: every request present at t=0, instant prefill
/// (`prefill_chunk == 0`), deterministic `Ideal` routing, no rebalancing
/// — the steady state is pure decode iterations.
fn scenario(n: usize, output_len: usize) -> (ClusterSimConfig, Vec<Request>) {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), 200.0)
        .search()
        .expect("tiny plan");
    let mut cfg = ClusterSimConfig::new(model, cluster, plan);
    cfg.seed = 7;
    cfg.plan.global_batch = n; // admit the whole workload in one wave
    cfg.prefill_chunk = 0;
    // `Ideal` routing is the zero-alloc path the throughput bench runs;
    // weighted popularity draws allocate inside the production
    // gating/dispatch code by design (see DESIGN.md).
    cfg.popularity = ExpertPopularity::Ideal;
    let reqs = (0..n as u64)
        .map(|id| Request {
            id,
            arrival: 0.0,
            input_len: 32,
            output_len,
            tenant: 0,
        })
        .collect();
    (cfg, reqs)
}

/// Run the scenario and return (allocations during the run, iterations).
fn measure(n: usize, output_len: usize) -> (u64, u64) {
    let (cfg, reqs) = scenario(n, output_len);
    let sim = ClusterSim::new(cfg);
    let before = ALLOCS.load(Ordering::Relaxed);
    let rep = sim.run(&reqs);
    let allocs = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(rep.completed, n as u64, "closed loop must drain");
    (allocs, rep.iterations)
}

#[test]
fn decode_loop_is_allocation_free_in_steady_state() {
    let n = 64;
    // Warm up lazily-initialized process state (stdout, test-harness
    // buffers) so it doesn't land in either measurement.
    let _ = measure(n, 8);

    let (short_allocs, short_iters) = measure(n, 256);
    let (long_allocs, long_iters) = measure(n, 1024);
    let extra_iters = long_iters - short_iters;
    assert!(
        extra_iters >= 512,
        "scenario mis-sized: only {extra_iters} extra iterations"
    );

    // The two runs are identical until the short one drains, so the
    // delta is exactly what the extra ~768 decode iterations allocate.
    let delta = long_allocs.saturating_sub(short_allocs);
    let budget = extra_iters / 2;
    assert!(
        delta < budget,
        "steady-state decode loop allocates: {delta} extra allocations over \
         {extra_iters} extra iterations (budget {budget}; short run {short_allocs}, \
         long run {long_allocs}) — a per-iteration allocation crept into the \
         fused path"
    );
}

/// Sweep-cell recycling: a run that adopts a warmed [`EngineScratch`]
/// (request table, pipeline core, fused queue, span/event scratch from a
/// previous cell) must allocate strictly fewer times than an identical
/// fresh run — and produce a byte-identical report. This is the property
/// `run_sweep` relies on to amortize engine state across a grid instead
/// of rebuilding it per cell.
#[test]
fn recycled_scratch_allocates_less_than_fresh_run() {
    use megascale_infer::sim::{ClusterEngine, EngineScratch};
    use megascale_infer::workload::TraceSource;

    let n = 64;
    // Warm up lazily-initialized process state.
    let _ = measure(n, 8);

    let (cfg, reqs) = scenario(n, 64);
    let (fresh_allocs, fresh_json) = {
        let cfg = cfg.clone();
        let src = Box::new(TraceSource::new(reqs.clone()));
        let before = ALLOCS.load(Ordering::Relaxed);
        let rep = ClusterEngine::new(cfg, src).run();
        let allocs = ALLOCS.load(Ordering::Relaxed) - before;
        assert_eq!(rep.completed, n as u64, "fresh run drains");
        (allocs, rep.to_json().to_string())
    };

    let mut scratch = EngineScratch::default();
    // First recycled run only warms the scratch buffers.
    let _ = ClusterEngine::new(cfg.clone(), Box::new(TraceSource::new(reqs.clone())))
        .run_recycled(&mut scratch);
    let (warm_allocs, warm_json) = {
        let cfg = cfg.clone();
        let src = Box::new(TraceSource::new(reqs.clone()));
        let before = ALLOCS.load(Ordering::Relaxed);
        let rep = ClusterEngine::new(cfg, src).run_recycled(&mut scratch);
        let allocs = ALLOCS.load(Ordering::Relaxed) - before;
        assert_eq!(rep.completed, n as u64, "recycled run drains");
        (allocs, rep.to_json().to_string())
    };

    assert_eq!(
        warm_json, fresh_json,
        "recycling must not change the report in any byte"
    );
    assert!(
        warm_allocs < fresh_allocs,
        "warmed scratch must cut allocations: fresh {fresh_allocs}, \
         recycled {warm_allocs}"
    );
}
