//! End-to-end PJRT integration: load the JAX/Pallas-AOT artifacts, execute
//! them through the `xla` crate, and check numerics against JAX golden
//! outputs recorded at compile time (manifest `test_vectors`).
//!
//! Requires `make artifacts` to have been run; tests skip (with a notice)
//! when the artifacts directory is absent so `cargo test` works standalone.

use std::path::{Path, PathBuf};

use megascale_infer::runtime::{
    artifacts::{ArtifactManifest, WeightStore},
    tensor::{i32_literal, HostTensor},
    Engine, ServingEngine,
};
use megascale_infer::workload::WorkloadSpec;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

fn allclose(name: &str, got: &[f32], want: &[f32], atol: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length mismatch");
    let mut worst = 0f32;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let diff = (g - w).abs();
        let tol = atol + 1e-3 * w.abs();
        assert!(
            diff <= tol.max(atol),
            "{name}[{i}]: got {g}, want {w} (diff {diff})"
        );
        worst = worst.max(diff);
    }
    eprintln!("  {name}: max abs diff {worst:.2e} over {} elems", got.len());
}

/// Every manifest test vector must reproduce through the PJRT executables.
#[test]
fn golden_vectors_reproduce_through_pjrt() {
    let dir = require_artifacts!();
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let store = WeightStore::load(&manifest).unwrap();
    let mut engine = Engine::cpu().unwrap();
    engine.load_manifest(&manifest).unwrap();
    assert!(!manifest.test_vectors.is_empty(), "no test vectors recorded");

    for tv in &manifest.test_vectors {
        eprintln!("vector {}", tv.name);
        let args: Vec<xla::Literal> = tv
            .inputs
            .iter()
            .map(|na| {
                if na.name == "positions" || na.name == "ids" {
                    let vals: Vec<i32> = na.data.iter().map(|&f| f as i32).collect();
                    i32_literal(&vals, &na.shape).unwrap()
                } else {
                    na.to_tensor(&store).unwrap().to_literal().unwrap()
                }
            })
            .collect();
        let outs = engine.run(&tv.name, &args).unwrap();
        assert_eq!(outs.len(), tv.outputs.len(), "{}: output arity", tv.name);
        for (lit, want) in outs.iter().zip(&tv.outputs) {
            let got = HostTensor::from_literal(lit).unwrap();
            let want_t = want.to_tensor(&store).unwrap();
            assert_eq!(got.shape, want_t.shape, "{}:{}", tv.name, want.name);
            allclose(
                &format!("{}:{}", tv.name, want.name),
                &got.data,
                &want_t.data,
                1e-3,
            );
        }
    }
}

/// The serving engine decodes a batch of requests to completion and the
/// decomposition (attention/expert/coordinator time) is reported.
#[test]
fn serving_engine_decodes_requests() {
    let dir = require_artifacts!();
    let mut engine = ServingEngine::load(&dir, 2).unwrap();
    let spec = WorkloadSpec {
        median_input: 6.0,
        median_output: 5.0,
        sigma: 0.3,
        max_len: engine.model().max_seq,
        ..Default::default()
    };
    let reqs = spec.generate(6, 7);
    let expected_tokens: u64 = reqs
        .iter()
        .map(|r| r.output_len.clamp(1, engine.model().max_seq / 2) as u64)
        .sum();
    let rep = engine.serve(&reqs).unwrap();
    assert_eq!(rep.completed, 6, "all requests complete");
    assert_eq!(rep.output_tokens, expected_tokens);
    assert!(rep.throughput > 0.0);
    assert!(rep.attn_time > 0.0 && rep.expert_time > 0.0);
    assert!(rep.decode_iterations > 0);
    eprintln!(
        "served 6 reqs: {} tokens, {:.1} tok/s, attn {:.2}s expert {:.2}s coord {:.2}s",
        rep.output_tokens, rep.throughput, rep.attn_time, rep.expert_time, rep.coord_time
    );
}

/// Decoding is deterministic: two engines fed the same requests produce the
/// same iteration and token counts.
#[test]
fn serving_is_deterministic() {
    let dir = require_artifacts!();
    let spec = WorkloadSpec {
        median_input: 4.0,
        median_output: 4.0,
        sigma: 0.2,
        max_len: 64,
        ..Default::default()
    };
    let reqs = spec.generate(3, 99);
    let run = || {
        let mut e = ServingEngine::load(&dir, 1).unwrap();
        let r = e.serve(&reqs).unwrap();
        (r.completed, r.output_tokens, r.decode_iterations)
    };
    assert_eq!(run(), run());
}

/// The grouped-expert fast path (§Perf) and the per-expert path produce
/// byte-identical decoding: same iteration count, same token totals.
#[test]
fn grouped_and_per_expert_paths_agree() {
    let dir = require_artifacts!();
    let spec = WorkloadSpec {
        median_input: 5.0,
        median_output: 4.0,
        sigma: 0.2,
        max_len: 64,
        ..Default::default()
    };
    let reqs = spec.generate(4, 123);
    let run = |grouped: bool| {
        let mut e = ServingEngine::load(&dir, 1).unwrap();
        if !grouped {
            e.disable_grouped_experts();
        }
        let r = e.serve(&reqs).unwrap();
        (r.completed, r.output_tokens, r.decode_iterations)
    };
    assert_eq!(run(true), run(false));
}
