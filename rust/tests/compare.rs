//! The simulated Figure-8 comparison path: golden ratio pins, colocated
//! engine conservation, and determinism of `msi compare` and
//! `msi plan --validate-top`.

use megascale_infer::baselines::{
    evaluate_at_batch, run_compare, BaselineDeployment, BaselineKind, ColocatedPlan,
    CompareConfig, SystemKind,
};
use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::plan::{validate_top_k, PlanSearcher, ValidationConfig};
use megascale_infer::sim::cluster::{ClusterSim, ClusterSimConfig, ExpertPopularity};
use megascale_infer::workload::{Request, WorkloadSpec};

/// `n` identical closed-loop requests (exact lengths, no generator
/// rounding) for tests that pin iteration counts.
fn fixed_requests(n: usize, input: usize, output: usize) -> Vec<Request> {
    (0..n)
        .map(|id| Request {
            id: id as u64,
            arrival: 0.0,
            input_len: input,
            output_len: output,
            tenant: 0,
        })
        .collect()
}

/// A deterministic paper-like workload: fixed lengths (sigma 0), closed
/// loop, single tenant.
fn paper_like_spec() -> WorkloadSpec {
    WorkloadSpec {
        median_input: 256.0,
        median_output: 24.0,
        sigma: 0.0,
        ..Default::default()
    }
}

/// Acceptance: on the default paper-like config, `msi compare` runs both
/// baselines and the disaggregated plan through the cluster engine on the
/// same workload, the per-GPU decode-throughput ratio lands in the paper's
/// measured band (≥ 1.2x vs the vLLM-style baseline), and the report is
/// bit-identical across two runs with the same seed.
#[test]
fn compare_golden_figure8_ratio_and_determinism() {
    let cfg = CompareConfig {
        spec: paper_like_spec(),
        seed: 7,
        ..CompareConfig::new(
            ModelConfig::mixtral_8x22b(),
            ClusterSpec::homogeneous(GpuKind::Ampere80G),
        )
    };
    let a = run_compare(&cfg).expect("comparison runs");
    // Every system serves the full workload to quiescence.
    for r in a.systems() {
        assert_eq!(
            r.report.completed, a.requests as u64,
            "{} completes the workload",
            r.system.name()
        );
        assert_eq!(r.report.rejected, 0);
        assert_eq!(r.report.unserved_queued, 0);
        assert!(r.gpus > 0 && r.report.per_gpu_throughput > 0.0);
    }
    // Figure 8's ordering: MSI > TRT-LLM-style > vLLM-style per GPU, with
    // the MSI/vLLM ratio in the paper's measured band.
    let ratio_v = a.ratio_vs_vllm();
    let ratio_t = a.ratio_vs_trtllm();
    assert!(
        ratio_v >= 1.2,
        "disaggregated should beat vLLM-style by ≥1.2x, got {ratio_v}"
    );
    assert!(ratio_v <= 8.0, "ratio {ratio_v} suspiciously large");
    assert!(
        ratio_t >= 1.05,
        "disaggregated should beat TRT-LLM-style, got {ratio_t}"
    );
    assert!(
        a.trtllm.report.per_gpu_throughput > a.vllm.report.per_gpu_throughput,
        "TRT-LLM-style custom kernels beat vLLM-style"
    );
    // The baselines' fleets were sized to at least the plan's GPU count.
    assert!(a.vllm.gpus >= a.plan.total_gpus());
    assert!(a.trtllm.gpus >= a.plan.total_gpus());

    // Bit-identical across runs with the same seed.
    let b = run_compare(&cfg).expect("second run");
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "same-seed comparison reports must be byte-identical"
    );
    assert_eq!(a.to_csv(), b.to_csv());
}

/// Token-copy conservation holds on the colocated engine path: every
/// decoded token traverses every layer as `top_k` copies through the
/// (zero-latency) link observers, exactly as in disaggregated mode.
#[test]
fn colocated_engine_conserves_tokens() {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let cplan = ColocatedPlan::sized_to_match(BaselineKind::Vllm, &model, &cluster, 8);
    assert_eq!((cplan.tp, cplan.pp, cplan.replicas), (8, 1, 1));
    let reqs = fixed_requests(256, 64, 8);
    let rep = ClusterSim::new(ClusterSimConfig {
        seed: 5,
        // Lockstep anchor: inline prefill off so every request enters the
        // first iteration and the iteration count is exact.
        prefill_chunk: 0,
        ..ClusterSimConfig::colocated(model.clone(), cluster, cplan)
    })
    .run(&reqs);
    assert_eq!(rep.completed, 256);
    assert_eq!(rep.tokens, 256 * 8);
    // Fixed lengths + a 256-cap group: all requests run in one full batch
    // for exactly `output_len` iterations.
    assert_eq!(rep.iterations, 8);
    let copies = rep.tokens * model.layers as u64 * model.top_k as u64;
    assert_eq!(rep.dispatched_copies, copies);
    assert_eq!(rep.processed_copies, copies);
    assert_eq!(rep.combined_copies, copies);
    // Colocated mode: one serial stage — the expert pool and link
    // contribute zero time.
    assert_eq!(rep.expert_utilization, 0.0);
    assert!(rep.attn_utilization > 0.9, "monolithic stage always busy");
    assert_eq!(rep.mean_t_e, 0.0);
    assert_eq!(rep.mean_t_c, 0.0);
}

/// The colocated engine's steady-state TPOT matches the analytic baseline
/// model: with fixed lengths the whole batch decodes in lockstep, so every
/// iteration's latency is `L · layer_time(batch)` at the live sequence
/// length — within a few percent of `evaluate_at_batch` at the mean.
#[test]
fn colocated_engine_tpot_tracks_analytic_model() {
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let cplan = ColocatedPlan::sized_to_match(BaselineKind::TrtLlm, &model, &cluster, 8);
    let input = 256usize;
    let output = 16usize;
    let batch = cplan.max_batch_per_group();
    let reqs = fixed_requests(batch, input, output);
    let rep = ClusterSim::new(ClusterSimConfig {
        seed: 13,
        // Lockstep anchor vs the analytic steady state: inline prefill off.
        prefill_chunk: 0,
        ..ClusterSimConfig::colocated(model.clone(), cluster.clone(), cplan.clone())
    })
    .run(&reqs);
    assert_eq!(rep.completed, batch as u64);
    assert_eq!(rep.iterations, output as u64);
    let analytic = evaluate_at_batch(
        &BaselineDeployment {
            kind: cplan.kind,
            tp: cplan.tp,
            pp: cplan.pp,
        },
        &model,
        &cluster,
        // Mean live sequence length across the run.
        input as f64 + output as f64 / 2.0,
        batch,
    );
    let rel = (rep.tpot.mean() - analytic.tpot).abs() / analytic.tpot;
    assert!(
        rel < 0.05,
        "engine TPOT {} vs analytic {} (rel {rel})",
        rep.tpot.mean(),
        analytic.tpot
    );
}

/// Colocated inline chunked prefill: prompts are chunked THROUGH decode
/// iterations, inflating the baseline's TPOT (the vLLM-style interference
/// the paper's disaggregation avoids), while conservation holds — every
/// prompt token is prefilled exactly once and the KV never crosses a link.
#[test]
fn colocated_inline_prefill_interferes_and_conserves() {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let cplan = ColocatedPlan::sized_to_match(BaselineKind::Vllm, &model, &cluster, 8);
    let reqs = fixed_requests(64, 128, 8);
    let run = |chunk: usize| {
        ClusterSim::new(ClusterSimConfig {
            seed: 3,
            prefill_chunk: chunk,
            ..ClusterSimConfig::colocated(model.clone(), cluster.clone(), cplan.clone())
        })
        .run(&reqs)
    };
    let off = run(0);
    let on = run(512);
    assert_eq!(on.completed, 64);
    assert_eq!(on.tokens, 64 * 8);
    // Conservation across the inline handoff.
    assert_eq!(on.prefilled_tokens, 64 * 128, "every prompt token chunked once");
    assert_eq!(on.kv_transferred_tokens, 0, "KV never leaves the group");
    assert_eq!(on.kv_blocks_in_use_at_end, 0);
    assert_eq!(off.prefilled_tokens, 0, "chunk 0 = prefill not modeled");
    // TTFT decomposition: prefill live, transfer exactly zero (colocated).
    assert!(on.ttft_prefill.mean() > 0.0);
    assert_eq!(on.ttft_transfer.mean(), 0.0);
    // Interference: chunked prefill inflates both TPOT and E2E vs the
    // instant-KV fiction.
    assert!(
        on.tpot.mean() > off.tpot.mean(),
        "mixed iterations inflate TPOT: {} vs {}",
        on.tpot.mean(),
        off.tpot.mean()
    );
    assert!(
        on.e2e.mean() > off.e2e.mean(),
        "prefill serializes ahead of decode: {} vs {}",
        on.e2e.mean(),
        off.e2e.mean()
    );
}

/// `--validate-top K` picks the same plan across runs (the CLI-facing
/// determinism guarantee; the unit suite pins the JSON too).
#[test]
fn validate_top_is_deterministic() {
    let searcher = PlanSearcher::new(
        ModelConfig::tiny(),
        ClusterSpec::homogeneous(GpuKind::Ampere80G),
        200.0,
    );
    let spec = WorkloadSpec {
        median_input: 64.0,
        median_output: 8.0,
        sigma: 0.3,
        ..Default::default()
    };
    let vcfg = ValidationConfig {
        top_k: 4,
        requests: 128,
        seed: 21,
        popularity: ExpertPopularity::Uniform,
    };
    let a = validate_top_k(&searcher, &spec, &vcfg).expect("validated plan");
    let b = validate_top_k(&searcher, &spec, &vcfg).expect("validated plan");
    assert_eq!(a.chosen, b.chosen);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    // The winner is one of the re-scored candidates and its score is the
    // maximum.
    let best = a
        .candidates
        .iter()
        .map(|c| c.goodput_per_dollar)
        .fold(f64::NEG_INFINITY, f64::max);
    assert_eq!(a.candidates[a.chosen].goodput_per_dollar, best);
}

/// The compare path honors multi-tenant workloads: per-class SLO slices
/// come back for every system on the same traffic mix.
#[test]
fn compare_reports_per_tenant_slices_for_every_system() {
    let mut spec = paper_like_spec();
    spec.tenants = vec![
        megascale_infer::workload::TenantClass {
            name: "interactive".into(),
            weight: 0.7,
            slo_e2e: 30.0,
        },
        megascale_infer::workload::TenantClass {
            name: "batch".into(),
            weight: 0.3,
            slo_e2e: 600.0,
        },
    ];
    let cfg = CompareConfig {
        spec,
        requests: 512,
        seed: 9,
        ..CompareConfig::new(
            ModelConfig::tiny(),
            ClusterSpec::homogeneous(GpuKind::Ampere80G),
        )
    };
    let rep = run_compare(&cfg).expect("comparison runs");
    for r in rep.systems() {
        assert!(!r.system.name().is_empty());
        assert_eq!(r.report.tenants.len(), 2, "{}", r.system.name());
        let done: u64 = r.report.tenants.iter().map(|t| t.completed).sum();
        assert_eq!(done, r.report.completed, "per-tenant partition");
    }
    assert_eq!(rep.disaggregated.system, SystemKind::Disaggregated);
}
