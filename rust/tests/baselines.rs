//! Integration tests for the baseline serving simulators (vLLM-like and
//! TensorRT-LLM-like) and the Figure 9 heterogeneous comparison.

use megascale_infer::baselines::{
    best_under_slo, evaluate_at_batch, kv_fits, minimal_deployment, BaselineDeployment,
    BaselineKind,
};
use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig, NodeSpec};
use megascale_infer::plan::{search_heterogeneous, PlanSearcher, SearchLimits};

fn cluster(gpu: GpuKind) -> ClusterSpec {
    ClusterSpec::homogeneous(gpu)
}

#[test]
fn baselines_feasible_for_all_models() {
    for model in ModelConfig::paper_models() {
        for kind in [BaselineKind::Vllm, BaselineKind::TrtLlm] {
            let c = cluster(GpuKind::Ampere80G);
            let dep = minimal_deployment(kind, &model, &c);
            let m = best_under_slo(&dep, &model, &c, 730.0, 0.150)
                .unwrap_or_else(|| panic!("{:?} infeasible for {}", kind, model.name));
            assert!(m.tpot <= 0.150);
            assert!(m.batch >= 1);
        }
    }
}

#[test]
fn ep_beats_tp_for_moe_layers() {
    // TRT-LLM's expert parallelism avoids re-streaming every expert's
    // sharded panels; at equal kernel efficiency EP should win for sparse
    // MoE. Compare the two MoE strategies at the same efficiency by using
    // TrtLlm vs a hypothetical TP deployment of the same kind.
    let model = ModelConfig::scaled_moe();
    let c = cluster(GpuKind::Ampere80G);
    let b = 256;
    let ep = evaluate_at_batch(
        &BaselineDeployment {
            kind: BaselineKind::TrtLlm,
            tp: 8,
            pp: 2,
        },
        &model,
        &c,
        730.0,
        b,
    );
    let tp = evaluate_at_batch(
        &BaselineDeployment {
            kind: BaselineKind::Vllm,
            tp: 8,
            pp: 2,
        },
        &model,
        &c,
        730.0,
        b,
    );
    assert!(ep.tpot < tp.tpot, "EP {} vs TP {}", ep.tpot, tp.tpot);
}

#[test]
fn kv_budget_caps_batch() {
    let model = ModelConfig::mixtral_8x22b();
    let c = cluster(GpuKind::Ampere80G);
    let dep = minimal_deployment(BaselineKind::Vllm, &model, &c);
    assert!(kv_fits(&dep, &model, &c, 730.0, 16));
    assert!(!kv_fits(&dep, &model, &c, 730.0, 4_000_000));
}

#[test]
fn fig9_heterogeneous_per_cost_shape() {
    // Figure 9: MSI on H20(attention)+L40S(experts) beats both baselines'
    // best homogeneous per-cost throughput, with the paper-reported band
    // (up to 3.24x vs vLLM, 1.86x vs TRT-LLM on H20).
    let model = ModelConfig::mixtral_8x22b();
    let hetero = search_heterogeneous(
        &model,
        &[GpuKind::H20, GpuKind::L40S],
        730.0,
        &SearchLimits::default(),
    );
    let msi_tpd = hetero
        .iter()
        .find(|r| r.attention_gpu == GpuKind::H20 && r.expert_gpu == GpuKind::L40S)
        .expect("hetero pairing")
        .plan
        .metrics
        .throughput_per_dollar;

    let mut best_baseline = 0.0f64;
    for gpu in [GpuKind::H20, GpuKind::L40S] {
        let c = cluster(gpu);
        for kind in [BaselineKind::Vllm, BaselineKind::TrtLlm] {
            let dep = minimal_deployment(kind, &model, &c);
            if let Some(m) = best_under_slo(&dep, &model, &c, 730.0, 0.150) {
                best_baseline = best_baseline.max(m.throughput_per_dollar);
            }
        }
    }
    assert!(best_baseline > 0.0, "no baseline point");
    let gain = msi_tpd / best_baseline;
    assert!(
        (1.05..5.0).contains(&gain),
        "per-cost gain {gain:.2} (paper up to 1.86x vs best baseline)"
    );
}

#[test]
fn h20_beats_l40s_for_baselines() {
    // §7.2: "vLLM and TensorRT-LLM achieve higher decoding throughput on
    // H20" (per cost) because L40S chokes on memory capacity + interconnect.
    let model = ModelConfig::mixtral_8x22b();
    for kind in [BaselineKind::Vllm, BaselineKind::TrtLlm] {
        let tpd = |gpu| {
            let c = cluster(gpu);
            let dep = minimal_deployment(kind, &model, &c);
            best_under_slo(&dep, &model, &c, 730.0, 0.150).map(|m| m.throughput_per_dollar)
        };
        let h20 = tpd(GpuKind::H20);
        let l40s = tpd(GpuKind::L40S);
        if let (Some(h), Some(l)) = (h20, l40s) {
            assert!(h > l, "{kind:?}: H20 {h:.2} should beat L40S {l:.2}");
        }
    }
}

#[test]
fn msi_supports_arbitrary_gpu_pairings() {
    // The plan search runs for every Table 3 pairing without panicking and
    // returns internally-consistent metrics.
    let model = ModelConfig::dbrx();
    for a in [GpuKind::H20, GpuKind::A800, GpuKind::L40S] {
        for e in [GpuKind::H20, GpuKind::A800, GpuKind::L40S] {
            let cluster = ClusterSpec {
                attention: NodeSpec {
                    gpu: a,
                    gpus_per_node: 8,
                    nodes: None,
                },
                expert: NodeSpec {
                    gpu: e,
                    gpus_per_node: 8,
                    nodes: None,
                },
            };
            if let Some(plan) = PlanSearcher::new(model.clone(), cluster, 730.0).search() {
                let m = &plan.metrics;
                assert!(m.tpot > 0.0 && m.tpot <= 0.150);
                assert!((m.throughput_per_dollar - m.throughput / m.cost).abs() < 1e-9);
            }
        }
    }
}
