//! Coordinator hot-path micro-benchmarks (the §Perf targets):
//! gating top-k, dispatch-table construction, combine, KV allocator churn,
//! the ping-pong DES, the M2N simulator event rate, and a full plan search.
//!
//! Run via `cargo bench --bench hot_paths`. Results feed EXPERIMENTS.md
//! §Perf (before/after the optimization pass).

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::{
    build_dispatch, combine_expert_outputs, gather_expert_input, softmax_topk, BlockAllocator,
    KvCacheConfig, PingPongSim,
};
use megascale_infer::m2n::{simulate_m2n, LibraryKind, LibraryProfile, M2nScenario};
use megascale_infer::plan::PlanSearcher;
use megascale_infer::sim::SimRng;
use megascale_infer::util::bench::{bench, black_box, section};

fn main() {
    section("hot paths (single core)");

    // ---- gating + dispatch + combine at serving-representative sizes ----
    let batch = 512usize;
    let experts = 16usize;
    let k = 4usize;
    let hidden = 128usize;
    let mut rng = SimRng::new(1);
    let logits: Vec<f32> = (0..batch * experts)
        .map(|_| rng.uniform() as f32)
        .collect();

    let r = bench("softmax_topk 512x16 k=4", || {
        black_box(softmax_topk(black_box(&logits), experts, k));
    });
    r.print();
    println!("    = {:.1} M tokens/s routed", batch as f64 * r.rate() / 1e6);

    let gating = softmax_topk(&logits, experts, k);
    let r = bench("build_dispatch 512x16 k=4", || {
        black_box(build_dispatch(black_box(&gating), experts));
    });
    r.print();
    println!(
        "    = {:.1} M token-copies/s",
        (batch * k) as f64 * r.rate() / 1e6
    );

    let plan = build_dispatch(&gating, experts);
    let x: Vec<f32> = (0..batch * hidden).map(|i| (i % 97) as f32).collect();
    let r = bench("gather_expert_input 512x128", || {
        for e in 0..experts {
            black_box(gather_expert_input(&plan, e, black_box(&x), hidden));
        }
    });
    r.print();

    let outputs: Vec<Vec<f32>> = (0..experts)
        .map(|e| gather_expert_input(&plan, e, &x, hidden))
        .collect();
    let r = bench("combine_expert_outputs 512x128", || {
        black_box(combine_expert_outputs(
            black_box(&plan),
            black_box(&outputs),
            batch,
            hidden,
        ));
    });
    r.print();
    println!(
        "    = {:.2} GB/s weighted-summed",
        (batch * k * hidden * 4) as f64 * r.rate() / 1e9
    );

    // ---- KV allocator churn ----
    let r = bench("kv_allocator admit/append/release x128", || {
        let mut a = BlockAllocator::new(KvCacheConfig {
            block_size: 16,
            num_blocks: 4096,
        });
        for id in 0..128u64 {
            a.admit(id, 500);
            a.append_token(id);
        }
        for id in 0..128u64 {
            a.release(id);
        }
        black_box(a.free_blocks());
    });
    r.print();

    // ---- ping-pong DES ----
    let r = bench("pingpong DES m=4 L=56", || {
        black_box(
            PingPongSim {
                t_a: 1.0,
                t_e: 0.9,
                t_c: 0.3,
                m: 4,
                layers: 56,
            }
            .run(),
        );
    });
    r.print();
    println!(
        "    = {:.2} M pipeline events/s",
        (4 * 56 * 5) as f64 * r.rate() / 1e6
    );

    // ---- M2N simulator ----
    let r = bench("m2n sim 8x8 x50 rounds", || {
        black_box(simulate_m2n(&M2nScenario {
            profile: LibraryProfile::of(LibraryKind::Nccl),
            senders: 8,
            receivers: 8,
            msg_bytes: 256 * 1024,
            rounds: 50,
            bidirectional: false,
            seed: 3,
        }));
    });
    r.print();
    println!(
        "    = {:.2} M messages/s simulated",
        (8 * 8 * 50) as f64 * r.rate() / 1e6
    );

    // ---- plan search ----
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let r = bench("plan search (Algorithm 1, Mixtral)", || {
        let s = PlanSearcher::new(model.clone(), cluster.clone(), 730.0);
        black_box(s.search());
    });
    r.print();
}
