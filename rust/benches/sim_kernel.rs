//! Event-kernel primitive micro-benchmarks: the four hot structures under
//! every end-to-end simulation — the indexed [`EventQueue`], the
//! slot-recycling [`RequestTable`], the ping-pong [`PipelineCore`] stepper
//! and [`Histogram::record`] — plus a small streamed end-to-end engine run.
//!
//! Run via `cargo bench --bench sim_kernel`. Pass `--quick` (CI smoke) to
//! exercise every benchmark body a fixed handful of times without the
//! ~20 ms auto-calibrated sampling — a crash/regression canary, not a
//! measurement. The committed perf baseline lives in `BENCH_sim.json`
//! (refreshed by `msi sweep --bench`, gated in CI by `--bench-compare`).

use megascale_infer::metrics::Histogram;
use megascale_infer::sim::{
    EventQueue, FusedQueue, PipeEvent, PipelineCore, RequestTable, SimRng, StageTimes,
};
use megascale_infer::util::bench::{bench, black_box, section};
use megascale_infer::workload::Request;

/// Full measurement, or a fixed-iteration smoke pass with `--quick`.
fn run<F: FnMut()>(name: &str, quick: bool, mut f: F) {
    if quick {
        for _ in 0..3 {
            f();
        }
        println!("  {name:<44} ok (quick)");
    } else {
        bench(name, f).print();
    }
}

fn req(id: u64) -> Request {
    Request {
        id,
        arrival: id as f64 * 1e-3,
        input_len: 512,
        output_len: 64,
        tenant: 0,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    section("event-kernel primitives");

    // ---- EventQueue: steady-state churn at a serving-like depth ----
    // Hold ~1k pending events and push+pop in a loop: the pattern every
    // engine iteration produces (a handful of schedules per pop).
    {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut rng = SimRng::new(7);
        for i in 0..1024u64 {
            q.schedule_in(rng.exponential(1.0), i);
        }
        run("event_queue push+pop, 1k pending", quick, || {
            for i in 0..64u64 {
                let (t, e) = q.pop().expect("queue stays primed");
                black_box((t, e));
                q.schedule_in(rng.exponential(1.0), i);
            }
        });
    }

    // ---- EventQueue: same-timestamp bursts (iteration barriers) ----
    {
        let mut q: EventQueue<u64> = EventQueue::new();
        run("event_queue burst fill+drain x256", quick, || {
            let base = q.now();
            for i in 0..256u64 {
                q.schedule_at(base + 0.5, i);
            }
            while let Some(x) = q.pop() {
                black_box(x);
            }
        });
    }

    // ---- RequestTable: slot claim/release recycling ----
    {
        let mut table = RequestTable::new();
        // Warm a steady in-flight population so the free list is hot.
        let mut live: Vec<usize> = (0..512).map(|i| table.insert(req(i))).collect();
        run("request_table insert+remove x64, 512 live", quick, || {
            for k in 0..64 {
                let slot = live[k * 7 % live.len()];
                black_box(table.remove(slot));
                live[k * 7 % live.len()] = table.insert(req(k as u64));
            }
            black_box(table.len());
        });
    }

    // ---- PipelineCore: a full ping-pong pass, event-stepped ----
    {
        run("pipeline_core full pass m=2 layers=8", quick, || {
            let mut core = PipelineCore::new(2, 8);
            let mut q: EventQueue<PipeEvent> = EventQueue::new();
            let mut out = Vec::new();
            let mut times = |_now: f64, _mb: usize, _layer: usize| StageTimes {
                t_a: 1.0e-3,
                t_e: 1.4e-3,
                t_c: 0.2e-3,
            };
            core.start(q.now(), &mut out);
            loop {
                for (at, ev) in out.drain(..) {
                    q.schedule_at(at, ev);
                }
                let Some((now, ev)) = q.pop() else { break };
                if let Some(stats) = core.on_event(now, ev, &mut times, &mut out) {
                    black_box(stats);
                    break;
                }
            }
        });
    }

    // ---- PipelineCore: the same pass on the fused local queue ----
    // The engine's fast path: one recycled core + a flat-Vec FusedQueue
    // instead of the global calendar. The gap between this and the
    // stepwise bench above is the per-iteration win of fusing.
    {
        let mut core = PipelineCore::new(2, 8);
        let mut q = FusedQueue::new();
        let mut out: Vec<(f64, PipeEvent)> = Vec::new();
        run("pipeline_core fused pass m=2 layers=8", quick, || {
            core.reset(2, 8);
            q.clear();
            out.clear();
            let mut times = |_now: f64, _mb: usize, _layer: usize| StageTimes {
                t_a: 1.0e-3,
                t_e: 1.4e-3,
                t_c: 0.2e-3,
            };
            core.start(0.0, &mut out);
            loop {
                for (at, ev) in out.drain(..) {
                    q.push(at, ev);
                }
                let Some((now, ev)) = q.pop() else { break };
                if core.on_event_done(now, ev, &mut times, &mut out) {
                    black_box(core.m);
                    break;
                }
            }
        });
    }

    // ---- Histogram::record on the exact→bucketed spectrum ----
    {
        let mut h = Histogram::new();
        let mut rng = SimRng::new(13);
        let samples: Vec<f64> = (0..1024).map(|_| rng.exponential(0.05)).collect();
        run("histogram record x1024", quick, || {
            for &v in &samples {
                h.record(v);
            }
            black_box(h.count());
        });
        black_box(h.percentile(99.0));
    }

    // ---- macro-stepping: span detection + bulk advance ----
    // A uniform closed-loop decode batch is one long externally-quiet
    // span: the first entry times the span probe plus the bulk
    // advance/flush machinery, the second the per-iteration boundary
    // loop it replaces (same trace, macro-stepping off). Their gap is
    // the per-span win `diurnal_*` in BENCH_sim.json measures at scale.
    {
        use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
        use megascale_infer::plan::PlanSearcher;
        use megascale_infer::sim::{ClusterEngine, ClusterSimConfig, ExpertPopularity};
        use megascale_infer::workload::{RequestStream, WorkloadSpec};

        let model = ModelConfig::tiny();
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        let spec = WorkloadSpec {
            median_input: 32.0,
            median_output: 256.0,
            sigma: 0.0,
            ..Default::default()
        };
        let mut plan = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len())
            .search()
            .expect("tiny plan");
        plan.n_a = 1;
        plan.m = 1;
        plan.global_batch = 256;
        plan.n_p = 0;
        let cfg = |macro_step: bool| ClusterSimConfig {
            popularity: ExpertPopularity::Ideal,
            seed: 17,
            macro_step,
            ..ClusterSimConfig::new(model.clone(), cluster.clone(), plan.clone())
        };
        run("engine span detect+bulk advance, 256x256", quick, || {
            let rep = ClusterEngine::new(
                cfg(true),
                Box::new(RequestStream::new(spec.clone(), 256, 17)),
            )
            .run();
            black_box(rep.iterations);
        });
        run("engine stepwise boundary loop, 256x256", quick, || {
            let rep = ClusterEngine::new(
                cfg(false),
                Box::new(RequestStream::new(spec.clone(), 256, 17)),
            )
            .run();
            black_box(rep.iterations);
        });
    }

    // ---- end-to-end: a small streamed engine run ----
    // The real composition of all of the above; `msi sweep --bench` runs
    // the full-size (1M-request) version and maintains BENCH_sim.json.
    // `None`: the bench binary may run outside the repo root, so the
    // scenario-library leg is left to `msi sweep --bench`.
    {
        use megascale_infer::sim::run_sim_bench;
        if quick {
            let payload = run_sim_bench(2_000, 42, None);
            println!("  {:<44} ok (quick)", "engine e2e 2k requests");
            black_box(payload);
        } else {
            let payload = run_sim_bench(50_000, 42, None);
            let tps = payload
                .get("tokens_per_wall_second")
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0);
            println!("  engine e2e 50k requests: {tps:.0} tok/wall-s");
        }
    }

    println!();
}
