//! Ablation — expert load balancing with on-device redundancy (paper §6):
//! end-to-end decoding throughput under uniform vs Zipf-skewed expert
//! popularity, with static one-expert-per-node placement vs the greedy
//! redundancy balancer, at the optimal Mixtral deployment plan.

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::{ExpertTraffic, RuntimeInstance};
use megascale_infer::plan::PlanSearcher;
use megascale_infer::util::bench::section;
use megascale_infer::workload::WorkloadSpec;

fn main() {
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
        .search()
        .expect("plan");
    let reqs = WorkloadSpec {
        median_output: 25.0,
        sigma: 0.1,
        ..Default::default()
    }
    .generate(plan.global_batch, 3);

    section("Ablation (§6): expert load balance under skewed popularity (Mixtral, optimal plan)");
    println!(
        "{:<34} {:>12} {:>12} {:>10}",
        "traffic / placement", "tok/s", "tok/s/GPU", "vs uniform"
    );
    let run = |traffic| {
        RuntimeInstance::new(model.clone(), cluster.clone(), plan.clone())
            .with_traffic(traffic, 9)
            .simulate(&reqs)
    };
    let uniform = run(ExpertTraffic::Uniform);
    for (label, traffic) in [
        ("uniform", ExpertTraffic::Uniform),
        ("zipf(0.5) static placement", ExpertTraffic::Skewed(0.5)),
        ("zipf(0.5) greedy redundancy", ExpertTraffic::SkewedBalanced(0.5)),
        ("zipf(1.0) static placement", ExpertTraffic::Skewed(1.0)),
        ("zipf(1.0) greedy redundancy", ExpertTraffic::SkewedBalanced(1.0)),
        ("zipf(1.5) static placement", ExpertTraffic::Skewed(1.5)),
        ("zipf(1.5) greedy redundancy", ExpertTraffic::SkewedBalanced(1.5)),
    ] {
        let r = run(traffic);
        println!(
            "{:<34} {:>12.0} {:>12.1} {:>9.2}x",
            label,
            r.throughput,
            r.per_gpu_throughput,
            r.throughput / uniform.throughput
        );
    }
    println!("\nexpected shape: skew degrades throughput; the §6 balancer recovers most of it");
}
