//! Figure 5 — One-to-N latency: a single sender transmits 128 KB to each of
//! N receivers, NCCL vs the perftest lower bound, at (a) median and (b) P99.
//!
//! Paper observations reproduced: NCCL's median sits well above the
//! baseline at every N; the gap explodes at the 99th percentile,
//! "particularly when scaling to 32 receivers", while perftest's tail
//! barely moves.

use megascale_infer::m2n::{simulate_m2n, LibraryKind, LibraryProfile, M2nScenario};
use megascale_infer::util::bench::section;

fn run(kind: LibraryKind, n: usize) -> (f64, f64) {
    let s = simulate_m2n(&M2nScenario {
        profile: LibraryProfile::of(kind),
        senders: 1,
        receivers: n,
        msg_bytes: 128 * 1024,
        rounds: 3000,
        bidirectional: false,
        seed: 5,
    });
    (s.latency.median() * 1e6, s.latency.p99() * 1e6)
}

fn main() {
    section("Figure 5: One-to-N latency, 128KB per receiver (us)");
    println!(
        "{:>4}  {:>14} {:>14}  {:>14} {:>14}  {:>9} {:>9}",
        "N", "NCCL p50", "perftest p50", "NCCL p99", "perftest p99", "gap p50", "gap p99"
    );
    for n in [8usize, 16, 32] {
        let (n50, n99) = run(LibraryKind::Nccl, n);
        let (p50, p99) = run(LibraryKind::Perftest, n);
        println!(
            "{:>4}  {:>14.1} {:>14.1}  {:>14.1} {:>14.1}  {:>8.2}x {:>8.2}x",
            n,
            n50,
            p50,
            n99,
            p99,
            n50 / p50,
            n99 / p99
        );
    }
}
