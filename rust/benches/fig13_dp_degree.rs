//! Figure 13 — DBRX latency and per-GPU throughput vs the data-parallel
//! degree of the attention pool (m = 3 fixed, constant per-node
//! micro-batch).
//!
//! Paper: latency stays flat while DP ≤ the balance point (attention-bound
//! regime, throughput scales linearly), peaks per-GPU throughput at DP = 8,
//! then latency rises and normalized throughput falls as experts become
//! the bottleneck.

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::PingPongSim;
use megascale_infer::perf_model::PerfModel;
use megascale_infer::util::bench::section;

fn main() {
    let model = ModelConfig::dbrx();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let (tp_a, tp_e) = (8usize, 8usize);
    let pm = PerfModel::new(&model, &cluster, tp_a, tp_e, 730.0);
    let b_a = 512.0;
    let m = 3usize;

    section("Figure 13: DBRX latency & per-GPU throughput vs attention DP degree (m=3)");
    println!(
        "{:>4}  {:>12} {:>14} {:>12} {:>12} {:>10}",
        "DP", "TPOT (ms)", "tok/s (inst)", "tok/s/GPU", "attn util", "expert util"
    );
    for n_a in [1usize, 2, 4, 8, 12, 16, 24] {
        let b_e = b_a * n_a as f64 * model.top_k as f64 / model.experts as f64;
        let stats = PingPongSim {
            t_a: pm.t_a(b_a),
            t_e: pm.t_e(b_e),
            t_c: pm.t_c(b_a, b_e),
            m,
            layers: model.layers,
        }
        .run();
        let global_batch = b_a * n_a as f64 * m as f64;
        let tput = global_batch / stats.total_time;
        let gpus = (tp_a * n_a + tp_e * model.experts) as f64;
        println!(
            "{:>4}  {:>12.1} {:>14.0} {:>12.1} {:>11.0}% {:>10.0}%",
            n_a,
            stats.total_time * 1e3,
            tput,
            tput / gpus,
            stats.attn_utilization * 100.0,
            stats.expert_utilization * 100.0,
        );
    }
    println!("\npaper reference: flat latency to DP~4, per-GPU peak at DP=8, decline beyond");
}
