//! Figure 9 — Per-cost decoding throughput (tokens/s per normalized dollar,
//! Table 3 prices) on the heterogeneous H20 + L40S cluster: baselines run
//! homogeneously on each GPU type (they do not support heterogeneous
//! deployment); MegaScale-Infer assigns H20 to attention and L40S to
//! experts.
//!
//! Paper: MSI improves per-cost throughput by up to 3.24x over vLLM and
//! 1.86x over TensorRT-LLM on H20; baselines do better on H20 than L40S.

use megascale_infer::baselines::{best_under_slo, minimal_deployment, BaselineKind};
use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig, NodeSpec};
use megascale_infer::plan::PlanSearcher;
use megascale_infer::util::bench::section;

fn baseline_tpd(kind: BaselineKind, model: &ModelConfig, gpu: GpuKind) -> Option<f64> {
    let c = ClusterSpec::homogeneous(gpu);
    let dep = minimal_deployment(kind, model, &c);
    best_under_slo(&dep, model, &c, 730.0, 0.150).map(|m| m.throughput_per_dollar)
}

fn main() {
    section("Figure 9: decoding throughput per normalized dollar, H20/L40S cluster");
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>10} {:>14} | {:>10} {:>10}",
        "model", "vLLM@H20", "vLLM@L40S", "TRT@H20", "TRT@L40S", "MSI H20+L40S", "vs vLLM", "vs TRT"
    );
    for model in ModelConfig::paper_models() {
        let v_h20 = baseline_tpd(BaselineKind::Vllm, &model, GpuKind::H20);
        let v_l40 = baseline_tpd(BaselineKind::Vllm, &model, GpuKind::L40S);
        let t_h20 = baseline_tpd(BaselineKind::TrtLlm, &model, GpuKind::H20);
        let t_l40 = baseline_tpd(BaselineKind::TrtLlm, &model, GpuKind::L40S);

        let cluster = ClusterSpec {
            attention: NodeSpec {
                gpu: GpuKind::H20,
                gpus_per_node: 8,
                nodes: None,
            },
            expert: NodeSpec {
                gpu: GpuKind::L40S,
                gpus_per_node: 8,
                nodes: None,
            },
        };
        let plan = PlanSearcher::new(model.clone(), cluster, 730.0)
            .search()
            .expect("plan");
        let msi = plan.metrics.throughput_per_dollar;
        let fmt = |x: Option<f64>| x.map(|v| format!("{v:.0}")).unwrap_or("n/a".into());
        println!(
            "{:<14} {:>10} {:>10} {:>10} {:>10} {:>14.0} | {:>9.2}x {:>9.2}x",
            model.name,
            fmt(v_h20),
            fmt(v_l40),
            fmt(t_h20),
            fmt(t_l40),
            msi,
            msi / v_h20.unwrap_or(f64::NAN).max(v_l40.unwrap_or(0.0)),
            msi / t_h20.unwrap_or(f64::NAN).max(t_l40.unwrap_or(0.0)),
        );
        println!(
            "{:<14} plan: H20 attention tp_a={} n_a={}, L40S experts tp_e={}x{}, m={}, B={}",
            "", plan.tp_a, plan.n_a, plan.tp_e, plan.n_e, plan.m, plan.global_batch
        );
    }
    println!("\npaper reference: up to 3.24x vs vLLM and 1.86x vs TRT-LLM (on H20)");
}
