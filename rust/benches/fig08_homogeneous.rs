//! Figure 8 — Normalized per-GPU decoding throughput of Mixtral-8x22B,
//! DBRX and Scaled-MoE on Ampere-80GB GPUs: vLLM vs TensorRT-LLM vs
//! MegaScale-Infer, each at its best feasible configuration under the
//! 150 ms TPOT SLO.
//!
//! Paper: MSI beats vLLM by 2.56x (avg of Mixtral+DBRX) and TRT-LLM by
//! 1.28x; on Scaled-MoE the gaps widen to 7.11x and 1.90x. The bench prints
//! absolute tokens/s/GPU and ratios normalized to vLLM, plus the MSI plan
//! and a cross-check from the virtual-time instance simulation.

use megascale_infer::baselines::{best_under_slo, minimal_deployment, BaselineKind};
use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::RuntimeInstance;
use megascale_infer::plan::PlanSearcher;
use megascale_infer::util::bench::section;
use megascale_infer::workload::WorkloadSpec;

fn main() {
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let spec = WorkloadSpec::default(); // paper trace medians 571/159
    let avg_seq = spec.avg_seq_len();

    section("Figure 8: per-GPU decoding throughput (tokens/s/GPU), Ampere, TPOT<=150ms");
    println!(
        "{:<14} {:>10} {:>13} {:>10} | {:>9} {:>9} | {:>11}",
        "model", "vLLM", "TensorRT-LLM", "MSI", "MSI/vLLM", "MSI/TRT", "MSI sim xchk"
    );
    for model in ModelConfig::paper_models() {
        let vllm = best_under_slo(
            &minimal_deployment(BaselineKind::Vllm, &model, &cluster),
            &model,
            &cluster,
            avg_seq,
            0.150,
        )
        .expect("vllm");
        let trt = best_under_slo(
            &minimal_deployment(BaselineKind::TrtLlm, &model, &cluster),
            &model,
            &cluster,
            avg_seq,
            0.150,
        )
        .expect("trt");
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), avg_seq)
            .search()
            .expect("plan");

        // Cross-check the analytical number against the virtual-time
        // instance serving a saturating workload.
        let reqs = WorkloadSpec {
            median_output: 40.0,
            sigma: 0.3,
            ..spec.clone()
        }
        .generate(plan.global_batch.max(64), 9);
        let sim = RuntimeInstance::new(model.clone(), cluster.clone(), plan.clone())
            .simulate(&reqs);

        println!(
            "{:<14} {:>10.0} {:>13.0} {:>10.0} | {:>8.2}x {:>8.2}x | {:>11.0}",
            model.name,
            vllm.per_gpu_throughput,
            trt.per_gpu_throughput,
            plan.metrics.per_gpu_throughput,
            plan.metrics.per_gpu_throughput / vllm.per_gpu_throughput,
            plan.metrics.per_gpu_throughput / trt.per_gpu_throughput,
            sim.per_gpu_throughput,
        );
        println!(
            "{:<14} plan: tp_a={} n_a={} tp_e={} m={} B={} (b_a={:.0}, TPOT {:.0} ms)",
            "",
            plan.tp_a,
            plan.n_a,
            plan.tp_e,
            plan.m,
            plan.global_batch,
            plan.b_a(),
            plan.metrics.tpot * 1e3
        );
    }
    println!("\npaper reference: 2.56x/1.28x (Mixtral+DBRX avg), 7.11x/1.90x (Scaled-MoE)");
}
