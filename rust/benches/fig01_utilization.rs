//! Figure 1 — GPU utilization of attention and FFN vs. decoding batch size
//! for (a) a dense model, (b) MoE, and (c) MegaScale-Infer's disaggregated
//! deployment, on A100-class hardware.
//!
//! Paper claims reproduced in shape: dense FFN saturates at b ≈ F/B ≈ 156;
//! MoE FFN needs E/K× larger batches (25% MFU at b = 156 for Mixtral);
//! attention stays pinned near the memory roofline regardless of batch;
//! aggregation across `n_a = E/K` attention replicas restores the dense
//! curve for the experts.

use megascale_infer::config::{GpuKind, GpuSpec, ModelConfig};
use megascale_infer::perf_model::{
    attention_utilization, ffn_utilization_dense, ffn_utilization_moe,
};
use megascale_infer::util::bench::section;

fn main() {
    let gpu = GpuSpec::of(GpuKind::Ampere80G);
    let model = ModelConfig::mixtral_8x22b();
    let n_a = model.experts / model.top_k; // aggregation factor

    section("Figure 1: GPU utilization vs decoding batch size (A100, Mixtral ratios)");
    println!(
        "{:>6}  {:>10}  {:>10}  {:>10}  {:>12}",
        "batch", "attention", "dense FFN", "MoE FFN", "MSI FFN(agg)"
    );
    for b in [1, 8, 16, 32, 64, 128, 156, 256, 512, 1024] {
        let bf = b as f64;
        println!(
            "{:>6}  {:>9.1}%  {:>9.1}%  {:>9.1}%  {:>11.1}%",
            b,
            attention_utilization(&gpu, 1.0) * 100.0,
            ffn_utilization_dense(&gpu, bf) * 100.0,
            ffn_utilization_moe(&gpu, bf, model.top_k, model.experts) * 100.0,
            ffn_utilization_moe(&gpu, bf * n_a as f64, model.top_k, model.experts) * 100.0,
        );
    }
    println!(
        "\nroofline batch F/B = {:.0} tokens; paper's Mixtral example: MoE MFU at b=156 = {:.0}%",
        gpu.roofline_batch(),
        ffn_utilization_moe(&gpu, 156.0, 2, 8) * 100.0
    );
}
