//! Table 3 — hardware specifications and cost-effectiveness ratios, plus
//! the §4.3 deployment intuition check (which GPU is best per role).

use megascale_infer::config::gpu_catalog;
use megascale_infer::util::bench::section;

fn main() {
    section("Table 3: performance specifications and cost-effectiveness");
    println!(
        "{:<12} {:>6} {:>6} {:>9} {:>9} | {:>7} {:>9} {:>9}",
        "Accelerator", "Price", "GB", "GB/s", "TFLOPS", "GB/$", "GB/s/$", "TFLOPS/$"
    );
    for g in gpu_catalog() {
        println!(
            "{:<12} {:>6.2} {:>6.0} {:>9.1} {:>9.1} | {:>7.1} {:>9.1} {:>9.1}",
            g.name,
            g.price,
            g.mem_gb,
            g.mem_bw_gbps,
            g.tflops,
            g.gb_per_cost(),
            g.bw_per_cost(),
            g.tflops_per_cost()
        );
    }

    let cat = gpu_catalog();
    let best_attn = cat
        .iter()
        .max_by(|a, b| a.bw_per_cost().total_cmp(&b.bw_per_cost()))
        .unwrap();
    let best_expert = cat
        .iter()
        .max_by(|a, b| a.tflops_per_cost().total_cmp(&b.tflops_per_cost()))
        .unwrap();
    println!(
        "\nbest attention GPU (GB/s per $): {}   best expert GPU (TFLOPS per $): {}",
        best_attn.name, best_expert.name
    );
    println!("paper reference: \"H20 is more suitable for attention ... L40S more cost-effective for experts\"");
}
