//! Figure 10 — M2N (8 senders, 8 receivers) latency and throughput vs
//! per-pair data size, MegaScale-Infer's library vs NCCL.
//!
//! Paper headlines at 256 KB: 68.2% lower median latency, 92.9% lower P99,
//! 4.2x throughput; up to 80.8% median reduction at small sizes and up to
//! 9.9x throughput overall.

use megascale_infer::m2n::{simulate_m2n, LibraryKind, LibraryProfile, M2nScenario, M2nStats};
use megascale_infer::util::bench::section;

fn run(kind: LibraryKind, kib: usize) -> M2nStats {
    simulate_m2n(&M2nScenario {
        profile: LibraryProfile::of(kind),
        senders: 8,
        receivers: 8,
        msg_bytes: kib * 1024,
        rounds: 1500,
        bidirectional: false,
        seed: 10,
    })
}

fn main() {
    section("Figure 10: M2N 8->8 latency + throughput vs data size");
    println!(
        "{:>7}  {:>9} {:>9} {:>7}  {:>9} {:>9} {:>7}  {:>8} {:>8} {:>6}",
        "size", "NCCL p50", "MSI p50", "red.", "NCCL p99", "MSI p99", "red.", "NCCL GB/s", "MSI GB/s", "x"
    );
    for kib in [4usize, 16, 64, 128, 256, 512, 1024] {
        let n = run(LibraryKind::Nccl, kib);
        let m = run(LibraryKind::MegaScale, kib);
        println!(
            "{:>5}KB  {:>8.1}u {:>8.1}u {:>6.1}%  {:>8.1}u {:>8.1}u {:>6.1}%  {:>8.2} {:>8.2} {:>5.1}x",
            kib,
            n.latency.median() * 1e6,
            m.latency.median() * 1e6,
            (1.0 - m.latency.median() / n.latency.median()) * 100.0,
            n.latency.p99() * 1e6,
            m.latency.p99() * 1e6,
            (1.0 - m.latency.p99() / n.latency.p99()) * 100.0,
            n.throughput / 1e9,
            m.throughput / 1e9,
            m.throughput / n.throughput,
        );
    }
    println!("\npaper reference @256KB: -68.2% median, -92.9% P99, 4.2x throughput");
}
