//! Figure 12 — Decoding throughput vs number of micro-batches `m` at
//! constant micro-batch size, for all three models on Ampere (balanced
//! deployment plans).
//!
//! Paper: m=1→2 improves throughput ~1.9x (ping-pong eliminates idle
//! phases); m=2→3 adds 1.10x/1.28x/1.38x for Mixtral/DBRX/Scaled-MoE
//! (communication overlap, larger models gain more); m=4 is marginal.

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::PingPongSim;
use megascale_infer::perf_model::PerfModel;
use megascale_infer::plan::PlanSearcher;
use megascale_infer::util::bench::section;

fn main() {
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    section("Figure 12: normalized decoding throughput vs #micro-batches (const micro-batch size)");
    println!(
        "{:<14} {:>8} {:>8} {:>8} {:>8}   {:>7} {:>7} {:>7}",
        "model", "m=1", "m=2", "m=3", "m=4", "2/1", "3/2", "4/3"
    );
    for model in ModelConfig::paper_models() {
        // "we adopt the optimal deployment plan where the computation times
        // of attention and FFN modules are nearly balanced" (§7.4).
        let plan = PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
            .search()
            .expect("plan");
        let pm = PerfModel::new(&model, &cluster, plan.tp_a, plan.tp_e, 730.0);
        let b_a = plan.b_a();
        let n_a = plan.n_a as f64;
        let b_e = plan.b_e(&model);
        let (t_a, t_e, t_c) = (pm.t_a(b_a), pm.t_e(b_e), pm.t_c(b_a, b_e));
        let tput = |m: usize| {
            let s = PingPongSim {
                t_a,
                t_e,
                t_c,
                m,
                layers: model.layers,
            }
            .run();
            // tokens/s for the global batch of m micro-batches
            m as f64 * b_a * n_a / s.total_time
        };
        let t: Vec<f64> = (1..=4).map(tput).collect();
        let norm = t[2]; // normalize to m=3 like the paper's bars
        println!(
            "{:<14} {:>8.2} {:>8.2} {:>8.2} {:>8.2}   {:>6.2}x {:>6.2}x {:>6.2}x",
            model.name,
            t[0] / norm,
            t[1] / norm,
            t[2] / norm,
            t[3] / norm,
            t[1] / t[0],
            t[2] / t[1],
            t[3] / t[2],
        );
    }
    println!("\npaper reference: m1->m2 ~1.9x; m2->m3 1.10x/1.28x/1.38x; m4 marginal");
}
