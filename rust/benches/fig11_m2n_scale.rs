//! Figure 11 — M2N latency and throughput vs the number of senders (M) and
//! receivers (N) at fixed 256 KB messages.
//!
//! Paper: MegaScale-Infer outperforms NCCL at every scale; NCCL's
//! instability grows with M,N; tail latency reduced 54.7%-96.9% and
//! throughput improved 3.3x-5.8x.

use megascale_infer::m2n::{simulate_m2n, LibraryKind, LibraryProfile, M2nScenario, M2nStats};
use megascale_infer::util::bench::section;

fn run(kind: LibraryKind, m: usize, n: usize) -> M2nStats {
    simulate_m2n(&M2nScenario {
        profile: LibraryProfile::of(kind),
        senders: m,
        receivers: n,
        msg_bytes: 256 * 1024,
        rounds: 800,
        bidirectional: false,
        seed: 11,
    })
}

fn main() {
    section("Figure 11: M2N scaling, 256KB messages");
    println!(
        "{:>9}  {:>9} {:>9}  {:>9} {:>9} {:>7}  {:>9} {:>9} {:>6}",
        "M x N", "NCCL p50", "MSI p50", "NCCL p99", "MSI p99", "red.", "NCCL GB/s", "MSI GB/s", "x"
    );
    for &(m, n) in &[(8usize, 8usize), (8, 16), (16, 16), (16, 32), (32, 32)] {
        let nc = run(LibraryKind::Nccl, m, n);
        let ms = run(LibraryKind::MegaScale, m, n);
        println!(
            "{:>4} x {:>2}  {:>8.1}u {:>8.1}u  {:>8.1}u {:>8.1}u {:>6.1}%  {:>9.2} {:>9.2} {:>5.1}x",
            m,
            n,
            nc.latency.median() * 1e6,
            ms.latency.median() * 1e6,
            nc.latency.p99() * 1e6,
            ms.latency.p99() * 1e6,
            (1.0 - ms.latency.p99() / nc.latency.p99()) * 100.0,
            nc.throughput / 1e9,
            ms.throughput / 1e9,
            ms.throughput / nc.throughput,
        );
    }
    println!("\npaper reference: tail -54.7%..-96.9%, throughput 3.3x-5.8x");
}
