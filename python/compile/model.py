"""L2: the MoE decode step, split along the paper's disaggregation boundary.

Each function below becomes one AOT-compiled PJRT executable (see aot.py).
The split *is* the architecture: the Rust coordinator shuttles activations
between the attention executable and the expert executable (ping-pong
pipeline), runs top-k/dispatch/combine itself, and owns all state.

    attention_step : attention-node work for one layer (pre-norm + QKV +
                     KV-cache scatter + Pallas attention core + output proj
                     + residual)
    gating_fn      : fused pre-FFN RMSNorm + router logits (Pallas)
    expert_fn      : one expert's SwiGLU FFN (Pallas)
    embed_fn       : token embedding lookup
    lm_head_fn     : final RMSNorm + tied-embedding logits

The demo model is a pre-norm transformer without positional encoding (NoPE);
see DESIGN.md §Substitutions.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import expert_ffn as expert_kernel
from .kernels import gating as gating_kernel
from .kernels.ref import rmsnorm


@dataclass(frozen=True)
class TinyConfig:
    """The tiny MoE compiled for the executable end-to-end path.

    Mirrors the structure of the paper's models (GQA attention, top-k
    gating, SwiGLU experts) at CPU-runnable scale.
    """

    layers: int = 4
    hidden: int = 256
    intermediate: int = 512
    experts: int = 8
    top_k: int = 2
    q_heads: int = 8
    kv_heads: int = 2
    head_dim: int = 32
    vocab: int = 512
    max_seq: int = 64
    micro_batch: int = 8


def attention_step(x, k_cache, v_cache, positions, attn_norm, wq, wk, wv, wo):
    """One layer's attention-node work for a single decode token per slot.

    x:         [b, h]        current token activations
    k_cache:   [b, S, KVH, D]
    v_cache:   [b, S, KVH, D]
    positions: [b] int32     write index for this token (per slot)
    weights:   attn_norm [h]; wq [h, QH*D]; wk, wv [h, KVH*D]; wo [QH*D, h]

    Returns (x + attn_out, new_k_cache, new_v_cache).
    """
    b, h = x.shape
    s, kvh, d = k_cache.shape[1], k_cache.shape[2], k_cache.shape[3]
    qh = wq.shape[1] // d

    xn = rmsnorm(x, attn_norm)
    q = (xn @ wq).reshape(b, qh, d)
    k = (xn @ wk).reshape(b, kvh, d)
    v = (xn @ wv).reshape(b, kvh, d)

    # Per-row scatter at `positions` via one-hot (rows have independent
    # write indices under continuous batching).
    onehot = (jnp.arange(s)[None, :] == positions[:, None]).astype(x.dtype)
    oh = onehot[:, :, None, None]  # [b, S, 1, 1]
    new_k = k_cache * (1.0 - oh) + k[:, None, :, :] * oh
    new_v = v_cache * (1.0 - oh) + v[:, None, :, :] * oh

    attn = attn_kernel.attention_core(q, new_k, new_v, positions)  # [b,QH,D]
    out = attn.reshape(b, qh * d) @ wo
    return x + out, new_k, new_v


def gating_fn(x, ffn_norm, wg):
    """Fused pre-FFN norm + router logits (Pallas kernel)."""
    return gating_kernel.gating(x, ffn_norm, wg)


def expert_fn(x, w1, w3, w2):
    """One expert's SwiGLU FFN (Pallas kernel). x: [b, h] (padded rows ok)."""
    return (expert_kernel.expert_ffn(x, w1, w3, w2),)


def experts_grouped_fn(x, w1, w3, w2):
    """All experts in one call (grouped kernel). x: [E, b, h]."""
    return (expert_kernel.expert_ffn_grouped(x, w1, w3, w2),)


def embed_fn(ids, emb):
    """Token embedding lookup. ids: [b] int32; emb: [V, h]."""
    return (jnp.take(emb, ids, axis=0),)


def lm_head_fn(x, final_norm, emb):
    """Final RMSNorm + tied-embedding logits. Returns [b, V]."""
    return (rmsnorm(x, final_norm) @ emb.T,)


def attention_step_tuple(*args):
    """Tuple-returning wrapper for AOT lowering."""
    return tuple(attention_step(*args))


def gating_tuple(*args):
    return tuple(gating_fn(*args))
